"""Training loop: data, step, checkpoint/auto-resume, fault handling.

Composes the substrates: deterministic sharded data (repro/data), the jitted
train step (repro/train/step.py), atomic sharded checkpoints with
auto-resume (repro/checkpoint), and the fault-tolerance runtime
(repro/runtime/fault.py). Works on 1 CPU device (smoke/e2e tests) and on a
production mesh (launch/train.py passes mesh + shardings).
"""

from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ModelConfig, RunConfig
from repro.data.synthetic import DataLoader
from repro.launch.steps import make_optimizer
from repro.models.model import Model, build
from repro.runtime.fault import PreemptionGuard, StepWatchdog
from repro.train.step import make_eval_step, make_train_step


def train(cfg: ModelConfig, run: RunConfig, *, batch: int = 8, seq: int = 64,
          mesh=None, log_every: int = 10,
          log_fn: Callable[[str], None] = print) -> dict:
    """Train cfg for run.steps on synthetic data. Returns final metrics +
    params. Auto-resumes from run.checkpoint_dir when a checkpoint exists."""
    model = build(cfg)
    opt = make_optimizer(run)
    params = model.init(jax.random.PRNGKey(run.seed))
    opt_state = opt.init(params)
    loader = DataLoader(cfg, global_batch=batch, seq=seq, seed=run.seed)
    start_step = 0

    if run.checkpoint_dir:
        last = ckpt.latest_step(run.checkpoint_dir)
        if last is not None:
            (params, opt_state), extra = ckpt.restore(
                run.checkpoint_dir, (params, opt_state))
            loader.restore(extra["data"])
            start_step = int(extra["step"])
            log_fn(f"resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(model, opt, run), donate_argnums=(0, 1))
    watchdog = StepWatchdog()
    history = []

    with PreemptionGuard() as guard:
        for step in range(start_step, run.steps):
            t0 = time.time()
            batch_data = next(loader)
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 batch_data)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            verdict = watchdog.observe(dt)
            history.append(loss)
            if step % log_every == 0 or step == run.steps - 1:
                log_fn(f"step {step}: loss {loss:.4f} "
                       f"({dt*1000:.0f} ms{', straggler' if verdict != 'ok' else ''})")
            should_ckpt = run.checkpoint_dir and (
                (step + 1) % run.checkpoint_every == 0
                or step == run.steps - 1 or guard.preempted)
            if should_ckpt:
                ckpt.save(run.checkpoint_dir, step + 1, (params, opt_state),
                          extra={"step": step + 1, "data": loader.state()},
                          keep=run.keep_checkpoints)
            if guard.preempted:
                log_fn(f"preempted at step {step}; checkpoint committed")
                break

    return {"params": params, "opt_state": opt_state, "losses": history,
            "final_loss": history[-1] if history else float("nan"),
            "stragglers": watchdog.stragglers, "model": model}


def evaluate(model: Model, params, *, batch: int = 8, seq: int = 64,
             steps: int = 8, seed: int = 0,
             start_step: int = 100_000) -> dict:
    """Held-out loss/perplexity: same seed (same synthetic language), a
    disjoint step range — a different seed would be a different language."""
    eval_fn = jax.jit(make_eval_step(model))
    loader = DataLoader(model.cfg, global_batch=batch, seq=seq, seed=seed,
                        start_step=start_step)
    losses = []
    for _ in range(steps):
        m = eval_fn(params, next(loader))
        losses.append(float(m["loss"]))
    mean = float(np.mean(losses))
    return {"loss": mean, "perplexity": float(np.exp(mean))}
