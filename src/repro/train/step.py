"""Loss and train/eval step factories."""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.optim.adamw import AdamW, clip_by_global_norm

MOE_AUX_WEIGHT = 0.01


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int) -> jax.Array:
    """Mean token cross-entropy. logits: (B, S, V_pad) f32; labels: (B, S).
    Padded-vocab logits are masked to -inf so they never receive mass."""
    v_pad = logits.shape[-1]
    iota = jnp.arange(v_pad)
    if v_pad != vocab_size:
        logits = jnp.where((iota < vocab_size)[None, None, :], logits, -1e30)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # gather-free gold logit: elementwise select + reduce keeps the vocab
    # dim shardable (a take_along_axis over a TP-sharded vocab would force
    # GSPMD to all-gather the logits).
    gold = jnp.sum(jnp.where(iota[None, None, :] == labels[..., None],
                             logits, 0.0), axis=-1)
    return jnp.mean(logz - gold)


def make_loss_fn(model, *, remat: bool = True):
    def loss_fn(params, batch):
        logits, aux = model.apply(params, batch, remat=remat)
        loss = cross_entropy(logits.astype(jnp.float32), batch["labels"],
                             model.cfg.vocab_size)
        total = loss
        if "moe_aux_loss" in aux:
            total = total + MOE_AUX_WEIGHT * aux["moe_aux_loss"]
        metrics = {"loss": loss, **{k: v for k, v in aux.items()}}
        return total, metrics

    return loss_fn


def make_train_step(model, opt: AdamW, run: RunConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics).

    Supports gradient accumulation over microbatches (run.microbatch) — the
    batch's leading dim is split and grads are averaged with lax.scan, which
    is also the pipeline-friendly layout for overlap.
    """
    loss_fn = make_loss_fn(model, remat=run.remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if run.microbatch is None:
            return grad_fn(params, batch)
        b = batch["tokens"].shape[0]
        mb = run.microbatch
        assert b % mb == 0
        n_micro = b // mb
        split = jax.tree.map(
            lambda x: x.reshape((n_micro, mb) + x.shape[1:]), batch)

        def body(carry, micro):
            (loss_acc, metr_acc, grads_acc) = carry
            (l, m), g = grad_fn(params, micro)
            grads_acc = jax.tree.map(jnp.add, grads_acc, g)
            metr_acc = jax.tree.map(jnp.add, metr_acc, m)
            return (loss_acc + l, metr_acc, grads_acc), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
        (l0, m0), g0 = grad_fn(params, jax.tree.map(lambda x: x[0], split))
        if n_micro > 1:
            (l, m, g), _ = jax.lax.scan(
                body, (l0, m0, jax.tree.map(lambda x: x.astype(jnp.float32),
                                            g0)),
                jax.tree.map(lambda x: x[1:], split))
        else:
            l, m, g = l0, m0, g0
        inv = 1.0 / n_micro
        return (l * inv, jax.tree.map(lambda x: x * inv, m)), \
            jax.tree.map(lambda x: x * inv, g)

    def train_step(params, opt_state, batch):
        (_, metrics), grads = compute_grads(params, batch)
        grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


def make_eval_step(model) -> Callable:
    loss_fn = make_loss_fn(model, remat=False)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step
