"""Mixture-of-Experts layer: top-k router + grouped-local gather dispatch.

Dispatch design (honest-roofline + communication-aware):

* Tokens are processed in G groups aligned with the mesh's data shards
  (G = ctx.data_shards()). Routing, position-in-expert, capacity and the
  dispatch gather are all *local to a group*, so no global token buffer is
  ever materialized — under pjit the gathers partition cleanly per data
  shard (a flat global gather forces GSPMD to replicate the (T, D) token
  buffer on every device, which is catastrophic at 1M tokens).
* Position-in-expert comes from an argsort over the group's assignment
  expert-ids (NOT a one-hot cumsum): HLO FLOPs track true expert FLOPs
  (2*T*k*3*D*F), keeping rooflines honest.
* Experts shard over the mesh "model" axis (EP) when E divides it;
  otherwise expert weights are TP-sharded on the hidden dim. Activations
  are replicated across "model" within a data row (Megatron-style), so
  dispatch needs no all_to_all; the combine gather across model-sharded
  expert outputs becomes the EP all-reduce.
* Capacity overflow drops tokens (capacity_factor 1.25 default), matching
  production dropping-MoE semantics; the aux loss balances load.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, qdot
from repro.quant.qtypes import QTensor
from repro.quant.quantize import dequantize
from repro.sharding.ctx import constrain, data_shards, model_shards


def _expert_matmul(x: jax.Array, w) -> jax.Array:
    """x: (G, E, C, K) @ w: (E, F, K) -> (G, E, C, F); w may be a QTensor."""
    if isinstance(w, QTensor):
        w = dequantize(w, x.dtype)
    return jnp.einsum("geck,efk->gecf", x, w)


def capacity_of(num_tokens: int, num_experts: int, top_k: int,
                capacity_factor: float) -> int:
    c = int(math.ceil(num_tokens * top_k * capacity_factor / num_experts))
    return max(8, int(math.ceil(c / 8)) * 8)  # pad to VPU sublane


def moe_block(p, x: jax.Array, *, num_experts: int, top_k: int,
              capacity_factor: float = 1.25):
    """x: (B, S, D) -> (y: (B, S, D), aux: dict with load-balancing loss)."""
    b, s, d = x.shape
    t = b * s
    e, k = num_experts, top_k
    g = data_shards()
    if t % g != 0 or t // g < e:  # decode with tiny batches etc.
        g = 1
    tg = t // g
    xt = constrain(x.reshape(g, tg, d), ("batch", None, None))

    # --- routing (f32 for numerics) ----------------------------------------
    router_logits = qdot(xt, p["router"], out_dtype=jnp.float32)  # (G,Tg,E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)                    # (G,Tg,K)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch-style), computed globally
    me = jnp.mean(probs, axis=(0, 1))                             # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32).sum(2),
                  axis=(0, 1))
    aux_loss = e * jnp.sum(me * ce)

    # --- per-group position-in-expert via stable argsort --------------------
    flat_e = expert_idx.reshape(g, tg * k)                         # (G, Tg*K)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    seg_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e)))(sorted_e)  # (G, E)
    pos_sorted = (jnp.arange(tg * k, dtype=jnp.int32)[None]
                  - jnp.take_along_axis(seg_start, sorted_e, axis=1))
    pos = jnp.zeros((g, tg * k), jnp.int32)
    pos = jax.vmap(lambda p_, o, v: p_.at[o].set(v))(pos, order, pos_sorted)

    cap = capacity_of(tg, e, k, capacity_factor)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)            # (G, Tg*K)

    # --- dispatch: per-group gather into (G, E, C, D) -----------------------
    tok_id = jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)[None]  # (1,Tg*K)
    tok_id = jnp.broadcast_to(tok_id, (g, tg * k))
    table = jax.vmap(lambda s_, t_: jnp.zeros((e * cap,), jnp.int32)
                     .at[s_].set(t_, mode="drop"))(slot, tok_id)
    xe = jax.vmap(lambda xg, tbl: jnp.take(xg, tbl, axis=0))(xt, table)
    xe = constrain(xe.reshape(g, e, cap, d),
                   ("batch", "expert", None, None))

    # zero out unfilled slots (token 0 would leak in otherwise)
    filled = jax.vmap(lambda s_: jnp.zeros((e * cap,), jnp.bool_)
                      .at[s_].set(True, mode="drop"))(slot)
    xe = xe * filled.reshape(g, e, cap, 1).astype(xe.dtype)

    # --- expert computation (SwiGLU) ----------------------------------------
    # EP when E divides the model axis; otherwise expert-TP: shard the
    # expert hidden dim F over "model" (E replicated) so the (E, C, F)
    # activations never materialize unsharded.
    ep = e % model_shards() == 0
    hid_spec = (("batch", "expert", None, None) if ep
                else ("batch", None, None, "model"))
    gt = constrain(_expert_matmul(xe, p["w_gate"]), hid_spec)
    up = constrain(_expert_matmul(xe, p["w_up"]), hid_spec)
    h = jax.nn.silu(gt.astype(jnp.float32)).astype(x.dtype) * up
    h = constrain(h, hid_spec)
    ye = _expert_matmul(h, p["w_down"])                            # (G,E,C,D)
    ye = constrain(ye, ("batch", "expert", None, None))

    # --- combine: per-group gather back, weight by gates ---------------------
    ye_flat = ye.reshape(g, e * cap, d)
    slot_c = jnp.minimum(slot, e * cap - 1)
    y_asgn = jax.vmap(lambda yg, s_: jnp.take(yg, s_, axis=0))(ye_flat,
                                                               slot_c)
    y_asgn = jnp.where(keep[..., None], y_asgn, 0)                 # (G,Tg*K,D)
    y = jnp.sum(y_asgn.reshape(g, tg, k, d)
                * gate.astype(y_asgn.dtype)[..., None], axis=2)
    y = constrain(y, ("batch", None, None))
    return y.reshape(b, s, d), {"moe_aux_loss": aux_loss}


def init_moe_params(key, d_model: int, expert_d_ff: int, num_experts: int,
                    num_layers: int, dtype):
    ks = jax.random.split(key, 4)
    e, d, f = num_experts, d_model, expert_d_ff
    down_scale = 1.0 / np.sqrt(2 * max(num_layers, 1))

    def stack(k, out, inp, scale=1.0):
        keys = jax.random.split(k, e)
        return jnp.stack([dense_init(kk, out, inp, dtype, scale=scale)
                          for kk in keys])

    return {
        "router": dense_init(ks[0], e, d, jnp.float32),
        "w_gate": stack(ks[1], f, d),
        "w_up": stack(ks[2], f, d),
        "w_down": stack(ks[3], d, f, scale=down_scale),
    }
