"""Feed-forward blocks: SwiGLU (llama family) and GeLU (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, qdot


def swiglu(p, x):
    g = qdot(x, p["w_gate"])
    u = qdot(x, p["w_up"])
    return qdot(jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u,
                p["w_down"])


def gelu_mlp(p, x):
    h = qdot(x, p["w_up"])
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return qdot(h, p["w_down"])


def mlp(p, x, act: str):
    return swiglu(p, x) if act == "swiglu" else gelu_mlp(p, x)


def init_mlp_params(key, d_model: int, d_ff: int, num_layers: int, dtype,
                    act: str = "swiglu"):
    ks = jax.random.split(key, 3)
    down_scale = 1.0 / np.sqrt(2 * max(num_layers, 1))
    if act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d_ff, d_model, dtype),
            "w_up": dense_init(ks[1], d_ff, d_model, dtype),
            "w_down": dense_init(ks[2], d_model, d_ff, dtype, scale=down_scale),
        }
    return {
        "w_up": dense_init(ks[0], d_ff, d_model, dtype),
        "w_down": dense_init(ks[1], d_model, d_ff, dtype, scale=down_scale),
    }
