"""Feed-forward blocks: SwiGLU (llama family) and GeLU (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.qmatmul.ops import fused_mlp
from repro.models.common import dense_init, qdot


def swiglu(p, x):
    # one Pallas launch on TPU (the (S, FF) hidden never reaches HBM);
    # bit-identical qdot sequence elsewhere — kernels/qmatmul/ops.fused_mlp
    return fused_mlp(x, p["w_gate"], p["w_up"], p["w_down"], act="swiglu")


def gelu_mlp(p, x):
    return fused_mlp(x, None, p["w_up"], p["w_down"], act="gelu")


def mlp(p, x, act: str):
    return swiglu(p, x) if act == "swiglu" else gelu_mlp(p, x)


def init_mlp_params(key, d_model: int, d_ff: int, num_layers: int, dtype,
                    act: str = "swiglu"):
    ks = jax.random.split(key, 3)
    down_scale = 1.0 / np.sqrt(2 * max(num_layers, 1))
    if act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d_ff, d_model, dtype),
            "w_up": dense_init(ks[1], d_ff, d_model, dtype),
            "w_down": dense_init(ks[2], d_model, d_ff, dtype, scale=down_scale),
        }
    return {
        "w_up": dense_init(ks[0], d_ff, d_model, dtype),
        "w_down": dense_init(ks[1], d_model, d_ff, dtype, scale=down_scale),
    }
