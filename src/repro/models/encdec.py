"""Encoder-decoder transformer (whisper-medium backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, encoder_seq, d_model). Encoder layers are
bidirectional self-attention + GeLU MLP; decoder layers are causal
self-attention + cross-attention + GeLU MLP; LayerNorm (scale-only), no rope
(whisper uses sinusoidal encoder / learned decoder positions — we use
sinusoidal for both; noted in docs/DESIGN.md §2.2).

Decode maintains per-layer self-attention KV caches plus precomputed
cross-attention K/V from the encoder pass.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import mlp as M
from repro.models.common import (decode_positions, dtype_of, embed_init,
                                 embed_lookup, dense_init, layer_norm,
                                 lm_head, sinusoidal_positions)
from repro.sharding.ctx import constrain, unroll_flag, unshard_fsdp


class EncDecCache(NamedTuple):
    k: jax.Array        # (Ld, B, S_max, Hkv, hd) decoder self-attn
    v: jax.Array        #   raw, or KVPage(s) (quantized serving cache)
    cross_k: jax.Array  # (Ld, B, S_enc, Hkv, hd) precomputed encoder K/V
    cross_v: jax.Array  #   quantized once at admission (always fully valid)
    pos: jax.Array      # int32 — scalar, or (B,) per-slot


CACHE_BATCH_AXES = EncDecCache(k=1, v=1, cross_k=1, cross_v=1, pos=0)
# fields the engine may replace with quantized KVPages (quant/kvcache.py)
KV_CACHE_FIELDS = ("k", "v", "cross_k", "cross_v")


def _ln(x, w, cfg):
    return layer_norm(x, w, cfg.norm_eps)


def _init_enc_layer(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "attn": A.init_attention_params(ks[0], cfg, dtype),
        "mlp": M.init_mlp_params(ks[1], cfg.d_model, cfg.d_ff,
                                 cfg.num_encoder_layers, dtype, "gelu"),
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
    }


def _init_dec_layer(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "self_attn": A.init_attention_params(ks[0], cfg, dtype),
        "cross_attn": A.init_attention_params(ks[1], cfg, dtype),
        "mlp": M.init_mlp_params(ks[2], cfg.d_model, cfg.d_ff, cfg.num_layers,
                                 dtype, "gelu"),
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln_x": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
    }


def init(key, cfg):
    dtype = dtype_of(cfg)
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    enc_keys = jax.random.split(k_enc, cfg.num_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    return {
        "embed": {"tok": embed_init(k_emb, cfg.padded_vocab, cfg.d_model,
                                    dtype)},
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(dec_keys),
        "final": {"enc_norm": jnp.ones((cfg.d_model,), dtype),
                  "norm": jnp.ones((cfg.d_model,), dtype)},
    }


def encode(params, frames: jax.Array, cfg, *, remat: bool = True):
    """frames: (B, S_enc, D) precomputed embeddings -> (B, S_enc, D)."""
    dtype = dtype_of(cfg)
    b, s, _ = frames.shape
    h = constrain(frames.astype(dtype)
                  + sinusoidal_positions(s, cfg.d_model).astype(dtype)[None],
                  ("batch", None, None))

    def body(h, p):
        p = unshard_fsdp(p)
        a, _ = A.attention(p["attn"], _ln(h, p["ln1"], cfg),
                           num_heads=cfg.num_heads,
                           num_kv_heads=cfg.num_kv_heads,
                           head_dim=cfg.head_dim, causal=False,
                           norm_eps=cfg.norm_eps)
        h = h + a
        h = h + M.mlp(p["mlp"], _ln(h, p["ln2"], cfg), "gelu")
        return constrain(h, ("batch", "seq", None)), None

    from repro.quant.apply import segment_slices
    fn = jax.checkpoint(body) if remat else body
    for part, _, _ in segment_slices(params["enc_layers"]):
        h, _ = jax.lax.scan(fn, h, part, unroll=unroll_flag())
    return _ln(h, params["final"]["enc_norm"], cfg)


def _dec_layer(p, h, enc_out, cfg, cache_kv=None, cache_pos=None,
               cross_kv=None, valid_bias=None):
    p = unshard_fsdp(p)
    a, new_kv = A.attention(p["self_attn"], _ln(h, p["ln1"], cfg),
                            num_heads=cfg.num_heads,
                            num_kv_heads=cfg.num_kv_heads,
                            head_dim=cfg.head_dim, causal=True,
                            norm_eps=cfg.norm_eps, cache=cache_kv,
                            cache_pos=cache_pos, valid_bias=valid_bias)
    h = h + a
    if cross_kv is not None:
        x, _ = A.attention(p["cross_attn"], _ln(h, p["ln_x"], cfg),
                           num_heads=cfg.num_heads,
                           num_kv_heads=cfg.num_kv_heads,
                           head_dim=cfg.head_dim, cached_kv=cross_kv,
                           norm_eps=cfg.norm_eps)
    else:
        x, _ = A.attention(p["cross_attn"], _ln(h, p["ln_x"], cfg),
                           num_heads=cfg.num_heads,
                           num_kv_heads=cfg.num_kv_heads,
                           head_dim=cfg.head_dim, causal=False,
                           kv_x=enc_out, norm_eps=cfg.norm_eps)
    h = h + x
    h = constrain(h + M.mlp(p["mlp"], _ln(h, p["ln2"], cfg), "gelu"),
                  ("batch", "seq", None))
    return h, new_kv


def apply(params, tokens: jax.Array, frames: jax.Array, cfg, *,
          remat: bool = True, last_only: bool = False):
    """Full enc-dec forward: (B, S) tokens + (B, S_enc, D) frames -> logits."""
    dtype = dtype_of(cfg)
    b, s = tokens.shape
    enc_out = encode(params, frames, cfg, remat=remat)
    embed_w = unshard_fsdp(params["embed"])["tok"]
    h = embed_lookup(embed_w, tokens, dtype)
    h = constrain(h + sinusoidal_positions(s, cfg.d_model).astype(dtype)[None],
                  ("batch", None, None))

    def body(h, p):
        h2, _ = _dec_layer(p, h, enc_out, cfg)
        return h2, None

    from repro.quant.apply import segment_slices
    fn = jax.checkpoint(body) if remat else body
    for part, _, _ in segment_slices(params["dec_layers"]):
        h, _ = jax.lax.scan(fn, h, part, unroll=unroll_flag())
    if last_only:
        h = h[:, -1:, :]
    h = _ln(h, params["final"]["norm"], cfg)
    logits = constrain(lm_head(h, embed_w),
                       ("batch", None, "model"))  # whisper ties emb/head
    return logits, {}


def init_cache(cfg, batch: int, max_seq: int) -> EncDecCache:
    dtype = dtype_of(cfg)
    shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    cross = (cfg.num_layers, batch, cfg.encoder_seq, cfg.num_kv_heads,
             cfg.head_dim)
    return EncDecCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                       cross_k=jnp.zeros(cross, dtype),
                       cross_v=jnp.zeros(cross, dtype), pos=jnp.int32(0))


def precompute_cross_kv(params, enc_out: jax.Array, cfg) -> tuple:
    """Encoder K/V for every decoder layer (run once per request)."""
    b, s, _ = enc_out.shape
    hkv, hd = cfg.num_kv_heads, cfg.head_dim

    def body(_, p):
        from repro.models.common import qdot
        k = qdot(enc_out, p["cross_attn"]["wk"]).reshape(b, s, hkv, hd)
        v = qdot(enc_out, p["cross_attn"]["wv"]).reshape(b, s, hkv, hd)
        return None, (k, v)

    from repro.quant.apply import segment_slices
    ks, vs = [], []
    for part, _, _ in segment_slices(params["dec_layers"]):
        _, (k_p, v_p) = jax.lax.scan(body, None, part)
        ks.append(k_p)
        vs.append(v_p)
    return (jnp.concatenate(ks, axis=0) if len(ks) > 1 else ks[0],
            jnp.concatenate(vs, axis=0) if len(vs) > 1 else vs[0])


def decode_step(params, cache: EncDecCache, tokens: jax.Array, cfg):
    dtype = dtype_of(cfg)
    b, s = tokens.shape
    embed_w = unshard_fsdp(params["embed"])["tok"]
    h = embed_lookup(embed_w, tokens, dtype)
    # sinusoidal positions from cache.pos (scalar, or (B,) per-slot vector);
    # s > 1 is a speculative verify window at consecutive positions
    half = cfg.d_model // 2
    freqs = 1.0 / (10000 ** (jnp.arange(half, dtype=jnp.float32) / half))
    pos = decode_positions(cache.pos, b, s)                     # (B, s)
    ang = pos.astype(jnp.float32)[..., None] * freqs[None, None]
    pos_emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)  # (B, s, D)
    h = h + pos_emb.astype(dtype)

    valid_bias = A.decode_step_bias(cache.k, cache.pos, s)

    def body(h, xs):
        p, k_l, v_l, ck_l, cv_l = xs
        h2, new_kv = _dec_layer(p, h, None, cfg,
                                cache_kv=A.KVCache(k=k_l, v=v_l),
                                cache_pos=cache.pos,
                                cross_kv=A.KVCache(k=ck_l, v=cv_l),
                                valid_bias=valid_bias)
        return h2, (new_kv.k, new_kv.v)

    from repro.quant.apply import segment_slices
    from repro.quant.kvcache import kv_rejoin, kv_segment
    ks, vs = [], []
    for si, (part, lo, hi) in enumerate(segment_slices(params["dec_layers"])):
        h, (nk, nv) = jax.lax.scan(
            body, h, (part, kv_segment(cache.k, si, lo, hi),
                      kv_segment(cache.v, si, lo, hi),
                      kv_segment(cache.cross_k, si, lo, hi),
                      kv_segment(cache.cross_v, si, lo, hi)),
            unroll=unroll_flag())
        ks.append(nk)
        vs.append(nv)
    new_k = kv_rejoin(cache.k, ks)
    new_v = kv_rejoin(cache.v, vs)
    h = _ln(h, params["final"]["norm"], cfg)
    logits = lm_head(h, embed_w)
    return logits, EncDecCache(k=new_k, v=new_v, cross_k=cache.cross_k,
                               cross_v=cache.cross_v, pos=cache.pos + s)


def spec_verify(params, cache: EncDecCache, tokens: jax.Array, cfg):
    """Fused multi-query verify over the decoder stack (cross-attention is
    non-causal over the fixed encoder K/V). Same contract as
    transformer.spec_verify — rollback is position arithmetic."""
    logits, new_cache = decode_step(params, cache, tokens, cfg)
    return logits, (new_cache, tokens.shape[1])


def spec_commit(snap, committed: jax.Array) -> EncDecCache:
    cache, s = snap
    return cache._replace(pos=cache.pos - s + committed)


def block_params(params) -> list[Any]:
    """[embed, enc_0..enc_{Le-1}, dec_0..dec_{Ld-1}] — two stacks, one plan."""
    blocks = [params["embed"]]
    for name in ("enc_layers", "dec_layers"):
        layers = params[name]
        n = jax.tree.leaves(layers)[0].shape[0]
        blocks += [jax.tree.map(lambda x: x[i], layers) for i in range(n)]
    return blocks
