"""Model registry: uniform interface over the four family modules."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, ssm_lm, transformer

_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "encdec": encdec,
    "hybrid": hybrid,
    "ssm": ssm_lm,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def module(self):
        return _FAMILIES[self.cfg.family]

    # ---- parameters -------------------------------------------------------
    def init(self, key) -> Any:
        return self.module.init(key, self.cfg)

    def abstract_params(self, key=None) -> Any:
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(lambda k: self.module.init(k, self.cfg), key)

    # ---- forward ----------------------------------------------------------
    def apply(self, params, batch: dict, *, remat: bool = True,
              last_only: bool = False):
        """batch: {"tokens": (B,S)} (+ "frames" for enc-dec). -> (logits, aux)."""
        if self.cfg.family == "encdec":
            return self.module.apply(params, batch["tokens"], batch["frames"],
                                     self.cfg, remat=remat,
                                     last_only=last_only)
        return self.module.apply(params, batch["tokens"], self.cfg,
                                 remat=remat, last_only=last_only)

    # ---- decode -----------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int):
        return self.module.init_cache(self.cfg, batch, max_seq)

    def decode_step(self, params, cache, tokens):
        return self.module.decode_step(params, cache, tokens, self.cfg)

    # ---- EWQ --------------------------------------------------------------
    def block_params(self, params) -> list:
        return self.module.block_params(params)


def build(cfg: ModelConfig) -> Model:
    if cfg.family not in _FAMILIES:
        raise ValueError(f"unknown family {cfg.family!r}")
    return Model(cfg=cfg)
