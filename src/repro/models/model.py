"""Model registry: uniform interface over the four family modules."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, ssm_lm, transformer

_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "encdec": encdec,
    "hybrid": hybrid,
    "ssm": ssm_lm,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def module(self):
        return _FAMILIES[self.cfg.family]

    # ---- parameters -------------------------------------------------------
    def init(self, key) -> Any:
        return self.module.init(key, self.cfg)

    def abstract_params(self, key=None) -> Any:
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(lambda k: self.module.init(k, self.cfg), key)

    # ---- forward ----------------------------------------------------------
    def apply(self, params, batch: dict, *, remat: bool = True,
              last_only: bool = False):
        """batch: {"tokens": (B,S)} (+ "frames" for enc-dec). -> (logits, aux)."""
        if self.cfg.family == "encdec":
            return self.module.apply(params, batch["tokens"], batch["frames"],
                                     self.cfg, remat=remat,
                                     last_only=last_only)
        return self.module.apply(params, batch["tokens"], self.cfg,
                                 remat=remat, last_only=last_only)

    # ---- decode -----------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int):
        return self.module.init_cache(self.cfg, batch, max_seq)

    def decode_step(self, params, cache, tokens):
        return self.module.decode_step(params, cache, tokens, self.cfg)

    # ---- speculative propose (fused, docs/DESIGN.md §12) -------------------
    @property
    def supports_fused_propose(self) -> bool:
        """True when the family has a read-only draft decode step (dense /
        MoE — the transformer module); other families fall back to the
        two-pass throwaway-cache propose."""
        return hasattr(self.module, "draft_propose_step")

    def draft_propose_step(self, params, cache, fresh_k, fresh_v, count,
                           tokens):
        """One read-only draft decode step: k/v go to row ``count`` of the
        (L_draft, B, K, Hkv, hd) side buffers, never to the cache. Returns
        (logits, fresh_k, fresh_v)."""
        return self.module.draft_propose_step(params, cache, fresh_k,
                                              fresh_v, count, tokens,
                                              self.cfg)

    # ---- speculative verify (docs/DESIGN.md §11) ---------------------------
    def spec_verify(self, params, cache, tokens):
        """Score a (B, K+1) verify window against the cache: attention
        families run ONE fused multi-query decode pass; SSM/hybrid scan
        single-token steps while checkpointing their sequential state.
        Returns (logits (B, K+1, V_pad), snap) — pass the snap plus the
        per-slot committed length to ``spec_commit`` to roll the cache
        back (position arithmetic over KV rows, snapshot selection over
        SSM summaries)."""
        return self.module.spec_verify(params, cache, tokens, self.cfg)

    def spec_commit(self, snap, committed):
        """Commit ``committed`` (B,) tokens out of a verify window; 0 rolls
        a slot fully back to its pre-verify cache."""
        return self.module.spec_commit(snap, committed)

    # ---- slotted decode (continuous batching) -----------------------------
    @property
    def cache_batch_axes(self):
        """Cache NamedTuple of ints: batch axis per field in the slotted
        layout (``pos`` held as a (B,) per-slot vector)."""
        return self.module.CACHE_BATCH_AXES

    @property
    def kv_cache_fields(self) -> tuple:
        """Cache fields the engine may replace with quantized KVPages."""
        return getattr(self.module, "KV_CACHE_FIELDS", ())

    def slotted_cache(self, num_slots: int, max_seq: int):
        """init_cache with per-slot positions — serving/batch.py layout."""
        cache = self.init_cache(num_slots, max_seq)
        return cache._replace(pos=jnp.zeros((num_slots,), jnp.int32))

    def insert_cache_slot(self, cache, one, slot, page_rows=None):
        """Write a single-request cache (batch=1 leaves, scalar or (1,) pos)
        into slot ``slot`` of a slotted batch cache. Traceable (``slot`` may
        be a traced index).

        Prefill always produces a raw bf16 cache; when the destination
        field holds quantized KVPages the prompt K/V are quantized here, at
        admission — the decode scan's steady-state carry never sees a raw
        copy (quantize-on-insert, docs/DESIGN.md §10). Paged-pool fields
        (quant/kvcache.PagedKV) additionally need ``page_rows=(row, wrow)``,
        the slot's page-table rows from the host allocator
        (serving/pool.py): ``row`` maps logical pages to physical,
        ``wrow`` redirects shared read-only prefix pages to the dump page
        so this insert cannot overwrite them (docs/DESIGN.md §13)."""
        from repro.quant import kvcache as KV

        def leaf(dst, src, axis):
            if KV.is_kv_page(dst):
                first = dst[0] if isinstance(dst, tuple) else dst
                if isinstance(first, KV.PagedKV):
                    from repro.quant import paged
                    assert page_rows is not None, \
                        "inserting into a paged cache needs page_rows"
                    return paged.insert_slot_paged(dst, jnp.asarray(src),
                                                   slot, *page_rows)
                return KV.insert_slot(dst, jnp.asarray(src), slot)
            src = jnp.asarray(src)
            if src.ndim < dst.ndim:           # scalar pos -> (1,) vector
                src = src[None]
            start = [0] * dst.ndim
            start[axis] = slot
            return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype),
                                                tuple(start))

        axes = self.cache_batch_axes
        return type(cache)(*(leaf(d, s, a)
                             for d, s, a in zip(cache, one, axes)))

    # ---- EWQ --------------------------------------------------------------
    def block_params(self, params) -> list:
        return self.module.block_params(params)

    def compile_plan(self, params, plan, group: int = 128, **kw):
        """Lower a QuantPlan onto this model's parameter layout — segmented
        quantized stacks for every family (quant/compiler.py,
        docs/DESIGN.md §8). Returns a CompiledPlan; its ``.params`` slot in
        for raw params everywhere (apply / decode_step / serving).
        ``kv_precision=``/``kv_group=`` additionally compile a KV-cache
        plan (docs/DESIGN.md §10)."""
        from repro.quant.compiler import compile_plan
        return compile_plan(self, params, plan, group, **kw)


def build(cfg: ModelConfig) -> Model:
    if cfg.family not in _FAMILIES:
        raise ValueError(f"unknown family {cfg.family!r}")
    return Model(cfg=cfg)
