"""Attention-free SSM LM (mamba2-780m): stacked Mamba2 SSD blocks."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import ssm as S
from repro.models.common import (dtype_of, embed_init, embed_lookup, lm_head,
                                 norm)
from repro.sharding.ctx import constrain, unroll_flag, unshard_fsdp


class SSMLMCache(NamedTuple):
    conv: jax.Array    # (L, B, W-1, conv_dim)
    state: jax.Array   # (L, B, H, P, N) f32
    pos: jax.Array     # int32 nominal position (state is O(1)) — scalar or (B,)


CACHE_BATCH_AXES = SSMLMCache(conv=1, state=1, pos=0)
# attention-free: no KV cache for the engine's kv_precision knob to quantize
KV_CACHE_FIELDS = ()


def _init_layer(key, cfg, dtype):
    p = S.init_ssm_params(key, cfg, dtype)
    if not cfg.nonparametric_norm:
        p["ln"] = jnp.ones((cfg.d_model,), dtype)
    return p


def init(key, cfg):
    dtype = dtype_of(cfg)
    k_emb, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    params = {
        "embed": {"tok": embed_init(k_emb, cfg.padded_vocab, cfg.d_model,
                                    dtype)},
        "layers": layers,
        "final": {"norm": jnp.ones((cfg.d_model,), dtype)},
    }
    # mamba2 ties embeddings (gpt-neox tokenizer family)
    return params


def apply(params, tokens: jax.Array, cfg, *, remat: bool = True,
          return_cache: bool = False, last_only: bool = False):
    dtype = dtype_of(cfg)
    embed_w = unshard_fsdp(params["embed"])["tok"]
    h = constrain(embed_lookup(embed_w, tokens, dtype),
                  ("batch", None, None))

    def body(h, p_layer):
        p_layer = unshard_fsdp(p_layer)
        y = S.ssm_block(p_layer, norm(h, p_layer.get("ln"), cfg), cfg)
        return constrain(h + y, ("batch", "seq", None)), {}

    from repro.quant.apply import segment_slices
    fn = jax.checkpoint(body) if remat else body
    for part, _, _ in segment_slices(params["layers"]):
        h, _ = jax.lax.scan(fn, h, part, unroll=unroll_flag())
    if last_only:
        h = h[:, -1:, :]
    h = norm(h, params["final"]["norm"], cfg)
    logits = constrain(lm_head(h, embed_w), ("batch", None, "model"))
    if return_cache:
        # SSM prefill-to-cache requires carrying final states; rerun decode
        # path is unnecessary — final_state is cheap to thread when needed.
        raise NotImplementedError("use decode_step from init_cache for SSM")
    return logits, {}


def init_cache(cfg, batch: int, max_seq: int) -> SSMLMCache:
    dtype = dtype_of(cfg)
    one = S.init_ssm_cache(batch, cfg, dtype)
    return SSMLMCache(
        conv=jnp.zeros((cfg.num_layers,) + one.conv.shape, dtype),
        state=jnp.zeros((cfg.num_layers,) + one.state.shape, jnp.float32),
        pos=jnp.int32(0))


def decode_step(params, cache: SSMLMCache, tokens: jax.Array, cfg):
    """tokens: (B, 1) -> (logits (B, 1, V), new cache). O(1) in seq_len."""
    dtype = dtype_of(cfg)
    b = tokens.shape[0]
    embed_w = unshard_fsdp(params["embed"])["tok"]
    h = embed_lookup(embed_w, tokens[:, 0], dtype)  # (B, D)

    def body(h, xs):
        p_layer, conv_l, state_l = xs
        p_layer = unshard_fsdp(p_layer)
        y, new = S.ssm_decode_step(
            p_layer, norm(h, p_layer.get("ln"), cfg),
            S.SSMCache(conv=conv_l, state=state_l), cfg)
        return h + y, (new.conv, new.state)

    from repro.quant.apply import segment_slices
    convs, states = [], []
    for part, lo, hi in segment_slices(params["layers"]):
        h, (nc, ns) = jax.lax.scan(
            body, h, (part, cache.conv[lo:hi], cache.state[lo:hi]),
            unroll=unroll_flag())
        convs.append(nc)
        states.append(ns)
    new_conv = jnp.concatenate(convs, axis=0) if len(convs) > 1 else convs[0]
    new_state = (jnp.concatenate(states, axis=0) if len(states) > 1
                 else states[0])
    h = norm(h, params["final"]["norm"], cfg)
    logits = lm_head(h[:, None, :], embed_w)
    return logits, SSMLMCache(conv=new_conv, state=new_state,
                              pos=cache.pos + 1)


def spec_verify(params, cache: SSMLMCache, tokens: jax.Array, cfg):
    """Score a verify window of ``tokens`` (B, K+1) by scanning single-token
    decode steps, checkpointing the sequential (conv, state) summaries
    after every step (snapshot 0 = the pre-verify state). The SSM state is
    O(1) and cannot be rewound by position arithmetic, so ``spec_commit``
    rolls back by SELECTING each slot's snapshot at its accepted length
    (docs/DESIGN.md §11). Returns (logits (B, K+1, V_pad), snap)."""

    def body(c, tok):
        logits, c2 = decode_step(params, c, tok[:, None], cfg)
        return c2, (logits[:, 0], c2.conv, c2.state)

    _, (lgs, convs, states) = jax.lax.scan(body, cache, tokens.T)
    convs = jnp.concatenate([cache.conv[None], convs])    # (K+2, L, B, ...)
    states = jnp.concatenate([cache.state[None], states])
    return jnp.moveaxis(lgs, 0, 1), (cache, convs, states)


def spec_commit(snap, committed: jax.Array) -> SSMLMCache:
    from repro.models.common import select_snapshot
    cache, convs, states = snap
    return SSMLMCache(conv=select_snapshot(convs, committed),
                      state=select_snapshot(states, committed),
                      pos=cache.pos + committed)


def block_params(params) -> list[Any]:
    layers = params["layers"]
    num_layers = jax.tree.leaves(layers)[0].shape[0]
    return [params["embed"]] + [jax.tree.map(lambda x: x[i], layers)
                                for i in range(num_layers)]
