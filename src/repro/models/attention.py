"""GQA attention with RoPE, KV cache, cross-attention and chunked
(online-softmax) execution for long sequences.

Weights per attention block (all stored (out, in)):
  wq: (H*hd, D)   wk: (Hkv*hd, D)   wv: (Hkv*hd, D)   wo: (D, H*hd)
Optionally q_norm / k_norm RMS weights (chameleon-style QK-norm).
"""

from __future__ import annotations

import functools
import os
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.ops import decode_attention
from repro.models.common import qdot, rms_norm, rope
from repro.quant import kvcache as KV
from repro.sharding.ctx import constrain, model_shards, unroll_flag

NEG_INF = -1e30
# Chunked-attention knobs, overridable per process via env
# (REPRO_CHUNK_THRESHOLD / REPRO_Q_CHUNK / REPRO_KV_CHUNK) or
# ``configure_chunking`` — read at TRACE time, so set them before jitting.
CHUNK_THRESHOLD = int(os.environ.get("REPRO_CHUNK_THRESHOLD", "8192"))
Q_CHUNK = int(os.environ.get("REPRO_Q_CHUNK", "2048"))
KV_CHUNK = int(os.environ.get("REPRO_KV_CHUNK", "2048"))


def configure_chunking(chunk_threshold: Optional[int] = None,
                       q_chunk: Optional[int] = None,
                       kv_chunk: Optional[int] = None) -> None:
    """Override the chunked-attention thresholds process-wide (functions
    jitted before the call keep the values they were traced with)."""
    global CHUNK_THRESHOLD, Q_CHUNK, KV_CHUNK
    for name, val in (("CHUNK_THRESHOLD", chunk_threshold),
                      ("Q_CHUNK", q_chunk), ("KV_CHUNK", kv_chunk)):
        if val is not None:
            if val < 1:
                raise ValueError(f"{name} must be >= 1, got {val}")
            globals()[name] = val


class KVCache(NamedTuple):
    """Per-layer attention cache. ``k``/``v`` are raw (B, S_max, Hkv, hd)
    arrays on the bf16 path, or ``quant.kvcache.KVPage``s (int8 / packed
    int4 payload + per-group scales) when serving with a quantized KV
    cache (docs/DESIGN.md §10)."""
    k: jax.Array
    v: jax.Array


def init_kv_cache(batch: int, max_seq: int, num_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, max_seq, num_kv_heads, head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def _project_qkv(p, x, kv_x, num_heads, num_kv_heads, head_dim, qk_norm,
                 norm_eps):
    b, s, _ = x.shape
    if kv_x is None:
        # self-attention: all three projections share x — one fused launch
        # on TPU, bit-identical qdot triple elsewhere
        from repro.kernels.qmatmul.ops import fused_qkv
        yq, yk, yv = fused_qkv(x, p["wq"], p["wk"], p["wv"])
        q = yq.reshape(b, s, num_heads, head_dim)
        k = yk.reshape(b, s, num_kv_heads, head_dim)
        v = yv.reshape(b, s, num_kv_heads, head_dim)
        skv = s
    else:
        q = qdot(x, p["wq"]).reshape(b, s, num_heads, head_dim)
        src = kv_x
        skv = src.shape[1]
        k = qdot(src, p["wk"]).reshape(b, skv, num_kv_heads, head_dim)
        v = qdot(src, p["wv"]).reshape(b, skv, num_kv_heads, head_dim)
    if qk_norm:
        q = rms_norm(q, p["q_norm"], norm_eps)
        k = rms_norm(k, p["k_norm"], norm_eps)
    q = constrain(q, ("batch", None, "model", None))
    k = constrain(k, ("batch", None, "model", None))
    v = constrain(v, ("batch", None, "model", None))
    return q, k, v


def _flatten_gqa_for_sharding(q, k, v):
    """TP-align attention when head counts don't divide the model axis.

    56 q-heads (arctic) or 36 (minicpm) on a 16-way model axis would be
    REPLICATED by the divisibility rule — 16x redundant attention compute
    and 16x score memory (the dominant term in the baseline sweep). Instead:
    repeat KV heads to the flat q-head count (rep=1 grouping) and zero-pad
    heads up to a multiple of the axis, so scores shard cleanly. Padding
    waste is (pad/H) extra attention FLOPs (14% for arctic, 33% for
    llama3.2-3b) versus a 16x replication loss. The TPU-target flash kernel
    handles grouped heads natively; this is the XLA-level layout
    (docs/DESIGN.md §5). Returns (q, k, v, original_h).
    """
    ms = model_shards()
    h, hkv = q.shape[2], k.shape[2]
    if ms <= 1 or (h % ms == 0 and hkv % ms == 0):
        return q, k, v, h
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    pad = (-h) % ms
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0))
        q, k, v = jnp.pad(q, widths), jnp.pad(k, widths), jnp.pad(v, widths)
    q = constrain(q, ("batch", None, "model", None))
    k = constrain(k, ("batch", None, "model", None))
    v = constrain(v, ("batch", None, "model", None))
    return q, k, v, h


def decode_valid_bias(cache_pos, s: int, t: int):
    """Additive decode mask for ``s`` query positions written at
    ``cache_pos``: query i (absolute position ``cache_pos + i``) sees cache
    rows ``<= cache_pos + i`` — per-query causal offset masking, so a
    speculative verify window (s = K+1, docs/DESIGN.md §11) never attends
    to its own future. s=1 reduces to the plain decode validity mask.
    Broadcastable against (B, Hkv, rep, S, T) scores.

    Identical for every layer of a decode step, so families compute it ONCE
    per step (``decode_step_bias``) and pass it down instead of rebuilding
    the (T,) iota-compare in each of L layers."""
    rows = jnp.arange(t)
    qi = jnp.arange(s)
    if getattr(cache_pos, "ndim", 0) == 1:
        valid = (rows[None, None, :]
                 <= cache_pos[:, None, None] + qi[None, :, None])  # (B, S, T)
        return jnp.where(valid, 0.0, NEG_INF)[:, None, None, :, :]
    valid = rows[None, :] <= (cache_pos + qi[:, None])             # (S, T)
    return jnp.where(valid, 0.0, NEG_INF)[None, None, None, :, :]


def decode_step_bias(cache_k_field, cache_pos, s: int = 1):
    """Per-step hoisted validity bias for a family's stacked cache field
    ((L, B, S_max, Hkv, hd)) and ``s`` query positions. Quantized caches
    return None — the fused decode kernel masks by position arithmetic
    instead of a bias tensor."""
    if KV.is_kv_page(cache_k_field):
        return None
    return decode_valid_bias(cache_pos, s, cache_k_field.shape[2])


def _gqa_scores(q, k):
    """q: (B,S,Hkv,rep,hd), k: (B,T,Hkv,hd) -> (B,Hkv,rep,S,T) f32."""
    return jnp.einsum("bshrd,bthd->bhrst", q, k,
                      preferred_element_type=jnp.float32)


def _full_attention(q, k, v, mask_bias):
    """Materialized-scores attention (short sequences / decode)."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    qh = q.reshape(b, s, hkv, rep, d)
    scores = _gqa_scores(qh, k) / jnp.sqrt(d).astype(jnp.float32)
    scores = scores + mask_bias  # (B,Hkv,rep,S,T) + broadcastable bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhrst,bthd->bshrd", probs.astype(v.dtype), v)
    return out.reshape(b, s, h, d)


def _chunked_causal_attention(q, k, v):
    """Online-softmax attention: scan over KV chunks for each Q chunk.

    Pure-JAX flash-attention analogue: temp memory is O(q_chunk * kv_chunk)
    instead of O(S*T). Causal masking via chunk-level position arithmetic.

    In ctx.cost_mode the loops fully unroll (XLA cost analysis counts while
    bodies once), with coarsened 4x4 chunking to bound HLO size — chunk
    granularity does not change the FLOP count.
    """
    from repro.sharding.ctx import in_cost_mode, unroll_flag
    b, s, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    t = k.shape[1]
    q_chunk_pref = max(s // 4, 1) if in_cost_mode() else Q_CHUNK
    kv_chunk_pref = max(t // 4, 1) if in_cost_mode() else KV_CHUNK
    nq = s // q_chunk_pref if s % q_chunk_pref == 0 else 1
    q_chunk = q_chunk_pref if s % q_chunk_pref == 0 else s
    nk = t // kv_chunk_pref if t % kv_chunk_pref == 0 else 1
    kv_chunk = kv_chunk_pref if t % kv_chunk_pref == 0 else t
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    qc = q.reshape(b, nq, q_chunk, hkv, rep, d)
    kc = k.reshape(b, nk, kv_chunk, hkv, d)
    vc = v.reshape(b, nk, kv_chunk, hkv, d)

    q_pos = jnp.arange(q_chunk)
    k_pos = jnp.arange(kv_chunk)

    def one_q_chunk(qi, q_blk):
        # q_blk: (b, q_chunk, hkv, rep, d)
        def body(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            scores = jnp.einsum("bshrd,bthd->bhrst", q_blk, k_blk,
                                preferred_element_type=jnp.float32) * scale
            abs_q = qi * q_chunk + q_pos
            abs_k = ki * kv_chunk + k_pos
            causal = abs_q[:, None] >= abs_k[None, :]
            scores = jnp.where(causal[None, None, None], scores, NEG_INF)
            m_blk = jnp.max(scores, axis=-1)
            m_new = jnp.maximum(m, m_blk)
            p = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhrst,bthd->bhrsd", p.astype(v_blk.dtype), v_blk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, rep, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, rep, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, rep, q_chunk, d), v.dtype)
        ks = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (ks, jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
            unroll=unroll_flag())
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return out  # (b, hkv, rep, q_chunk, d)

    _, outs = jax.lax.scan(
        lambda c, args: (c, one_q_chunk(*args)), None,
        (jnp.arange(nq), jnp.moveaxis(qc, 1, 0)), unroll=unroll_flag())
    # outs: (nq, b, hkv, rep, q_chunk, d) -> (b, s, h, d)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5)
    return out.reshape(b, s, h, d)


def attention(p, x, *, num_heads: int, num_kv_heads: int, head_dim: int,
              positions: Optional[jax.Array] = None,
              rope_theta: Optional[float] = None,
              causal: bool = True, qk_norm: bool = False,
              norm_eps: float = 1e-5,
              kv_x: Optional[jax.Array] = None,
              cache: Optional[KVCache] = None,
              cache_pos: Optional[jax.Array] = None,
              cached_kv: Optional[KVCache] = None,
              valid_bias: Optional[jax.Array] = None,
              fresh_kv: Optional[tuple] = None,
              emit_kv: bool = False):
    """General attention entry point.

    Modes:
      * prefill/train: cache=None — full or chunked causal attention.
      * decode: cache given, x is (B, 1, D); k/v written at cache_pos and
        attention runs against the cache. A raw cache masks with
        ``valid_bias`` (hoisted once per step by the family decode loop,
        rebuilt inline for direct callers); a quantized cache (KVPage)
        quantizes-on-insert and runs the fused streaming kernel —
        no (…, S_max) score tensor is materialized.
      * read-only decode (fused draft propose, docs/DESIGN.md §12):
        cache AND ``fresh_kv=(fresh_k, fresh_v, count)`` given — the new
        k/v are appended at row ``count`` of the raw (B, K, Hkv, hd) side
        buffers instead of being written to the cache; the decode kernel
        sweeps the buffer rows at logical positions ``cache_pos + j`` with
        the page's exact quantize-on-write math. Returns the UPDATED side
        buffers (as a KVCache) in the cache slot; the cache is untouched.
      * cross-attention decode: cached_kv given (precomputed encoder K/V,
        raw or quantized).
    Returns (out, new_cache_or_None).
    """
    b, s, _ = x.shape

    if cached_kv is not None:
        # Cross-attention against fixed precomputed K/V.
        q = qdot(x, p["wq"]).reshape(b, s, num_heads, head_dim)
        if qk_norm:
            q = rms_norm(q, p["q_norm"], norm_eps)
        if KV.is_kv_page(cached_kv.k):
            # non-causal: every (verify) query sees the whole encoder cache
            out = decode_attention(q, cached_kv.k, cached_kv.v, causal=False)
        else:
            out = _full_attention(q, cached_kv.k, cached_kv.v, 0.0)
        return qdot(out.reshape(b, s, num_heads * head_dim), p["wo"]), None

    q, k, v = _project_qkv(p, x, kv_x, num_heads, num_kv_heads, head_dim,
                           qk_norm, norm_eps)
    if rope_theta is not None and positions is not None:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)

    if cache is not None and fresh_kv is not None:
        # Read-only draft propose: k/v go into the side buffer at row
        # ``count``; the cache itself is never written (zero draft-side
        # KV traffic — the whole point of the fused propose path).
        fk, fv, count = fresh_kv
        fk = jax.lax.dynamic_update_slice(
            fk, k.astype(fk.dtype), (0, count, 0, 0))
        fv = jax.lax.dynamic_update_slice(
            fv, v.astype(fv.dtype), (0, count, 0, 0))
        out = decode_attention(q, cache.k, cache.v,
                               valid_len=cache_pos + count + s,
                               fresh_kv=(fk, fv, cache_pos))
        new_cache = KVCache(k=fk, v=fv)          # the updated side buffers
    elif cache is not None:
        # Decode: insert new k/v at cache_pos, attend over the cache.
        # cache_pos is a scalar (whole batch at one position) or a (B,)
        # vector (continuous batching: per-slot positions).
        if KV.is_kv_page(cache.k):
            # Quantized KV cache: quantize-on-insert, then stream the int8
            # / int4 pages through the fused online-softmax decode kernel.
            k_cache = KV.update_page(cache.k, k, cache_pos)
            v_cache = KV.update_page(cache.v, v, cache_pos)
            out = decode_attention(q, k_cache, v_cache,
                                   valid_len=cache_pos + s)
        else:
            if getattr(cache_pos, "ndim", 0) == 1:
                write = jax.vmap(lambda c, n, p: jax.lax.dynamic_update_slice(
                    c, n, (p, 0, 0)))
                k_cache = write(cache.k, k.astype(cache.k.dtype), cache_pos)
                v_cache = write(cache.v, v.astype(cache.v.dtype), cache_pos)
            else:
                k_cache = jax.lax.dynamic_update_slice(
                    cache.k, k.astype(cache.k.dtype), (0, cache_pos, 0, 0))
                v_cache = jax.lax.dynamic_update_slice(
                    cache.v, v.astype(cache.v.dtype), (0, cache_pos, 0, 0))
            bias = valid_bias if valid_bias is not None else \
                decode_valid_bias(cache_pos, s, k_cache.shape[1])
            out = _full_attention(q, k_cache, v_cache, bias)
        new_cache = KVCache(k=k_cache, v=v_cache)
    elif causal:
        new_cache = KVCache(k=k, v=v) if emit_kv else None
        q, k, v, h_orig = _flatten_gqa_for_sharding(q, k, v)
        if s > CHUNK_THRESHOLD:
            out = _chunked_causal_attention(q, k, v)
        else:
            t = k.shape[1]
            causal_mask = jnp.tril(jnp.ones((s, t), bool))
            bias = jnp.where(causal_mask, 0.0, NEG_INF)[None, None, None]
            out = _full_attention(q, k, v, bias)
        out = out[:, :, :h_orig, :]
    else:  # bidirectional (encoder)
        new_cache = KVCache(k=k, v=v) if emit_kv else None
        q, k, v, h_orig = _flatten_gqa_for_sharding(q, k, v)
        out = _full_attention(q, k, v, 0.0)
        out = out[:, :, :h_orig, :]

    out = constrain(out, ("batch", None, "model", None))
    out = qdot(out.reshape(b, s, num_heads * head_dim), p["wo"])
    out = constrain(out, ("batch", None, None))
    return out, new_cache


def init_attention_params(key, cfg, dtype, with_qk_norm=False):
    import numpy as np
    ks = jax.random.split(key, 4)
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    from repro.models.common import dense_init
    p = {
        "wq": dense_init(ks[0], h * hd, d, dtype),
        "wk": dense_init(ks[1], hkv * hd, d, dtype),
        "wv": dense_init(ks[2], hkv * hd, d, dtype),
        "wo": dense_init(ks[3], d, h * hd, dtype,
                         scale=1.0 / np.sqrt(2 * max(cfg.num_layers, 1))),
    }
    if with_qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p
