"""Hybrid Mamba2 + shared-attention LM (zamba2-2.7b).

Structure: num_layers Mamba2 blocks; ONE shared attention+MLP block (shared
weights, per Zamba2's design) is applied before every ``shared_attn_period``
Mamba2 layers. With 54 layers and period 6 the shared block runs 9 times.
Execution scans over 9 units; each unit = shared block + inner scan over the
unit's 6 stacked Mamba2 layers. The KV cache carries one (B, S, Hkv, hd)
entry per shared-block *application site* (activations differ per site even
though weights are shared).

Deviation noted in docs/DESIGN.md §2.1: Zamba2's per-application LoRA
adapters on the shared block are omitted; shared-block quantization applies
to all sites. Mixed-precision plans execute per-unit segments
(docs/DESIGN.md §8).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import mlp as M
from repro.models import ssm as S
from repro.models.common import (decode_positions, dtype_of, embed_init,
                                 embed_lookup, lm_head, norm)
from repro.sharding.ctx import constrain, unroll_flag, unshard_fsdp


class HybridCache(NamedTuple):
    conv: jax.Array    # (L, B, W-1, conv_dim)
    state: jax.Array   # (L, B, H, P, N) f32
    k: jax.Array       # (U, B, S_max, Hkv, hd) — U shared-attn sites;
    v: jax.Array       #   raw, or a single KVPage (one shared-block decision)
    pos: jax.Array     # int32 — scalar, or (B,) per-slot


CACHE_BATCH_AXES = HybridCache(conv=1, state=1, k=1, v=1, pos=0)
# fields the engine may replace with quantized KVPages (quant/kvcache.py)
KV_CACHE_FIELDS = ("k", "v")


def _num_units(cfg) -> int:
    assert cfg.num_layers % cfg.shared_attn_period == 0
    return cfg.num_layers // cfg.shared_attn_period


def init(key, cfg):
    dtype = dtype_of(cfg)
    k_emb, k_layers, k_shared, k_mlp = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)

    def init_mamba_layer(k):
        p = S.init_ssm_params(k, cfg, dtype)
        p["ln"] = jnp.ones((cfg.d_model,), dtype)
        return p

    layers = jax.vmap(init_mamba_layer)(layer_keys)
    shared = {
        "attn": A.init_attention_params(k_shared, cfg, dtype),
        "mlp": M.init_mlp_params(k_mlp, cfg.d_model, cfg.d_ff, cfg.num_layers,
                                 dtype, cfg.mlp_act),
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
    }
    return {
        "embed": {"tok": embed_init(k_emb, cfg.padded_vocab, cfg.d_model,
                                    dtype)},
        "layers": layers,
        "shared": shared,
        "final": {"norm": jnp.ones((cfg.d_model,), dtype)},
    }


def _shared_block(shared, h, positions, cfg, cache_kv=None, cache_pos=None,
                  valid_bias=None):
    a, new_kv = A.attention(
        shared["attn"], norm(h, shared["ln1"], cfg),
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim, positions=positions,
        rope_theta=cfg.rope_theta, causal=True, norm_eps=cfg.norm_eps,
        cache=cache_kv, cache_pos=cache_pos, valid_bias=valid_bias)
    h = h + a
    h = h + M.mlp(shared["mlp"], norm(h, shared["ln2"], cfg), cfg.mlp_act)
    return h, new_kv


def _unit_stack(layers, cfg):
    """Reshape stacked (L, ...) mamba params into (U, period, ...)."""
    u, p = _num_units(cfg), cfg.shared_attn_period
    return jax.tree.map(lambda x: x.reshape((u, p) + x.shape[1:]), layers)


def _layer_stack(layers, cfg):
    """Resolve a (possibly segmented) mamba stack for execution.

    Returns ``(uniform_stack, segments_by_unit)``: exactly one is non-None.
    A plain stacked tree or a single-segment ``SegmentedParams`` (uniform
    plan) executes via the fused unit-scan fast path; a mixed-precision
    ``SegmentedParams`` (compiler cuts segments at unit boundaries —
    docs/DESIGN.md §8) executes per-unit, scanning each segment's slice
    inside its unit."""
    from repro.quant.apply import SegmentedParams
    if not isinstance(layers, SegmentedParams):
        return layers, None
    if len(layers.segments) == 1:
        return layers.segments[0].params, None
    period = cfg.shared_attn_period
    by_unit = [[] for _ in range(_num_units(cfg))]
    for seg in layers.segments:
        assert seg.start // period == (seg.stop - 1) // period, \
            f"segment [{seg.start},{seg.stop}) crosses a unit boundary"
        by_unit[seg.start // period].append(seg)
    return None, by_unit


def apply(params, tokens: jax.Array, cfg, *, remat: bool = True,
          last_only: bool = False):
    dtype = dtype_of(cfg)
    b, s = tokens.shape
    embed_w = unshard_fsdp(params["embed"])["tok"]
    h = constrain(embed_lookup(embed_w, tokens, dtype),
                  ("batch", None, None))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    shared = unshard_fsdp(params["shared"])
    stacked, by_unit = _layer_stack(params["layers"], cfg)

    def mamba_body(h, p_layer):
        p_layer = unshard_fsdp(p_layer)
        y = S.ssm_block(p_layer, norm(h, p_layer["ln"], cfg), cfg)
        return constrain(h + y, ("batch", "seq", None)), None

    inner = jax.checkpoint(mamba_body) if remat else mamba_body

    if by_unit is not None:
        # mixed-precision: units unrolled, one scan per in-unit segment
        for unit_segs in by_unit:
            h, _ = _shared_block(shared, h, positions, cfg)
            for seg in unit_segs:
                h, _ = jax.lax.scan(inner, h, seg.params,
                                    unroll=unroll_flag())
    else:
        units = _unit_stack(stacked, cfg)

        def unit_body(h, unit_layers):
            h, _ = _shared_block(shared, h, positions, cfg)
            h, _ = jax.lax.scan(inner, h, unit_layers, unroll=unroll_flag())
            return h, None

        fn = jax.checkpoint(unit_body) if remat else unit_body
        h, _ = jax.lax.scan(fn, h, units, unroll=unroll_flag())
    if last_only:
        h = h[:, -1:, :]
    h = norm(h, params["final"]["norm"], cfg)
    logits = constrain(lm_head(h, embed_w), ("batch", None, "model"))
    return logits, {}


def init_cache(cfg, batch: int, max_seq: int) -> HybridCache:
    dtype = dtype_of(cfg)
    one = S.init_ssm_cache(batch, cfg, dtype)
    u = _num_units(cfg)
    kv_shape = (u, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    return HybridCache(
        conv=jnp.zeros((cfg.num_layers,) + one.conv.shape, dtype),
        state=jnp.zeros((cfg.num_layers,) + one.state.shape, jnp.float32),
        k=jnp.zeros(kv_shape, dtype), v=jnp.zeros(kv_shape, dtype),
        pos=jnp.int32(0))


def decode_step(params, cache: HybridCache, tokens: jax.Array, cfg):
    dtype = dtype_of(cfg)
    b = tokens.shape[0]
    embed_w = unshard_fsdp(params["embed"])["tok"]
    h2d = embed_lookup(embed_w, tokens[:, 0], dtype)  # (B, D)
    positions = decode_positions(cache.pos, b, 1)
    u, period = _num_units(cfg), cfg.shared_attn_period
    shared = unshard_fsdp(params["shared"])
    stacked, by_unit = _layer_stack(params["layers"], cfg)
    from repro.quant.kvcache import kv_layer, kv_stack
    valid_bias = A.decode_step_bias(cache.k, cache.pos)

    def mamba_body(h, xs_inner):
        p_layer, c_l, s_l = xs_inner
        p_layer = unshard_fsdp(p_layer)
        y, new = S.ssm_decode_step(
            p_layer, norm(h, p_layer["ln"], cfg),
            S.SSMCache(conv=c_l, state=s_l), cfg)
        return h + y, (new.conv, new.state)

    if by_unit is not None:
        # mixed-precision: units unrolled; each segment scans its slice of
        # the per-layer conv/state cache inside its unit
        convs, states, new_ks, new_vs = [], [], [], []
        for ui, unit_segs in enumerate(by_unit):
            h3 = h2d[:, None, :]  # (B, 1, D) for attention
            h3, new_kv = _shared_block(
                shared, h3, positions, cfg,
                cache_kv=A.KVCache(k=kv_layer(cache.k, ui),
                                   v=kv_layer(cache.v, ui)),
                cache_pos=cache.pos, valid_bias=valid_bias)
            h2d = h3[:, 0, :]
            new_ks.append(new_kv.k)
            new_vs.append(new_kv.v)
            for seg in unit_segs:
                h2d, (nc, ns) = jax.lax.scan(
                    mamba_body, h2d,
                    (seg.params, cache.conv[seg.start:seg.stop],
                     cache.state[seg.start:seg.stop]),
                    unroll=unroll_flag())
                convs.append(nc)
                states.append(ns)
        new_cache = HybridCache(
            conv=jnp.concatenate(convs, axis=0),
            state=jnp.concatenate(states, axis=0),
            k=kv_stack(cache.k, new_ks), v=kv_stack(cache.v, new_vs),
            pos=cache.pos + 1)
    else:
        units = _unit_stack(stacked, cfg)
        conv_u = cache.conv.reshape((u, period) + cache.conv.shape[1:])
        state_u = cache.state.reshape((u, period) + cache.state.shape[1:])

        def unit_body(h, xs):
            unit_layers, conv_l, state_l, k_l, v_l = xs
            h3 = h[:, None, :]  # (B, 1, D) for attention
            h3, new_kv = _shared_block(shared, h3, positions, cfg,
                                       cache_kv=A.KVCache(k=k_l, v=v_l),
                                       cache_pos=cache.pos,
                                       valid_bias=valid_bias)
            h = h3[:, 0, :]
            h, (nc, ns) = jax.lax.scan(mamba_body, h,
                                       (unit_layers, conv_l, state_l),
                                       unroll=unroll_flag())
            return h, (nc, ns, new_kv.k, new_kv.v)

        h2d, (new_conv, new_state, new_k, new_v) = jax.lax.scan(
            unit_body, h2d, (units, conv_u, state_u, cache.k, cache.v),
            unroll=unroll_flag())
        new_cache = HybridCache(
            conv=new_conv.reshape(cache.conv.shape),
            state=new_state.reshape(cache.state.shape),
            k=new_k, v=new_v, pos=cache.pos + 1)

    h = norm(h2d, params["final"]["norm"], cfg)
    logits = lm_head(h[:, None, :], embed_w)
    return logits, new_cache


def spec_verify(params, cache: HybridCache, tokens: jax.Array, cfg):
    """Score a verify window of ``tokens`` (B, K+1) by scanning single-token
    decode steps. Rollback is split by state kind (docs/DESIGN.md §11):
    the shared-attention K/V rows written past the commit point are rolled
    back by position arithmetic (they stay in memory, masked invalid),
    while the sequential Mamba2 (conv, state) summaries are checkpointed
    per step and selected per slot in ``spec_commit``."""

    def body(c, tok):
        logits, c2 = decode_step(params, c, tok[:, None], cfg)
        return c2, (logits[:, 0], c2.conv, c2.state)

    final, (lgs, convs, states) = jax.lax.scan(body, cache, tokens.T)
    convs = jnp.concatenate([cache.conv[None], convs])    # (K+2, L, B, ...)
    states = jnp.concatenate([cache.state[None], states])
    return jnp.moveaxis(lgs, 0, 1), (cache, final, convs, states)


def spec_commit(snap, committed: jax.Array) -> HybridCache:
    from repro.models.common import select_snapshot
    cache0, final, convs, states = snap
    return final._replace(conv=select_snapshot(convs, committed),
                          state=select_snapshot(states, committed),
                          pos=cache0.pos + committed)


def block_params(params) -> list[Any]:
    layers = params["layers"]
    num_layers = jax.tree.leaves(layers)[0].shape[0]
    blocks = [params["embed"]]
    blocks += [jax.tree.map(lambda x: x[i], layers) for i in range(num_layers)]
    blocks.append(params["shared"])
    return blocks
