"""Decoder-only LM covering the dense and MoE families.

Architectures served: chameleon-34b (qk-norm, VQ-token vocab), minicpm-2b,
yi-9b, llama3.2-3b, olmo-1b (non-parametric LN, tied embeddings),
arctic-480b (MoE + dense residual), grok-1-314b (MoE).

Layers are stacked on a leading axis and executed with lax.scan (optionally
rematerialized); parameters may be raw arrays or QTensors (EWQ-quantized).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import mlp as M
from repro.models import moe as MOE
from repro.models.common import (decode_positions, dtype_of, embed_init,
                                 embed_lookup, dense_init, lm_head, norm, qdot)
from repro.sharding.ctx import constrain, unroll_flag, unshard_fsdp


class DecodeCache(NamedTuple):
    k: jax.Array    # (L, B, S_max, Hkv, hd) — raw, or KVPage(s) (quantized)
    v: jax.Array    # (L, B, S_max, Hkv, hd)
    pos: jax.Array  # int32 next write position — scalar, or (B,) per-slot


# batch axis of each cache field once ``pos`` is a (B,) vector
# (serving/batch.py slotted layout; model.insert_cache_slot)
CACHE_BATCH_AXES = DecodeCache(k=1, v=1, pos=0)
# fields the engine may replace with quantized KVPages (quant/kvcache.py)
KV_CACHE_FIELDS = ("k", "v")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    p = {"attn": A.init_attention_params(ks[0], cfg, dtype,
                                         with_qk_norm=cfg.qk_norm)}
    if not cfg.nonparametric_norm:
        p["ln1"] = jnp.ones((cfg.d_model,), dtype)
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.num_experts > 0:
        p["moe"] = MOE.init_moe_params(ks[1], cfg.d_model, cfg.expert_d_ff,
                                       cfg.num_experts, cfg.num_layers, dtype)
        if cfg.dense_residual:
            p["mlp"] = M.init_mlp_params(ks[2], cfg.d_model, cfg.d_ff,
                                         cfg.num_layers, dtype, cfg.mlp_act)
    else:
        p["mlp"] = M.init_mlp_params(ks[2], cfg.d_model, cfg.d_ff,
                                     cfg.num_layers, dtype, cfg.mlp_act)
    return p


def init(key, cfg):
    dtype = dtype_of(cfg)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    params = {
        "embed": {"tok": embed_init(k_emb, cfg.padded_vocab, cfg.d_model,
                                    dtype)},
        "layers": layers,
        "final": {},
    }
    if not cfg.nonparametric_norm:
        params["final"]["norm"] = jnp.ones((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["final"]["head"] = dense_init(k_head, cfg.padded_vocab,
                                             cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------
# layer body
# ---------------------------------------------------------------------------

def _layer(p, h, positions, cfg, cache_kv=None, cache_pos=None,
           valid_bias=None, fresh_kv=None):
    p = unshard_fsdp(p)
    ln1 = p.get("ln1")
    ln2 = p.get("ln2")
    a, new_kv = A.attention(
        p["attn"], norm(h, ln1, cfg),
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim, positions=positions,
        rope_theta=cfg.rope_theta, causal=True, qk_norm=cfg.qk_norm,
        norm_eps=cfg.norm_eps, cache=cache_kv, cache_pos=cache_pos,
        valid_bias=valid_bias, fresh_kv=fresh_kv)
    h = h + a
    hn = norm(h, ln2, cfg)
    aux = {}
    if cfg.num_experts > 0:
        m, aux = MOE.moe_block(p["moe"], hn, num_experts=cfg.num_experts,
                               top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor)
        if cfg.dense_residual:
            m = m + M.mlp(p["mlp"], hn, cfg.mlp_act)
    else:
        m = M.mlp(p["mlp"], hn, cfg.mlp_act)
    h = constrain(h + m, ("batch", "seq", None))
    return h, aux, new_kv


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def apply(params, tokens: jax.Array, cfg, *, remat: bool = True,
          return_cache: bool = False, last_only: bool = False):
    """tokens: (B, S) int32 -> logits (B, S, V_pad) f32 (+ aux dict).

    last_only=True computes head logits for the final position only
    (serving prefill: next-token logits without a (B, S, V) temp)."""
    dtype = dtype_of(cfg)
    b, s = tokens.shape
    embed_w = unshard_fsdp(params["embed"])["tok"]
    h = constrain(embed_lookup(embed_w, tokens, dtype),
                  ("batch", None, None))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(h, p_layer):
        h2, aux, _ = _layer(p_layer, h, positions, cfg)
        return h2, aux

    def body_cache(h, p_layer):
        p_layer = unshard_fsdp(p_layer)
        hn = norm(h, p_layer.get("ln1"), cfg)
        a, kv = A.attention(
            p_layer["attn"], hn, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
            positions=positions, rope_theta=cfg.rope_theta, causal=True,
            qk_norm=cfg.qk_norm, norm_eps=cfg.norm_eps, emit_kv=True)
        h = h + a
        hn2 = norm(h, p_layer.get("ln2"), cfg)
        if cfg.num_experts > 0:
            m, aux = MOE.moe_block(p_layer["moe"], hn2,
                                   num_experts=cfg.num_experts,
                                   top_k=cfg.top_k,
                                   capacity_factor=cfg.capacity_factor)
            if cfg.dense_residual:
                m = m + M.mlp(p_layer["mlp"], hn2, cfg.mlp_act)
        else:
            aux = {}
            m = M.mlp(p_layer["mlp"], hn2, cfg.mlp_act)
        return h + m, (aux, kv)

    from repro.quant.apply import segment_slices
    layers = params["layers"]
    if return_cache:
        fn = jax.checkpoint(body_cache) if remat else body_cache
        auxs, ks, vs = None, [], []
        for part, _, _ in segment_slices(layers):
            h, (seg_auxs, kv) = jax.lax.scan(fn, h, part,
                                             unroll=unroll_flag())
            ks.append(kv[0])
            vs.append(kv[1])
            auxs = seg_auxs if auxs is None else jax.tree.map(
                lambda a, b: jnp.concatenate([a, b]), auxs, seg_auxs)
        kvs = (jnp.concatenate(ks, axis=0) if len(ks) > 1 else ks[0],
               jnp.concatenate(vs, axis=0) if len(vs) > 1 else vs[0])
        cache = DecodeCache(k=kvs[0], v=kvs[1], pos=jnp.int32(s))
    else:
        fn = jax.checkpoint(body) if remat else body
        auxs = None
        for part, _, _ in segment_slices(layers):
            h, seg_auxs = jax.lax.scan(fn, h, part, unroll=unroll_flag())
            auxs = seg_auxs if auxs is None else jax.tree.map(
                lambda a, b: jnp.concatenate([a, b]), auxs, seg_auxs)
        cache = None

    if last_only:
        h = h[:, -1:, :]
    h = norm(h, params["final"].get("norm"), cfg)
    head_w = unshard_fsdp(params["final"]).get("head", embed_w)
    logits = constrain(lm_head(h, head_w), ("batch", None, "model"))
    aux = {k: jnp.sum(v) for k, v in (auxs or {}).items()}
    return (logits, aux, cache) if return_cache else (logits, aux)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int) -> DecodeCache:
    dtype = dtype_of(cfg)
    shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    return DecodeCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                       pos=jnp.int32(0))


def decode_step(params, cache: DecodeCache, tokens: jax.Array, cfg):
    """tokens: (B, 1) -> (logits (B, 1, V_pad), new cache)."""
    dtype = dtype_of(cfg)
    b, s = tokens.shape
    embed_w = unshard_fsdp(params["embed"])["tok"]
    h = constrain(embed_lookup(embed_w, tokens, dtype),
                  ("batch", None, None))
    positions = decode_positions(cache.pos, b, s)
    # validity mask is layer-invariant: hoist it out of the per-layer
    # attention (None for quantized caches — the kernel masks by position;
    # s > 1 is the speculative verify window with per-query causal offsets)
    valid_bias = A.decode_step_bias(cache.k, cache.pos, s)

    def body(h, xs):
        p_layer, k_l, v_l = xs
        h2, _, new_kv = _layer(p_layer, h, positions, cfg,
                               cache_kv=A.KVCache(k=k_l, v=v_l),
                               cache_pos=cache.pos, valid_bias=valid_bias)
        return h2, (new_kv.k, new_kv.v)

    from repro.quant.apply import segment_slices
    from repro.quant.kvcache import kv_rejoin, kv_segment
    ks, vs = [], []
    for si, (part, lo, hi) in enumerate(segment_slices(params["layers"])):
        h, (nk, nv) = jax.lax.scan(
            body, h, (part, kv_segment(cache.k, si, lo, hi),
                      kv_segment(cache.v, si, lo, hi)),
            unroll=unroll_flag())
        ks.append(nk)
        vs.append(nv)
    new_k = kv_rejoin(cache.k, ks)
    new_v = kv_rejoin(cache.v, vs)
    h = norm(h, params["final"].get("norm"), cfg)
    head_w = unshard_fsdp(params["final"]).get("head", embed_w)
    logits = constrain(lm_head(h, head_w), ("batch", None, "model"))
    return logits, DecodeCache(k=new_k, v=new_v, pos=cache.pos + s)


def draft_propose_step(params, cache: DecodeCache, fresh_k, fresh_v,
                       count, tokens: jax.Array, cfg):
    """One READ-ONLY draft decode step (fused spec propose, docs/DESIGN.md
    §12): the cache is only read — each layer's new k/v land in row
    ``count`` of the raw per-layer side buffers ``fresh_k``/``fresh_v``
    ((L_draft, B, K, Hkv, hd)), and attention sweeps cache + buffer in one
    fused pass with buffer rows at logical positions ``cache.pos + j``.
    A k-round therefore costs ZERO draft-side cache writes (no throwaway
    cache copy, no k*L quantize-and-scatter) and one sweep per step.

    ``params`` may be a truncated draft (first N layers of the target —
    compile_draft_plan(draft_layers=N)); cache pages are sliced per draft
    segment, which always sits inside one page (kv_take_layers).

    tokens: (B, 1) -> (logits (B, 1, V_pad), fresh_k, fresh_v) with the
    updated buffers carrying row ``count``."""
    dtype = dtype_of(cfg)
    b, s = tokens.shape
    embed_w = unshard_fsdp(params["embed"])["tok"]
    h = constrain(embed_lookup(embed_w, tokens, dtype),
                  ("batch", None, None))
    positions = decode_positions(cache.pos + count, b, s)

    def body(h, xs):
        p_layer, k_l, v_l, fk_l, fv_l = xs
        h2, _, new_kv = _layer(p_layer, h, positions, cfg,
                               cache_kv=A.KVCache(k=k_l, v=v_l),
                               cache_pos=cache.pos,
                               fresh_kv=(fk_l, fv_l, count))
        return h2, (new_kv.k, new_kv.v)

    from repro.quant.apply import segment_slices
    from repro.quant.kvcache import kv_take_layers
    fks, fvs = [], []
    for part, lo, hi in segment_slices(params["layers"]):
        h, (nfk, nfv) = jax.lax.scan(
            body, h, (part, kv_take_layers(cache.k, lo, hi),
                      kv_take_layers(cache.v, lo, hi),
                      fresh_k[lo:hi], fresh_v[lo:hi]),
            unroll=unroll_flag())
        fks.append(nfk)
        fvs.append(nfv)
    fresh_k = jnp.concatenate(fks, axis=0) if len(fks) > 1 else fks[0]
    fresh_v = jnp.concatenate(fvs, axis=0) if len(fvs) > 1 else fvs[0]
    h = norm(h, params["final"].get("norm"), cfg)
    head_w = unshard_fsdp(params["final"]).get("head", embed_w)
    logits = constrain(lm_head(h, head_w), ("batch", None, "model"))
    return logits, fresh_k, fresh_v


# ---------------------------------------------------------------------------
# speculative verify (docs/DESIGN.md §11)
# ---------------------------------------------------------------------------

def spec_verify(params, cache: DecodeCache, tokens: jax.Array, cfg):
    """Score a verify window of ``tokens`` (B, K+1) in ONE fused multi-query
    decode pass. Returns (logits (B, K+1, V_pad), snap); the snap rolls the
    cache back to any per-slot accepted length via ``spec_commit`` —
    rollback is pure position arithmetic over the (quantized) KV cache:
    rows past the commit point stay in memory but are masked invalid."""
    logits, new_cache = decode_step(params, cache, tokens, cfg)
    return logits, (new_cache, tokens.shape[1])


def spec_commit(snap, committed: jax.Array) -> DecodeCache:
    """``committed`` (B,) tokens kept out of the verify window (0 rolls a
    slot all the way back to its pre-verify position)."""
    cache, s = snap
    return cache._replace(pos=cache.pos - s + committed)


# ---------------------------------------------------------------------------
# EWQ view
# ---------------------------------------------------------------------------

def block_params(params) -> list[Any]:
    """[embedding block, layer_0, ..., layer_{L-1}] — paper exec_index order."""
    layers = params["layers"]
    num_layers = jax.tree.leaves(layers)[0].shape[0]
    blocks = [params["embed"]]
    for i in range(num_layers):
        blocks.append(jax.tree.map(lambda x: x[i], layers))
    return blocks
