"""Shared model building blocks (norms, init, embeddings, quant-aware dense).

Conventions:
* every weight matrix is stored ``(out_features, in_features)`` and applied
  with ``qdot`` (einsum '...k,nk->...n'), so quantization groups along the
  last axis coincide with the contraction axis (fused dequant);
* stacked (scanned) layers carry a leading layer axis;
* activations are bf16 by default, reductions/softmax in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.qmatmul.ops import qdot
from repro.quant.qtypes import QTensor
from repro.quant.quantize import dequantize


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


def decode_positions(pos: jax.Array, b: int, s: int) -> jax.Array:
    """(B, S) int32 token positions for a decode step.

    ``pos`` is the cache position — a scalar (whole batch in lockstep) or a
    (B,) vector (slotted continuous batching, one position per slot)."""
    step = jnp.arange(s, dtype=jnp.int32)[None]
    if getattr(pos, "ndim", 0) == 1:
        return pos.astype(jnp.int32)[:, None] + step
    return jnp.broadcast_to(pos.astype(jnp.int32)[None, None] + step, (b, s))


def select_snapshot(snaps: jax.Array, idx: jax.Array,
                    batch_axis: int = 2) -> jax.Array:
    """Per-slot gather over stacked sequential-state snapshots.

    ``snaps`` holds N checkpoints stacked on a new leading axis, so the
    slot/batch dim sits at ``batch_axis`` of ``snaps`` (2 for the usual
    (N, L, B, ...) state stack); ``idx`` is a (B,) per-slot snapshot index
    in [0, N). Returns the un-stacked layout (batch back at
    ``batch_axis - 1``) with each slot's rows taken from its own
    snapshot — the SSM-state rollback primitive for speculative decoding
    (conv/state are O(1) summaries that cannot be rewound by position
    arithmetic, so the verify scan checkpoints them per step and commit
    selects per slot; docs/DESIGN.md §11)."""
    moved = jnp.moveaxis(snaps, batch_axis, 0)       # (B, N, ...)
    out = jax.vmap(lambda sn, i: sn[i])(moved, idx)  # (B, ...)
    return jnp.moveaxis(out, 0, batch_axis - 1)


# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------

def dense_init(key, out_dim: int, in_dim: int, dtype, scale: float = 1.0):
    std = scale / np.sqrt(in_dim)
    return (jax.random.normal(key, (out_dim, in_dim), jnp.float32) * std
            ).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02
            ).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, w, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if w is not None:
        y = y * w.astype(jnp.float32)
    return y.astype(x.dtype)


def layer_norm(x: jax.Array, w, eps: float = 1e-5) -> jax.Array:
    """Non-parametric when w is None (OLMo-style)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if w is not None:
        y = y * w.astype(jnp.float32)
    return y.astype(x.dtype)


def norm(x, w, cfg):
    if cfg.nonparametric_norm:
        return layer_norm(x, None, cfg.norm_eps)
    return rms_norm(x, w, cfg.norm_eps)


# --------------------------------------------------------------------------
# Rotary position embedding
# --------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    pos = np.arange(seq)[:, None]
    i = np.arange(dim // 2)[None, :]
    ang = pos / (10000 ** (2 * i / dim))
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


# --------------------------------------------------------------------------
# Embedding lookup (quant-aware)
# --------------------------------------------------------------------------

def embed_lookup(table, ids: jax.Array, dtype) -> jax.Array:
    if isinstance(table, QTensor):
        rows = jnp.take(table.data, ids, axis=0)
        scales = jnp.take(table.scale, ids, axis=0)
        if table.precision == "int4":
            from repro.quant.quantize import unpack_int4
            rows = unpack_int4(rows)
        k = rows.shape[-1]
        g = rows.astype(jnp.float32).reshape(*rows.shape[:-1], k // table.group,
                                             table.group)
        out = (g * scales.astype(jnp.float32)[..., None]).reshape(
            *rows.shape[:-1], k)
        return out.astype(dtype)
    return jnp.take(table, ids, axis=0).astype(dtype)


def lm_head(x: jax.Array, head_w, dtype=jnp.float32) -> jax.Array:
    """Final projection to (padded) vocab logits in f32."""
    return qdot(x, head_w, out_dtype=dtype)


__all__ = ["qdot", "dense_init", "embed_init", "rms_norm", "layer_norm",
           "norm", "rope", "sinusoidal_positions", "embed_lookup", "lm_head",
           "dtype_of", "QTensor", "dequantize"]
