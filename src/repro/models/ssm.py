"""Mamba2 (SSD — state-space duality) block: chunked prefill + O(1) decode.

Prefill uses the SSD chunked algorithm: quadratic attention-like computation
inside fixed-size chunks (decay matrix via segment-sums), and a *sequential
scan* over chunk states for the inter-chunk recurrence (linear in sequence
length — the reference "minimal SSD" builds a (nc x nc) chunk decay matrix,
which is quadratic in chunk count and would blow up at 500k tokens).

Decode maintains (conv_state, ssm_state) and is O(1) per token — the reason
``long_500k`` is runnable for SSM/hybrid archs.

Per-layer parameters (stored (out, in)):
  w_in     : (2*d_inner + 2*G*N + H, D)
  conv_w   : (conv_dim, W)      depthwise causal conv, conv_dim = d_inner+2GN
  conv_b   : (conv_dim,)
  A_log    : (H,)               A = -exp(A_log)
  D        : (H,)               skip gain
  dt_bias  : (H,)
  norm_w   : (d_inner,)         gated RMSNorm
  w_out    : (D, d_inner)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, qdot
from repro.sharding.ctx import constrain, unroll_flag


class SSMCache(NamedTuple):
    conv: jax.Array   # (B, W-1, conv_dim)
    state: jax.Array  # (B, H, P, N) f32


def init_ssm_cache(batch, cfg, dtype=jnp.bfloat16) -> SSMCache:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        state=jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim,
                         cfg.ssm_state), jnp.float32))


def init_ssm_params(key, cfg, dtype):
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    conv_dim = di + 2 * g * n
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense_init(ks[0], 2 * di + 2 * g * n + h, d, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_dim, cfg.ssm_conv),
                                     jnp.float32)
                   / np.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.asarray(
            np.log(np.expm1(np.linspace(1e-3, 1e-1, h))), jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "w_out": dense_init(ks[2], d, di, dtype,
                            scale=1.0 / np.sqrt(2 * max(cfg.num_layers, 1))),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B, L, C), w (C, W) -> (B, L, C)."""
    bsz, l, c = x.shape
    width = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        out = out + xp[:, i:i + l, :].astype(jnp.float32) \
            * w[:, i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _gated_rms_norm(y, z, w, eps=1e-5):
    yz = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yz * yz, axis=-1, keepdims=True)
    return (yz * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
            ).astype(y.dtype)


def _ssd_chunked(x, a, bm, cm, chunk: int):
    """SSD scan. x: (B, L, H, P) premultiplied by dt; a: (B, L, H) = dt*A;
    bm, cm: (B, L, H, N). Returns (y: (B, L, H, P), final_state)."""
    bsz, l, h, p = x.shape
    n = bm.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    xs = x.reshape(bsz, nc, chunk, h, p)
    asr = a.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bs = bm.reshape(bsz, nc, chunk, h, n)
    cs = cm.reshape(bsz, nc, chunk, h, n)
    a_cum = jnp.cumsum(asr, axis=2)                      # (B, nc, cs, H)

    # --- intra-chunk (diagonal blocks) -------------------------------------
    # mask BEFORE exp: the upper triangle of seg is positive (a_cum is
    # decreasing), and exp(+big) -> inf would poison gradients through the
    # masked-out entries (0 * inf = nan in the backward pass).
    seg = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]   # (B,nc,s,t,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    lmat = jnp.exp(seg)
    y_diag = jnp.einsum("bcshn,bcthn,bcsth,bcthp->bcshp",
                        cs.astype(jnp.float32), bs.astype(jnp.float32),
                        lmat, xs.astype(jnp.float32))

    # --- per-chunk end states ----------------------------------------------
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)       # (B,nc,cs,H)
    states = jnp.einsum("bcthn,bcth,bcthp->bchpn",
                        bs.astype(jnp.float32), decay_states,
                        xs.astype(jnp.float32))               # (B,nc,H,P,N)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])                 # (B,nc,H)

    # --- inter-chunk recurrence: sequential scan (linear in nc) ------------
    def scan_f(s, inp):
        cd, st = inp                                          # (B,H), (B,H,P,N)
        s_new = cd[:, :, None, None] * s + st
        return s_new, s                                       # emit ENTERING state

    s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    final_state, entering = jax.lax.scan(
        scan_f, s0, (jnp.moveaxis(chunk_decay, 1, 0),
                     jnp.moveaxis(states, 1, 0)), unroll=unroll_flag())
    entering = jnp.moveaxis(entering, 0, 1)                   # (B,nc,H,P,N)

    # --- off-diagonal contribution ------------------------------------------
    state_decay = jnp.exp(a_cum)                              # (B,nc,cs,H)
    y_off = jnp.einsum("bcshn,bcsh,bchpn->bcshp",
                       cs.astype(jnp.float32), state_decay, entering)
    y = (y_diag + y_off).reshape(bsz, l, h, p)
    return y, final_state


def _split_zxbcdt(zxbcdt, cfg):
    di = cfg.d_inner
    gn = cfg.ssm_ngroups * cfg.ssm_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:2 * di + 2 * gn]
    dt = zxbcdt[..., 2 * di + 2 * gn:]
    return z, xbc, dt


def _heads_from_groups(m, cfg):
    """(B, ..., G, N) -> (B, ..., H, N) by repeating groups."""
    rep = cfg.ssm_nheads // cfg.ssm_ngroups
    return jnp.repeat(m, rep, axis=-2)


def ssm_block(p, u: jax.Array, cfg):
    """Prefill/train path. u: (B, L, D) -> (B, L, D)."""
    bsz, l, _ = u.shape
    di, h, pd = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    g, n = cfg.ssm_ngroups, cfg.ssm_state

    zxbcdt = constrain(qdot(u, p["w_in"]), ("batch", None, None))
    z, xbc, dt = _split_zxbcdt(zxbcdt, cfg)
    xbc = jax.nn.silu(
        _causal_conv(xbc, p["conv_w"], p["conv_b"]).astype(jnp.float32)
    ).astype(u.dtype)
    x = xbc[..., :di].reshape(bsz, l, h, pd)
    x = constrain(x, ("batch", None, "model", None))
    bm = _heads_from_groups(
        xbc[..., di:di + g * n].reshape(bsz, l, g, n), cfg)
    cm = _heads_from_groups(
        xbc[..., di + g * n:].reshape(bsz, l, g, n), cfg)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, L, H)
    a = -jnp.exp(p["A_log"])                                       # (H,)
    y, _ = _ssd_chunked(x * dt[..., None].astype(x.dtype),
                        dt * a, bm, cm, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * x.astype(jnp.float32)
    y = _gated_rms_norm(y.reshape(bsz, l, di).astype(u.dtype),
                        z, p["norm_w"], cfg.norm_eps)
    return qdot(y, p["w_out"])


def ssm_decode_step(p, u: jax.Array, cache: SSMCache, cfg):
    """Single-token decode. u: (B, D) -> ((B, D), new cache)."""
    bsz, _ = u.shape
    di, h, pd = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    g, n = cfg.ssm_ngroups, cfg.ssm_state

    zxbcdt = qdot(u, p["w_in"])                                    # (B, ...)
    z, xbc, dt = _split_zxbcdt(zxbcdt, cfg)

    window = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)
    conv_out = (jnp.einsum("bwc,cw->bc", window.astype(jnp.float32),
                           p["conv_w"].astype(jnp.float32))
                + p["conv_b"].astype(jnp.float32))
    new_conv = window[:, 1:, :]
    xbc = jax.nn.silu(conv_out).astype(u.dtype)

    x = xbc[..., :di].reshape(bsz, h, pd)
    bm = _heads_from_groups(xbc[..., di:di + g * n].reshape(bsz, g, n), cfg)
    cm = _heads_from_groups(xbc[..., di + g * n:].reshape(bsz, g, n), cfg)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B, H)
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * a)                                           # (B, H)
    xdt = x.astype(jnp.float32) * dt[..., None]
    state = (cache.state * da[:, :, None, None]
             + jnp.einsum("bhp,bhn->bhpn", xdt, bm.astype(jnp.float32)))
    y = (jnp.einsum("bhpn,bhn->bhp", state, cm.astype(jnp.float32))
         + p["D"][None, :, None] * x.astype(jnp.float32))
    y = _gated_rms_norm(y.reshape(bsz, di).astype(u.dtype), z, p["norm_w"],
                        cfg.norm_eps)
    return qdot(y, p["w_out"]), SSMCache(conv=new_conv, state=state)
