"""Deterministic synthetic LM data pipeline.

Sequences are produced by a counter-based hash (step, shard, position) so
any worker can materialize its shard without coordination — restart-safe and
elastic (re-sharding the data axis re-partitions the same global stream).
A light Markov structure (next token depends on previous token's hash) gives
models something learnable, so perplexity decreases under training and
quantization deltas are measurable (benchmarks Tables 1/6/7).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _hash(x: np.ndarray) -> np.ndarray:
    x = (x ^ 61) ^ (x >> 16)
    x = (x + (x << 3)) & 0xFFFFFFFF
    x = x ^ (x >> 4)
    x = (x * 0x27D4EB2D) & 0xFFFFFFFF
    return x ^ (x >> 15)


def synthetic_tokens(*, batch: int, seq: int, vocab: int, step: int,
                     seed: int = 0, shard: int = 0,
                     num_shards: int = 1) -> np.ndarray:
    """(batch, seq+1) int32 tokens for LM training (inputs + shifted labels).

    Markov-ish: token_{t+1} = hash(token_t * K + position-salt) % vocab with
    a narrow candidate set per previous token, making the stream learnable.
    """
    assert batch % num_shards == 0
    local = batch // num_shards
    rows = np.arange(local, dtype=np.uint64) + shard * local \
        + np.uint64(step) * np.uint64(batch)
    base = _hash((rows * 2654435761 + seed) & 0xFFFFFFFF)
    toks = np.empty((local, seq + 1), np.int64)
    toks[:, 0] = base % vocab
    state = base.copy()
    branch_bits = 2  # 4 possible successors per token -> learnable
    for t in range(1, seq + 1):
        state = _hash((state + t) & 0xFFFFFFFF)
        succ = _hash((toks[:, t - 1].astype(np.uint64) * 31 + seed)
                     & 0xFFFFFFFF)
        toks[:, t] = (succ + (state & ((1 << branch_bits) - 1))) % vocab
    return toks.astype(np.int32)


def synthetic_batch(cfg, *, batch: int, seq: int, step: int, seed: int = 0,
                    shard: int = 0, num_shards: int = 1) -> dict:
    toks = synthetic_tokens(batch=batch, seq=seq, vocab=cfg.vocab_size,
                            step=step, seed=seed, shard=shard,
                            num_shards=num_shards)
    out = {"tokens": jnp.asarray(toks[:, :-1]),
           "labels": jnp.asarray(toks[:, 1:])}
    if cfg.family == "encdec":
        rng = np.random.default_rng(seed * 1_000_003 + step)
        local = batch // num_shards
        out["frames"] = jnp.asarray(
            rng.standard_normal((local, cfg.encoder_seq, cfg.d_model),
                                np.float32).astype(np.float32),
            dtype=jnp.bfloat16)
    return out


class DataLoader:
    """Shard-aware stepwise loader over the deterministic stream."""

    def __init__(self, cfg, *, global_batch: int, seq: int, seed: int = 0,
                 shard: int = 0, num_shards: int = 1, start_step: int = 0):
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq = seq
        self.seed = seed
        self.shard = shard
        self.num_shards = num_shards
        self.step = start_step

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        b = synthetic_batch(self.cfg, batch=self.global_batch, seq=self.seq,
                            step=self.step, seed=self.seed, shard=self.shard,
                            num_shards=self.num_shards)
        self.step += 1
        return b

    def state(self) -> dict:
        """Checkpointable position — restart resumes the exact stream."""
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict):
        self.step = int(state["step"])
        self.seed = int(state["seed"])
