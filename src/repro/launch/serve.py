"""Serving launcher: train-or-load a model, EWQ/FastEWQ-quantize, serve.

Usage:
  python -m repro.launch.serve --arch yi-9b --smoke --variant 4bit/8bit
  python -m repro.launch.serve --arch llama3.2-3b --smoke --fast \
      --prompt-len 16 --max-new 16

Request-stream simulation (continuous batching — new requests are admitted
into freed slots between decode chunks):
  python -m repro.launch.serve --arch llama3.2-3b --smoke \
      --num-requests 16 --arrival-rate 0.5 --num-slots 4 --chunk 8

Compiled-plan artifacts (compile once, serve many — docs/DESIGN.md §8):
  # first run: train, analyze, compile, persist the quantized checkpoint
  python -m repro.launch.serve --arch zamba2-2.7b --smoke \
      --variant 4bit/8bit --plan-artifact /tmp/zamba_plan
  # later runs boot from the artifact: no weight load, no entropy analysis
  python -m repro.launch.serve --arch zamba2-2.7b --smoke \
      --plan-artifact /tmp/zamba_plan

Paged KV pool with COW prefix sharing (docs/DESIGN.md §13): ``--paged``
serves K/V from a fixed pool of quantized pages instead of contiguous
per-slot reservations; ``--shared-prefix-len N`` gives every simulated
request a common system prefix so the prefix cache gets hits, and
``--check-paged-parity`` asserts token-identical greedy output vs the
dense engine:
  python -m repro.launch.serve --arch llama3.2-3b --smoke \
      --num-requests 8 --paged --page-size 8 --shared-prefix-len 8 \
      --check-paged-parity

Self-speculative decoding (docs/DESIGN.md §11): ``--spec-k 4`` serves with
draft-propose/verify rounds — the entropy-ordered all-int4 draft shares
payloads with the target; ``--check-greedy-parity`` additionally runs the
non-spec engine on the same requests and asserts token-identical greedy
output (the CI anchor).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.configs.registry import ARCHS, get_config
from repro.serving.engine import ServeEngine
from repro.serving.quantized import plan_for_variant
from repro.serving.scheduler import synthetic_stream
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--variant", default="8bit-mixed",
                    choices=["raw", "4bit", "8bit", "8bit-mixed",
                             "4bit/8bit"])
    ap.add_argument("--fast", action="store_true",
                    help="FastEWQ metadata plan (no weight analysis)")
    ap.add_argument("--kv-precision", default=None,
                    choices=["bf16", "int8", "int4", "auto"],
                    help="KV-cache precision: int8/int4 quantize every "
                         "layer's cache; auto derives per-layer precision "
                         "from the plan's entropy decisions "
                         "(docs/DESIGN.md §10). Default: bf16, or the "
                         "policy stamped into --plan-artifact; pass bf16 "
                         "explicitly to override a quantized artifact")
    ap.add_argument("--train-steps", type=int, default=30,
                    help="brief training so weights are non-degenerate")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--plan-artifact", default=None,
                    help="compiled-plan artifact dir: boot from it when it "
                         "exists, else compile + persist into it")
    # request-stream simulation (continuous batching)
    ap.add_argument("--num-requests", type=int, default=0,
                    help="simulate a stream of N requests (0: single batch)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="requests per decode step (0: all arrive at once)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps per jitted chunk")
    ap.add_argument("--num-slots", type=int, default=4,
                    help="concurrent decode slots")
    # self-speculative decoding (docs/DESIGN.md §11)
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft tokens per round "
                         "(0 disables; the all-int4 draft is derived from "
                         "the plan and shares payloads with the target)")
    ap.add_argument("--spec-draft", default="model",
                    choices=("model", "ngram"),
                    help="with --spec-k: 'model' drafts with the int4 "
                         "self-draft; 'ngram' proposes by prompt lookup "
                         "(no draft model — a round costs ~one fused "
                         "multi-query verify step)")
    ap.add_argument("--check-greedy-parity", action="store_true",
                    help="with --spec-k: also run the non-spec engine on "
                         "the same requests and assert token-identical "
                         "greedy output")
    # paged KV pool + prefix sharing (docs/DESIGN.md §13)
    ap.add_argument("--paged", action="store_true",
                    help="serve K/V from a paged pool with copy-on-write "
                         "prefix sharing instead of contiguous per-slot "
                         "reservations")
    ap.add_argument("--page-size", type=int, default=64,
                    help="tokens per KV page (with --paged)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="physical pages in the pool (0: equal-memory "
                         "default, num_slots * ceil(max_seq/page_size))")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="with --paged: disable the COW prefix cache")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="overwrite the first N prompt tokens of every "
                         "simulated request with a common system prefix "
                         "(exercises prefix sharing)")
    ap.add_argument("--check-paged-parity", action="store_true",
                    help="with --paged: also run the dense (contiguous) "
                         "engine on the same requests and assert "
                         "token-identical greedy output")
    # mesh-parallel serving (docs/DESIGN.md §9)
    ap.add_argument("--mesh", default=None,
                    help="comma-separated mesh axis names (e.g. data,model): "
                         "shard weights/caches and serve mesh-parallel")
    ap.add_argument("--mesh-shape", default=None,
                    help="comma-separated per-axis device counts (e.g. 1,8); "
                         "default puts every device on the last axis")
    # chunked prefill + SLO scheduling + DP replicas (docs/DESIGN.md §14)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="interleave prompt prefill in N-token chunks "
                         "between decode chunks (Sarathi-style) instead of "
                         "one monolithic prefill per admission (0: off)")
    ap.add_argument("--poisson", action="store_true",
                    help="draw seeded exponential inter-arrival gaps with "
                         "mean 1/--arrival-rate (open-loop load) instead "
                         "of fixed spacing")
    ap.add_argument("--priorities", default=None,
                    help="comma-separated priority cycle over the stream "
                         "(0 = most urgent), e.g. 0,1,1,1 for 25%% "
                         "interactive traffic")
    ap.add_argument("--ttft-target-ms", type=float, default=0.0,
                    help="SLO: time-to-first-token target; queued requests "
                         "past it bypass the admission gate (0: unset)")
    ap.add_argument("--tpot-target-ms", type=float, default=0.0,
                    help="SLO: per-output-token target; admissions are "
                         "deferred while the rolling decode-chunk latency "
                         "exceeds it (0: unset)")
    ap.add_argument("--preempt", action="store_true",
                    help="allow a strictly-higher-priority waiter to evict "
                         "the lowest-priority decoding slot (restart-style; "
                         "pages release, the victim requeues)")
    ap.add_argument("--queue-timeout-steps", type=int, default=0,
                    help="drop requests still QUEUED after N decode steps "
                         "(finish_reason='timeout'; 0: never)")
    ap.add_argument("--deadline-steps", type=int, default=0,
                    help="abort requests (queued or running) N decode steps "
                         "after arrival (finish_reason='deadline'; 0: never)")
    ap.add_argument("--dp", action="store_true",
                    help="serve DP x TP: split the mesh's data axis into "
                         "replicas, one engine each, and route the request "
                         "stream load-aware across them")
    ap.add_argument("--check-dp-parity", action="store_true",
                    help="with --dp: also serve on the single full-mesh "
                         "engine and assert token-identical greedy output")
    # fault tolerance + graceful degradation (docs/DESIGN.md §15)
    ap.add_argument("--chaos", default=None,
                    help="comma-separated fault-injection shorthands "
                         "(serving/chaos.py): replica_fault, "
                         "replica_transient, oom, stall, artifact — "
                         "deterministic under --chaos-seed")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the chaos injector's fault schedule")
    ap.add_argument("--degrade-policy", default="off",
                    choices=["off", "ewq"],
                    help="graceful degradation under pool pressure: 'ewq' "
                         "spills KV precision down the entropy-ordered "
                         "tier ladder (FastEWQ/plan-derived) instead of "
                         "rejecting work, promoting back when headroom "
                         "returns (requires --paged)")
    ap.add_argument("--watchdog-ms", type=float, default=0.0,
                    help="per-replica dispatch->harvest deadline; overruns "
                         "surface as watchdog_trips (0: off)")
    ap.add_argument("--check-chaos-parity", action="store_true",
                    help="with --chaos: serve fault-free FIRST, then the "
                         "chaos run, and assert token-identical greedy "
                         "output (every request completes despite the "
                         "injected faults)")
    # serving telemetry (docs/DESIGN.md §16)
    ap.add_argument("--trace-out", default=None,
                    help="write per-request/engine span tracing for the "
                         "measured serve as Chrome trace_event JSON "
                         "(load in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the serve metrics registry as Prometheus "
                         "text exposition (plus a stable .json snapshot "
                         "next to it)")
    ap.add_argument("--profile-steps", default=None,
                    help="A:B — arm a jax.profiler capture window over "
                         "decode steps [A, B) and per-chunk device-time "
                         "fences (device vs host-gap attribution)")
    ap.add_argument("--profile-dir", default="/tmp/repro-profile",
                    help="output dir for --profile-steps traces")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import parse_mesh
        mesh = parse_mesh(args.mesh, args.mesh_shape)
        print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")
    elif args.mesh_shape:
        raise SystemExit("--mesh-shape requires --mesh")

    spec = None
    if args.spec_k > 0:
        from repro.serving.spec import SpecConfig
        spec = SpecConfig(k=args.spec_k, draft_source=args.spec_draft)
    elif args.check_greedy_parity:
        raise SystemExit("--check-greedy-parity requires --spec-k")

    paged = None
    if args.paged:
        from repro.serving.pool import PagedConfig
        paged = PagedConfig(page_size=args.page_size,
                            pool_pages=args.pool_pages or None,
                            prefix_sharing=not args.no_prefix_sharing)
    elif args.check_paged_parity:
        raise SystemExit("--check-paged-parity requires --paged")

    slo = None
    if args.ttft_target_ms or args.tpot_target_ms or args.preempt:
        from repro.serving.scheduler import SLOConfig
        slo = SLOConfig(
            ttft_target_s=(args.ttft_target_ms / 1e3
                           if args.ttft_target_ms else None),
            tpot_target_s=(args.tpot_target_ms / 1e3
                           if args.tpot_target_ms else None),
            preempt=args.preempt)
    if args.poisson and not args.arrival_rate:
        raise SystemExit("--poisson requires --arrival-rate > 0")
    if args.dp and not args.num_requests:
        raise SystemExit("--dp serves a request stream; set --num-requests")
    if args.dp and not args.mesh:
        raise SystemExit("--dp requires --mesh with a data axis >= 2 "
                         "(e.g. --mesh data,model --mesh-shape 2,4)")
    if args.check_dp_parity and not args.dp:
        raise SystemExit("--check-dp-parity requires --dp")
    if args.check_chaos_parity and not args.chaos:
        raise SystemExit("--check-chaos-parity requires --chaos")
    if args.chaos and not args.num_requests:
        raise SystemExit("--chaos injects into the serve loop; set "
                         "--num-requests")
    if ((args.trace_out or args.metrics_out or args.profile_steps)
            and not args.num_requests):
        raise SystemExit("--trace-out/--metrics-out/--profile-steps "
                         "instrument the serve loop; set --num-requests")

    degrade = None
    if args.degrade_policy != "off":
        if paged is None:
            raise SystemExit("--degrade-policy trades KV precision for pool "
                             "pages; it requires --paged")
        from repro.serving.session import DegradeConfig
        degrade = DegradeConfig(policy=args.degrade_policy)
    failover = None
    if args.dp and (args.chaos or args.watchdog_ms):
        from repro.serving.replica import FailoverConfig
        failover = FailoverConfig(watchdog_s=(args.watchdog_ms / 1e3
                                              if args.watchdog_ms else None))

    requests = None
    max_seq = args.prompt_len + args.max_new
    if args.num_requests > 0:
        priorities = (tuple(int(p) for p in args.priorities.split(","))
                      if args.priorities else None)
        requests = synthetic_stream(
            args.num_requests, vocab_size=cfg.vocab_size,
            prompt_len=args.prompt_len, max_new_tokens=args.max_new,
            arrival_rate=args.arrival_rate, poisson=args.poisson,
            priorities=priorities)
        for r in requests:
            if args.queue_timeout_steps:
                r.queue_timeout_steps = args.queue_timeout_steps
            if args.deadline_steps:
                r.deadline_steps = args.deadline_steps
        if args.shared_prefix_len > 0:
            if args.shared_prefix_len >= args.prompt_len:
                raise SystemExit("--shared-prefix-len must be shorter than "
                                 "--prompt-len (at least one distinct token "
                                 "must remain per request)")
            shared = requests[0].prompt[:args.shared_prefix_len].copy()
            for r in requests:
                r.prompt[:args.shared_prefix_len] = shared
        max_seq = max(len(r.prompt) + r.max_new_tokens for r in requests)
    elif args.shared_prefix_len > 0:
        raise SystemExit("--shared-prefix-len requires --num-requests")
    if spec is not None:
        max_seq += spec.k   # verify-window headroom (engine asserts)

    from repro.checkpoint import ckpt
    if args.plan_artifact and ckpt.is_artifact(args.plan_artifact):
        # cold boot: quantized weights straight from the compiled artifact —
        # no training/raw-weight load, no entropy analysis, no quantization
        from repro.models.model import build
        model = build(cfg)
        t0 = time.perf_counter()
        # None = not specified -> the artifact's stamped kv policy governs;
        # an explicit value (including bf16) overrides it
        kv_kw = ({} if args.kv_precision is None
                 else {"kv_precision": args.kv_precision})

        def make_engine(m):
            return ServeEngine.from_artifact(model, args.plan_artifact,
                                             max_seq=max_seq, mesh=m,
                                             spec=spec, paged=paged, **kv_kw)

        engine = make_engine(mesh)
        plan = engine.plan
        print(f"booted from artifact {args.plan_artifact} in "
              f"{time.perf_counter() - t0:.2f}s"
              + (" (weights landed sharded)" if mesh is not None else ""))
    else:
        run = RunConfig(steps=args.train_steps, learning_rate=1e-3,
                        warmup_steps=3, remat=False)
        result = train(cfg, run, batch=args.batch, seq=args.prompt_len * 2)
        model, params = result["model"], result["params"]
        plan = plan_for_variant(model, params, args.variant, fast=args.fast)
        kv_precision = args.kv_precision or "bf16"
        if kv_precision == "auto" and plan is None:
            raise SystemExit("--kv-precision auto derives per-layer cache "
                             "precision from the weight plan; it cannot be "
                             "combined with --variant raw")
        if plan is not None:
            compiled = model.compile_plan(params, plan,
                                          kv_precision=kv_precision)

            def make_engine(m):
                e = ServeEngine(model, compiled.params, max_seq=max_seq,
                                mesh=m,
                                kv_precision=compiled.kv_plan or "bf16",
                                spec=spec, paged=paged)
                e.plan = plan
                return e

            engine = make_engine(mesh)
            if args.plan_artifact:
                from repro.quant.compiler import save_artifact
                if spec is not None and spec.draft_source == "model":
                    # stamp the draft derivation into the manifest so cold
                    # boots re-derive the identical draft
                    compiled.draft = engine._ensure_draft().to_manifest()
                path = save_artifact(args.plan_artifact, compiled, mesh=mesh)
                print(f"saved compiled plan artifact to {path}")
        else:
            def make_engine(m):
                return ServeEngine(model, params, max_seq=max_seq, mesh=m,
                                   kv_precision=kv_precision, spec=spec,
                                   paged=paged)

            engine = make_engine(mesh)

    raw_bits = 32.0 if cfg.dtype == "float32" else 16.0
    raw_bytes = cfg.param_count() * raw_bits / 8.0
    print(f"weights: {engine.weight_bytes()/2**20:.1f} MiB effective "
          f"(raw {raw_bytes/2**20:.1f} MiB)")
    if mesh is not None:
        print(f"per-device weight bytes: "
              f"{engine.weight_bytes_per_device()/2**20:.1f} MiB "
              f"on {mesh.size} devices")
    if plan:
        print(f"plan: {plan.counts()}")
    if engine.kv_plan is not None:
        kv_counts: dict = {}
        for p in engine.kv_plan.precisions:
            kv_counts[p] = kv_counts.get(p, 0) + 1
        print(f"kv cache: {engine.kv_bytes_per_slot()/2**20:.2f} MiB/slot "
              f"at max_seq={max_seq} ({kv_counts})")

    if spec is not None:
        if spec.draft_source == "ngram":
            print(f"spec decode: k={spec.k}, ngram prompt-lookup draft "
                  f"(no draft model)")
        else:
            print(f"spec decode: k={spec.k}, draft overhead "
                  f"{engine.draft_overhead_bytes()/2**20:.2f} MiB "
                  f"({engine._ensure_draft().shared_blocks} blocks shared, "
                  f"{engine._ensure_draft().requantized_blocks} "
                  f"re-quantized)")

    if requests is not None:
        serve_kw = dict(num_slots=args.num_slots, chunk=args.chunk,
                        prefill_chunk=args.prefill_chunk or None, slo=slo,
                        degrade=degrade)
        rstats = None
        replica = None
        if args.dp:
            from repro.launch.mesh import split_data_replicas
            from repro.serving.replica import ReplicaServe
            subs = split_data_replicas(mesh)
            if len(subs) < 2:
                raise SystemExit(f"--dp found {len(subs)} replica(s) in "
                                 f"mesh {dict(mesh.shape)}; need a data "
                                 "axis of size >= 2")
            replica = ReplicaServe([make_engine(m) for m in subs])
        chaos_ref = None
        if args.check_chaos_parity:
            # fault-free baseline FIRST, at nominal precision (no degrade):
            # each serve builds fresh sessions and pool pages, so the chaos
            # run below starts from identical state
            base_kw = dict(serve_kw, degrade=None)
            if replica is not None:
                chaos_ref, _ = replica.serve(requests, **base_kw)
            else:
                chaos_ref, _ = engine.serve(requests, **base_kw)
        injector = None
        if args.chaos:
            from repro.serving import chaos as chaos_mod
            injector = chaos_mod.ChaosInjector(
                chaos_mod.FaultConfig.parse(args.chaos,
                                            seed=args.chaos_seed))
            chaos_mod.install(injector)
            print(f"chaos: injecting {args.chaos} (seed {args.chaos_seed})")
        # serving telemetry (docs/DESIGN.md §16): sinks install AFTER any
        # parity baseline so only the measured serve is traced, and
        # uninstall before the parity re-serves below
        tracer = metrics_reg = prof = None
        obs_on = bool(args.trace_out or args.metrics_out
                      or args.profile_steps)
        if obs_on:
            from repro import obs
            if args.trace_out:
                tracer = obs.Tracer()
            if args.metrics_out:
                metrics_reg = obs.MetricsRegistry()
            if args.profile_steps:
                prof = obs.ProfileHooks.parse(args.profile_steps,
                                              trace_dir=args.profile_dir)
            obs.install(tracer, metrics_reg, prof)
        t0 = time.perf_counter()
        try:
            if replica is not None:
                outputs, rstats = replica.serve(requests,
                                                failover=failover,
                                                **serve_kw)
                stats = rstats.aggregate
            else:
                outputs, stats = engine.serve(requests, **serve_kw)
        finally:
            if injector is not None:
                chaos_mod.install(None)
            if obs_on:
                if prof is not None:
                    prof.stop()
                obs.install(None, None, None)
        dt = time.perf_counter() - t0
        from repro.obs import render as obs_render
        for line in obs_render.serve_report(
                stats, wall_s=dt, num_requests=len(outputs),
                chunk=args.chunk,
                queueing=bool(args.arrival_rate or slo is not None),
                prefill_chunk=args.prefill_chunk,
                replicas=(dict(replicas=rstats.replicas,
                               mesh_shape=dict(
                                   replica.engines[0].mesh.shape),
                               assignments=rstats.assignments,
                               occupancy=rstats.occupancy_per_replica)
                          if rstats is not None else None),
                fault=bool(args.chaos or degrade is not None
                           or args.watchdog_ms),
                chaos_fired=(injector.log if injector is not None
                             else None),
                spec=spec is not None,
                paged=(dict(num_slots=args.num_slots,
                            kv_bytes_per_slot=engine.kv_bytes_per_slot(),
                            max_seq=max_seq)
                       if args.paged else None)):
            print(line)
        if tracer is not None:
            tracer.write(args.trace_out)
            print(f"trace: {len(tracer.events)} events -> {args.trace_out} "
                  f"({len(tracer.open_spans())} open spans)")
        if metrics_reg is not None:
            metrics_reg.write_prometheus(args.metrics_out)
            metrics_reg.write_json(args.metrics_out + ".json")
            print(f"metrics: {len(metrics_reg.names())} families -> "
                  f"{args.metrics_out} (+ .json snapshot)")
        if prof is not None and prof.windows:
            print(f"profiler: {prof.windows} capture window(s) -> "
                  f"{prof.trace_dir}")
        if args.check_chaos_parity:
            import numpy as np
            agree = (len(chaos_ref) == len(outputs)
                     and all(a.rid == b.rid
                             and np.array_equal(a.tokens, b.tokens)
                             for a, b in zip(chaos_ref, outputs)))
            print(f"greedy-agree vs fault-free run: {float(agree):.1f} "
                  f"({len(outputs)}/{len(chaos_ref)} requests completed)")
            if not agree:
                raise SystemExit("chaos-run greedy output DIVERGED from "
                                 "the fault-free run (or requests were "
                                 "lost)")
        if args.check_dp_parity:
            import numpy as np
            ref_out, _ = engine.serve(requests, **serve_kw)
            agree = (len(ref_out) == len(outputs)
                     and all(a.rid == b.rid
                             and np.array_equal(a.tokens, b.tokens)
                             for a, b in zip(ref_out, outputs)))
            print(f"greedy-agree vs single full-mesh engine: "
                  f"{float(agree):.1f}")
            if not agree:
                raise SystemExit("DP x TP greedy output DIVERGED from the "
                                 "single full-mesh engine")
        if args.check_paged_parity:
            import numpy as np
            base = ServeEngine(model, engine.params, max_seq=max_seq,
                               kv_precision=engine.kv_plan or "bf16",
                               spec=spec)
            base.plan = engine.plan
            base_outputs, _ = base.serve(requests,
                                         num_slots=args.num_slots,
                                         chunk=args.chunk)
            agree = all(np.array_equal(a.tokens, b.tokens)
                        for a, b in zip(base_outputs, outputs))
            print(f"greedy-agree vs dense engine: {float(agree):.1f}")
            if not agree:
                raise SystemExit("paged greedy output DIVERGED from the "
                                 "dense (contiguous) engine")
        if args.check_greedy_parity:
            import numpy as np
            base = ServeEngine(model, engine.params, max_seq=max_seq,
                               kv_precision=engine.kv_plan or "bf16")
            base.plan = engine.plan
            base_outputs, _ = base.serve(requests,
                                         num_slots=args.num_slots,
                                         chunk=args.chunk)
            agree = all(np.array_equal(a.tokens, b.tokens)
                        for a, b in zip(base_outputs, outputs))
            print(f"greedy-agree vs non-spec engine: {float(agree):.1f}")
            if not agree:
                raise SystemExit("speculative greedy output DIVERGED from "
                                 "the non-spec engine")
        print("sample:", outputs[0].generated.tolist())
        return

    prompts = jax.random.randint(jax.random.PRNGKey(7),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    out = engine.generate(prompts, args.max_new, chunk=args.chunk)
    print(f"generated {out.tokens.shape[1] - args.prompt_len} tokens/seq; "
          f"mean logprob {float(out.logprobs.mean()):.3f}")
    if args.check_greedy_parity:
        import numpy as np
        base = ServeEngine(model, engine.params, max_seq=max_seq,
                           kv_precision=engine.kv_plan or "bf16")
        base.plan = engine.plan
        ref = base.generate(prompts, args.max_new, chunk=args.chunk)
        agree = bool(np.array_equal(np.asarray(ref.tokens),
                                    np.asarray(out.tokens)))
        print(f"greedy-agree vs non-spec engine: {float(agree):.1f}")
        if not agree:
            raise SystemExit("speculative greedy output DIVERGED from the "
                             "non-spec engine")
    if args.check_paged_parity:
        import numpy as np
        base = ServeEngine(model, engine.params, max_seq=max_seq,
                           kv_precision=engine.kv_plan or "bf16", spec=spec)
        base.plan = engine.plan
        ref = base.generate(prompts, args.max_new, chunk=args.chunk)
        agree = bool(np.array_equal(np.asarray(ref.tokens),
                                    np.asarray(out.tokens)))
        print(f"greedy-agree vs dense engine: {float(agree):.1f}")
        if not agree:
            raise SystemExit("paged greedy output DIVERGED from the dense "
                             "(contiguous) engine")
    print("sample:", out.tokens[0, -args.max_new:].tolist())


if __name__ == "__main__":
    main()
