"""Serving launcher: train-or-load a model, EWQ/FastEWQ-quantize, serve.

Usage:
  python -m repro.launch.serve --arch yi-9b --smoke --variant 4bit/8bit
  python -m repro.launch.serve --arch llama3.2-3b --smoke --fast \
      --prompt-len 16 --max-new 16

Request-stream simulation (continuous batching — new requests are admitted
into freed slots between decode chunks):
  python -m repro.launch.serve --arch llama3.2-3b --smoke \
      --num-requests 16 --arrival-rate 0.5 --num-slots 4 --chunk 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.configs.registry import ARCHS, get_config
from repro.serving.engine import ServeEngine
from repro.serving.quantized import plan_for_variant
from repro.serving.scheduler import synthetic_stream
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--variant", default="8bit-mixed",
                    choices=["raw", "4bit", "8bit", "8bit-mixed",
                             "4bit/8bit"])
    ap.add_argument("--fast", action="store_true",
                    help="FastEWQ metadata plan (no weight analysis)")
    ap.add_argument("--train-steps", type=int, default=30,
                    help="brief training so weights are non-degenerate")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    # request-stream simulation (continuous batching)
    ap.add_argument("--num-requests", type=int, default=0,
                    help="simulate a stream of N requests (0: single batch)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="requests per decode step (0: all arrive at once)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps per jitted chunk")
    ap.add_argument("--num-slots", type=int, default=4,
                    help="concurrent decode slots")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    run = RunConfig(steps=args.train_steps, learning_rate=1e-3,
                    warmup_steps=3, remat=False)
    result = train(cfg, run, batch=args.batch, seq=args.prompt_len * 2)
    model, params = result["model"], result["params"]

    requests = None
    max_seq = args.prompt_len + args.max_new
    if args.num_requests > 0:
        requests = synthetic_stream(
            args.num_requests, vocab_size=cfg.vocab_size,
            prompt_len=args.prompt_len, max_new_tokens=args.max_new,
            arrival_rate=args.arrival_rate)
        max_seq = max(len(r.prompt) + r.max_new_tokens for r in requests)

    plan = plan_for_variant(model, params, args.variant, fast=args.fast)
    engine = ServeEngine(model, params, plan=plan, max_seq=max_seq)
    raw_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    print(f"weights: {engine.weight_bytes()/2**20:.1f} MiB effective "
          f"(raw {raw_bytes/2**20:.1f} MiB)")
    if plan:
        print(f"plan: {plan.counts()}")

    if requests is not None:
        t0 = time.perf_counter()
        outputs, stats = engine.serve(requests, num_slots=args.num_slots,
                                      chunk=args.chunk)
        dt = time.perf_counter() - t0
        print(f"served {len(outputs)} requests in {dt:.1f}s "
              f"({stats.generated_tokens/dt:.1f} tok/s): "
              f"{stats.num_chunks} chunks x {args.chunk} steps, "
              f"occupancy {stats.occupancy:.1%}, "
              f"{stats.admissions} mid-run admissions")
        print("sample:", outputs[0].generated.tolist())
        return

    prompts = jax.random.randint(jax.random.PRNGKey(7),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    out = engine.generate(prompts, args.max_new, chunk=args.chunk)
    print(f"generated {out.tokens.shape[1] - args.prompt_len} tokens/seq; "
          f"mean logprob {float(out.logprobs.mean()):.3f}")
    print("sample:", out.tokens[0, -args.max_new:].tolist())


if __name__ == "__main__":
    main()
