"""Serving launcher: train-or-load a model, EWQ/FastEWQ-quantize, serve.

Usage:
  python -m repro.launch.serve --arch yi-9b --smoke --variant 4bit/8bit
  python -m repro.launch.serve --arch llama3.2-3b --smoke --fast \
      --prompt-len 16 --max-new 16
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.configs.registry import ARCHS, get_config
from repro.core.planner import plan_model
from repro.models.model import build
from repro.serving.engine import ServeEngine
from repro.serving.quantized import fastewq_metadata_plan
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--variant", default="8bit-mixed",
                    choices=["raw", "4bit", "8bit", "8bit-mixed",
                             "4bit/8bit"])
    ap.add_argument("--fast", action="store_true",
                    help="FastEWQ metadata plan (no weight analysis)")
    ap.add_argument("--train-steps", type=int, default=30,
                    help="brief training so weights are non-degenerate")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    run = RunConfig(steps=args.train_steps, learning_rate=1e-3,
                    warmup_steps=3, remat=False)
    result = train(cfg, run, batch=args.batch, seq=args.prompt_len * 2)
    model, params = result["model"], result["params"]

    if args.variant == "raw":
        plan = None
    elif args.fast:
        plan = fastewq_metadata_plan(cfg, args.variant)
    else:
        plan = plan_model(model, params, variant=args.variant)
    engine = ServeEngine(model, params, plan=plan,
                         max_seq=args.prompt_len + args.max_new)
    raw_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    print(f"weights: {engine.weight_bytes()/2**20:.1f} MiB effective "
          f"(raw {raw_bytes/2**20:.1f} MiB)")
    if plan:
        print(f"plan: {plan.counts()}")

    prompts = jax.random.randint(jax.random.PRNGKey(7),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    out = engine.generate(prompts, args.max_new)
    print(f"generated {out.tokens.shape[1] - args.prompt_len} tokens/seq; "
          f"mean logprob {float(out.logprobs.mean()):.3f}")
    print("sample:", out.tokens[0, -args.max_new:].tolist())


if __name__ == "__main__":
    main()
