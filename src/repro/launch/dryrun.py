import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Per cell, two kinds of artifacts are produced:

1. PROOF + MEMORY — the full-depth step (layers under lax.scan) is lowered
   and compiled on the production mesh; ``memory_analysis()`` gives
   per-device argument/output/temp bytes (proves HBM fit) and the compile
   itself proves the sharding config is coherent.

2. COST — XLA's HloCostAnalysis counts a while-loop body ONCE regardless of
   trip count, so the scan hides depth. The dry-run therefore lowers
   reduced-depth variants with every scan fully unrolled (ctx.cost_mode)
   and solves the affine model  cost = base + sum_i depth_i * per_layer_i
   (one term per independent layer stack) to extrapolate per-device FLOPs /
   HBM bytes / collective bytes to full depth. Those feed the §Roofline
   terms (launch/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod|--both-meshes]
  python -m repro.launch.dryrun --arch yi-9b --shape decode_32k \
      --quant 8bit-mixed --tag quant_decode
"""

import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs.base import SHAPES, RunConfig
from repro.configs.registry import ARCHS, get_config, shape_runnable
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (collective_bytes_from_hlo, model_flops,
                                   roofline_terms)
from repro.launch.steps import step_for_shape
from repro.models.model import build
from repro.sharding.ctx import activation_sharding, cost_mode
from repro.sharding.specs import (batch_specs, cache_specs, opt_state_specs,
                                  param_specs, to_shardings)

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / \
    "results" / "dryrun.jsonl"


def _serving_tp_only(model, mesh) -> bool:
    """Serving keeps weights TP-sharded (no per-step FSDP gathers) when the
    per-device TP shard fits comfortably in HBM."""
    tp = mesh.shape["model"]
    return model.cfg.param_count() * 2 / tp <= 8e9


def input_shardings_for(model, shape, inputs, mesh):
    if shape.kind == "train":
        params, opt_state, batch = inputs
        pspecs = param_specs(params, mesh)
        return (pspecs, opt_state_specs(opt_state, pspecs, mesh),
                batch_specs(batch, mesh))
    serving = _serving_tp_only(model, mesh)
    if shape.kind == "prefill":
        params, batch = inputs
        return (param_specs(params, mesh, serving=serving),
                batch_specs(batch, mesh))
    params, cache, tokens = inputs
    return (param_specs(params, mesh, serving=serving),
            cache_specs(cache, mesh),
            batch_specs({"t": tokens}, mesh)["t"])


def _build_step(cfg, shape, run_cfg, quant, plan=None):
    model = build(cfg)
    if quant and shape.kind == "decode":
        from repro.serving.quantized import quantize_decode_inputs
        fn, inputs = quantize_decode_inputs(model, shape, quant, plan=plan)
    else:
        fn, inputs = step_for_shape(model, shape, run_cfg)
    return model, fn, inputs


def _lower_compile(cfg, shape, mesh, run_cfg, quant, *, cost: bool,
                   plan=None):
    import contextlib
    model, fn, inputs = _build_step(cfg, shape, run_cfg, quant, plan)
    shardings = to_shardings(
        input_shardings_for(model, shape, inputs, mesh), mesh)
    # Buffer donation: train donates (params, opt_state); decode donates the
    # cache — without aliasing, XLA materializes a full copy of the updated
    # state per step (a 2x bytes tax the baseline sweep paid).
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
    cm = cost_mode() if cost else contextlib.nullcontext()
    with mesh, activation_sharding(mesh), cm:
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*inputs)
        compiled = lowered.compile()
    return compiled


def depth_variants(cfg, quant: str | None):
    """[(cfg_variant, depths_dict, plan)], full_depths — affine stacks."""
    r = dataclasses.replace
    if quant:  # two stacks: raw layers vs quantized layers (dense/ssm)
        from repro.serving.quantized import explicit_plan

        def ep(cfg_v, precs):
            # explicit_plan covers encoder+decoder stacks for enc-dec; the
            # affine raw/quant split applies to the decoder, encoder raw
            ne = cfg_v.num_encoder_layers or 0
            return explicit_plan(cfg_v, ["raw"] * ne + precs, quant)

        fulls = _quant_counts(cfg, quant)
        return ([
            (r(cfg, num_layers=2), {"raw": 1, "quant": 1},
             ep(r(cfg, num_layers=2), ["raw", "int8"])),
            (r(cfg, num_layers=3), {"raw": 1, "quant": 2},
             ep(r(cfg, num_layers=3), ["raw", "int8", "int8"])),
            (r(cfg, num_layers=3), {"raw": 2, "quant": 1},
             ep(r(cfg, num_layers=3), ["raw", "raw", "int8"])),
        ], fulls)
    if cfg.family == "encdec":
        return ([
            (r(cfg, num_encoder_layers=1, num_layers=1),
             {"enc": 1, "dec": 1}, None),
            (r(cfg, num_encoder_layers=2, num_layers=1),
             {"enc": 2, "dec": 1}, None),
            (r(cfg, num_encoder_layers=1, num_layers=2),
             {"enc": 1, "dec": 2}, None),
        ], {"enc": cfg.num_encoder_layers, "dec": cfg.num_layers})
    if cfg.family == "hybrid":
        p = cfg.shared_attn_period
        return ([
            (r(cfg, num_layers=p), {"units": 1}, None),
            (r(cfg, num_layers=2 * p), {"units": 2}, None),
        ], {"units": cfg.num_layers // p})
    return ([
        (r(cfg, num_layers=1), {"layers": 1}, None),
        (r(cfg, num_layers=2), {"layers": 2}, None),
    ], {"layers": cfg.num_layers})


def _quant_counts(cfg, quant):
    from repro.serving.quantized import fastewq_metadata_plan
    plan = fastewq_metadata_plan(cfg, quant)
    qs = sum(1 for d in plan.decisions[1:1 + cfg.num_layers] if d.quantized)
    return {"raw": cfg.num_layers - qs, "quant": qs}


def solve_affine(measurements, full_depths):
    """measurements: [(depths_dict, value_dict)]; returns extrapolated dict."""
    stacks = sorted(full_depths)
    a = np.array([[1.0] + [float(d.get(s, 0)) for s in stacks]
                  for d, _ in measurements])
    keys = measurements[0][1].keys()
    out = {}
    full_vec = np.array([1.0] + [float(full_depths[s]) for s in stacks])
    for k in keys:
        y = np.array([float(v[k]) for _, v in measurements])
        coef, *_ = np.linalg.lstsq(a, y, rcond=None)
        out[k] = float(max(full_vec @ coef, 0.0))
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             quant: str | None = None, run_cfg: RunConfig | None = None,
             tag: str = "baseline", skip_full: bool = False, extra=None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_runnable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "tag": tag,
           "quant": quant}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    run_cfg = run_cfg or RunConfig(
        moment_dtype="bfloat16" if cfg.param_count() > 1e11 else "float32")
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        # ---- 1) full-depth proof + memory --------------------------------
        t0 = time.time()
        if not skip_full:
            compiled = _lower_compile(cfg, shape, mesh, run_cfg, quant,
                                      cost=False)
            mem = compiled.memory_analysis()
            rec.update(
                compile_s=round(time.time() - t0, 1),
                arg_bytes_per_dev=mem.argument_size_in_bytes,
                out_bytes_per_dev=mem.output_size_in_bytes,
                temp_bytes_per_dev=mem.temp_size_in_bytes,
                peak_bytes_per_dev=(mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes))
            del compiled

        # ---- 2) reduced-depth unrolled cost variants ----------------------
        variants, full_depths = depth_variants(cfg, quant)
        meas = []
        for cfg_v, depths, plan in variants:
            cv = _lower_compile(cfg_v, shape, mesh, run_cfg, quant,
                                cost=True, plan=plan)
            cost = cv.cost_analysis()
            coll = collective_bytes_from_hlo(cv.as_text())
            vals = {"flops": float(cost.get("flops", 0.0)),
                    "bytes": float(cost.get("bytes accessed", 0.0)),
                    "coll": coll["total"]}
            for op, b in coll["by_op"].items():
                vals[f"coll_{op}"] = b
            meas.append((depths, vals))
            del cv
        # union of keys (ops may differ across variants)
        all_keys = set().union(*[v.keys() for _, v in meas])
        meas = [(d, {k: v.get(k, 0.0) for k in all_keys}) for d, v in meas]
        solved = solve_affine(meas, full_depths)

        terms = roofline_terms(flops_dev=solved["flops"],
                               bytes_dev=solved["bytes"],
                               coll_dev=solved["coll"])
        mf = model_flops(cfg, shape)
        rec.update(
            status="ok", devices=mesh.size,
            cost_s=round(time.time() - t0, 1),
            hlo_flops_dev=solved["flops"], hlo_bytes_dev=solved["bytes"],
            collective_bytes_dev=solved["coll"],
            collectives={k[5:]: v for k, v in solved.items()
                         if k.startswith("coll_")},
            model_flops=mf,
            model_flops_dev=mf / mesh.size,
            useful_flop_frac=(mf / mesh.size / solved["flops"]
                              if solved["flops"] else 0.0),
            **terms)
        if extra:
            rec.update(extra)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def append_result(rec):
    RESULTS.parent.mkdir(parents=True, exist_ok=True)
    with open(RESULTS, "a") as f:
        f.write(json.dumps(rec) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-full", action="store_true",
                    help="cost variants only (fast perf iteration)")
    ap.add_argument("--quant", default=None,
                    help="EWQ variant for decode cells (e.g. 8bit-mixed)")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    cells = ([(a, s) for a in ARCHS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    if not args.all:
        assert args.arch and args.shape, "--arch/--shape or --all"

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for a, s in cells:
        for mp in meshes:
            rec = run_cell(a, s, multi_pod=mp, quant=args.quant, tag=args.tag,
                           skip_full=args.skip_full)
            append_result(rec)
            status = rec["status"]
            if status == "ok":
                peak = rec.get("peak_bytes_per_dev", 0) / 2 ** 30
                print(f"[{rec['mesh']}] {a} x {s}: OK "
                      f"compute={rec['t_compute_s']:.4f}s "
                      f"memory={rec['t_memory_s']:.4f}s "
                      f"collective={rec['t_collective_s']:.4f}s "
                      f"bound={rec['bound']} peak/dev={peak:.2f}GiB "
                      f"(full compile {rec.get('compile_s', '-')}s, "
                      f"cost {rec['cost_s']}s)", flush=True)
            elif status == "skipped":
                print(f"[{rec['mesh']}] {a} x {s}: SKIP "
                      f"({rec['reason'][:60]})", flush=True)
            else:
                failures += 1
                print(f"[{rec['mesh']}] {a} x {s}: ERROR {rec['error']}",
                      flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
