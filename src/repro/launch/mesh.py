"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — critical because smoke tests run with one
CPU device while the dry-run forces 512 virtual devices via XLA_FLAGS.
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n: int) -> dict:
    """axis_types=(Auto,)*n on jax versions that have it (>= 0.5)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / small-scale runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def parse_mesh(axes: str, shape: str | None = None):
    """CLI mesh spec -> Mesh (launch/serve.py ``--mesh``/``--mesh-shape``).

    ``axes`` is comma-separated axis names ("data,model"); ``shape`` is
    comma-separated sizes ("2,4"). When ``shape`` is omitted, all available
    devices go on the LAST axis (so ``--mesh data,model`` on 8 devices is a
    1x8 pure-TP serving mesh).
    """
    axis_names = tuple(a.strip() for a in axes.split(",") if a.strip())
    if not axis_names:
        raise ValueError(f"empty mesh axes spec {axes!r}")
    if shape:
        sizes = tuple(int(s) for s in shape.split(","))
        if len(sizes) != len(axis_names):
            raise ValueError(f"--mesh-shape {shape!r} has {len(sizes)} "
                             f"entries for {len(axis_names)} axes {axis_names}")
    else:
        sizes = (1,) * (len(axis_names) - 1) + (len(jax.devices()),)
    return make_mesh(sizes, axis_names)


def data_axis_names(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def split_data_replicas(mesh) -> list:
    """One serving submesh per index along the data axes — the DP x TP
    replica split (docs/DESIGN.md §14, serving/replica.py).

    A ``(data=R, model=T)`` mesh becomes R submeshes of shape
    ``(data=1, model=T)``: each keeps every axis NAME (so the TP-only
    serving specs resolve unchanged — a size-1 data axis shards nothing)
    but owns a disjoint 1/R slice of the devices. Weights placed per
    submesh are therefore replicated across replicas and TP-sharded
    within one. Meshes without a data axis (or with data=1) return
    ``[mesh]`` — plain single-replica serving.
    """
    import itertools

    import numpy as np

    names = mesh.axis_names
    axes = [names.index(a) for a in data_axis_names(mesh) if a in names]
    sizes = [mesh.devices.shape[a] for a in axes]
    if not axes or int(np.prod(sizes)) == 1:
        return [mesh]
    subs = []
    for idx in itertools.product(*(range(s) for s in sizes)):
        devs = mesh.devices
        for a, i in zip(axes, idx):
            devs = np.take(devs, [i], axis=a)
        subs.append(jax.sharding.Mesh(devs, names))
    return subs
