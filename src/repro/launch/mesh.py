"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — critical because smoke tests run with one
CPU device while the dry-run forces 512 virtual devices via XLA_FLAGS.
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n: int) -> dict:
    """axis_types=(Auto,)*n on jax versions that have it (>= 0.5)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / small-scale runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def data_axis_names(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
