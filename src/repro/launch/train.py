"""Production training launcher.

Single-host CPU runs execute directly; the same entry point drives a
production mesh when launched under multi-host JAX (jax.distributed) — the
mesh shape and shardings come from the same specs the dry-run proves.

Usage:
  python -m repro.launch.train --arch olmo-1b --smoke --steps 100
  python -m repro.launch.train --arch llama3.2-3b --smoke --steps 200 \
      --checkpoint-dir /tmp/ckpt --grad-compression int8_ef
"""

from __future__ import annotations

import argparse

from repro.configs.base import RunConfig
from repro.configs.registry import ARCHS, get_config
from repro.train.loop import evaluate, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "linear"])
    ap.add_argument("--moment-dtype", default="float32",
                    choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--grad-compression", default=None,
                    choices=[None, "int8_ef"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    # minicpm trains with WSD per its paper
    schedule = "wsd" if args.arch == "minicpm-2b" and \
        args.schedule == "cosine" else args.schedule
    run = RunConfig(steps=args.steps, learning_rate=args.lr,
                    schedule=schedule, moment_dtype=args.moment_dtype,
                    microbatch=args.microbatch,
                    checkpoint_dir=args.checkpoint_dir,
                    checkpoint_every=args.checkpoint_every,
                    grad_compression=args.grad_compression, seed=args.seed,
                    warmup_steps=max(args.steps // 20, 1), remat=False)
    result = train(cfg, run, batch=args.batch, seq=args.seq)
    ev = evaluate(result["model"], result["params"], batch=args.batch,
                  seq=args.seq)
    print(f"final train loss {result['final_loss']:.4f}; "
          f"eval loss {ev['loss']:.4f} ppl {ev['perplexity']:.2f}")


if __name__ == "__main__":
    main()
