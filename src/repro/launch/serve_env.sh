#!/usr/bin/env bash
# Perf-environment launcher for the serving CLI (docs/DESIGN.md §14).
#
# Benchmarks should reflect a tuned runtime, not the interpreter's
# defaults: this wrapper pins threads, preloads tcmalloc when available,
# sets the XLA flags that matter for serving latency, and then exec's
# `python -m repro.launch.serve` with every argument passed through.
#
#   ./serve_env.sh --arch llama3.2-3b --smoke --num-requests 32 \
#       --arrival-rate 0.5 --poisson --prefill-chunk 64
#
# Environment knobs (all overridable by exporting before the call):
#   REPRO_HOST_DEVICES   virtual CPU device count (DP/TP smoke on one
#                        host; maps to --xla_force_host_platform_device_count)
#   REPRO_THREADS        intra-op thread count (default: physical cores)
#   REPRO_XLA_FLAGS      extra XLA flags appended after the defaults
#   REPRO_PYTHON         interpreter (default: python3)
set -euo pipefail

PYTHON="${REPRO_PYTHON:-python3}"

# -- thread pinning ----------------------------------------------------------
# One intra-op pool sized to the physical cores (hyperthread siblings only
# add scheduler jitter to latency percentiles), and no nested BLAS pools
# fighting XLA for the same cores.
if [[ -z "${REPRO_THREADS:-}" ]]; then
  if command -v lscpu >/dev/null 2>&1; then
    REPRO_THREADS=$(lscpu -p=Core,Socket 2>/dev/null | grep -v '^#' \
                    | sort -u | wc -l)
  else
    REPRO_THREADS=$(getconf _NPROCESSORS_ONLN 2>/dev/null || echo 1)
  fi
fi
export OMP_NUM_THREADS="${REPRO_THREADS}"
export OPENBLAS_NUM_THREADS=1
export MKL_NUM_THREADS=1
export VECLIB_MAXIMUM_THREADS=1

# -- allocator ---------------------------------------------------------------
# tcmalloc beats glibc malloc on the host-side page-table/bookkeeping churn
# of continuous batching; preload it when the box has it, skip silently
# otherwise (no hard dependency).
if [[ -z "${REPRO_NO_TCMALLOC:-}" ]]; then
  for so in libtcmalloc_minimal.so.4 libtcmalloc.so.4 libtcmalloc.so; do
    found=$(ldconfig -p 2>/dev/null | awk -v so="$so" \
            '$1 == so {print $NF; exit}') || true
    if [[ -n "${found:-}" ]]; then
      export LD_PRELOAD="${found}${LD_PRELOAD:+:$LD_PRELOAD}"
      break
    fi
  done
fi

# -- XLA flags ---------------------------------------------------------------
# Defaults tuned for serving: multi-threaded Eigen backed by the pinned
# pool, and (optionally) N virtual host devices so DP x TP mesh shapes
# run on a single machine exactly like CI does.
XLA="--xla_cpu_multi_thread_eigen=true"
XLA+=" --xla_force_host_platform_device_count=${REPRO_HOST_DEVICES:-1}"
export XLA_FLAGS="${XLA}${REPRO_XLA_FLAGS:+ $REPRO_XLA_FLAGS}${XLA_FLAGS:+ $XLA_FLAGS}"

# Async dispatch keeps the decode stream full; donation reuses cache
# buffers across chunks. Both are defaults today — pinned here so an
# environment override can't silently de-tune a benchmark run.
export JAX_ENABLE_X64=0

# -- launch ------------------------------------------------------------------
# PYTHONPATH: resolve src/ relative to this script so the wrapper works
# from any cwd (src/repro/launch/serve_env.sh -> src).
SRC_DIR=$(cd -- "$(dirname -- "${BASH_SOURCE[0]}")/../.." && pwd)
export PYTHONPATH="${SRC_DIR}${PYTHONPATH:+:$PYTHONPATH}"

echo "serve_env: threads=${OMP_NUM_THREADS}" \
     "host_devices=${REPRO_HOST_DEVICES:-1}" \
     "tcmalloc=${LD_PRELOAD:-off}" >&2
exec "${PYTHON}" -m repro.launch.serve "$@"
