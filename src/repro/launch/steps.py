"""Step builders shared by the dry-run, the trainer and the serving engine."""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models.model import Model, build
from repro.optim.adamw import AdamW
from repro.optim.schedule import make_schedule
from repro.train.step import make_train_step


def make_optimizer(run: RunConfig) -> AdamW:
    sched = make_schedule(run.schedule, base_lr=run.learning_rate,
                          warmup_steps=run.warmup_steps,
                          total_steps=max(run.steps, 1))
    return AdamW(learning_rate=sched, weight_decay=run.weight_decay,
                 moment_dtype=run.moment_dtype)


def abstract_train_state(model: Model, run: RunConfig):
    """(params, opt_state) as ShapeDtypeStructs — no allocation."""
    params = model.abstract_params()
    opt = make_optimizer(run)
    opt_state = jax.eval_shape(opt.init, params)
    return params, opt_state, opt


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


def decode_inputs(model: Model, shape: ShapeConfig):
    """(cache, tokens) specs for one decode step with a seq_len-deep cache."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return cache, tokens


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        # serving prefill: next-token logits only (no (B, S, V) temp)
        logits, _ = model.apply(params, batch, remat=False, last_only=True)
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens):
        logits, new_cache = model.decode_step(params, cache, tokens)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, new_cache

    return decode_step


def step_for_shape(model: Model, shape: ShapeConfig, run: RunConfig):
    """Returns (fn, example_inputs) for the shape's kind."""
    if shape.kind == "train":
        params, opt_state, opt = abstract_train_state(model, run)
        fn = make_train_step(model, opt, run)
        return fn, (params, opt_state, train_batch_specs(model.cfg, shape))
    if shape.kind == "prefill":
        params = model.abstract_params()
        return make_prefill_step(model), (params,
                                          prefill_batch_specs(model.cfg,
                                                              shape))
    if shape.kind == "decode":
        params = model.abstract_params()
        cache, tokens = decode_inputs(model, shape)
        return make_decode_step(model), (params, cache, tokens)
    raise ValueError(shape.kind)
