"""Roofline term derivation from compiled dry-run artifacts.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s per ICI link.

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

``collective_bytes_from_hlo`` parses the post-SPMD optimized HLO and sums
the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute. HLO_FLOPs and HLO_bytes from
``compiled.cost_analysis()`` are whole-program (all devices); dividing by
the chip count gives per-chip seconds under perfect balance — the sharded
layouts make this a good approximation, and imbalances (e.g. padded uneven
head shards) show up as a documented caveat per cell.
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 0.5, "u4": 0.5,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(.*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Sum payload bytes of collective ops in post-SPMD optimized HLO.

    Shapes are per-participant shard shapes, so totals are per-device
    payload bytes (one SPMD program = one device's schedule). The RESULT
    type between '=' and the op name is the payload:
      all-gather result already spans the group; all-reduce payload = buffer
      size; reduce-scatter result is the post-scatter shard, so it is scaled
      by the group size to count the pre-reduce wire traffic.
    '-done' halves of async pairs are skipped.
    """
    by_op: dict[str, float] = {}
    for line in hlo.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        type_str, op, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue
        b = _shape_bytes(type_str)
        if op == "reduce-scatter":
            g = _GROUPS_RE.search(line)
            if g:
                b *= int(g.group(2))
        by_op[op] = by_op.get(op, 0.0) + b
    return {"total": sum(by_op.values()), "by_op": by_op}


def roofline_terms(*, flops_dev: float, bytes_dev: float,
                   coll_dev: float) -> dict:
    """All inputs are PER-DEVICE (the compiled SPMD module is one device's
    program; affine depth extrapolation preserves that)."""
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_collective = coll_dev / ICI_BW
    terms = {"t_compute_s": t_compute, "t_memory_s": t_memory,
             "t_collective_s": t_collective}
    bound = max(terms, key=terms.get)
    terms["bound"] = {"t_compute_s": "compute", "t_memory_s": "memory",
                      "t_collective_s": "collective"}[bound]
    total = max(t_compute, t_memory, t_collective)
    terms["roofline_frac_compute"] = (t_compute / total) if total else 0.0
    return terms


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) useful training FLOPs; forward-only
    (2ND) for prefill; 2*N_active per token for decode."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch
