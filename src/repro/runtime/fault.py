"""Fault tolerance: step watchdog, straggler detection, bounded retry,
preemption-aware checkpointing.

At 1000+ node scale the failure model is: (a) hard node loss — the run dies
and restarts from the latest atomic checkpoint on a (possibly re-sized)
mesh; (b) stragglers — a slow host stretches every collective; (c)
preemption — the scheduler gives notice and the run must commit state NOW.

This module implements the host-side runtime pieces that wrap the training
loop (repro/train/loop.py):

* ``StepWatchdog`` — EWMA step-time tracking; flags steps slower than
  ``threshold`` x the EWMA. On real pods the flagged host's neighbors report
  it to the coordinator for drain/replace; here the policy decision
  (CONTINUE / CHECKPOINT_AND_RESHARD) is surfaced to the loop.
* ``retry`` — bounded retry with exponential backoff for transient errors
  (collective timeouts, flaky interconnect).
* ``PreemptionGuard`` — SIGTERM/SIGINT installs a flag; the loop checkpoints
  at the next step boundary and exits cleanly.
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class StepWatchdog:
    threshold: float = 2.5          # x EWMA -> straggler
    ewma_alpha: float = 0.1
    grace_steps: int = 5            # ignore compile/warmup steps
    ewma: Optional[float] = None
    steps: int = 0
    stragglers: list = field(default_factory=list)

    def observe(self, step_time_s: float) -> str:
        """Returns "ok" | "straggler"."""
        self.steps += 1
        if self.steps <= self.grace_steps:
            return "ok"
        if self.ewma is None:
            self.ewma = step_time_s
            return "ok"
        verdict = "ok"
        if step_time_s > self.threshold * self.ewma:
            verdict = "straggler"
            self.stragglers.append((self.steps, step_time_s, self.ewma))
        self.ewma = (1 - self.ewma_alpha) * self.ewma \
            + self.ewma_alpha * step_time_s
        return verdict

    def should_reshard(self, window: int = 20, limit: int = 5) -> bool:
        """Persistent straggling -> advise checkpoint + elastic reshard."""
        recent = [s for s, _, _ in self.stragglers
                  if s > self.steps - window]
        return len(recent) >= limit


def retry(fn: Callable, *, attempts: int = 3, base_delay: float = 0.5,
          retriable=(RuntimeError, TimeoutError), on_retry=None):
    """Bounded retry with exponential backoff for transient runtime errors."""
    last = None
    for i in range(attempts):
        try:
            return fn()
        except retriable as e:  # noqa: PERF203
            last = e
            if on_retry:
                on_retry(i, e)
            if i + 1 < attempts:
                time.sleep(base_delay * (2 ** i))
    raise last


class PreemptionGuard:
    """SIGTERM/SIGINT -> checkpoint at the next step boundary."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = False
        self._signals = signals
        self._old = {}

    def __enter__(self):
        for s in self._signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def _handler(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def __exit__(self, *exc):
        for s, h in self._old.items():
            signal.signal(s, h)
        return False
