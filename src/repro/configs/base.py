"""Model/run configuration dataclasses."""

from __future__ import annotations

import dataclasses
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # "dense" | "moe" | "encdec" | "hybrid" | "ssm"
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0            # expert hidden size (0 -> d_ff)
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- hybrid (zamba2) ---
    shared_attn_period: int = 0   # apply shared attn block every N ssm layers
    # --- enc-dec (whisper) ---
    num_encoder_layers: int = 0
    encoder_seq: int = 1500       # whisper: 30s audio -> 1500 frames
    # --- misc ---
    max_seq_len: int = 8192
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    qk_norm: bool = False         # chameleon
    nonparametric_norm: bool = False  # olmo
    mlp_act: str = "swiglu"       # "swiglu" | "gelu"
    dtype: str = "bfloat16"
    # quantization grouping
    quant_group: int = 128

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing -> long_500k is runnable."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (matches init; used for 6ND roofline)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd, nh, nkv = self.head_dim, self.num_heads, self.num_kv_heads
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        if self.qk_norm:
            attn += 2 * hd
        mlp = 3 * d * f if self.mlp_act == "swiglu" else 2 * d * f
        norms = 0 if self.nonparametric_norm else 2 * d
        tied = self.tie_embeddings or self.family in ("encdec", "hybrid",
                                                      "ssm")
        if self.family in ("dense",):
            per_layer = attn + mlp + norms
            layers = self.num_layers * per_layer
        elif self.family == "moe":
            ef = self.expert_d_ff
            moe = self.num_experts * 3 * d * ef + d * self.num_experts
            dense = 3 * d * f if self.dense_residual else 0
            per_layer = attn + moe + dense + norms
            layers = self.num_layers * per_layer
        elif self.family == "encdec":
            enc_layer = attn + 2 * d * f + 2 * d            # gelu mlp
            dec_layer = attn + attn + 2 * d * f + 3 * d     # self+cross+3 LN
            layers = (self.num_encoder_layers * enc_layer
                      + self.num_layers * dec_layer)
        elif self.family in ("ssm", "hybrid"):
            di, ns, ng = self.d_inner, self.ssm_state, self.ssm_ngroups
            nh_s = self.ssm_nheads
            conv_ch = di + 2 * ng * ns
            in_proj = d * (2 * di + 2 * ng * ns + nh_s)
            per_layer = (in_proj + di * d + (self.ssm_conv + 1) * conv_ch
                         + 3 * nh_s + di
                         + (0 if self.nonparametric_norm else d))
            layers = self.num_layers * per_layer
            if self.family == "hybrid":
                layers += attn + mlp + 2 * d  # one shared block
        else:
            raise ValueError(self.family)
        embed = v * d
        head = 0 if tied else v * d
        if self.family == "encdec":
            final_norm = 2 * d  # enc_norm + dec norm
        else:
            final_norm = 0 if self.nonparametric_norm else d
        return layers + embed + head + final_norm

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, ef = self.d_model, self.expert_d_ff
        inactive = (self.num_experts - self.top_k) * 3 * d * ef * self.num_layers
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training/serving run options (see repro/launch/train.py)."""
    steps: int = 100
    learning_rate: float = 3e-4
    warmup_steps: int = 10
    schedule: str = "cosine"          # "cosine" | "wsd" | "linear"
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0
    microbatch: Optional[int] = None  # grad-accum microbatch size
    moment_dtype: str = "float32"     # "float32" | "bfloat16" | "int8"
    grad_compression: Optional[str] = None  # None | "int8_ef"
    remat: bool = True
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
