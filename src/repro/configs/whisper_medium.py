"""whisper-medium [audio]: enc-dec transformer, conv frontend stubbed.

24L (enc) + 24L (dec) d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=51865 —
arXiv:2212.04356. ``input_specs`` provides precomputed frame embeddings
(B, 1500, d_model) in place of the mel+conv frontend.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, num_encoder_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=51865,
    mlp_act="gelu", encoder_seq=1500, max_seq_len=32768,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    num_layers=2, num_encoder_layers=2, d_model=128,
    num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=512,
    mlp_act="gelu", encoder_seq=64, max_seq_len=128,
)
