"""mamba2-780m [ssm]: attention-free SSD (state-space duality) LM.

48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128 — arXiv:2405.21060.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_ngroups=1,
    max_seq_len=8192,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    num_layers=2, d_model=128, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=512,
    ssm_state=16, ssm_headdim=32, ssm_expand=2, ssm_ngroups=1,
    max_seq_len=128, ssm_chunk=32,
)
