"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention block.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64 —
arXiv:2411.15242. One shared attn+MLP block applied every 6 Mamba2 layers
(9 sites); per-site LoRA adapters omitted (docs/DESIGN.md §2.1).
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_ngroups=1,
    shared_attn_period=6, rope_theta=10000.0, max_seq_len=4096,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512,
    ssm_state=16, ssm_headdim=32, ssm_expand=2, ssm_ngroups=1,
    shared_attn_period=2, rope_theta=10000.0, max_seq_len=128,
    ssm_chunk=32,
)
