"""arctic-480b [moe]: 128-expert top-2 MoE with dense residual FFN.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2 —
hf:Snowflake/snowflake-arctic-base (dense-MoE hybrid: dense FFN residual in
parallel with the MoE branch).
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    num_experts=128, top_k=2, moe_d_ff=4864, dense_residual=True,
    max_seq_len=4096,
)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512,
    num_experts=8, top_k=2, moe_d_ff=256, dense_residual=True,
    max_seq_len=128,
)
