"""yi-9b [dense]: llama-arch GQA.

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 — arXiv:2403.04652.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="yi-9b", family="dense",
    num_layers=48, d_model=4096, num_heads=32, num_kv_heads=4,
    d_ff=11008, vocab_size=64000, rope_theta=10000.0, max_seq_len=4096,
)

SMOKE = ModelConfig(
    name="yi-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, rope_theta=10000.0, max_seq_len=128,
)
