"""llama3.2-3b [dense]: small llama3.

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256 —
hf:meta-llama/Llama-3.2 family.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="llama3.2-3b", family="dense",
    num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=128256, rope_theta=500000.0,
    tie_embeddings=True, max_seq_len=8192,
)

SMOKE = ModelConfig(
    name="llama32-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, rope_theta=500000.0,
    tie_embeddings=True, max_seq_len=128,
)
