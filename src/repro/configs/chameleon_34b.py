"""chameleon-34b [vlm]: early-fusion VLM backbone, VQ image tokens in vocab.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 — arXiv:2405.09818.
The VQ-VAE image tokenizer is a stub: image tokens are ordinary vocab ids
(early fusion), so ``input_specs`` is a plain token batch. QK-norm per the
Chameleon recipe.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="chameleon-34b", family="dense",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=65536, qk_norm=True, rope_theta=10000.0,
    max_seq_len=4096,
)

SMOKE = ModelConfig(
    name="chameleon-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, qk_norm=True, rope_theta=10000.0,
    max_seq_len=128,
)
