"""minicpm-2b [dense]: llama-like arch trained with the WSD schedule.

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753 — arXiv:2404.06395.
The WSD (warmup-stable-decay) schedule lives in repro/optim/schedule.py and
is the default for this config's training runs.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753, tie_embeddings=True,
    rope_theta=10000.0, max_seq_len=4096,
)

SMOKE = ModelConfig(
    name="minicpm-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, tie_embeddings=True,
    rope_theta=10000.0, max_seq_len=128,
)
