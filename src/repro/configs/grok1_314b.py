"""grok-1-314b [moe]: 8-expert top-2 MoE.

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072 — hf:xai-org/grok-1.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32768, vocab_size=131072,
    num_experts=8, top_k=2, moe_d_ff=32768,
    max_seq_len=8192,
)

SMOKE = ModelConfig(
    name="grok-smoke", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512,
    num_experts=4, top_k=2, moe_d_ff=256,
    max_seq_len=128,
)
