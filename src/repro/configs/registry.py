"""Architecture registry: ``--arch <id>`` resolution + per-arch shape sets."""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "chameleon-34b": "repro.configs.chameleon_34b",
    "arctic-480b": "repro.configs.arctic_480b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "whisper-medium": "repro.configs.whisper_medium",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "yi-9b": "repro.configs.yi_9b",
    "llama3.2-3b": "repro.configs.llama32_3b",
    "olmo-1b": "repro.configs.olmo_1b",
    "zamba2-2.7b": "repro.configs.zamba2_27b",
    "mamba2-780m": "repro.configs.mamba2_780m",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.SMOKE if smoke else mod.FULL


def shape_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is (arch, shape) a runnable dry-run cell? Returns (ok, reason)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: quadratic attention at "
                       "524288 tokens; skipped per docs/DESIGN.md §2.3")
    return True, ""


def cells(archs=ARCHS, shapes=tuple(SHAPES)):
    """All 40 (arch, shape) cells with runnability annotations."""
    out = []
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            ok, reason = shape_runnable(cfg, SHAPES[s])
            out.append((a, s, ok, reason))
    return out
