"""olmo-1b [dense]: non-parametric LayerNorm, tied embeddings.

16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304 — arXiv:2402.00838.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="olmo-1b", family="dense",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=50304, nonparametric_norm=True,
    tie_embeddings=True, rope_theta=10000.0, max_seq_len=4096,
)

SMOKE = ModelConfig(
    name="olmo-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, nonparametric_norm=True,
    tie_embeddings=True, rope_theta=10000.0, max_seq_len=128,
)
