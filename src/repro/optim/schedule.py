"""LR schedules: linear warmup + {cosine, WSD (minicpm), linear} decay."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, base_lr: float, warmup_steps: int,
                  total_steps: int, final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup_steps, 1)
    frac = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup_steps, warm, base_lr * cos)


def wsd(step, *, base_lr: float, warmup_steps: int, total_steps: int,
        decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup-Stable-Decay (MiniCPM): warmup, long stable plateau, then a
    short exponential-ish (here: linear-in-log) decay over the last
    ``decay_frac`` of training."""
    step = jnp.asarray(step, jnp.float32)
    decay_start = total_steps * (1.0 - decay_frac)
    warm = base_lr * step / jnp.maximum(warmup_steps, 1)
    frac = (step - decay_start) / jnp.maximum(total_steps - decay_start, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    decayed = base_lr * jnp.exp(jnp.log(final_frac) * frac)
    out = jnp.where(step < warmup_steps, warm, base_lr)
    return jnp.where(step > decay_start, decayed, out)


def warmup_linear(step, *, base_lr: float, warmup_steps: int,
                  total_steps: int, final_frac: float = 0.0):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup_steps, 1)
    frac = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    lin = base_lr * (1 - (1 - final_frac) * frac)
    return jnp.where(step < warmup_steps, warm, lin)


SCHEDULES = {"cosine": warmup_cosine, "wsd": wsd, "linear": warmup_linear}


def make_schedule(name: str, *, base_lr: float, warmup_steps: int,
                  total_steps: int):
    fn = SCHEDULES[name]
    return lambda step: fn(step, base_lr=base_lr, warmup_steps=warmup_steps,
                           total_steps=total_steps)
