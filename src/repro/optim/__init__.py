from repro.optim.adamw import AdamW, AdamWState, clip_by_global_norm, global_norm  # noqa: F401
from repro.optim.schedule import make_schedule  # noqa: F401
