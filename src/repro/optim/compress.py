"""Int8 error-feedback gradient compression for cross-replica all-reduce.

Distributed-optimization trick (docs/DESIGN.md §7.3): per-leaf group-wise int8
quantization of gradients before the data-parallel all-reduce, with a
persistent error-feedback buffer so quantization error is carried to the
next step instead of lost (Seide et al.-style EF-SGD, here applied to the
mean-reduce).

Usage is via shard_map over the data axes: each replica quantizes
(grad + error), all-reduces the int8 payload as f32-summed groups (TPU
all-reduce executes in the payload dtype; we psum the int8 carried in
int32 to avoid overflow, then rescale), decodes the mean, and keeps
``error = grad - decoded`` locally.

The EWQ tie-in: ``entropy_threshold`` optionally compresses ONLY leaves
whose weight-entropy block was marked quantizable by the plan — high-entropy
(sensitive) blocks keep full-precision gradients.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quant_leaf(g: jax.Array, group: int = 256):
    """Group-wise absmax int8 along a flattened view."""
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % group
    flat = jnp.pad(flat, (0, pad))
    gr = flat.reshape(-1, group)
    absmax = jnp.max(jnp.abs(gr), axis=-1, keepdims=True)
    scale = absmax / 127.0
    q = jnp.round(gr / jnp.where(scale == 0, 1.0, scale))
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale[:, 0], n


def _dequant_leaf(q: jax.Array, scale: jax.Array, n: int, shape) -> jax.Array:
    vals = q.astype(jnp.float32) * scale[:, None]
    return vals.reshape(-1)[:n].reshape(shape)


def compressed_psum_mean(grads, error, axis_names, group: int = 256):
    """Inside shard_map: int8-EF all-reduce-mean over ``axis_names``.

    Returns (mean_grads, new_error). 4x fewer all-reduce payload bytes than
    f32 (2x vs bf16) at the cost of a small scale side-channel.
    """
    # jax.lax.axis_size is 0.5+; psum(1, ax) is the portable spelling
    axis_size = getattr(jax.lax, "axis_size",
                        lambda ax: jax.lax.psum(1, ax))
    n_replicas = 1
    for ax in axis_names:
        n_replicas *= axis_size(ax)

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        # Phase 1: agree on a GLOBAL per-group scale (pmax of absmax — a
        # tiny f32 side-channel, 1/group of the payload). A shared scale
        # makes the int8 payloads directly summable; per-replica scales
        # would make sum(q_r)*mean(s_r) a biased decode.
        flat = corrected.reshape(-1)
        n = flat.shape[0]
        pad = (-n) % group
        flat = jnp.pad(flat, (0, pad))
        gr = flat.reshape(-1, group)
        absmax = jnp.max(jnp.abs(gr), axis=-1)
        for ax in axis_names:
            absmax = jax.lax.pmax(absmax, ax)
        scale = absmax / 127.0
        safe = jnp.where(scale == 0, 1.0, scale)
        # Phase 2: quantize against the shared scale; psum the int payload
        # in int32 (no overflow below 2^23 replicas).
        q = jnp.clip(jnp.round(gr / safe[:, None]), -127, 127)
        q_sum = q.astype(jnp.int32)
        for ax in axis_names:
            q_sum = jax.lax.psum(q_sum, ax)
        decoded = (q_sum.astype(jnp.float32) * scale[:, None] / n_replicas)
        decoded = decoded.reshape(-1)[:n].reshape(g.shape)
        # Error feedback: what this replica's payload failed to carry.
        decoded_local = (q * scale[:, None]).reshape(-1)[:n].reshape(g.shape)
        new_e = corrected - decoded_local
        return decoded.astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_error = jax.tree.unflatten(treedef, [o[1] for o in out])
    return mean, new_error


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
