"""AdamW with configurable moment dtype (f32 / bf16 / int8-quantized).

The int8 option applies the paper's idea to optimizer state: moments are
stored as group-wise absmax int8 (the same quantizer the EWQ serving path
uses), dequantized on read and requantized on write. This is the 8-bit-Adam
analogue that makes ≥300B-param training fit per-device HBM budgets
(EXPERIMENTS.md §Dry-run discusses the arctic/grok memory deltas).

Moments inherit the parameter sharding (FSDP+TP), giving ZeRO-equivalent
optimizer-state partitioning under pjit.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.quant.qtypes import QTensor
from repro.quant.quantize import quantize_int8, dequantize


class AdamWState(NamedTuple):
    count: jax.Array
    m: Any
    v: Any


def _encode_moment(x: jax.Array, dtype: str):
    if dtype == "float32":
        return x.astype(jnp.float32)
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    if dtype == "int8":
        if x.ndim >= 1 and x.shape[-1] % 128 == 0:
            return quantize_int8(x, group=128)
        return x.astype(jnp.float32)  # small/ragged leaves stay f32
    raise ValueError(dtype)


def _decode_moment(x) -> jax.Array:
    if isinstance(x, QTensor):
        return dequantize(x, jnp.float32)
    return x.astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Any            # float or callable(step) -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: _encode_moment(jnp.zeros(p.shape, jnp.float32),
                                     self.moment_dtype), params)
        zeros_v = jax.tree.map(
            lambda p: _encode_moment(jnp.zeros(p.shape, jnp.float32),
                                     self.moment_dtype), params)
        return AdamWState(count=jnp.zeros((), jnp.int32), m=zeros, v=zeros_v)

    def update(self, grads, state: AdamWState, params):
        count = state.count + 1
        lr = (self.learning_rate(count)
              if callable(self.learning_rate) else self.learning_rate)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def leaf(g, m_enc, v_enc, p):
            g = g.astype(jnp.float32)
            m = b1 * _decode_moment(m_enc) + (1 - b1) * g
            v = b2 * _decode_moment(v_enc) + (1 - b2) * g * g
            update = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                update = update + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
            return new_p, _encode_moment(m, self.moment_dtype), \
                _encode_moment(v, self.moment_dtype)

        is_q = lambda x: isinstance(x, QTensor)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.m, is_leaf=is_q)
        flat_v = jax.tree.leaves(state.v, is_leaf=is_q)
        flat_p, treedef = jax.tree.flatten(params)
        out = [leaf(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        return new_p, AdamWState(count=count, m=new_m, v=new_v)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm
