"""Quantized tensor pytree for weight-only quantization.

A QTensor stores:
  data   : int8 array. For int8 this is the values; for int4 two nibbles are
           packed per int8 along the *last* (contraction-group) axis; for
           ternary values are {-1, 0, +1} stored in int8 (2 trits per byte
           would complicate the matmul kernel; size accounting reports the
           1.58-bit figure separately).
  scale  : bf16/f32 per-group scales with shape data_shape[:-1] + (groups,)
  precision: "int8" | "int4" | "ternary"
  shape  : logical (unquantized) shape
  group  : group size along the last axis (contraction dim), default 128.

Registered as a pytree so QTensors flow through jit/scan/pjit and can carry
shardings like any other leaf bundle.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_GROUP = 128


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    data: jax.Array
    scale: jax.Array
    precision: str = dataclasses.field(metadata={"static": True})
    shape: tuple[int, ...] = dataclasses.field(metadata={"static": True})
    group: int = DEFAULT_GROUP

    # -- pytree protocol -----------------------------------------------------
    def tree_flatten(self):
        return (self.data, self.scale), (self.precision, self.shape, self.group)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, scale = children
        precision, shape, group = aux
        return cls(data=data, scale=scale, precision=precision, shape=shape,
                   group=group)

    # -- info ------------------------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def logical_size(self) -> int:
        return int(np.prod(self.shape))

    def nbytes_effective(self) -> float:
        """Effective storage bytes (counts ternary at 1.58 bits even though
        the in-memory carrier is int8)."""
        bits = {"int8": 8.0, "int4": 4.0, "ternary": 1.58}[self.precision]
        scale_bytes = float(np.prod(self.scale.shape)) * 2.0  # bf16 scales
        return self.logical_size * bits / 8.0 + scale_bytes


def is_qtensor(x: Any) -> bool:
    return isinstance(x, QTensor)


def qtensor_specs(q: QTensor) -> "QTensor":
    """ShapeDtypeStruct twin of a QTensor (for dry-run input_specs)."""
    return QTensor(
        data=jax.ShapeDtypeStruct(q.data.shape, q.data.dtype),
        scale=jax.ShapeDtypeStruct(q.scale.shape, q.scale.dtype),
        precision=q.precision, shape=q.shape, group=q.group)
