from repro.quant.qtypes import QTensor, is_qtensor  # noqa: F401
from repro.quant.quantize import (  # noqa: F401
    quantize, dequantize, quantize_int8, quantize_int4, quantize_ternary,
)
from repro.quant.compiler import (  # noqa: F401
    CompiledPlan, compile_kv_plan, compile_plan, family_layout,
    load_artifact, plan_length, save_artifact,
)
from repro.quant.kvcache import (  # noqa: F401
    KVPage, KVPlan, dequantize_kv, is_kv_page, quantize_kv,
)
