"""Entropy-weighted quantized KV cache (docs/DESIGN.md §10).

The serving KV cache dominates decode memory at ``num_slots x max_seq`` and
is re-read in full every token step. ``KVPage`` extends the paper's
layer-level entropy argument from weights to that cache: each attention
layer's K/V buffers are stored int8 or packed int4 with per-group scales,
the per-layer precision chosen by a ``KVPlan`` (uniform, or derived from
the layer's existing entropy decision — quant/compiler.compile_kv_plan).

Layout
------
A page covers a contiguous run of cache layers at ONE precision:

  data  : (L?, B, S, Hkv, hd)      int8   ("int8")
          (L?, B, S, F // 2)       int8   ("int4", two nibbles per byte,
                                           stored FLAT over F = Hkv * hd)
          (L?, B, S, Hkv, hd)      bf16   ("bf16", scale is None)
  scale : (L?, B, S, F // group)   bf16   — F = Hkv * hd, groups along the
          FLATTENED head axis so small head dims still amortize one bf16
          scale over ``group`` elements (bytes/slot stays ~bits/8 per elem).

int4 pages drop the (Hkv, hd//2) head split in storage: the packed payload
keeps the flat F/2 axis as its minor dimension. The bytes are identical
(row-major (Hkv, hd//2) and F/2 coincide) but the SHAPE matters to XLA's
CPU fallback codegen: elementwise nibble/convert loops over a minor
dimension of hd//2 (32 for hd=64) de-vectorize to ~4x the cost of the
same ops over an F/2-wide minor axis, which made int4 decode pay ~2x over
int8 despite reading half the bytes. ``dequantize_kv`` restores the
(Hkv, hd) head split only on its OUTPUT, after the hot unpack/scale ops.

Pages are registered pytrees, so they ride through jit / lax.scan (the
leading layer axis is scanned over exactly like a raw stacked cache) and
through ``serving/batch.DecodeState`` as the decode-loop carry.

Quantize-on-insert invariant: prefill runs in bf16; K/V enter a page only
through ``update_page`` (per-token decode write) or ``insert_slot``
(admitting a prefilled request into a slot), both of which quantize at the
write. The steady-state carry of the jitted decode scan is therefore
always quantized — decode never holds a bf16 copy of the cache.

Mixed per-layer plans cut the cache into a tuple of pages whose boundaries
are forced to the parameter-stack segment boundaries (``cuts``), so page i
lines up 1:1 with ``quant.apply.segment_slices`` segment i and each model
scan sees a single-precision page.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_KV_GROUP = 64
KV_PRECISIONS = ("bf16", "int8", "int4")


@dataclasses.dataclass(frozen=True)
class KVPlan:
    """Per-cache-layer precision plan for a family's KV cache.

    ``precisions`` carries one entry per element of the cache's leading
    layer axis (L decoder layers; U shared-attention sites for hybrid).
    ``group`` is the scale-group size along the flattened (Hkv*hd) axis.
    """
    precisions: tuple[str, ...]
    group: int = DEFAULT_KV_GROUP

    def __post_init__(self):
        for p in self.precisions:
            if p not in KV_PRECISIONS:
                raise ValueError(f"unknown KV precision {p!r}; "
                                 f"one of {KV_PRECISIONS}")

    def pages(self, cuts: Sequence[int] = ()) -> list[tuple[str, int, int]]:
        """Maximal equal-precision runs, additionally cut at ``cuts`` (the
        parameter-stack segment boundaries) so pages align 1:1 with the
        segments the model scans."""
        cutset = set(cuts)
        runs: list[tuple[str, int, int]] = []
        start = 0
        n = len(self.precisions)
        for i in range(1, n + 1):
            if (i == n or self.precisions[i] != self.precisions[start]
                    or i in cutset):
                runs.append((self.precisions[start], start, i))
                start = i
        return runs

    def to_dict(self) -> dict:
        return {"precisions": list(self.precisions), "group": self.group}

    @staticmethod
    def from_dict(d: dict) -> "KVPlan":
        return KVPlan(precisions=tuple(d["precisions"]), group=int(d["group"]))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KVPage:
    """One contiguous run of cache layers at a single precision.

    Shapes are derived from ``data`` (not static metadata) so pages stay
    valid under scan/vmap slicing of the leading layer axis.
    """
    data: Any                 # see module docstring
    scale: Any                # bf16 per-group scales, or None for "bf16"
    precision: str            # static
    head_dim: int             # static logical hd (int4 stores hd//2 bytes)
    group: int                # static, divides Hkv*hd

    def tree_flatten(self):
        return (self.data, self.scale), (self.precision, self.head_dim,
                                         self.group)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, scale = children
        precision, head_dim, group = aux
        return cls(data=data, scale=scale, precision=precision,
                   head_dim=head_dim, group=group)

    @property
    def num_kv_heads(self) -> int:
        if self.precision == "int4":    # flat (..., F // 2) payload
            return 2 * self.data.shape[-1] // self.head_dim
        return self.data.shape[-2]

    @property
    def seq_len(self) -> int:
        return self.data.shape[-2 if self.precision == "int4" else -4]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKV:
    """Pool-backed paged layout of a KV cache field (docs/DESIGN.md §13).

    Instead of a dense per-slot (B, S_max) reservation, tokens live in a
    shared pool of physical pages of ``page_size`` tokens each, reached
    through a per-slot page table:

      data  : (L?, N, P, Hkv, hd)  int8 | float   pool payload
              (L?, N, P, F // 2)   int8           ("int4", packed flat)
      scale : (L?, N, P, F//group) bf16, or None  per-group scales
      table : (L?, B, n_log)       int32          slot -> physical page

    N = pool_pages + 1: physical page 0 is the sacrificial DUMP page — it
    is never allocated, and every released / unallocated table entry points
    at it, so writes from inactive slots land on garbage instead of
    corrupting a reallocated page (reads past ``valid_len`` are masked by
    the decode kernels, so the garbage is never observed).

    The table broadcasts over the same leading layer axis as the payload,
    so scan/vmap slicing of the layer axis (hybrid's per-unit scan, the
    draft's kv_take_layers) slices every leaf uniformly. "bf16"-precision
    pools store the raw cache dtype verbatim (no bf16 rounding), keeping
    the paged bf16 engine numerically identical to the dense raw path.
    """
    data: Any
    scale: Any
    table: Any
    precision: str            # static
    head_dim: int             # static logical hd (int4 stores hd//2 bytes)
    group: int                # static, divides Hkv*hd
    page_size: int            # static tokens per physical page

    def tree_flatten(self):
        return (self.data, self.scale, self.table), (
            self.precision, self.head_dim, self.group, self.page_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, scale, table = children
        precision, head_dim, group, page_size = aux
        return cls(data=data, scale=scale, table=table, precision=precision,
                   head_dim=head_dim, group=group, page_size=page_size)

    @property
    def num_kv_heads(self) -> int:
        if self.precision == "int4":    # flat (..., F // 2) payload
            return 2 * self.data.shape[-1] // self.head_dim
        return self.data.shape[-2]

    @property
    def seq_len(self) -> int:
        """Logical sequence capacity a slot's page table addresses."""
        return self.table.shape[-1] * self.page_size

    @property
    def num_pages(self) -> int:
        """Physical pool pages including the dump page."""
        axis = -3 if self.precision == "int4" else -4
        return self.data.shape[axis]


def is_kv_page(x: Any) -> bool:
    """True for a KVPage/PagedKV or a (non-empty) tuple of them."""
    if isinstance(x, (KVPage, PagedKV)):
        return True
    return (isinstance(x, tuple) and len(x) > 0
            and all(isinstance(p, (KVPage, PagedKV)) for p in x))


# ---------------------------------------------------------------------------
# quantize / dequantize (flat-head grouping)
# ---------------------------------------------------------------------------

def _flat_groups(x: jax.Array, group: int) -> jax.Array:
    """(..., Hkv, hd) -> (..., F//group, group) over the flattened heads."""
    *lead, hkv, hd = x.shape
    f = hkv * hd
    assert f % group == 0, f"Hkv*hd={f} not divisible by kv group {group}"
    return x.reshape(*lead, f // group, group)


def quantize_kv(x: jax.Array, precision: str, group: int
                ) -> tuple[jax.Array, Optional[jax.Array]]:
    """x: (..., Hkv, hd) float -> (data, scale) in the page layout."""
    *lead, hkv, hd = x.shape
    if precision == "bf16":
        return x.astype(jnp.bfloat16), None
    g = _flat_groups(x.astype(jnp.float32), group)
    absmax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    qmax = 127.0 if precision == "int8" else 7.0
    scale = absmax / qmax
    q = jnp.round(g / jnp.where(scale == 0, 1.0, scale))
    q = jnp.clip(q, -qmax, qmax).astype(jnp.int8)
    scale = scale[..., 0].astype(jnp.bfloat16)
    if precision == "int8":
        return q.reshape(*lead, hkv, hd), scale
    if precision == "int4":
        assert hd % 2 == 0, f"int4 KV packing needs an even head dim, {hd}"
        # split-half packing over the flat F axis: byte j holds flat
        # elements j (low nibble) and j + F/2 (high nibble), so the unpack
        # on the decode hot path is a single concat — no interleave
        # shuffle. Stored FLAT (..., F//2): see the module docstring.
        flat = q.reshape(*lead, hkv * hd)
        half = hkv * hd // 2
        packed = ((flat[..., :half] & 0x0F)
                  | ((flat[..., half:] & 0x0F) << 4)).astype(jnp.int8)
        return packed, scale
    raise ValueError(f"cannot quantize KV to {precision!r}")


def _unpack_kv_int4(data: jax.Array) -> jax.Array:
    """(..., P) packed -> (..., 2P): low nibbles are flat elements [0, P),
    high nibbles [P, 2P) (split-half layout — see ``quantize_kv``). The
    low nibble sign-extends with xor/sub (no select); the high nibble via
    int8 arithmetic right-shift — one op each."""
    lo = ((data & 0x0F) ^ 8) - 8
    hi = data >> 4
    return jnp.concatenate([lo, hi], axis=-1).astype(jnp.int8)


def dequantize_kv(page: KVPage, dtype=jnp.float32) -> jax.Array:
    """Page -> (..., Hkv, hd) in ``dtype`` (bf16 pages: a plain cast)."""
    if page.precision == "bf16":
        return page.data.astype(dtype)
    data = page.data
    if page.precision == "int4":
        # unpack over the stored flat F axis; every op here runs with the
        # wide F/2 (then F) minor dimension — the head split is restored
        # only on the output reshape below
        data = _unpack_kv_int4(data)                      # (..., F) int8
        *lead, f = data.shape
        g = data.astype(jnp.float32).reshape(*lead, f // page.group,
                                             page.group)
        out = g * page.scale.astype(jnp.float32)[..., None]
        return out.reshape(*lead, f // page.head_dim,
                           page.head_dim).astype(dtype)
    *lead, hkv, hd = data.shape
    g = data.astype(jnp.float32).reshape(*lead, hkv * hd // page.group,
                                         page.group)
    out = g * page.scale.astype(jnp.float32)[..., None]
    return out.reshape(*lead, hkv, hd).astype(dtype)


def make_page(raw: jax.Array, precision: str, group: int) -> KVPage:
    """Quantize a raw (..., S, Hkv, hd) cache buffer into one page."""
    data, scale = quantize_kv(raw, precision, group)
    return KVPage(data=data, scale=scale, precision=precision,
                  head_dim=raw.shape[-1], group=group)


# ---------------------------------------------------------------------------
# page writes (quantize-on-insert)
# ---------------------------------------------------------------------------

def update_page(page, new: jax.Array, pos: jax.Array):
    """Decode-step write: quantize ``new`` (B, s, Hkv, hd) and store it at
    sequence position ``pos`` (scalar, or (B,) per-slot vector). Paged
    fields scatter through the slot's page table instead (quant/paged.py)."""
    if isinstance(page, PagedKV):
        from repro.quant import paged
        return paged.update_pages(page, new, pos)
    data_n, scale_n = quantize_kv(new, page.precision, page.group)
    data_n = data_n.astype(page.data.dtype)

    def write(dst, src, p):
        if getattr(p, "ndim", 0) == 1:  # per-slot positions
            return jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
                c, n, (i,) + (0,) * (c.ndim - 1)))(dst, src, p)
        start = (jnp.int32(0), p) + (0,) * (dst.ndim - 2)
        return jax.lax.dynamic_update_slice(dst, src, start)

    data = write(page.data, data_n, pos)
    scale = (None if scale_n is None
             else write(page.scale, scale_n.astype(page.scale.dtype), pos))
    return dataclasses.replace(page, data=data, scale=scale)


def _page_lengths(field) -> list[int]:
    pages = field if isinstance(field, tuple) else (field,)
    return [p.data.shape[0] for p in pages]


def insert_slot(field, src: jax.Array, slot) -> Any:
    """Admit a prefilled request: quantize the raw batch=1 cache ``src``
    ((L, 1, S, Hkv, hd)) into slot ``slot`` of a slotted page field (batch
    axis 1). ``field`` is a KVPage or tuple of KVPages over layer runs."""
    pages = field if isinstance(field, tuple) else (field,)
    out, lo = [], 0
    for page in pages:
        hi = lo + page.data.shape[0]
        data_n, scale_n = quantize_kv(src[lo:hi], page.precision, page.group)

        def write(dst, new):
            start = (0, slot) + (0,) * (dst.ndim - 2)
            return jax.lax.dynamic_update_slice(dst, new.astype(dst.dtype),
                                                start)

        out.append(dataclasses.replace(
            page, data=write(page.data, data_n),
            scale=None if scale_n is None else write(page.scale, scale_n)))
        lo = hi
    return tuple(out) if isinstance(field, tuple) else out[0]


# ---------------------------------------------------------------------------
# model-cache conversion and per-segment access helpers
# ---------------------------------------------------------------------------

def quantize_cache_field(raw: jax.Array, plan: KVPlan,
                         cuts: Sequence[int] = ()) -> Any:
    """Raw stacked (L, B, S, Hkv, hd) cache buffer -> page container.

    Single-run plans yield a bare KVPage; mixed plans a tuple of pages cut
    at ``cuts`` so page i aligns with parameter segment i."""
    runs = plan.pages(cuts)
    assert runs and runs[-1][2] == raw.shape[0], \
        (f"KV plan covers {runs[-1][2] if runs else 0} layers; cache has "
         f"{raw.shape[0]}")
    pages = tuple(make_page(raw[lo:hi], prec, plan.group)
                  for prec, lo, hi in runs)
    return pages if len(pages) > 1 else pages[0]


def quantize_model_cache(cache, plan: KVPlan, cuts: Sequence[int],
                         fields: Sequence[str]):
    """Replace each named KV field of a family cache with quantized pages
    (no-op for families without attention caches)."""
    reps = {}
    for name in fields:
        raw = getattr(cache, name)
        if is_kv_page(raw):
            reps[name] = raw  # already quantized
        else:
            reps[name] = quantize_cache_field(raw, plan, cuts)
    return cache._replace(**reps) if reps else cache


def kv_segment(field, si: int, lo: int, hi: int):
    """Slice a cache field for parameter segment ``si`` covering layers
    [lo, hi). Quantized fields are page-aligned 1:1 with segments."""
    if isinstance(field, tuple):
        page = field[si]
        assert page.data.shape[0] == hi - lo, \
            (f"KV page {si} holds {page.data.shape[0]} layers; segment "
             f"[{lo},{hi}) expects {hi - lo} — cache pages must be built "
             f"with the parameter segmentation's cuts")
        return page
    if isinstance(field, (KVPage, PagedKV)):
        assert si == 0, "single-page cache with a multi-segment stack"
        return field
    return field[lo:hi]


def kv_rejoin(field, parts: list):
    """Rebuild a cache field from per-segment scan outputs, preserving the
    original container type."""
    if isinstance(field, tuple):
        return tuple(parts)
    if isinstance(field, (KVPage, PagedKV)):
        return parts[0]
    return jnp.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]


def kv_take_layers(field, lo: int, hi: int):
    """Read-only slice of cache layers [lo, hi) from any container (raw
    stack, single page, page tuple). Unlike ``kv_segment`` the range does
    not have to BE a page — it only has to sit INSIDE one. The fused draft
    propose path iterates the DRAFT's segments, which refine the target
    segmentation the pages were cut at (quant/compiler.compile_draft_plan
    preserves boundaries; truncation only shortens the last segment), so
    single-page coverage is guaranteed by construction."""
    if isinstance(field, tuple):
        plo = 0
        for page in field:
            phi = plo + page.data.shape[0]
            if plo <= lo and hi <= phi:
                return jax.tree.map(lambda x: x[lo - plo:hi - plo], page)
            plo = phi
        raise ValueError(
            f"layer range [{lo},{hi}) straddles KV page boundaries "
            f"(page lengths {_page_lengths(field)}) — draft segments must "
            f"refine the segmentation the cache pages were cut at")
    if isinstance(field, (KVPage, PagedKV)):
        return jax.tree.map(lambda x: x[lo:hi], field)
    return field[lo:hi]


def kv_layer(field, i: int):
    """Index one layer/site of a cache field (hybrid's unrolled units)."""
    if isinstance(field, (KVPage, PagedKV)):
        return jax.tree.map(lambda x: x[i], field)
    assert not isinstance(field, tuple), \
        "per-layer indexing expects a single-page (uniform) hybrid cache"
    return field[i]


def kv_stack(field, parts: list):
    """Stack per-layer results back into the original container layout."""
    if isinstance(field, (KVPage, PagedKV)):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *parts)
    return jnp.stack(parts)


def kv_field_nbytes(field) -> float:
    """Physical bytes of a cache field (pages count data + scales)."""
    total = 0.0
    for leaf in jax.tree.leaves(field):
        total += float(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total
