"""Family-universal plan compiler: QuantPlan -> CompiledPlan.

Lowers an EWQ/FastEWQ ``QuantPlan`` (one precision decision per block in
``Model.block_params`` order) onto a model family's concrete parameter
layout, replacing the previous per-family branching in serving/quantized.py
(which silently fell back to RAW weights for hybrid and enc-dec mixed
plans). Every family now yields quantized segmented stacks:

* dense / moe / ssm — one layer stack, segmented into maximal runs of equal
  precision (``SegmentedParams``);
* hybrid — the Mamba2 layer stack is additionally cut at shared-attention
  unit boundaries when the plan is mixed, so each segment executes inside
  exactly one unit of the unit-scan (models/hybrid.py); the shared block is
  a per-block extra quantized at its own decision;
* encdec — independent segmented encoder and decoder stacks.

The result carries a serializable manifest (family, plan, segment layout,
group, effective bytes), and ``save_artifact``/``load_artifact`` persist the
quantized parameters + manifest as a bootable checkpoint so a server cold
start skips raw-weight loading AND entropy analysis entirely
(``launch/serve.py --plan-artifact``). Contract details: docs/DESIGN.md §8.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import QuantPlan
from repro.quant.apply import (Segment, SegmentedParams, _quantizable,
                               apply_plan_stacked, quantize_tree, tree_nbytes)
from repro.quant.kvcache import DEFAULT_KV_GROUP, KVPlan

ARTIFACT_VERSION = 1

# Block decisions at (or below) these precisions already carry int4-or-lower
# payloads — a self-speculative draft (docs/DESIGN.md §11) shares them
# byte-for-byte with the target instead of storing a copy.
DRAFT_SHARED = ("int4", "int3", "ternary")

# Entropy-weighted weight decision -> KV-cache precision (docs/DESIGN.md
# §10): layers whose weights tolerate aggressive quantization (low entropy)
# also take the int4 cache; sensitive (raw-weight) layers keep bf16 K/V.
KV_OF_WEIGHT = {"ternary": "int4", "int3": "int4", "int4": "int4",
                "int8": "int8", "raw": "bf16"}


@dataclasses.dataclass(frozen=True)
class StackSpec:
    """One scanned layer stack: param key + the plan slice covering it."""
    key: str                        # params dict key ("layers", "enc_layers", ...)
    lo: int                         # first plan decision index (inclusive)
    hi: int                         # last plan decision index (exclusive)
    cut_period: Optional[int] = None  # forced segment cuts every N layers


@dataclasses.dataclass(frozen=True)
class ExtraSpec:
    """One non-stacked block quantized whole (embedding, hybrid shared)."""
    key: str
    index: int                      # plan decision index


def family_layout(cfg: ModelConfig) -> tuple[list[StackSpec], list[ExtraSpec]]:
    """Map a family's ``block_params`` order onto its param-dict layout.

    The decision order is [embed] + stacked layers (+ family extras), matching
    ``Model.block_params`` / the planner's exec_index convention.
    """
    n = cfg.num_layers
    if cfg.family in ("dense", "moe", "ssm"):
        return [StackSpec("layers", 1, 1 + n)], [ExtraSpec("embed", 0)]
    if cfg.family == "hybrid":
        # Mixed plans must not let a segment span a shared-attention site:
        # cut at unit boundaries so execution stays a per-unit inner scan.
        return ([StackSpec("layers", 1, 1 + n,
                           cut_period=cfg.shared_attn_period)],
                [ExtraSpec("embed", 0), ExtraSpec("shared", 1 + n)])
    if cfg.family == "encdec":
        ne = cfg.num_encoder_layers
        return ([StackSpec("enc_layers", 1, 1 + ne),
                 StackSpec("dec_layers", 1 + ne, 1 + ne + n)],
                [ExtraSpec("embed", 0)])
    raise ValueError(f"unknown family {cfg.family!r}")


def plan_length(cfg: ModelConfig) -> int:
    """Number of block decisions a plan for ``cfg`` must carry."""
    stacks, extras = family_layout(cfg)
    return max([s.hi for s in stacks] + [e.index + 1 for e in extras])


def _subplan(plan: QuantPlan, lo: int, hi: int) -> QuantPlan:
    return dataclasses.replace(plan, decisions=plan.decisions[lo:hi])


def kv_cache_layers(cfg: ModelConfig) -> int:
    """Leading-axis length of the family's attention cache (0: no cache)."""
    if cfg.family in ("dense", "moe"):
        return cfg.num_layers
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.shared_attn_period  # U shared sites
    if cfg.family == "encdec":
        return cfg.num_layers                            # decoder stack
    return 0                                             # ssm


def compile_kv_plan(cfg: ModelConfig, plan: Optional[QuantPlan],
                    kv_precision: str = "auto",
                    group: int = DEFAULT_KV_GROUP) -> Optional[KVPlan]:
    """Lower a KV-cache precision policy onto a family's cache layout.

    ``kv_precision``:
      "bf16"          — no quantized cache (None)
      "int8" / "int4" — uniform across all cache layers
      "auto"          — entropy-weighted: each cache layer inherits its
        block's weight decision via ``KV_OF_WEIGHT`` (hybrid's shared-site
        cache follows the shared block's single decision; enc-dec follows
        the decoder stack). Requires ``plan``.
    """
    if kv_precision in (None, "bf16"):
        return None
    n = kv_cache_layers(cfg)
    if n == 0:          # attention-free (ssm): nothing to plan
        return None
    if kv_precision in ("int8", "int4"):
        return KVPlan(precisions=(kv_precision,) * n, group=group)
    if kv_precision != "auto":
        raise ValueError(f"unknown kv_precision {kv_precision!r}; one of "
                         f"('bf16', 'int8', 'int4', 'auto')")
    if plan is None:
        raise ValueError("kv_precision='auto' derives per-layer cache "
                         "precision from the weight plan's entropy "
                         "decisions — pass a QuantPlan")
    if cfg.family == "hybrid":
        shared = plan.decisions[1 + cfg.num_layers].precision
        prec = (KV_OF_WEIGHT[shared],) * n
    elif cfg.family == "encdec":
        ne = cfg.num_encoder_layers
        prec = tuple(KV_OF_WEIGHT[d.precision]
                     for d in plan.decisions[1 + ne:1 + ne + cfg.num_layers])
    else:
        prec = tuple(KV_OF_WEIGHT[d.precision]
                     for d in plan.decisions[1:1 + cfg.num_layers])
    return KVPlan(precisions=prec, group=group)


_KV_DOWN = {"bf16": "int8", "int8": "int4", "int4": "int4"}


def degrade_kv_ladder(cfg: ModelConfig, plan: Optional[QuantPlan],
                      base: Optional[KVPlan],
                      group: int = DEFAULT_KV_GROUP, *,
                      fastewq=None, block_sizes=None,
                      cuts: Sequence[int] = ()) -> list:
    """Entropy-ordered KV degradation tiers (DESIGN.md §15).

    Tier 0 is the serving policy (``base``; None = bf16). Deeper tiers
    spill cache precision down bf16→int8→int4 in the order the layer-
    level entropy signal dictates: layers whose weight blocks the plan
    marked quantizable (or that a FastEWQ classifier predicts quantizable
    from metadata alone, O(1) per block) spill FIRST; entropy-sensitive
    layers follow one tier later; the final tier is all-int4. Lowering
    precision at constant byte budget buys proportionally more pool
    pages, which is what relieves ``OutOfPages`` pressure — see
    ``ServeEngine.apply_kv_plan``.
    """
    n = kv_cache_layers(cfg)
    if n == 0:
        return []
    base_prec = list(base.precisions) if base is not None else ["bf16"] * n
    if base is not None:
        group = base.group
    if plan is not None:
        if cfg.family == "hybrid":
            spill = [plan.decisions[1 + cfg.num_layers].quantized] * n
        elif cfg.family == "encdec":
            ne = cfg.num_encoder_layers
            spill = [d.quantized
                     for d in plan.decisions[1 + ne:1 + ne + cfg.num_layers]]
        else:
            spill = [d.quantized for d in plan.decisions[1:1 + cfg.num_layers]]
    elif fastewq is not None and block_sizes is not None:
        order = fastewq.kv_spill_order(block_sizes)
        first = set(order[:max(1, len(order) // 2)])
        spill = [i in first for i in range(n)]
    else:
        # no entropy signal: deepest layers spill first (paper §6.3 —
        # the highest exec-index quantized block is first to drop a tier)
        spill = [i >= n // 2 for i in range(n)]
    # decode scans the cache one pool run per parameter segment
    # (kvcache.kv_segment), so a tier's precision must be uniform within
    # each segment (no cuts = ONE segment spanning the stack): a segment
    # spills when at least half of its layers' entropy decisions say spill
    bounds = [0] + [c for c in sorted(set(cuts)) if 0 < c < n] + [n]
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        seg = sum(spill[lo:hi]) * 2 >= (hi - lo)
        spill[lo:hi] = [seg] * (hi - lo)
    if not any(spill):
        spill = [True] * n
    t1 = [_KV_DOWN[p] if s else p for p, s in zip(base_prec, spill)]
    t2 = [_KV_DOWN[_KV_DOWN[p]] if s else _KV_DOWN[p]
          for p, s in zip(base_prec, spill)]
    t3 = ["int4"] * n
    tiers = [base]
    last = base_prec
    for t in (t1, t2, t3):
        if t != last:
            tiers.append(KVPlan(precisions=tuple(t), group=group))
            last = t
    return tiers


def kv_tier_labels(ladder: Sequence[Optional[KVPlan]]) -> list[str]:
    """Human precision label per degradation tier ("bf16" / "int8" /
    "mixed" / ...), used as the ``precision`` metric label on
    ``serve_kv_tier_steps_total`` so a dashboard shows which cache
    precision the degraded steps actually ran at."""
    labels = []
    for kv in ladder:
        if kv is None:
            labels.append("bf16")
            continue
        uniq = sorted(set(kv.precisions))
        labels.append(uniq[0] if len(uniq) == 1 else "mixed")
    return labels


@dataclasses.dataclass
class CompiledPlan:
    """A QuantPlan lowered onto one model's parameters.

    ``params`` is the full parameter tree ready for the model/serving stack:
    every scanned stack is a ``SegmentedParams`` (even uniform/raw plans —
    one segment), per-block extras are quantized trees, and untouched keys
    ("final", ...) pass through. ``kv_plan`` (optional) records the
    KV-cache precision policy compiled alongside the weights; it is
    stamped into the artifact manifest so a cold boot serves with the same
    cache quantization without re-analysis.
    """
    family: str
    config_name: str
    group: int
    plan: QuantPlan
    params: Any
    kv_plan: Optional[KVPlan] = None
    # self-speculative draft stamp (DraftPlan.to_manifest()): recorded so a
    # cold boot re-derives the identical draft without re-deciding anything
    # (the derivation is deterministic given plan + params); the stamped
    # overhead_bytes is the deployment-memory number (docs/DESIGN.md §11)
    draft: Optional[dict] = None

    def stack_keys(self) -> list[str]:
        return [k for k, v in self.params.items()
                if isinstance(v, SegmentedParams)]

    def nbytes_effective(self) -> float:
        total = 0.0
        for v in self.params.values():
            total += (v.nbytes_effective() if isinstance(v, SegmentedParams)
                      else tree_nbytes(v))
        return total

    def manifest(self) -> dict:
        stacks = {}
        for key in self.stack_keys():
            seg = self.params[key]
            stacks[key] = [{"precision": s.precision, "start": s.start,
                            "stop": s.stop} for s in seg.segments]
        out = {
            "version": ARTIFACT_VERSION,
            "family": self.family,
            "config_name": self.config_name,
            "group": self.group,
            "plan": json.loads(self.plan.to_json()),
            "stacks": stacks,
            "effective_bytes": float(self.nbytes_effective()),
        }
        if self.kv_plan is not None:
            out["kv_plan"] = self.kv_plan.to_dict()
        if self.draft is not None:
            out["draft"] = self.draft
        return out


def compile_plan(model, params, plan: QuantPlan, group: int = 128,
                 kv_precision: str = "bf16",
                 kv_group: int = DEFAULT_KV_GROUP) -> CompiledPlan:
    """Lower ``plan`` onto ``params`` for any model family.

    ``kv_precision`` additionally compiles a KV-cache plan
    (``compile_kv_plan``) carried on the result and stamped into artifact
    manifests. Traceable (pure jnp + static python control flow), so it
    runs under ``jax.eval_shape`` for abstract/dry-run inputs.
    """
    cfg = model.cfg
    expected = plan_length(cfg)
    assert len(plan.decisions) == expected, \
        (f"plan has {len(plan.decisions)} decisions; family {cfg.family!r} "
         f"needs {expected}")
    stacks, extras = family_layout(cfg)
    new = dict(params)
    for spec in stacks:
        sub = _subplan(plan, spec.lo, spec.hi)
        cuts: Sequence[int] = ()
        if spec.cut_period and len(set(sub.precisions())) > 1:
            cuts = range(spec.cut_period, spec.hi - spec.lo, spec.cut_period)
        new[spec.key] = apply_plan_stacked(params[spec.key], sub, group,
                                           cuts=cuts)
    for spec in extras:
        new[spec.key] = quantize_tree(
            params[spec.key], plan.decisions[spec.index].precision, group)
    return CompiledPlan(family=cfg.family, config_name=cfg.name, group=group,
                        plan=plan, params=new,
                        kv_plan=compile_kv_plan(cfg, plan, kv_precision,
                                                kv_group))


# ---------------------------------------------------------------------------
# self-speculative draft plans (docs/DESIGN.md §11)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DraftPlan:
    """An entropy-ordered all-int4 draft derived from a compiled target.

    ``params`` is a full parameter tree executable by the same model code
    as the target: blocks the entropy plan already pushed to int4 (or
    lower) REFERENCE the target's QTensor payloads — the same jax.Arrays,
    zero extra HBM — while raw/int8 blocks carry a draft-only int4
    requantization. ``overhead_bytes`` is exactly those draft-only
    payloads (the manifest number; by construction it is bounded by the
    int4 size of the blocks it re-quantizes)."""
    params: Any
    precisions: tuple[str, ...]     # per-block draft decision (plan order;
                                    # "skip" = truncated away, not executed)
    shared_blocks: int              # decisions sharing target payloads
    requantized_blocks: int         # decisions with a draft-only int4 copy
    overhead_bytes: float
    group: int
    draft_layers: Optional[int] = None  # truncated layer count (None: full)

    def to_manifest(self) -> dict:
        return {"precisions": list(self.precisions),
                "shared_blocks": self.shared_blocks,
                "requantized_blocks": self.requantized_blocks,
                "overhead_bytes": float(self.overhead_bytes),
                "group": self.group,
                "draft_layers": self.draft_layers}


def _draft_tree(tree: Any, group: int, min_ndim: int) -> tuple[Any, float]:
    """Requantize one block's tree to int4, dequantizing int8 QTensors
    first; already-aggressive QTensors and ineligible leaves are shared.
    Returns (draft_tree, draft_only_bytes)."""
    from repro.quant.qtypes import QTensor
    from repro.quant.quantize import dequantize, quantize
    overhead = [0.0]

    def leaf(x):
        if isinstance(x, QTensor):
            if x.precision in DRAFT_SHARED:
                return x                       # shared payload, zero bytes
            q = quantize(dequantize(x, jnp.float32), "int4", x.group)
            overhead[0] += q.nbytes_effective()
            return q
        if _quantizable(x, group, min_ndim):
            q = quantize(x, "int4", group)
            overhead[0] += q.nbytes_effective()
            return q
        return x                               # norms/biases: shared raw

    out = jax.tree.map(leaf, tree,
                       is_leaf=lambda x: isinstance(x, QTensor))
    return out, overhead[0]


def _slice_stack_layers(tree: Any, take: int) -> Any:
    """Slice the leading (stacked-layer) axis of every leaf to [0, take),
    rebuilding the STATIC logical shape QTensors carry (a plain tree.map
    would slice data/scale but leave ``shape`` stale)."""
    from repro.quant.qtypes import QTensor

    def leaf(x):
        if isinstance(x, QTensor):
            return QTensor(data=x.data[:take], scale=x.scale[:take],
                           precision=x.precision,
                           shape=(take,) + tuple(x.shape[1:]),
                           group=x.group)
        return x[:take]

    return jax.tree.map(leaf, tree,
                        is_leaf=lambda x: isinstance(x, QTensor))


def compile_draft_plan(model, params, plan: Optional[QuantPlan],
                       group: int = 128,
                       draft_layers: Optional[int] = None) -> DraftPlan:
    """Derive the self-speculative all-int4 draft from a served model.

    ``params`` is the tree the engine serves (compiled: segmented stacks +
    quantized extras; or raw when ``plan`` is None). The draft derivation
    rule follows the plan's entropy ordering: every block decision maps to
    ``min(decision, int4)`` — blocks the entropy analysis already marked
    aggressive keep their exact payloads (shared, no copy), higher-entropy
    raw/int8 blocks get a draft-only int4 requantization. With no plan
    (raw serving) the draft is a uniform int4 copy of every eligible
    block. Segment boundaries are preserved 1:1 with the target, so the
    draft executes through the identical segmented scan paths (hybrid unit
    cuts included) and shares the target's KV-cache layout.

    ``draft_layers=N`` truncates the draft to the first N layers of the
    stack (early-exit drafting, fused-propose families only — the target's
    verification keeps greedy output exact regardless of draft depth). A
    segment the cut lands inside is sliced; slicing materializes a copy,
    so sliced segments count toward ``overhead_bytes`` even when their
    precision would otherwise share the target payload. Truncated-away
    blocks are stamped ``"skip"`` in ``precisions``."""
    cfg = model.cfg
    if draft_layers is not None:
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"draft_layers needs the fused propose path (dense/moe "
                f"families); family is {cfg.family!r}")
        if not 1 <= draft_layers <= cfg.num_layers:
            raise ValueError(
                f"draft_layers must be in [1, {cfg.num_layers}], got "
                f"{draft_layers}")
    new = dict(params)
    stacks, extras = family_layout(cfg)
    overhead = 0.0
    shared = requant = 0
    n_blocks = plan_length(cfg)
    precisions = ["int4"] * n_blocks

    def mark_skipped():
        if draft_layers is None:
            return
        for spec in stacks:                    # dense/moe: one "layers" stack
            for i in range(draft_layers, spec.hi - spec.lo):
                precisions[spec.lo + i] = "skip"

    if plan is None:
        for key, val in params.items():
            n = draft_layers if key == "layers" else None
            if isinstance(val, SegmentedParams):
                segs = []
                for seg in val.segments:
                    if n is not None and seg.start >= n:
                        break
                    stop = min(seg.stop, n) if n is not None else seg.stop
                    src = (_slice_stack_layers(seg.params, stop - seg.start)
                           if stop < seg.stop else seg.params)
                    t, ob = _draft_tree(src, group, min_ndim=3)
                    segs.append(Segment(precision="int4", start=seg.start,
                                        stop=stop, params=t))
                    overhead += ob
                new[key] = SegmentedParams(
                    segments=segs,
                    num_layers=n if n is not None else val.num_layers)
            elif key in ("embed", "shared") or any(s.key == key
                                                   for s in stacks):
                if n is not None:
                    val = _slice_stack_layers(val, n)
                new[key], ob = _draft_tree(val, group,
                                           min_ndim=3 if any(
                                               s.key == key for s in stacks)
                                           else 2)
                overhead += ob
        mark_skipped()
        requant = sum(1 for p in precisions if p != "skip")
        return DraftPlan(params=new, precisions=tuple(precisions),
                         shared_blocks=0, requantized_blocks=requant,
                         overhead_bytes=overhead, group=group,
                         draft_layers=draft_layers)

    assert len(plan.decisions) == n_blocks, \
        (f"plan has {len(plan.decisions)} decisions; family {cfg.family!r} "
         f"needs {n_blocks}")
    for spec in stacks:
        layers = params[spec.key]
        assert isinstance(layers, SegmentedParams), \
            (f"draft derivation expects compiled (segmented) stacks; "
             f"{spec.key!r} is {type(layers).__name__} — compile the plan "
             f"first (quant/compiler.compile_plan)")
        n = draft_layers if spec.key == "layers" else None
        segs = []
        for seg in layers.segments:
            if n is not None and seg.start >= n:
                break
            sliced = n is not None and seg.stop > n
            stop = n if sliced else seg.stop
            if seg.precision in DRAFT_SHARED and not sliced:
                segs.append(seg)               # payloads shared verbatim
                shared += stop - seg.start
                for i in range(seg.start, stop):
                    precisions[spec.lo + i] = seg.precision
            elif seg.precision in DRAFT_SHARED:
                # the slice materializes a draft-only copy of an
                # already-aggressive payload — same precision, real bytes
                t = _slice_stack_layers(seg.params, stop - seg.start)
                segs.append(Segment(precision=seg.precision,
                                    start=seg.start, stop=stop, params=t))
                overhead += tree_nbytes(t)
                shared += stop - seg.start
                for i in range(seg.start, stop):
                    precisions[spec.lo + i] = seg.precision
            else:
                src = (_slice_stack_layers(seg.params, stop - seg.start)
                       if sliced else seg.params)
                t, ob = _draft_tree(src, group, min_ndim=3)
                segs.append(Segment(precision="int4", start=seg.start,
                                    stop=stop, params=t))
                overhead += ob
                requant += stop - seg.start
        new[spec.key] = SegmentedParams(
            segments=segs,
            num_layers=n if n is not None else layers.num_layers)
    mark_skipped()
    for spec in extras:
        prec = plan.decisions[spec.index].precision
        if prec in DRAFT_SHARED:
            shared += 1
            precisions[spec.index] = prec
        else:
            new[spec.key], ob = _draft_tree(params[spec.key], group,
                                            min_ndim=2)
            overhead += ob
            requant += 1
    return DraftPlan(params=new, precisions=tuple(precisions),
                     shared_blocks=shared, requantized_blocks=requant,
                     overhead_bytes=overhead, group=group,
                     draft_layers=draft_layers)


# ---------------------------------------------------------------------------
# persisted artifacts (compile once, serve many)
# ---------------------------------------------------------------------------

def validate_manifest(manifest: dict, cfg: ModelConfig) -> None:
    """Check an artifact manifest against a target model config up front.

    Raises a ``ValueError`` naming the mismatch (family, config, plan
    length, stack layout, group size) instead of letting the restore fail
    deep inside per-leaf shape checks.
    """
    def bail(msg):
        raise ValueError(f"artifact/model mismatch: {msg}")

    if manifest.get("version") != ARTIFACT_VERSION:
        bail(f"manifest version {manifest.get('version')!r}, this build "
             f"reads version {ARTIFACT_VERSION}")
    if manifest["family"] != cfg.family or manifest["config_name"] != cfg.name:
        bail(f"artifact was compiled for {manifest['config_name']!r} "
             f"({manifest['family']}); model is {cfg.name!r} ({cfg.family})")
    expected = plan_length(cfg)
    got = len(manifest["plan"]["decisions"])
    if got != expected:
        bail(f"plan carries {got} block decisions; family {cfg.family!r} "
             f"config {cfg.name!r} needs {expected} (layer counts differ?)")
    stacks, _ = family_layout(cfg)
    want_stacks = {s.key: s.hi - s.lo for s in stacks}
    got_stacks = manifest.get("stacks", {})
    if set(got_stacks) != set(want_stacks):
        bail(f"stack keys {sorted(got_stacks)} != expected "
             f"{sorted(want_stacks)}")
    for key, segs in got_stacks.items():
        covered = sum(s["stop"] - s["start"] for s in segs)
        if covered != want_stacks[key]:
            bail(f"stack {key!r} segments cover {covered} layers; config "
                 f"has {want_stacks[key]}")
    group = manifest["group"]
    if not isinstance(group, int) or group < 1:
        bail(f"group size {group!r} is not a positive integer")
    # A group that quantizes different leaves than the save-time compile
    # (e.g. a tampered manifest) surfaces as a leaf-KIND mismatch between
    # the rebuilt skeleton and the checkpoint — ckpt.restore names it.


def save_artifact(directory: str, compiled: CompiledPlan,
                  mesh=None) -> str:
    """Persist a compiled plan: quantized params checkpoint + manifest.

    Arrays are stored logically (shards are gathered to host buffers), so
    the artifact is mesh-portable: it can be restored onto any mesh — or
    none. ``mesh`` only stamps the save-time layout into the manifest for
    provenance."""
    from repro.checkpoint import ckpt
    from repro.kernels.autotune import current_stamp
    manifest = compiled.manifest()
    # which kernel-tuning config (kernels/autotune.py) was live when the
    # artifact was produced — "untuned" for library defaults. Cold-booted
    # replicas re-resolve against their own device's cache; this records
    # provenance for the numbers benchmarked at save time.
    manifest["autotune"] = current_stamp()
    if mesh is not None:
        manifest["saved_mesh"] = {
            "axis_names": list(mesh.axis_names),
            "shape": [int(mesh.shape[a]) for a in mesh.axis_names]}
    return ckpt.save_artifact(directory, compiled.params, manifest)


def load_artifact(directory: str, model, *, mesh=None) -> CompiledPlan:
    """Boot a CompiledPlan from disk without raw weights or entropy analysis.

    The manifest's plan is re-lowered through ``compile_plan`` under
    ``eval_shape`` to rebuild the exact (segmented, quantized) tree skeleton,
    then the checkpointed leaves are restored into it. With ``mesh``, every
    leaf is device_put to its TP-only serving NamedSharding
    (``param_specs(serving=True)``) straight from the checkpoint file —
    weights land sharded, never materialized replicated.
    """
    from repro.checkpoint import ckpt
    manifest = ckpt.load_artifact_manifest(directory)
    cfg = model.cfg
    validate_manifest(manifest, cfg)
    plan = QuantPlan.from_json(json.dumps(manifest["plan"]))
    group = manifest["group"]
    skeleton = jax.eval_shape(
        lambda p: compile_plan(model, p, plan, group).params,
        model.abstract_params())
    if mesh is not None:
        from repro.sharding.specs import param_specs
        specs = param_specs(skeleton, mesh, serving=True)
        # specs mirrors the skeleton leaf-for-leaf, so restore device_puts
        # every leaf to its NamedSharding — already committed jax.Arrays.
        params = ckpt.restore_artifact(directory, skeleton, mesh=mesh,
                                       specs=specs)
    else:
        params = ckpt.restore_artifact(directory, skeleton)
        params = jax.tree.map(jnp.asarray, params)
    kv_plan = (KVPlan.from_dict(manifest["kv_plan"])
               if manifest.get("kv_plan") else None)
    return CompiledPlan(family=cfg.family, config_name=cfg.name, group=group,
                        plan=plan, params=params, kv_plan=kv_plan,
                        draft=manifest.get("draft"))
