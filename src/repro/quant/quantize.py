"""Group-wise symmetric absmax quantization (int8 / packed int4 / ternary).

Quantization groups run along the tensor's LAST axis. All matmul weights in
this framework are stored ``(out_features, in_features)`` (and stacked
``(layers, out, in)``), so the last axis is the contraction axis and the
per-group scale factors out of each partial dot product — dequantization
fuses into the matmul (see repro/kernels/qmatmul). Embedding tables (V, D)
are gathered along axis 0, so per-row groups along D likewise dequantize
cheaply at lookup.

int4 packing: two nibbles per int8, low nibble = even element. Packing is
along the last axis, so a (..., K) tensor stores (..., K//2) int8.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.qtypes import DEFAULT_GROUP, QTensor


def _grouped(w: jax.Array, group: int) -> jax.Array:
    *lead, k = w.shape
    assert k % group == 0, f"last dim {k} not divisible by group {group}"
    return w.reshape(*lead, k // group, group)


def quantize_int8(w: jax.Array, group: int = DEFAULT_GROUP) -> QTensor:
    g = _grouped(w.astype(jnp.float32), group)
    absmax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = absmax / 127.0
    q = jnp.round(g / jnp.where(scale == 0, 1.0, scale))
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return QTensor(data=q.reshape(w.shape), scale=scale[..., 0].astype(jnp.bfloat16),
                   precision="int8", shape=tuple(w.shape), group=group)


def quantize_int4(w: jax.Array, group: int = DEFAULT_GROUP) -> QTensor:
    g = _grouped(w.astype(jnp.float32), group)
    absmax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = absmax / 7.0
    q = jnp.round(g / jnp.where(scale == 0, 1.0, scale))
    q = jnp.clip(q, -7, 7).astype(jnp.int8).reshape(w.shape)
    # Pack two 4-bit values per int8 along the last axis.
    *lead, k = w.shape
    q2 = q.reshape(*lead, k // 2, 2)
    lo = q2[..., 0] & 0x0F
    hi = (q2[..., 1] & 0x0F) << 4
    packed = (lo | hi).astype(jnp.int8)
    return QTensor(data=packed, scale=scale[..., 0].astype(jnp.bfloat16),
                   precision="int4", shape=tuple(w.shape), group=group)


def unpack_int4(data: jax.Array) -> jax.Array:
    """Unpack int8-packed nibbles back to signed int8 in [-7, 7]."""
    lo = (data & 0x0F).astype(jnp.int8)
    hi = ((data >> 4) & 0x0F).astype(jnp.int8)
    # Sign-extend 4-bit two's complement.
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    return jnp.stack([lo, hi], axis=-1).reshape(*data.shape[:-1], data.shape[-1] * 2)


def quantize_ternary(w: jax.Array, group: int = DEFAULT_GROUP) -> QTensor:
    """1.58-bit (BitNet-style) ternary: W ~ scale * sign(W) * 1{|W| > tau},
    tau = mean(|W|) per group (standard absmean ternarization)."""
    g = _grouped(w.astype(jnp.float32), group)
    absmean = jnp.mean(jnp.abs(g), axis=-1, keepdims=True)
    q = jnp.where(jnp.abs(g) > 0.5 * absmean, jnp.sign(g), 0.0)
    # Scale minimizes ||W - s*q||^2 per group: s = <W,q>/<q,q>.
    num = jnp.sum(g * q, axis=-1, keepdims=True)
    den = jnp.sum(q * q, axis=-1, keepdims=True)
    scale = num / jnp.where(den == 0, 1.0, den)
    return QTensor(data=q.reshape(w.shape).astype(jnp.int8),
                   scale=scale[..., 0].astype(jnp.bfloat16),
                   precision="ternary", shape=tuple(w.shape), group=group)


def quantize(w: jax.Array, precision: str, group: int = DEFAULT_GROUP) -> QTensor:
    if precision == "int8":
        return quantize_int8(w, group)
    if precision in ("int4", "int3"):  # int3 uses the int4 carrier at [-3,3]
        return quantize_int4(w, group)
    if precision == "ternary":
        return quantize_ternary(w, group)
    raise ValueError(f"cannot quantize to precision={precision!r}")


def dequantize(q: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    """Dequantize. Shapes are derived from ``q.data`` (not the static
    ``q.shape`` metadata) so QTensors stay valid under scan/vmap slicing."""
    if q.precision == "int8":
        vals = q.data.astype(jnp.float32)
    elif q.precision == "int4":
        vals = unpack_int4(q.data).astype(jnp.float32)
    elif q.precision == "ternary":
        vals = q.data.astype(jnp.float32)
    else:
        raise ValueError(q.precision)
    *lead, k = vals.shape
    g = vals.reshape(*lead, k // q.group, q.group)
    out = g * q.scale.astype(jnp.float32)[..., None]
    return out.reshape(*lead, k).astype(dtype)
