"""Apply a QuantPlan to model parameters.

Two layouts are supported:

* ``apply_plan_blocks``   — params as an ordered list of per-block dicts
  (serving engine / small models / tests).  Each >=2D leaf of a block whose
  decision is quantized becomes a QTensor.
* ``apply_plan_stacked``  — params with leaves stacked over a leading layer
  axis (the scan layout used by every model here).  The layer stack is
  partitioned into maximal contiguous *segments* of equal precision; each
  segment keeps its stacked layout (quantized per segment precision), so the
  model can scan each segment separately.  A uniform plan degenerates to one
  segment (the fast path).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import QuantPlan
from repro.quant.qtypes import QTensor
from repro.quant.quantize import quantize


def _quantizable(x: Any, group: int, min_ndim: int) -> bool:
    return (hasattr(x, "ndim") and x.ndim >= min_ndim
            and x.shape[-1] % group == 0 and x.shape[-1] % 2 == 0)


def quantize_tree(tree: Any, precision: str, group: int = 128,
                  min_ndim: int = 2) -> Any:
    """Quantize every eligible leaf of a pytree; ineligible leaves pass
    through. ``min_ndim=3`` for layer-stacked trees, where 1D per-layer
    vectors (norm scales, biases, A_log) appear as 2D (L, D) leaves and must
    stay raw (the paper quantizes Linear/Embedding weights only)."""
    if precision == "raw":
        return tree

    def leaf(x):
        if _quantizable(x, group, min_ndim):
            return quantize(x, precision, group)
        return x

    return jax.tree.map(leaf, tree)


def apply_plan_blocks(blocks: list[Mapping[str, Any]], plan: QuantPlan,
                      group: int = 128) -> list[Any]:
    assert len(blocks) == len(plan.decisions), \
        f"{len(blocks)} blocks vs {len(plan.decisions)} decisions"
    return [quantize_tree(b, d.precision, group)
            for b, d in zip(blocks, plan.decisions)]


# ---------------------------------------------------------------------------
# Stacked (scan) layout
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Segment:
    precision: str
    start: int          # first layer index (inclusive)
    stop: int           # last layer index (exclusive)
    params: Any         # stacked over [start:stop), quantized unless raw

    def tree_flatten(self):
        return (self.params,), (self.precision, self.start, self.stop)

    @classmethod
    def tree_unflatten(cls, aux, children):
        precision, start, stop = aux
        return cls(precision=precision, start=start, stop=stop,
                   params=children[0])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SegmentedParams:
    segments: list[Segment]
    num_layers: int

    def tree_flatten(self):
        return (self.segments,), (self.num_layers,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(segments=children[0], num_layers=aux[0])

    def nbytes_effective(self) -> float:
        total = 0.0
        for seg in self.segments:
            for leaf in jax.tree.leaves(
                    seg.params, is_leaf=lambda x: isinstance(x, QTensor)):
                if isinstance(leaf, QTensor):
                    total += leaf.nbytes_effective()
                else:
                    total += leaf.size * leaf.dtype.itemsize
        return total


def plan_segments(plan: QuantPlan,
                  cuts: Sequence[int] = ()) -> list[tuple[str, int, int]]:
    """Maximal runs of equal precision over block_index order.

    ``cuts`` forces additional segment boundaries at the given layer indices
    (the hybrid family cuts at shared-attention unit boundaries so every
    segment executes inside exactly one unit — docs/DESIGN.md §8)."""
    precisions = plan.precisions()
    cutset = set(cuts)
    runs: list[tuple[str, int, int]] = []
    start = 0
    for i in range(1, len(precisions) + 1):
        if (i == len(precisions) or precisions[i] != precisions[start]
                or i in cutset):
            runs.append((precisions[start], start, i))
            start = i
    return runs


def apply_plan_stacked(stacked: Any, plan: QuantPlan, group: int = 128,
                       cuts: Sequence[int] = ()) -> SegmentedParams:
    """``stacked`` leaves have a leading layer axis of length == len(plan).

    The plan here must cover exactly the stacked layers (embedding / final
    params are handled separately by the caller).
    """
    num_layers = len(plan.decisions)
    segs = []
    for precision, start, stop in plan_segments(plan, cuts):
        sliced = jax.tree.map(lambda x: x[start:stop], stacked)
        segs.append(Segment(precision=precision, start=start, stop=stop,
                            params=quantize_tree(sliced, precision, group,
                                                 min_ndim=3)))
    return SegmentedParams(segments=segs, num_layers=num_layers)


def segment_slices(layers: Any) -> list[tuple[Any, int, int]]:
    """Uniform iteration over a layer stack that may or may not be segmented.

    Returns ``[(stacked_params, start, stop), ...]`` — one entry per segment
    for a ``SegmentedParams``, or a single full-range entry for a plain
    stacked tree. Model scan bodies use this to run each segment (and its
    cache slice ``[start:stop]``) through one ``lax.scan`` without branching
    on the parameter layout."""
    if isinstance(layers, SegmentedParams):
        return [(s.params, s.start, s.stop) for s in layers.segments]
    n = jax.tree.leaves(layers)[0].shape[0]
    return [(layers, 0, n)]


def tree_nbytes(tree: Any) -> float:
    """Effective byte count of a tree that may contain QTensors."""
    total = 0.0
    for leaf in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes_effective()
        elif hasattr(leaf, "size"):
            total += leaf.size * np.dtype(leaf.dtype).itemsize
    return total
