"""Device-side ops for the paged KV pool (docs/DESIGN.md §13).

A ``kvcache.PagedKV`` field keeps K/V tokens in a shared pool of
fixed-size pages reached through a per-slot page table. Everything here
is traceable and shape-static:

* ``init_pool_field``   — build an empty pool for a cache field, cut into
  per-precision runs exactly like ``quantize_cache_field``;
* ``update_pages``      — decode-step write: scatter s quantized token
  rows through the page table (the paged twin of ``update_page``);
* ``insert_slot_paged`` — admission: quantize a whole prefilled request
  and scatter it page-by-page into the slot's physical pages in ONE jit
  (shared prefix pages are redirected to the dump page, so the same
  compiled insert serves any prefix-hit length);
* ``gather`` / ``gather_rows`` — materialize pool pages back into a dense
  ``KVPage`` view (the ``simple`` decode backend; prefix-hit seeding).

Write-safety invariant: decode/spec-verify writes always target positions
``>= prompt_len`` (fresh slots sit at ``pos == prompt_len``), and pages
shared through the prefix cache cover only full prompt pages
(``(j+1) * P <= prompt_len``), so a shared physical page is never written
by any slot mapping it — copy-on-write resolves at admission time (the
divergent boundary page is materialized into a private page by the
insert), never in the decode hot path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.kvcache import KVPage, PagedKV, dequantize_kv, quantize_kv

DUMP_PAGE = 0


def _quant_rows(x: jax.Array, precision: str, group: int, data_dtype
                ) -> tuple[jax.Array, Optional[jax.Array]]:
    """Quantize token rows with the page's exact write math. "bf16" pools
    store the pool dtype verbatim (the raw cache dtype — NOT forced to
    bfloat16), so a paged bf16 engine matches the dense raw path's values
    bit-for-bit."""
    if precision == "bf16":
        return x.astype(data_dtype), None
    data, scale = quantize_kv(x, precision, group)
    return data.astype(data_dtype), scale


def init_pool_field(raw_proto: jax.Array, runs: Sequence[tuple[str, int, int]],
                    *, num_pages: int, page_size: int, num_slots: int,
                    group: int) -> Any:
    """Empty pool(s) for one cache field.

    ``raw_proto``: the dense raw field the pool replaces — only its shape
    (L, B, S, Hkv, hd) and dtype are read. ``runs``: (precision, lo, hi)
    layer runs (KVPlan.pages(cuts), or a single bf16 run). ``num_pages``
    counts allocatable pages; physical page 0 (the dump page) is added on
    top. Every table starts all-dump."""
    l_total, _, _, hkv, hd = raw_proto.shape
    assert runs and runs[-1][2] == l_total, (runs, l_total)
    n_log = -(-raw_proto.shape[2] // page_size) if raw_proto.shape[2] else 1
    n_phys = num_pages + 1
    f = hkv * hd
    pools = []
    for precision, lo, hi in runs:
        ll = hi - lo
        table = jnp.zeros((ll, num_slots, n_log), jnp.int32)
        if precision == "bf16":
            data = jnp.zeros((ll, n_phys, page_size, hkv, hd),
                             raw_proto.dtype)
            scale = None
        elif precision == "int8":
            data = jnp.zeros((ll, n_phys, page_size, hkv, hd), jnp.int8)
            scale = jnp.zeros((ll, n_phys, page_size, f // group),
                              jnp.bfloat16)
        elif precision == "int4":
            data = jnp.zeros((ll, n_phys, page_size, f // 2), jnp.int8)
            scale = jnp.zeros((ll, n_phys, page_size, f // group),
                              jnp.bfloat16)
        else:
            raise ValueError(f"cannot build a {precision!r} pool")
        pools.append(PagedKV(data=data, scale=scale, table=table,
                             precision=precision, head_dim=hd, group=group,
                             page_size=page_size))
    return tuple(pools) if len(pools) > 1 else pools[0]


def logical_pages(max_seq: int, page_size: int) -> int:
    """Pages a slot's table addresses: ceil(max_seq / page_size)."""
    return -(-max_seq // page_size)


# ---------------------------------------------------------------------------
# writes
# ---------------------------------------------------------------------------

def update_pages(pg: PagedKV, new: jax.Array, pos) -> PagedKV:
    """Decode-step write of ``new`` (B, s, Hkv, hd) at position ``pos``
    (scalar or (B,)) through each slot's page table. Rows whose logical
    page is unallocated (table entry 0) land on the dump page — inactive
    slots write garbage nobody reads instead of corrupting live pages."""
    b, s = new.shape[0], new.shape[1]
    p_sz, n_log = pg.page_size, pg.table.shape[-1]
    data_n, scale_n = _quant_rows(new, pg.precision, pg.group, pg.data.dtype)
    if pg.precision == "int4":
        data_n = data_n.reshape(b, s, -1)          # flat (B, s, F//2)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    data, scale, table = pg.data, pg.scale, pg.table
    for j in range(s):                              # static, s is 1 or K+1
        pj = pos + j
        lpage = jnp.minimum(pj // p_sz, n_log - 1)  # clamp stale deep slots
        phys = jnp.take_along_axis(table, lpage[:, None], axis=1)[:, 0]
        data = data.at[phys, pj % p_sz].set(data_n[:, j])
        if scale is not None:
            scale = scale.at[phys, pj % p_sz].set(
                scale_n[:, j].astype(scale.dtype))
    return dataclasses.replace(pg, data=data, scale=scale)


def _pagify(x: jax.Array, n_log: int, page_size: int) -> jax.Array:
    """(L, n_log * P, ...) -> (L, n_log, P, ...)."""
    return x.reshape(x.shape[0], n_log, page_size, *x.shape[2:])


def insert_slot_paged(field, src: jax.Array, slot, row, wrow):
    """Admit a prefilled request into ``slot`` of a paged field.

    ``src``: raw (L, 1, S, Hkv, hd) batch=1 prefill cache; ``row``: (n_log,)
    int32 physical page per logical page (0 past the request's allocation);
    ``wrow``: same, but with prefix-SHARED pages redirected to the dump
    page — their rows were written by the donor's insert and must not be
    re-written (they are refcounted read-only). The whole prompt is
    quantized and scattered in one shot, so the compiled insert is keyed
    only by the prompt shape — a prefix hit of any length reuses it."""
    pages = field if isinstance(field, tuple) else (field,)
    out, lo = [], 0
    for pg in pages:
        hi = lo + pg.data.shape[0]
        out.append(_insert_one(pg, src[lo:hi], slot, row, wrow))
        lo = hi
    return tuple(out) if isinstance(field, tuple) else out[0]


def _insert_one(pg: PagedKV, src: jax.Array, slot, row, wrow) -> PagedKV:
    l, _, s = src.shape[:3]
    p_sz, n_log = pg.page_size, pg.table.shape[-1]
    rows = src[:, 0]                                  # (L, S, Hkv, hd)
    pad = n_log * p_sz - s
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, pad), (0, 0), (0, 0)))
    data_n, scale_n = _quant_rows(rows, pg.precision, pg.group,
                                  pg.data.dtype)
    if pg.precision == "int4":
        data_n = data_n.reshape(l, n_log * p_sz, -1)
    wrow = jnp.asarray(wrow, jnp.int32)
    # one scatter over the page axis; duplicate dump-page indices are
    # harmless (undefined write order on a garbage page)
    data = pg.data.at[:, wrow].set(_pagify(data_n, n_log, p_sz))
    scale = (None if scale_n is None else
             pg.scale.at[:, wrow].set(
                 _pagify(scale_n.astype(pg.scale.dtype), n_log, p_sz)))
    table = pg.table.at[:, slot].set(jnp.asarray(row, jnp.int32))
    return dataclasses.replace(pg, data=data, scale=scale, table=table)


def release_slot_pages(field, slot):
    """Point a released slot's table at the dump page so its (masked)
    in-flight writes cannot touch pages the allocator hands out again."""
    def one(pg):
        return dataclasses.replace(
            pg, table=pg.table.at[:, slot].set(DUMP_PAGE))
    if isinstance(field, tuple):
        return tuple(one(pg) for pg in field)
    return one(field)


# ---------------------------------------------------------------------------
# reads (dense materialization)
# ---------------------------------------------------------------------------

def _dense_view(pg: PagedKV, gathered_data, gathered_scale) -> KVPage:
    return KVPage(data=gathered_data, scale=gathered_scale,
                  precision=pg.precision, head_dim=pg.head_dim,
                  group=pg.group)


def gather(pg: PagedKV) -> KVPage:
    """Single-layer pool (table (B, n_log)) -> dense (B, n_log*P, ...)
    KVPage view of every slot (the ``simple`` backend's oracle path)."""
    t = pg.table

    def gat(x):
        y = x[t]                                    # (B, n_log, P, ...)
        return y.reshape(y.shape[0], t.shape[1] * pg.page_size,
                         *y.shape[3:])

    return _dense_view(pg, gat(pg.data),
                       None if pg.scale is None else gat(pg.scale))


def gather_rows(pg: PagedKV, row: jax.Array) -> KVPage:
    """Layered pool + one explicit page row (n_log,) -> dense batch=1
    (L, 1, n_log*P, ...) KVPage (prefix-hit prefill seeding)."""
    def gat(x):
        y = x[:, row]                               # (L, n_log, P, ...)
        return y.reshape(y.shape[0], row.shape[0] * pg.page_size,
                         *y.shape[3:])[:, None]

    return _dense_view(pg, gat(pg.data),
                       None if pg.scale is None else gat(pg.scale))


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------

def page_nbytes(field) -> float:
    """Physical bytes ONE logical page costs across a field's pools
    (payload + scales, summed over layer runs; the table is negligible
    and excluded)."""
    pages = field if isinstance(field, tuple) else (field,)
    total = 0.0
    for pg in pages:
        for leaf in (pg.data, pg.scale):
            if leaf is None:
                continue
            n_phys = leaf.shape[1]
            total += (float(np.prod(leaf.shape))
                      * np.dtype(leaf.dtype).itemsize) / n_phys
    return total


# ---------------------------------------------------------------------------
# live repack (graceful degradation, docs/DESIGN.md §15)
# ---------------------------------------------------------------------------

def repack_pool_field(field, runs_new: Sequence[tuple[str, int, int]], *,
                      perm: np.ndarray, inv: np.ndarray, group: int,
                      raw_dtype):
    """Rebuild one paged field under new precision runs and pool size,
    carrying every live page's payload across the transition.

    Each old run is dequantized to ``raw_dtype`` (the dense cache dtype),
    pages move through ``inv`` (new physical id -> old physical id;
    ``inv[0] = 0`` keeps the dump page) and are requantized with the
    exact write math admission would have applied at the new precision —
    a demoted page holds the same values as if its request had been
    admitted at the lower tier. Page tables remap through ``perm`` (old
    physical id -> new; dead pages -> dump). Fully traceable: the caller
    jits one repack per tier transition."""
    pages = field if isinstance(field, tuple) else (field,)
    p_sz = pages[0].page_size
    raws = [dequantize_kv(pg, raw_dtype) for pg in pages]
    full = jnp.concatenate(raws, 0) if len(raws) > 1 else raws[0]
    tables = [pg.table for pg in pages]
    table_full = (jnp.concatenate(tables, 0) if len(tables) > 1
                  else tables[0])
    new_raw = full[:, jnp.asarray(inv, jnp.int32)]   # (L, n_phys_new, P, ...)
    new_table = jnp.asarray(perm, jnp.int32)[table_full]
    hd = new_raw.shape[-1]
    out = []
    for precision, lo, hi in runs_new:
        seg = new_raw[lo:hi]
        data_dtype = (raw_dtype if precision == "bf16"
                      else jnp.int8)
        data, scale = _quant_rows(seg, precision, group, data_dtype)
        if precision == "int4":
            ll, n_phys = data.shape[:2]
            data = data.reshape(ll, n_phys, p_sz, -1)
        out.append(PagedKV(data=data, scale=scale,
                           table=new_table[lo:hi], precision=precision,
                           head_dim=hd, group=group, page_size=p_sz))
    return tuple(out) if len(out) > 1 else out[0]
