"""Chunking / block-shape autotuner for the quantized serving kernels.

Every Pallas kernel and jnp fallback in the serving path carries a small
set of process-wide knobs, all read at TRACE time:

* prefill chunked attention — ``CHUNK_THRESHOLD`` / ``Q_CHUNK`` /
  ``KV_CHUNK`` (models/attention.configure_chunking)
* decode attention sweep    — ``kv_chunk`` + backend
  (kernels/decode_attn.configure_decode_attn)
* qmatmul / megakernel tiles — ``bm`` / ``bn`` / ``bk``
  (kernels/qmatmul.configure_qmatmul)

The right values depend on the accelerator generation, the model family
and the serving precision — int4's packed payload halves the lane width,
so the kv_chunk that saturates an int8 sweep starves an int4 one. This
module turns those knobs into a persisted, keyed configuration:

* ``TunedConfig``  — one immutable bundle of knob values (None = leave
  the library default alone).
* ``tune_key``     — ``device_kind|family|precision|backend``; the same
  binary on new hardware misses the cache and serves untuned rather than
  inheriting another chip's tiles (re-run benchmarks/autotune_sweep.py).
* ``AutotuneCache`` — JSON file (``REPRO_AUTOTUNE_CACHE`` or
  ``~/.cache/repro/autotune.json``) mapping keys to configs + the
  measured tok/s that selected them.
* ``apply_config`` / ``maybe_apply_tuned`` — push a config into the
  three ``configure_*`` hooks; ServeEngine calls ``maybe_apply_tuned``
  before building its jitted executables so tuned values are what the
  traces bake in, and stamps the result ("untuned" or the cache key)
  into ServeStats and saved artifact manifests.
* ``autotune``     — measure candidates with a caller-supplied benchmark
  callable, keep the fastest, persist it.

The sweep driver is benchmarks/autotune_sweep.py; the cache format and
re-tuning policy are documented in docs/DESIGN.md §12.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Callable, Optional, Sequence

import jax

CACHE_VERSION = 1
_ENV_PATH = "REPRO_AUTOTUNE_CACHE"
# stamp of the most recently applied tuned config (None -> "untuned");
# read by ServeEngine and save_artifact for provenance
_applied_key: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One bundle of kernel-knob overrides. ``None`` fields leave the
    library default (or a previously applied value) untouched, so a
    config tuned for the decode sweep composes with one tuned for
    prefill chunking."""
    decode_kv_chunk: Optional[int] = None   # decode-attention sweep width
    chunk_threshold: Optional[int] = None   # prefill: chunk when S exceeds
    q_chunk: Optional[int] = None           # prefill query tile
    kv_chunk: Optional[int] = None          # prefill key/value tile
    qmatmul_bm: Optional[int] = None        # Pallas qmatmul/megakernel tiles
    qmatmul_bn: Optional[int] = None
    qmatmul_bk: Optional[int] = None

    def to_dict(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}

    @classmethod
    def from_dict(cls, d: dict) -> "TunedConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: int(v) for k, v in d.items() if k in names})


def kv_label(kv_plan) -> str:
    """Precision label for a resolved KV plan: the single precision it
    serves, "mixed" for a heterogeneous per-layer plan, "bf16" for no
    plan (raw cache)."""
    if kv_plan is None:
        return "bf16"
    uniq = sorted(set(kv_plan.precisions))
    return uniq[0] if len(uniq) == 1 else "mixed"


def tune_key(family: str, precision: str,
             backend: Optional[str] = None,
             device_kind: Optional[str] = None) -> str:
    """Cache key: ``device_kind|family|precision|backend``. device_kind
    distinguishes accelerator generations (e.g. "TPU v5e" vs "cpu"), so a
    cache carried to new hardware misses instead of mis-tiling."""
    if device_kind is None:
        device_kind = jax.devices()[0].device_kind
    if backend is None:
        backend = jax.default_backend()
    device_kind = device_kind.replace("|", "_").replace(" ", "-")
    return f"{device_kind}|{family}|{precision}|{backend}"


def default_cache_path() -> str:
    return os.environ.get(
        _ENV_PATH,
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"))


class AutotuneCache:
    """JSON-persisted map of tune_key -> {config, metrics}.

    Deterministic: the same key always returns the same stored config
    (no timestamps, no environment-dependent rewriting on load), and
    ``save`` writes sorted keys so the file round-trips byte-stable.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self.data: dict = {"version": CACHE_VERSION, "configs": {}}
        if os.path.exists(self.path):
            with open(self.path) as f:
                loaded = json.load(f)
            if loaded.get("version") == CACHE_VERSION:
                self.data = loaded

    def get(self, key: str) -> Optional[TunedConfig]:
        entry = self.data["configs"].get(key)
        if entry is None:
            return None
        return TunedConfig.from_dict(entry["config"])

    def metrics(self, key: str) -> dict:
        entry = self.data["configs"].get(key) or {}
        return dict(entry.get("metrics", {}))

    def put(self, key: str, config: TunedConfig,
            metrics: Optional[dict] = None) -> None:
        self.data["configs"][key] = {
            "config": config.to_dict(),
            "metrics": dict(metrics or {}),
        }

    def save(self) -> str:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        # atomic replace so a crashed sweep never truncates the cache
        fd, tmp = tempfile.mkstemp(dir=d or ".", suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(self.data, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)
        return self.path


def snapshot() -> dict:
    """Capture every knob the autotuner can touch (sweeps restore it)."""
    from repro.kernels.decode_attn import ops as dops
    from repro.kernels.qmatmul import ops as qops
    from repro.models import attention as attn
    return {
        "decode_kv_chunk": dops.get_decode_kv_chunk(),
        "chunk_threshold": attn.CHUNK_THRESHOLD,
        "q_chunk": attn.Q_CHUNK,
        "kv_chunk": attn.KV_CHUNK,
        **{f"qmatmul_{k}": v for k, v in qops.get_qmatmul_blocks().items()},
    }


def restore(snap: dict) -> None:
    from repro.kernels.decode_attn import ops as dops
    from repro.kernels.qmatmul import ops as qops
    from repro.models import attention as attn
    global _applied_key
    dops.configure_decode_attn(kv_chunk=snap["decode_kv_chunk"])
    attn.configure_chunking(chunk_threshold=snap["chunk_threshold"],
                            q_chunk=snap["q_chunk"],
                            kv_chunk=snap["kv_chunk"])
    qops._blocks.update({k.replace("qmatmul_", ""): v
                         for k, v in snap.items()
                         if k.startswith("qmatmul_")})
    _applied_key = None


def apply_config(config: TunedConfig, key: Optional[str] = None) -> None:
    """Push a TunedConfig into the three configure_* hooks. Read at
    TRACE time — apply before building jitted executables."""
    from repro.kernels.decode_attn import ops as dops
    from repro.kernels.qmatmul import ops as qops
    from repro.models import attention as attn
    global _applied_key
    if config.decode_kv_chunk is not None:
        dops.configure_decode_attn(kv_chunk=config.decode_kv_chunk)
    attn.configure_chunking(chunk_threshold=config.chunk_threshold,
                            q_chunk=config.q_chunk,
                            kv_chunk=config.kv_chunk)
    qops.configure_qmatmul(bm=config.qmatmul_bm, bn=config.qmatmul_bn,
                           bk=config.qmatmul_bk)
    _applied_key = key or "manual"


def current_stamp() -> str:
    """Provenance stamp for ServeStats / artifact manifests: the cache
    key of the last applied config, or "untuned"."""
    return _applied_key or "untuned"


def maybe_apply_tuned(family: str, precision: str,
                      path: Optional[str] = None) -> str:
    """Engine hook: look the (device, family, precision, backend) key up
    in the cache and apply its config if present. Returns the stamp —
    the key on a hit, "untuned" on a miss (library defaults stand)."""
    try:
        cache = AutotuneCache(path)
    except (OSError, json.JSONDecodeError):
        return "untuned"
    key = tune_key(family, precision)
    config = cache.get(key)
    if config is None:
        return "untuned"
    apply_config(config, key=key)
    return key


def default_candidates(precision: str = "bf16",
                       backend: Optional[str] = None
                       ) -> list[TunedConfig]:
    """Sweep grid. Decode tok/s is dominated by the cache-sweep chunk
    width, so that is the primary axis; int4's packed payload halves the
    bytes per chunk, so its grid reaches wider. On TPU the megakernel
    tiles join the grid; the jnp fallbacks ignore them."""
    if backend is None:
        backend = jax.default_backend()
    widths = (64, 128, 256, 512)
    if precision == "int4":
        # packed payload halves the bytes per chunk: keep the narrow
        # widths (they win the CPU fallback) and reach one step wider
        widths = (64, 128, 256, 512, 1024)
    out = [TunedConfig(decode_kv_chunk=w) for w in widths]
    if backend == "tpu":
        out += [TunedConfig(decode_kv_chunk=256, qmatmul_bm=bm,
                            qmatmul_bn=bn)
                for bm in (128, 256) for bn in (128, 256, 512)]
    return out


def autotune(key: str, bench: Callable[[TunedConfig], float],
             candidates: Sequence[TunedConfig],
             cache: Optional[AutotuneCache] = None,
             save: bool = True) -> tuple[TunedConfig, list[dict]]:
    """Measure every candidate with ``bench`` (returns cost in seconds,
    lower is better — build a FRESH jitted callable per call: the knobs
    are trace-time), keep the fastest, persist it under ``key``, leave
    it applied. Returns (best, per-candidate results)."""
    if not candidates:
        raise ValueError("autotune needs at least one candidate")
    saved = snapshot()
    results = []
    try:
        for config in candidates:
            apply_config(config, key=key)
            cost = float(bench(config))
            results.append({"config": config.to_dict(), "cost_s": cost})
    finally:
        restore(saved)
    best_i = min(range(len(results)), key=lambda i: results[i]["cost_s"])
    best = candidates[best_i]
    if cache is None:
        cache = AutotuneCache()
    cache.put(key, best, metrics={"cost_s": results[best_i]["cost_s"],
                                  "candidates": len(results)})
    if save:
        cache.save()
    apply_config(best, key=key)
    return best, results
