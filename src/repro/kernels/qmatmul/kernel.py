"""Pallas TPU kernel: fused weight-dequant matmul (W8A16 / W4A16 / ternary).

Computes y[m, n] = sum_k x[m, k] * q[n, k] * s[n, k // G]

Design for TPU (target: v5e; validated on CPU via interpret=True):

* Grid (M/BM, N/BN, K/BK) with the K dimension innermost so each (m, n)
  output tile is revisited and accumulated in-place in VMEM.
* BM/BN/BK are multiples of 128 so MXU matmul dims are hardware aligned and
  the int8 weight tiles respect the (32, 128) int8 VMEM tiling.
* Weights stay int8 in VMEM; dequant happens on the tile just before the
  MXU dot: reshape (BN, BK) -> (BN, BK/G, G), multiply by the (BN, BK/G)
  scale tile, flatten back. The per-group scale multiplies the *weight*
  operand, so the MXU sees a plain bf16xbf16 -> f32 dot.
* int4 weights arrive packed two-per-byte (BN, BK/2) and are unpacked with
  shifts/masks in VMEM — HBM traffic is half of int8.
* Accumulation is f32 in the output tile; the epilogue casts on the last
  K step.

VMEM budget @ BM=BN=256, BK=512: x 256x512x2 = 256KB, w 256x512 = 128KB
(int8) or 64KB (int4), scales 4KB, acc 256x256x4 = 256KB -> ~0.7MB, well
under the ~16MB/core VMEM of v5e; double-buffered pipelining has room.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _unpack_int4(packed: jax.Array) -> jax.Array:
    """(BN, BK//2) int8 -> (BN, BK) int8 in [-7, 7]; low nibble = even col."""
    lo = (packed & 0x0F).astype(jnp.int8)
    hi = ((packed >> 4) & 0x0F).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    n, kh = packed.shape
    return jnp.stack([lo, hi], axis=-1).reshape(n, kh * 2)


def _dequant_block(w: jax.Array, s: jax.Array, *, group: int,
                   packed: bool) -> jax.Array:
    """One weight tile (BN, BK_store) + scales (BN, BK/G) -> (BN, BK) f32,
    dequantized in VMEM just before the MXU dot."""
    if packed:
        w = _unpack_int4(w)
    bn, bk = w.shape
    wf = w.astype(jnp.float32).reshape(bn, bk // group, group)
    return (wf * s.astype(jnp.float32)[:, :, None]).reshape(bn, bk)


def _qmatmul_kernel(x_ref, w_ref, s_ref, o_ref, *, group: int, packed: bool):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                      # (BM, BK)
    wf = _dequant_block(w_ref[...], s_ref[...], group=group, packed=packed)
    o_ref[...] += jax.lax.dot_general(
        x, wf, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("group", "precision", "bm", "bn",
                                             "bk", "interpret"))
def qmatmul_pallas(x: jax.Array, data: jax.Array, scale: jax.Array, *,
                   group: int = 128, precision: str = "int8",
                   bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                   bk: int = DEFAULT_BK, interpret: bool = False) -> jax.Array:
    """x: (M, K) bf16/f32; data: (N, K) int8 or (N, K//2) packed int4;
    scale: (N, K//group). Returns (M, N) f32."""
    m, k = x.shape
    packed = precision == "int4"
    n = data.shape[0]
    k_data = data.shape[1] * (2 if packed else 1)
    assert k_data == k, (data.shape, x.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bk % group == 0
    n_k_steps = k // bk

    kernel = functools.partial(_qmatmul_kernel, group=group, packed=packed)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // 2 if packed else bk),
                         lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // group), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, data, scale)


# ---------------------------------------------------------------------------
# megakernels (docs/DESIGN.md §12): whole quantized sub-blocks in one launch
# ---------------------------------------------------------------------------

def _qmlp_kernel(*refs, group: int, packed: bool, swiglu: bool):
    if swiglu:
        (x_ref, g_ref, gs_ref, u_ref, us_ref,
         d_ref, ds_ref, o_ref) = refs
    else:
        x_ref, u_ref, us_ref, d_ref, ds_ref, o_ref = refs
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                      # (BM, K)
    wu = _dequant_block(u_ref[...], us_ref[...], group=group, packed=packed)
    u = jax.lax.dot_general(x, wu, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (BM, BF)
    if swiglu:
        wg = _dequant_block(g_ref[...], gs_ref[...], group=group,
                            packed=packed)
        g = jax.lax.dot_general(x, wg, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(u)
    wd = _dequant_block(d_ref[...], ds_ref[...], group=group, packed=packed)
    o_ref[...] += jax.lax.dot_general(                      # (BM, D)
        h, wd, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("group", "precision", "act",
                                             "bm", "bf", "interpret"))
def qmlp_pallas(x: jax.Array, gate_data, gate_scale, up_data, up_scale,
                down_data, down_scale, *, group: int = 128,
                precision: str = "int8", act: str = "swiglu",
                bm: int = DEFAULT_BM, bf: int = DEFAULT_BN,
                interpret: bool = False) -> jax.Array:
    """Fused quantized MLP: y = act-combine(x W_gate^T, x W_up^T) W_down^T
    with EVERY weight dequantized tile-by-tile in VMEM and the (M, FF)
    hidden activation living only as (BM, BF) register tiles — it is never
    written to HBM, and no bf16 copy of any weight ever exists.

    Grid (M/BM, FF/BF) with FF innermost: each FF step computes one hidden
    tile and immediately accumulates its contribution through W_down into
    the (BM, D) output block. x: (M, K); gate/up: (FF, K_store); down:
    (D, FF_store); scales per ``group`` along each contraction. ``act``
    "swiglu" (gate_* used) or "gelu" (gate_* must be None). Returns (M, D)
    f32.

    VMEM @ BM=BF=256, K=D=2048: x 1MB (bf16) + 3 weight tiles ~1.5MB
    (int8) + acc 2MB — comfortably under v5e's ~16MB/core."""
    m, k = x.shape
    packed = precision == "int4"
    swiglu = act == "swiglu"
    assert (gate_data is None) == (not swiglu), \
        "gate weights iff act == 'swiglu'"
    ff = up_data.shape[0]
    d = down_data.shape[0]
    bm, bf = min(bm, m), min(bf, ff)
    assert m % bm == 0 and ff % bf == 0, (m, ff, bm, bf)
    assert k % group == 0 and bf % group == 0, (k, bf, group)
    k_store = k // 2 if packed else k
    bf_store = bf // 2 if packed else bf
    assert up_data.shape[1] == k_store and down_data.shape[1] * \
        (2 if packed else 1) == ff, (up_data.shape, down_data.shape)

    kernel = functools.partial(_qmlp_kernel, group=group, packed=packed,
                               swiglu=swiglu)
    in_specs = [pl.BlockSpec((bm, k), lambda i, f: (i, 0))]
    operands = [x]
    if swiglu:
        in_specs += [pl.BlockSpec((bf, k_store), lambda i, f: (f, 0)),
                     pl.BlockSpec((bf, k // group), lambda i, f: (f, 0))]
        operands += [gate_data, gate_scale]
    in_specs += [pl.BlockSpec((bf, k_store), lambda i, f: (f, 0)),
                 pl.BlockSpec((bf, k // group), lambda i, f: (f, 0)),
                 pl.BlockSpec((d, bf_store), lambda i, f: (0, f)),
                 pl.BlockSpec((d, bf // group), lambda i, f: (0, f))]
    operands += [up_data, up_scale, down_data, down_scale]
    return pl.pallas_call(
        kernel,
        grid=(m // bm, ff // bf),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, d), lambda i, f: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=interpret,
    )(*operands)


def _qkv_kernel(x_ref, q_ref, qs_ref, k_ref, ks_ref, v_ref, vs_ref,
                oq_ref, ok_ref, ov_ref, *, group: int, packed: bool):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        oq_ref[...] = jnp.zeros_like(oq_ref)
        ok_ref[...] = jnp.zeros_like(ok_ref)
        ov_ref[...] = jnp.zeros_like(ov_ref)

    x = x_ref[...].astype(jnp.float32)                      # (BM, BK)
    for w_ref, s_ref, o_ref in ((q_ref, qs_ref, oq_ref),
                                (k_ref, ks_ref, ok_ref),
                                (v_ref, vs_ref, ov_ref)):
        wf = _dequant_block(w_ref[...], s_ref[...], group=group,
                            packed=packed)
        o_ref[...] += jax.lax.dot_general(
            x, wf, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("group", "precision", "bm",
                                             "bk", "interpret"))
def qkv_pallas(x: jax.Array, q_data, q_scale, k_data, k_scale, v_data,
               v_scale, *, group: int = 128, precision: str = "int8",
               bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
               interpret: bool = False):
    """Fused quantized QKV projection: the three decode-path projections
    share one sweep of the activation — each (BM, BK) x tile is read from
    HBM once and feeds all three accumulating output blocks, instead of
    three separate kernel launches re-reading x.

    x: (M, K); q/k/v data: (N_*, K_store) int8 (packed int4: K/2); scales
    (N_*, K/group). Grid (M/BM, K/BK), K innermost. Returns a 3-tuple of
    (M, N_*) f32."""
    m, k = x.shape
    packed = precision == "int4"
    nq, nk, nv = q_data.shape[0], k_data.shape[0], v_data.shape[0]
    bm, bk = min(bm, m), min(bk, k)
    assert m % bm == 0 and k % bk == 0 and bk % group == 0, (m, k, bm, bk)
    bk_store = bk // 2 if packed else bk

    kernel = functools.partial(_qkv_kernel, group=group, packed=packed)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, kk: (i, kk)),
            pl.BlockSpec((nq, bk_store), lambda i, kk: (0, kk)),
            pl.BlockSpec((nq, bk // group), lambda i, kk: (0, kk)),
            pl.BlockSpec((nk, bk_store), lambda i, kk: (0, kk)),
            pl.BlockSpec((nk, bk // group), lambda i, kk: (0, kk)),
            pl.BlockSpec((nv, bk_store), lambda i, kk: (0, kk)),
            pl.BlockSpec((nv, bk // group), lambda i, kk: (0, kk)),
        ],
        out_specs=[
            pl.BlockSpec((bm, nq), lambda i, kk: (i, 0)),
            pl.BlockSpec((bm, nk), lambda i, kk: (i, 0)),
            pl.BlockSpec((bm, nv), lambda i, kk: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, nq), jnp.float32),
            jax.ShapeDtypeStruct((m, nk), jnp.float32),
            jax.ShapeDtypeStruct((m, nv), jnp.float32),
        ],
        interpret=interpret,
    )(x, q_data, q_scale, k_data, k_scale, v_data, v_scale)
