"""Pallas TPU kernel: fused weight-dequant matmul (W8A16 / W4A16 / ternary).

Computes y[m, n] = sum_k x[m, k] * q[n, k] * s[n, k // G]

Design for TPU (target: v5e; validated on CPU via interpret=True):

* Grid (M/BM, N/BN, K/BK) with the K dimension innermost so each (m, n)
  output tile is revisited and accumulated in-place in VMEM.
* BM/BN/BK are multiples of 128 so MXU matmul dims are hardware aligned and
  the int8 weight tiles respect the (32, 128) int8 VMEM tiling.
* Weights stay int8 in VMEM; dequant happens on the tile just before the
  MXU dot: reshape (BN, BK) -> (BN, BK/G, G), multiply by the (BN, BK/G)
  scale tile, flatten back. The per-group scale multiplies the *weight*
  operand, so the MXU sees a plain bf16xbf16 -> f32 dot.
* int4 weights arrive packed two-per-byte (BN, BK/2) and are unpacked with
  shifts/masks in VMEM — HBM traffic is half of int8.
* Accumulation is f32 in the output tile; the epilogue casts on the last
  K step.

VMEM budget @ BM=BN=256, BK=512: x 256x512x2 = 256KB, w 256x512 = 128KB
(int8) or 64KB (int4), scales 4KB, acc 256x256x4 = 256KB -> ~0.7MB, well
under the ~16MB/core VMEM of v5e; double-buffered pipelining has room.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _unpack_int4(packed: jax.Array) -> jax.Array:
    """(BN, BK//2) int8 -> (BN, BK) int8 in [-7, 7]; low nibble = even col."""
    lo = (packed & 0x0F).astype(jnp.int8)
    hi = ((packed >> 4) & 0x0F).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    n, kh = packed.shape
    return jnp.stack([lo, hi], axis=-1).reshape(n, kh * 2)


def _qmatmul_kernel(x_ref, w_ref, s_ref, o_ref, *, group: int, packed: bool):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                      # (BM, BK)
    w = w_ref[...]
    if packed:
        w = _unpack_int4(w)                                  # (BN, BK)
    s = s_ref[...].astype(jnp.float32)                      # (BN, BK/G)
    bn, bk = w.shape
    wf = w.astype(jnp.float32).reshape(bn, bk // group, group)
    wf = (wf * s[:, :, None]).reshape(bn, bk)               # dequant in VMEM
    o_ref[...] += jax.lax.dot_general(
        x, wf, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("group", "precision", "bm", "bn",
                                             "bk", "interpret"))
def qmatmul_pallas(x: jax.Array, data: jax.Array, scale: jax.Array, *,
                   group: int = 128, precision: str = "int8",
                   bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                   bk: int = DEFAULT_BK, interpret: bool = False) -> jax.Array:
    """x: (M, K) bf16/f32; data: (N, K) int8 or (N, K//2) packed int4;
    scale: (N, K//group). Returns (M, N) f32."""
    m, k = x.shape
    packed = precision == "int4"
    n = data.shape[0]
    k_data = data.shape[1] * (2 if packed else 1)
    assert k_data == k, (data.shape, x.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bk % group == 0
    n_k_steps = k // bk

    kernel = functools.partial(_qmatmul_kernel, group=group, packed=packed)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // 2 if packed else bk),
                         lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // group), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, data, scale)
