"""Pure-jnp oracle for the fused dequant matmul.

Computes  y = x @ dequant(w).T  for x:(M, K) and w a QTensor with logical
shape (N, K) quantized group-wise along K.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.qtypes import QTensor
from repro.quant.quantize import dequantize


def qmatmul_ref(x: jax.Array, w: QTensor, out_dtype=jnp.float32) -> jax.Array:
    wd = dequantize(w, jnp.float32)
    return jnp.einsum("mk,nk->mn", x.astype(jnp.float32), wd,
                      preferred_element_type=jnp.float32).astype(out_dtype)


def matmul_ref(x: jax.Array, w: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    return jnp.einsum("mk,nk->mn", x.astype(jnp.float32),
                      w.astype(jnp.float32),
                      preferred_element_type=jnp.float32).astype(out_dtype)
