"""jit'd public wrapper for the fused dequant matmul.

``qdot(x, w)`` is the single entry point the model stack uses for every
weight matmul. ``w`` may be:

* a plain jax.Array (raw / bf16 path)          -> einsum
* a QTensor (int8 / int4 / ternary)            -> fused dequant matmul

Backend selection (explicit, per-call or process-wide):

* ``auto``    — (default) the Pallas kernel on TPU when shapes are tile
  aligned, else the ``simple`` jnp fallback. XLA fuses the fallback
  reasonably, keeping HLO byte counts faithful to weight-only quantization
  (int8/int4 weights are read at their quantized width; dequant is a
  flop-cheap broadcast-multiply).
* ``pallas``  — force the Pallas kernel (raises off-TPU / on misaligned
  shapes rather than silently degrading).
* ``grouped`` — jnp fallback with the kernel's exact math: per-group
  partial sums are scaled, never materializing a dequantized weight.
* ``simple``  — dequantize-then-dot fallback.

Set process-wide via ``set_qdot_backend`` or the ``REPRO_QDOT_BACKEND``
env var; both jnp fallbacks are validated against ref.py
(tests/test_compiler.py::test_qdot_backends).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.quant.qtypes import QTensor
from repro.quant.quantize import unpack_int4
from repro.kernels.qmatmul.kernel import qmatmul_pallas

BACKENDS = ("auto", "pallas", "grouped", "simple")
_backend = os.environ.get("REPRO_QDOT_BACKEND", "auto")


def set_qdot_backend(name: str) -> None:
    """Select the process-wide default qdot backend (see module docstring).

    The selection is read at TRACE time: functions jitted before the call
    (e.g. a ServeEngine's cached decode/prefill executables) keep the
    backend they were traced with — rebuild them (or pass ``backend=`` per
    call) to switch."""
    if name not in BACKENDS:
        raise ValueError(f"unknown qdot backend {name!r}; one of {BACKENDS}")
    global _backend
    _backend = name


def get_qdot_backend() -> str:
    return _backend


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _pallas_aligned(m: int, n: int, k: int, precision: str = "int8") -> bool:
    """Tile alignment for the Pallas kernel.

    ``k`` is the UNPACKED activation contraction dim; int4 payloads pack
    two nibbles per byte, so the weight's physical lane dim is k/2 and must
    itself satisfy the 512-lane block alignment (k % 1024) — checking the
    unpacked k alone would admit shapes whose packed tiles misalign."""
    lane = k // 2 if precision == "int4" else k
    return m % 128 == 0 and n % 128 == 0 and lane % 512 == 0


def _dequant_fused(x2d: jax.Array, w: QTensor) -> jax.Array:
    """jnp fallback with the same math as the kernel: accumulate scaled
    per-group partial sums over a scan of the K/group blocks rather than
    materializing a full dequantized weight — temp memory stays O(M*N)
    (one partial product), never O(M*N*K/group)."""
    data = w.data
    if w.precision == "int4":
        data = unpack_int4(data)
    m = x2d.shape[0]
    n, k = data.shape
    g = w.group
    # (G, M, g) x (G, N, g): one (M, N) partial per group block, scaled.
    xg = jnp.moveaxis(x2d.reshape(m, k // g, g), 1, 0).astype(jnp.float32)
    wg = jnp.moveaxis(data.reshape(n, k // g, g), 1, 0).astype(jnp.float32)
    sg = jnp.moveaxis(w.scale.astype(jnp.float32), -1, 0)  # (G, N)

    def body(acc, xs):
        x_g, w_g, s_g = xs
        part = jnp.einsum("mk,nk->mn", x_g, w_g,
                          preferred_element_type=jnp.float32)
        return acc + part * s_g[None, :], None

    y, _ = jax.lax.scan(body, jnp.zeros((m, n), jnp.float32), (xg, wg, sg))
    return y


def _dequant_simple(x2d: jax.Array, w: QTensor) -> jax.Array:
    """Dequantize-then-dot fallback (lets XLA fuse convert into the dot)."""
    from repro.quant.quantize import dequantize
    wd = dequantize(w, jnp.bfloat16)
    return jax.lax.dot_general(x2d, wd, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


def qdot(x: jax.Array, w, out_dtype=None, backend: str | None = None
         ) -> jax.Array:
    """y[..., n] = sum_k x[..., k] * W[n, k] with W possibly quantized.

    ``backend`` overrides the process-wide selection for this call."""
    backend = backend or _backend
    if backend not in BACKENDS:
        raise ValueError(f"unknown qdot backend {backend!r}; "
                         f"one of {BACKENDS}")
    if out_dtype is None:
        out_dtype = x.dtype
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2d = x.reshape(-1, k)
    if isinstance(w, QTensor):
        m, n = x2d.shape[0], w.data.shape[0]
        aligned = _pallas_aligned(m, n, k, w.precision)
        if backend == "pallas" or (backend == "auto" and _use_pallas()
                                   and aligned):
            if backend == "pallas" and not (_use_pallas() and aligned):
                raise ValueError(
                    f"qdot backend 'pallas' needs a TPU and tile-aligned "
                    f"shapes (m%128, n%128, payload-lane%512 — k%1024 for "
                    f"packed int4); got m={m} n={n} k={k} "
                    f"precision={w.precision!r} on "
                    f"{jax.default_backend()!r}")
            y = qmatmul_pallas(x2d, w.data, w.scale, group=w.group,
                               precision=w.precision)
        elif backend == "grouped":
            y = _dequant_fused(x2d, w)
        else:
            y = _dequant_simple(x2d, w)
        n_out = n
    else:
        y = jax.lax.dot_general(x2d, w, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        n_out = w.shape[0]
    return y.reshape(*lead, n_out).astype(out_dtype)
