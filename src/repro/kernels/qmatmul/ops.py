"""jit'd public wrapper for the fused dequant matmul.

``qdot(x, w)`` is the single entry point the model stack uses for every
weight matmul. ``w`` may be:

* a plain jax.Array (raw / bf16 path)          -> einsum
* a QTensor (int8 / int4 / ternary)            -> fused dequant matmul

Backend selection (explicit, per-call or process-wide):

* ``auto``    — (default) the Pallas kernel on TPU when shapes are tile
  aligned, else the ``simple`` jnp fallback. XLA fuses the fallback
  reasonably, keeping HLO byte counts faithful to weight-only quantization
  (int8/int4 weights are read at their quantized width; dequant is a
  flop-cheap broadcast-multiply).
* ``pallas``  — force the Pallas kernel (raises off-TPU / on misaligned
  shapes rather than silently degrading).
* ``grouped`` — jnp fallback with the kernel's exact math: per-group
  partial sums are scaled, never materializing a dequantized weight.
* ``simple``  — dequantize-then-dot fallback.

Set process-wide via ``set_qdot_backend`` or the ``REPRO_QDOT_BACKEND``
env var; both jnp fallbacks are validated against ref.py
(tests/test_compiler.py::test_qdot_backends).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.quant.qtypes import QTensor
from repro.quant.quantize import unpack_int4
from repro.kernels.qmatmul.kernel import (DEFAULT_BK, DEFAULT_BM, DEFAULT_BN,
                                          qkv_pallas, qmatmul_pallas,
                                          qmlp_pallas)

BACKENDS = ("auto", "pallas", "grouped", "simple")
_backend = os.environ.get("REPRO_QDOT_BACKEND", "auto")
# Pallas block-shape overrides (None -> kernel defaults); set by
# configure_qmatmul, swept by kernels/autotune.py. Read at TRACE time.
_blocks: dict = {"bm": None, "bn": None, "bk": None}


def configure_qmatmul(bm: int | None = None, bn: int | None = None,
                      bk: int | None = None,
                      backend: str | None = None) -> None:
    """Override the Pallas qmatmul/megakernel block shapes (and optionally
    the backend) process-wide — the autotuner's hook (kernels/autotune.py).
    Read at TRACE time like ``set_qdot_backend``; blocks that do not divide
    a particular call's shape fall back to the kernel defaults for that
    call."""
    global _blocks
    for name, val in (("bm", bm), ("bn", bn), ("bk", bk)):
        if val is not None:
            if val < 128 or val % 128:
                raise ValueError(f"{name} must be a multiple of 128, "
                                 f"got {val}")
            _blocks[name] = val
    if backend is not None:
        set_qdot_backend(backend)


def get_qmatmul_blocks() -> dict:
    return dict(_blocks)


def _block_kwargs(m: int, n: int, k: int) -> dict:
    """Tuned block overrides that actually divide this call's shape."""
    kw = {}
    for name, dim in (("bm", m), ("bn", n), ("bk", k)):
        v = _blocks[name]
        if v is not None and dim % min(v, dim) == 0:
            kw[name] = v
    return kw


def set_qdot_backend(name: str) -> None:
    """Select the process-wide default qdot backend (see module docstring).

    The selection is read at TRACE time: functions jitted before the call
    (e.g. a ServeEngine's cached decode/prefill executables) keep the
    backend they were traced with — rebuild them (or pass ``backend=`` per
    call) to switch."""
    if name not in BACKENDS:
        raise ValueError(f"unknown qdot backend {name!r}; one of {BACKENDS}")
    global _backend
    _backend = name


def get_qdot_backend() -> str:
    return _backend


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _pallas_aligned(m: int, n: int, k: int, precision: str = "int8") -> bool:
    """Tile alignment for the Pallas kernel.

    ``k`` is the UNPACKED activation contraction dim; int4 payloads pack
    two nibbles per byte, so the weight's physical lane dim is k/2 and must
    itself satisfy the 512-lane block alignment (k % 1024) — checking the
    unpacked k alone would admit shapes whose packed tiles misalign."""
    lane = k // 2 if precision == "int4" else k
    return m % 128 == 0 and n % 128 == 0 and lane % 512 == 0


def _dequant_fused(x2d: jax.Array, w: QTensor) -> jax.Array:
    """jnp fallback with the same math as the kernel: accumulate scaled
    per-group partial sums over a scan of the K/group blocks rather than
    materializing a full dequantized weight — temp memory stays O(M*N)
    (one partial product), never O(M*N*K/group)."""
    data = w.data
    if w.precision == "int4":
        data = unpack_int4(data)
    m = x2d.shape[0]
    n, k = data.shape
    g = w.group
    # (G, M, g) x (G, N, g): one (M, N) partial per group block, scaled.
    xg = jnp.moveaxis(x2d.reshape(m, k // g, g), 1, 0).astype(jnp.float32)
    wg = jnp.moveaxis(data.reshape(n, k // g, g), 1, 0).astype(jnp.float32)
    sg = jnp.moveaxis(w.scale.astype(jnp.float32), -1, 0)  # (G, N)

    def body(acc, xs):
        x_g, w_g, s_g = xs
        part = jnp.einsum("mk,nk->mn", x_g, w_g,
                          preferred_element_type=jnp.float32)
        return acc + part * s_g[None, :], None

    y, _ = jax.lax.scan(body, jnp.zeros((m, n), jnp.float32), (xg, wg, sg))
    return y


def _dequant_simple(x2d: jax.Array, w: QTensor) -> jax.Array:
    """Dequantize-then-dot fallback (lets XLA fuse convert into the dot)."""
    from repro.quant.quantize import dequantize
    wd = dequantize(w, jnp.bfloat16)
    return jax.lax.dot_general(x2d, wd, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


def qdot(x: jax.Array, w, out_dtype=None, backend: str | None = None
         ) -> jax.Array:
    """y[..., n] = sum_k x[..., k] * W[n, k] with W possibly quantized.

    ``backend`` overrides the process-wide selection for this call."""
    backend = backend or _backend
    if backend not in BACKENDS:
        raise ValueError(f"unknown qdot backend {backend!r}; "
                         f"one of {BACKENDS}")
    if out_dtype is None:
        out_dtype = x.dtype
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2d = x.reshape(-1, k)
    if isinstance(w, QTensor):
        m, n = x2d.shape[0], w.data.shape[0]
        aligned = _pallas_aligned(m, n, k, w.precision)
        if backend == "pallas" or (backend == "auto" and _use_pallas()
                                   and aligned):
            if backend == "pallas" and not (_use_pallas() and aligned):
                raise ValueError(
                    f"qdot backend 'pallas' needs a TPU and tile-aligned "
                    f"shapes (m%128, n%128, payload-lane%512 — k%1024 for "
                    f"packed int4); got m={m} n={n} k={k} "
                    f"precision={w.precision!r} on "
                    f"{jax.default_backend()!r}")
            y = qmatmul_pallas(x2d, w.data, w.scale, group=w.group,
                               precision=w.precision,
                               **_block_kwargs(m, n, k))
        elif backend == "grouped":
            y = _dequant_fused(x2d, w)
        else:
            y = _dequant_simple(x2d, w)
        n_out = n
    else:
        y = jax.lax.dot_general(x2d, w, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        n_out = w.shape[0]
    return y.reshape(*lead, n_out).astype(out_dtype)


# ---------------------------------------------------------------------------
# megakernel entry points (docs/DESIGN.md §12)
# ---------------------------------------------------------------------------

def _mega_eligible(ws) -> bool:
    """All operands QTensors of one (precision, group) — the megakernels
    dequantize every tile with a single rule per launch."""
    return (all(isinstance(w, QTensor) for w in ws)
            and len({(w.precision, w.group) for w in ws}) == 1)


def _out_dim(w) -> int:
    return w.data.shape[0] if isinstance(w, QTensor) else w.shape[0]


def fused_mlp(x: jax.Array, w_gate, w_up, w_down, act: str = "swiglu",
              backend: str | None = None) -> jax.Array:
    """Whole quantized MLP block in one call: on TPU with aligned shapes a
    single Pallas launch where the (M, FF) hidden activation never reaches
    HBM and no bf16 weight copy ever exists; everywhere else the EXACT
    qdot sequence of models/mlp.py (bit-identical fallback — greedy serving
    output does not depend on which path ran). ``w_gate`` is None for
    act="gelu"."""
    backend = backend or _backend
    if backend not in BACKENDS:
        raise ValueError(f"unknown qdot backend {backend!r}; "
                         f"one of {BACKENDS}")
    lead, k = x.shape[:-1], x.shape[-1]
    x2d = x.reshape(-1, k)
    m = x2d.shape[0]
    ws = [w for w in (w_gate, w_up, w_down) if w is not None]
    if _mega_eligible(ws):
        w = w_up
        ff, d = _out_dim(w_up), _out_dim(w_down)
        aligned = (_pallas_aligned(m, ff, k, w.precision)
                   and d % 128 == 0
                   and _pallas_aligned(m, d, ff, w.precision))
        if backend == "pallas" or (backend == "auto" and _use_pallas()
                                   and aligned):
            if backend == "pallas" and not (_use_pallas() and aligned):
                raise ValueError(
                    f"fused_mlp backend 'pallas' needs a TPU and aligned "
                    f"shapes; got m={m} ff={ff} d={d} k={k} "
                    f"precision={w.precision!r} on "
                    f"{jax.default_backend()!r}")
            bk = _block_kwargs(m, ff, k)
            y = qmlp_pallas(
                x2d,
                None if w_gate is None else w_gate.data,
                None if w_gate is None else w_gate.scale,
                w_up.data, w_up.scale, w_down.data, w_down.scale,
                group=w.group, precision=w.precision, act=act,
                bm=bk.get("bm", DEFAULT_BM), bf=bk.get("bn", DEFAULT_BN))
            return y.reshape(*lead, d).astype(x.dtype)
    # fallback: models/mlp.py's exact op sequence
    if act == "swiglu":
        g = qdot(x, w_gate, backend=backend)
        u = qdot(x, w_up, backend=backend)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return qdot(h, w_down, backend=backend)
    h = qdot(x, w_up, backend=backend)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return qdot(h, w_down, backend=backend)


def fused_qkv(x: jax.Array, wq, wk, wv, backend: str | None = None):
    """The three attention projections in one launch: each activation tile
    is read from HBM once and feeds all three accumulators. Fallback is
    exactly three ``qdot`` calls (bit-identical). Returns (q, k, v) with
    qdot's dtype convention."""
    backend = backend or _backend
    if backend not in BACKENDS:
        raise ValueError(f"unknown qdot backend {backend!r}; "
                         f"one of {BACKENDS}")
    lead, k = x.shape[:-1], x.shape[-1]
    x2d = x.reshape(-1, k)
    m = x2d.shape[0]
    if _mega_eligible((wq, wk, wv)):
        nq, nkk, nv = _out_dim(wq), _out_dim(wk), _out_dim(wv)
        aligned = all(_pallas_aligned(m, n, k, wq.precision)
                      for n in (nq, nkk, nv))
        if backend == "pallas" or (backend == "auto" and _use_pallas()
                                   and aligned):
            if backend == "pallas" and not (_use_pallas() and aligned):
                raise ValueError(
                    f"fused_qkv backend 'pallas' needs a TPU and aligned "
                    f"shapes; got m={m} n=({nq},{nkk},{nv}) k={k} "
                    f"precision={wq.precision!r} on "
                    f"{jax.default_backend()!r}")
            bk = _block_kwargs(m, nq, k)
            yq, yk, yv = qkv_pallas(
                x2d, wq.data, wq.scale, wk.data, wk.scale, wv.data,
                wv.scale, group=wq.group, precision=wq.precision,
                bm=bk.get("bm", DEFAULT_BM), bk=bk.get("bk", DEFAULT_BK))
            return (yq.reshape(*lead, nq).astype(x.dtype),
                    yk.reshape(*lead, nkk).astype(x.dtype),
                    yv.reshape(*lead, nv).astype(x.dtype))
    return (qdot(x, wq, backend=backend), qdot(x, wk, backend=backend),
            qdot(x, wv, backend=backend))
