"""jit'd public wrapper for the fused dequant matmul.

``qdot(x, w)`` is the single entry point the model stack uses for every
weight matmul. ``w`` may be:

* a plain jax.Array (raw / bf16 path)          -> einsum
* a QTensor (int8 / int4 / ternary)            -> fused dequant matmul

Backend selection: on TPU the Pallas kernel runs natively; elsewhere
(CPU dry-run/tests) we use the jnp fallback, which XLA fuses reasonably,
keeping HLO byte counts faithful to weight-only quantization (int8/int4
weights are read at their quantized width; dequant is a flop-cheap
broadcast-multiply). The Pallas kernel itself is validated against ref.py
in interpret mode (tests/test_kernels_qmatmul.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.qtypes import QTensor
from repro.quant.quantize import unpack_int4
from repro.kernels.qmatmul.kernel import qmatmul_pallas


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _dequant_fused(x2d: jax.Array, w: QTensor) -> jax.Array:
    """jnp fallback with the same math as the kernel: scale the per-group
    partial sums rather than materializing a full dequantized weight when
    the contraction is grouped."""
    data = w.data
    if w.precision == "int4":
        data = unpack_int4(data)
    n, k = data.shape
    g = w.group
    # (M, K) x (N, K) grouped: einsum over (group-blocks, in-group).
    xg = x2d.reshape(x2d.shape[0], k // g, g).astype(jnp.float32)
    wg = data.reshape(n, k // g, g).astype(jnp.float32)
    partial = jnp.einsum("mgk,ngk->mng", xg, wg,
                         preferred_element_type=jnp.float32)
    return jnp.einsum("mng,ng->mn", partial, w.scale.astype(jnp.float32))


def _dequant_simple(x2d: jax.Array, w: QTensor) -> jax.Array:
    """Dequantize-then-dot fallback (lets XLA fuse convert into the dot)."""
    from repro.quant.quantize import dequantize
    wd = dequantize(w, jnp.bfloat16)
    return jax.lax.dot_general(x2d, wd, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


def qdot(x: jax.Array, w, out_dtype=None) -> jax.Array:
    """y[..., n] = sum_k x[..., k] * W[n, k] with W possibly quantized."""
    if out_dtype is None:
        out_dtype = x.dtype
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2d = x.reshape(-1, k)
    if isinstance(w, QTensor):
        m, n = x2d.shape[0], w.data.shape[0]
        if (_use_pallas() and m % 128 == 0 and n % 128 == 0
                and k % 512 == 0):
            y = qmatmul_pallas(x2d, w.data, w.scale, group=w.group,
                               precision=w.precision)
        else:
            y = _dequant_simple(x2d, w)
        n_out = n
    else:
        y = jax.lax.dot_general(x2d, w, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        n_out = w.shape[0]
    return y.reshape(*lead, n_out).astype(out_dtype)
