"""jit'd wrapper for the streaming entropy kernel with CPU fallback."""

from __future__ import annotations

import jax

from repro.kernels.entropy.kernel import entropy_pallas
from repro.kernels.entropy.ref import entropy_ref


def matrix_entropy(w: jax.Array) -> jax.Array:
    """Streaming softmax-entropy (eps=0 closed form). Pallas on TPU,
    interpret-mode kernel is exercised in tests; jnp oracle elsewhere."""
    if jax.default_backend() == "tpu":
        return entropy_pallas(w)
    return entropy_ref(w)
