"""Pallas TPU kernel: single-pass streaming softmax-entropy of a weight array.

H = lse(w) - E_p[w] with p = softmax(flatten(w)).

The array is viewed as (n_chunks, CHUNK) and the grid walks chunks
sequentially. A (1, 3) f32 scratch accumulator in VMEM carries the online
state (running max m, Z = sum e^{w-m}, S = sum w e^{w-m}) across grid
steps — the standard online-logsumexp merge. The final grid step writes
H = (m + log Z) - S/Z.

This is the TPU-native form of the paper's §3.1 analysis: one HBM read of
the weights, no softmax materialization, O(1) VMEM. CHUNK = 8*128 lanes
aligns to the VPU (8, 128) vector registers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 8 * 128


def _entropy_kernel(w_ref, o_ref, acc_ref, *, n_steps: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        acc_ref[0, 0] = -jnp.inf   # running max
        acc_ref[0, 1] = 0.0        # Z
        acc_ref[0, 2] = 0.0        # S

    x = w_ref[...].astype(jnp.float32)            # (1, CHUNK), -inf padded
    m_old = acc_ref[0, 0]
    cm = jnp.max(x)
    m_new = jnp.maximum(m_old, cm)
    rescale = jnp.exp(m_old - m_new)              # exp(-inf - m) = 0 at init
    e = jnp.exp(x - m_new)
    we = jnp.where(jnp.isfinite(x), x * e, 0.0)   # mask -inf padding
    acc_ref[0, 0] = m_new
    acc_ref[0, 1] = acc_ref[0, 1] * rescale + jnp.sum(e)
    acc_ref[0, 2] = acc_ref[0, 2] * rescale + jnp.sum(we)

    @pl.when(step == n_steps - 1)
    def _finalize():
        m, z, s = acc_ref[0, 0], acc_ref[0, 1], acc_ref[0, 2]
        o_ref[0, 0] = (m + jnp.log(z)) - s / z


@functools.partial(jax.jit, static_argnames=("interpret",))
def entropy_pallas(w: jax.Array, *, interpret: bool = False) -> jax.Array:
    flat = w.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % CHUNK
    flat = jnp.pad(flat, (0, pad), constant_values=-jnp.inf)
    chunks = flat.reshape(-1, CHUNK)
    n_steps = chunks.shape[0]
    out = pl.pallas_call(
        functools.partial(_entropy_kernel, n_steps=n_steps),
        grid=(n_steps,),
        in_specs=[pl.BlockSpec((1, CHUNK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, 3), jnp.float32)],
        interpret=interpret,
    )(chunks)
    return out[0, 0]
