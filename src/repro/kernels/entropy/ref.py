"""Pure-jnp oracle for the streaming entropy kernel (eps=0 closed form).

H = -sum_i p_i log p_i ,  p = softmax(w)
  = logsumexp(w) - sum_i w_i e^{w_i} / sum_i e^{w_i}
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def entropy_ref(w: jax.Array) -> jax.Array:
    flat = w.reshape(-1).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(flat)
    p = jnp.exp(flat - lse)
    return lse - jnp.sum(p * flat)
