"""Pallas TPU kernel: fused (multi-)query GQA decode attention over a
quantized KV cache.

Computes, for one decode step (or a short speculative verify window of
``qs`` token positions) per batch slot,

  out[b, h, r, i] = softmax_t( q[b, h, r, i] . K[b, t, h] / sqrt(hd) )
                    . V[b, t, h]

with t masked per query: with ``causal=True`` query i sits at absolute
cache position ``valid_len - qs + i`` and sees rows ``<= valid_len - qs
+ i`` (qs=1 recovers the plain decode mask; qs=K+1 is the speculative
verify window — all draft positions scored in ONE streaming pass,
docs/DESIGN.md §11); ``causal=False`` (cross-attention verify) lets
every query see all ``valid_len`` rows. K/V are stored int8 /
packed-int4 with per-group scales (quant/kvcache.py layout) or bf16.
Design for TPU (validated on CPU via interpret=True, like qmatmul):

* Grid (B, S/C) with the KV-chunk dimension innermost: the online-softmax
  running max / normalizer / accumulator live in VMEM scratch and are
  revisited per chunk — no (…, S) score tensor ever exists; temp memory
  is O(C) per step.
* The cache arrives with heads flattened, data (B, S, F_store) and scales
  (B, S, F/G): one chunk dequantizes in-register as a single
  (C, F/G, G) * scale broadcast-multiply (int4 is split-half unpacked —
  one concat, no interleave shuffle — so HBM traffic is half of int8),
  then each head's (C, hd) slab feeds a (rep*qs, hd) x (hd, C) MXU dot.
  The per-head loop is a static python unroll (Hkv is small).
* Per-slot validity: ``valid_len`` (B, 1) int32 rides in SMEM; chunk
  positions are compared against each query's causal limit so
  freshly-admitted slots with short prompts never attend to stale cache
  rows and verify queries never see their own future.
* Optional FRESH rows (speculative draft propose with zero cache
  writes, docs/DESIGN.md §12): a small already-quantized side buffer
  (B, Sf, F_store) at logical positions ``base + j`` is swept as an
  epilogue block on the LAST chunk step of the same online softmax —
  the k-round costs one cache sweep, not one per draft write. Cache
  rows at positions >= base are masked stale (the side buffer holds
  what a write would have stored).

VMEM @ C=256, F=Hkv*hd=4096: data 2x256x4096 = 2MB (int8), scales 32KB,
scratch (Hkv, rep, qs, hd) f32 ~64KB*qs — well under ~16MB/core of v5e.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# single source of truth for the nibble order / sign-extension invariant:
# the pack side lives in quant/kvcache.quantize_kv
from repro.quant.kvcache import _unpack_kv_int4

DEFAULT_KV_CHUNK = 256
NEG_INF = -1e30


def _dequant(data, scale, *, precision: str, group: int) -> jax.Array:
    """One KV chunk (C, F_store) -> (C, F) f32, dequantized in-register."""
    if precision == "bf16":
        return data.astype(jnp.float32)
    if precision == "int4":
        data = _unpack_kv_int4(data)
    c, f = data.shape
    g = data.astype(jnp.float32).reshape(c, f // group, group)
    g = g * scale.astype(jnp.float32)[:, :, None]
    return g.reshape(c, f)


def _decode_attn_kernel(*refs, precision: str, group: int,
                        num_kv_heads: int, head_dim: int, qs: int,
                        causal: bool, chunk: int, num_chunks: int,
                        fresh_rows: int):
    if fresh_rows:
        (valid_ref, base_ref, q_ref, kd_ref, ks_ref, vd_ref, vs_ref,
         fkd_ref, fks_ref, fvd_ref, fvs_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (valid_ref, q_ref, kd_ref, ks_ref, vd_ref, vs_ref,
         o_ref, m_ref, l_ref, acc_ref) = refs
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = valid_ref[0, 0]
    if causal:
        # query i sees rows < valid - qs + 1 + i
        limit = (valid - qs + 1
                 + jax.lax.broadcasted_iota(jnp.int32, (qs, 1), 0))
    else:
        limit = jnp.full((qs, 1), valid, jnp.int32)
    inv_sqrt = 1.0 / jnp.sqrt(head_dim).astype(jnp.float32)

    def online_update(kf, vf, mask, rows):
        """One masked online-softmax block update over ``rows`` KV rows."""
        for h in range(num_kv_heads):                 # static unroll
            q_h = q_ref[0, h].astype(jnp.float32)     # (rep, qs, hd)
            rep = q_h.shape[0]
            k_h = kf[:, h * head_dim:(h + 1) * head_dim]     # (rows, hd)
            v_h = vf[:, h * head_dim:(h + 1) * head_dim]
            s_h = jax.lax.dot_general(
                q_h.reshape(rep * qs, head_dim), k_h,
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * inv_sqrt
            s_h = s_h.reshape(rep, qs, rows)
            s_h = jnp.where(mask[None], s_h, NEG_INF)
            m_prev = m_ref[h]                         # (rep, qs)
            m_new = jnp.maximum(m_prev, jnp.max(s_h, axis=-1))
            p = jnp.exp(s_h - m_new[..., None])       # (rep, qs, rows)
            corr = jnp.exp(m_prev - m_new)
            l_ref[h] = l_ref[h] * corr + jnp.sum(p, axis=-1)
            acc_ref[h] = acc_ref[h] * corr[..., None] + jax.lax.dot_general(
                p.reshape(rep * qs, rows), v_h, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32
            ).reshape(rep, qs, head_dim)
            m_ref[h] = m_new

    kf = _dequant(kd_ref[0], ks_ref[0], precision=precision, group=group)
    vf = _dequant(vd_ref[0], vs_ref[0], precision=precision, group=group)
    pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (1, chunk), 1)
    # cache rows at positions >= base are stale when fresh rows supersede
    cache_limit = (jnp.minimum(limit, base_ref[0, 0]) if fresh_rows
                   else limit)
    mask = pos < cache_limit                                  # (qs, C)
    # zero invalid V rows: their probability is exactly 0, but a padded
    # tail block (ceil-div grid) may hold NaN/garbage and 0 * NaN = NaN
    row_valid = (pos < valid).reshape(chunk, 1)
    vf = jnp.where(row_valid, vf, 0.0)
    online_update(kf, vf, mask, chunk)

    if fresh_rows:
        @pl.when(ci == num_chunks - 1)
        def _fresh():
            kff = _dequant(fkd_ref[0], fks_ref[0], precision=precision,
                           group=group)
            vff = _dequant(fvd_ref[0], fvs_ref[0], precision=precision,
                           group=group)
            pos_f = base_ref[0, 0] + jax.lax.broadcasted_iota(
                jnp.int32, (1, fresh_rows), 1)
            mask_f = pos_f < limit                            # (qs, Sf)
            vff2 = jnp.where((pos_f < valid).reshape(fresh_rows, 1),
                             vff, 0.0)
            online_update(kff, vff2, mask_f, fresh_rows)

    @pl.when(ci == num_chunks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = acc_ref[...] / l[..., None]


@functools.partial(jax.jit, static_argnames=("precision", "group",
                                             "head_dim", "kv_chunk",
                                             "causal", "interpret"))
def decode_attn_pallas(q: jax.Array, k_data: jax.Array, k_scale: jax.Array,
                       v_data: jax.Array, v_scale: jax.Array,
                       valid_len: jax.Array, *, precision: str = "int8",
                       group: int = 64, head_dim: int,
                       kv_chunk: int = DEFAULT_KV_CHUNK,
                       causal: bool = True,
                       fresh_k_data: jax.Array | None = None,
                       fresh_k_scale: jax.Array | None = None,
                       fresh_v_data: jax.Array | None = None,
                       fresh_v_scale: jax.Array | None = None,
                       base: jax.Array | None = None,
                       page_table: jax.Array | None = None,
                       interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, rep, Qs, hd) f32/bf16; k/v data: (B, S, F_store) int8 or
    bf16 (F_store = Hkv*hd, int4: Hkv*hd//2); k/v scale: (B, S, F//group)
    bf16; valid_len: (B, 1) int32 rows valid AFTER the Qs query rows were
    written. Optional fresh_* / base: an already-quantized (B, Sf,
    F_store) side buffer swept at logical positions ``base + j`` with
    cache rows >= base masked stale (no-write speculative propose).

    Optional ``page_table`` (B, n_log) int32 switches K/V to a PAGED pool:
    k/v data become (N_phys, P, F_store) page pools and the table maps
    slot i's logical chunk c to its physical page. The table rides as a
    scalar-prefetch operand (pltpu.PrefetchScalarGridSpec) so the block
    index maps can read it — grid step (i, c) DMAs physical page
    ``table[i, c]`` while positions stay logical (c * P + row), which
    keeps the kernel body byte-identical to the dense path. The KV chunk
    is pinned to the page size; reads of unallocated logical pages hit
    the dump page and are discarded by the validity mask.
    Returns (B, Hkv, rep, Qs, hd) f32."""
    b, hkv, rep, qs, hd = q.shape
    assert hd == head_dim, (q.shape, head_dim)
    s = k_data.shape[1]
    chunk = min(kv_chunk, s)
    # ceil-div grid: a non-dividing final chunk reads a padded block whose
    # tail rows sit past the cache; their positions are >= s >= valid_len,
    # so the kernel's validity mask discards them
    nc = -(-s // chunk)
    ng = k_scale.shape[-1]
    fresh_rows = 0 if fresh_k_data is None else fresh_k_data.shape[1]

    if page_table is not None:
        chunk = k_data.shape[1]              # one physical page per step
        nc = page_table.shape[1]             # n_log logical pages
        kernel = functools.partial(
            _decode_attn_kernel, precision=precision, group=group,
            num_kv_heads=hkv, head_dim=hd, qs=qs, causal=causal,
            chunk=chunk, num_chunks=nc, fresh_rows=fresh_rows)
        # index maps receive (*grid_ids, *scalar_refs): (i, c, table_ref)
        in_specs = [pl.BlockSpec((1, 1), lambda i, c, t: (i, 0))]
        operands = [valid_len]
        if fresh_rows:
            in_specs.append(pl.BlockSpec((1, 1), lambda i, c, t: (i, 0)))
            operands.append(base)
        in_specs += [
            pl.BlockSpec((1, hkv, rep, qs, hd),
                         lambda i, c, t: (i, 0, 0, 0, 0)),
            pl.BlockSpec((1, chunk, k_data.shape[-1]),
                         lambda i, c, t: (t[i, c], 0, 0)),
            pl.BlockSpec((1, chunk, ng), lambda i, c, t: (t[i, c], 0, 0)),
            pl.BlockSpec((1, chunk, v_data.shape[-1]),
                         lambda i, c, t: (t[i, c], 0, 0)),
            pl.BlockSpec((1, chunk, ng), lambda i, c, t: (t[i, c], 0, 0)),
        ]
        operands += [q, k_data, k_scale, v_data, v_scale]
        if fresh_rows:
            fng = fresh_k_scale.shape[-1]
            in_specs += [
                pl.BlockSpec((1, fresh_rows, fresh_k_data.shape[-1]),
                             lambda i, c, t: (i, 0, 0)),
                pl.BlockSpec((1, fresh_rows, fng),
                             lambda i, c, t: (i, 0, 0)),
                pl.BlockSpec((1, fresh_rows, fresh_v_data.shape[-1]),
                             lambda i, c, t: (i, 0, 0)),
                pl.BlockSpec((1, fresh_rows, fng),
                             lambda i, c, t: (i, 0, 0)),
            ]
            operands += [fresh_k_data, fresh_k_scale,
                         fresh_v_data, fresh_v_scale]
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, nc),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, hkv, rep, qs, hd),
                                   lambda i, c, t: (i, 0, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((hkv, rep, qs), jnp.float32),
                pltpu.VMEM((hkv, rep, qs), jnp.float32),
                pltpu.VMEM((hkv, rep, qs, hd), jnp.float32),
            ])
        return pl.pallas_call(
            # the kernel body never reads the table — only the index maps
            # do — so drop the leading scalar-prefetch ref
            lambda t_ref, *refs: kernel(*refs),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((b, hkv, rep, qs, hd),
                                           jnp.float32),
            interpret=interpret,
        )(page_table.astype(jnp.int32), *operands)

    kernel = functools.partial(
        _decode_attn_kernel, precision=precision, group=group,
        num_kv_heads=hkv, head_dim=hd, qs=qs, causal=causal, chunk=chunk,
        num_chunks=nc, fresh_rows=fresh_rows)
    in_specs = [
        pl.BlockSpec((1, 1), lambda i, c: (i, 0)),
    ]
    operands = [valid_len]
    if fresh_rows:
        in_specs.append(pl.BlockSpec((1, 1), lambda i, c: (i, 0)))
        operands.append(base)
    in_specs += [
        pl.BlockSpec((1, hkv, rep, qs, hd), lambda i, c: (i, 0, 0, 0, 0)),
        pl.BlockSpec((1, chunk, k_data.shape[-1]),
                     lambda i, c: (i, c, 0)),
        pl.BlockSpec((1, chunk, ng), lambda i, c: (i, c, 0)),
        pl.BlockSpec((1, chunk, v_data.shape[-1]),
                     lambda i, c: (i, c, 0)),
        pl.BlockSpec((1, chunk, ng), lambda i, c: (i, c, 0)),
    ]
    operands += [q, k_data, k_scale, v_data, v_scale]
    if fresh_rows:
        fng = fresh_k_scale.shape[-1]
        in_specs += [
            pl.BlockSpec((1, fresh_rows, fresh_k_data.shape[-1]),
                         lambda i, c: (i, 0, 0)),
            pl.BlockSpec((1, fresh_rows, fng), lambda i, c: (i, 0, 0)),
            pl.BlockSpec((1, fresh_rows, fresh_v_data.shape[-1]),
                         lambda i, c: (i, 0, 0)),
            pl.BlockSpec((1, fresh_rows, fng), lambda i, c: (i, 0, 0)),
        ]
        operands += [fresh_k_data, fresh_k_scale,
                     fresh_v_data, fresh_v_scale]
    return pl.pallas_call(
        kernel,
        grid=(b, nc),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, hkv, rep, qs, hd),
                               lambda i, c: (i, 0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, qs, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((hkv, rep, qs), jnp.float32),
            pltpu.VMEM((hkv, rep, qs), jnp.float32),
            pltpu.VMEM((hkv, rep, qs, hd), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
