"""Public entry point for fused decode attention over a (quantized) KV cache.

``decode_attention(q, k, v, valid_len=...)`` is what the model stack calls
on the decode hot path. ``q`` is a single decode token (B, 1, H, hd) or a
short multi-query verify window (B, K+1, H, hd) — speculative decoding
scores all draft positions in one streaming pass with per-query causal
offset masking (docs/DESIGN.md §11). ``k``/``v`` may be:

* ``quant.kvcache.KVPage``   (int8 / packed int4 / bf16 + per-group scales)
* plain jax.Array            (raw bf16 cache, (B, S, Hkv, hd))

Backend selection mirrors ``qdot`` (kernels/qmatmul/ops.py):

* ``auto``    — (default) the Pallas kernel on TPU, else the ``grouped``
  jnp fallback. Both stream the cache in KV chunks with an online softmax
  and dequantize in-register — no (…, S_max) score tensor is ever
  materialized on the decode path.
* ``pallas``  — force the Pallas kernel (raises off-TPU rather than
  silently degrading).
* ``grouped`` — jnp fallback with the kernel's exact math (chunked online
  softmax; temp memory O(kv_chunk) per step).
* ``simple``  — dequantize-the-cache + dense-softmax oracle (materializes
  the (…, S) scores; parity baseline only).

Set process-wide via ``configure_decode_attn`` (or the
``REPRO_DECODE_ATTN_BACKEND`` / ``REPRO_DECODE_KV_CHUNK`` env vars, read
once at import as initial defaults — NOT the same knob as the
prefill-side ``REPRO_KV_CHUNK`` of models/attention.py). Any chunk width
works for any cache length: a non-dividing final chunk is read
clamped/padded and the extra rows are masked out. Both fallbacks are
validated against ref.py (tests/test_decode_attn.py,
tests/test_spec_decode.py).

``fresh_kv=(fresh_k, fresh_v, base)`` appends a small raw K/V side
buffer — quantized in-call with the page's exact write math — at logical
positions ``base + j`` WITHOUT writing the cache; cache rows at
positions >= base are masked stale. This is what lets the speculative
draft propose k tokens with zero cache writes (docs/DESIGN.md §12).
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.kernel import decode_attn_pallas
from repro.kernels.decode_attn.ref import decode_attn_ref
from repro.quant import paged as paged_ops
from repro.quant.kvcache import (KVPage, PagedKV, dequantize_kv, quantize_kv,
                                 update_page)

BACKENDS = ("auto", "pallas", "grouped", "simple")
NEG_INF = -1e30
_backend = os.environ.get("REPRO_DECODE_ATTN_BACKEND", "auto")
_kv_chunk = int(os.environ.get("REPRO_DECODE_KV_CHUNK", "256"))


def configure_decode_attn(backend: Optional[str] = None,
                          kv_chunk: Optional[int] = None) -> None:
    """Override the decode-attention knobs process-wide (mirrors
    ``models/attention.configure_chunking``). Read at TRACE time — rebuild
    jitted executables, or pass ``backend=`` / ``kv_chunk=`` per call, to
    switch after tracing."""
    global _backend, _kv_chunk
    if backend is not None:
        if backend not in BACKENDS:
            raise ValueError(f"unknown decode-attn backend {backend!r}; "
                             f"one of {BACKENDS}")
        _backend = backend
    if kv_chunk is not None:
        if kv_chunk < 1:
            raise ValueError(f"kv chunk must be >= 1, got {kv_chunk}")
        _kv_chunk = kv_chunk


def set_decode_attn_backend(name: str) -> None:
    """Back-compat alias for ``configure_decode_attn(backend=...)``."""
    configure_decode_attn(backend=name)


def get_decode_attn_backend() -> str:
    return _backend


def set_decode_kv_chunk(n: int) -> None:
    """Back-compat alias for ``configure_decode_attn(kv_chunk=...)``."""
    configure_decode_attn(kv_chunk=n)


def get_decode_kv_chunk() -> int:
    return _kv_chunk


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _page_of(x):
    """Normalize a cache operand to a KVPage (raw arrays become bf16-style
    pages with no scales). PagedKV pool views pass through — every backend
    reads them through the slot page table."""
    if isinstance(x, (KVPage, PagedKV)):
        return x
    return KVPage(data=x, scale=None, precision="bf16",
                  head_dim=x.shape[-1], group=x.shape[-1])


def _valid_vec(valid_len, b: int, s: int) -> jax.Array:
    if valid_len is None:
        return jnp.full((b,), s, jnp.int32)
    return jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))


def _fresh_page(raw: jax.Array, like: KVPage) -> KVPage:
    """Quantize fresh rows with the page's EXACT write math (update_page's
    quantize-on-insert), so the fused no-write draft sweep reads values
    bit-identical to what a cache write would have stored."""
    data, scale = quantize_kv(raw, like.precision, like.group)
    return KVPage(data=data.astype(like.data.dtype), scale=scale,
                  precision=like.precision, head_dim=raw.shape[-1],
                  group=like.group)


def _simple(q, kp, vp, valid, causal: bool, fresh=None) -> jax.Array:
    if isinstance(kp, PagedKV):
        # materialize the pool through the page table FIRST so the fresh
        # rows below go through the dense page's write math (`update_page`
        # quantize-on-insert) exactly like `_fresh_page` does in the
        # streaming backends
        kp, vp = paged_ops.gather(kp), paged_ops.gather(vp)
    if fresh is not None:
        # reference semantics: fresh rows behave exactly as if written
        fk, fv, base = fresh
        kp = update_page(kp, fk, base)
        vp = update_page(vp, fv, base)
    return decode_attn_ref(q, dequantize_kv(kp), dequantize_kv(vp), valid,
                           causal=causal)


def _grouped(q, kp, vp, valid, kv_chunk: int,
             causal: bool, fresh=None) -> jax.Array:
    """Chunked online-softmax decode attention — the kernel's exact math in
    jnp. Chunks are carved out of the cache in place with dynamic slices
    (no reshaped/transposed copy of the full cache), so temp memory is
    O(B * Hkv * rep * S * kv_chunk), never O(S_max) — for ANY cache
    length: a non-dividing final chunk is read with a clamped start and
    the re-visited rows are masked out, so every row contributes exactly
    once. Paged pools read the same chunks through the slot page table:
    the chunk width snaps to a whole number of pages and each chunk is a
    (B, pages_per_chunk) table gather instead of a dense slice — identical
    masking arithmetic, so paged/dense outputs match bit-for-bit."""
    b, s, h, d = q.shape
    hkv = kp.num_kv_heads
    rep = h // hkv
    if isinstance(kp, PagedKV):
        p_sz, n_log = kp.page_size, kp.table.shape[-1]
        t = n_log * p_sz
        g = max(1, min(kv_chunk // p_sz, n_log))
        chunk = g * p_sz
    else:
        t = kp.data.shape[1]
        chunk = min(kv_chunk, t)
    nc = -(-t // chunk)                              # ceil-div
    qh = jnp.moveaxis(q.reshape(b, s, hkv, rep, d), 1, 3)  # (B,Hkv,rep,S,d)
    qh = qh.astype(jnp.float32)
    inv_sqrt = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    if causal:
        # query i sees rows < valid - s + 1 + i
        limit = valid[:, None] - s + 1 + jnp.arange(s)[None, :]   # (B, S)
    else:
        limit = jnp.broadcast_to(valid[:, None], (b, s))
    if fresh is not None:
        # cache rows at positions >= base are STALE: the fresh side buffer
        # supersedes them (it holds the rows a cache write would have put
        # there)
        base = fresh[2]
        cache_limit = jnp.minimum(limit, base[:, None])
    else:
        cache_limit = limit

    def take(page, start):
        if isinstance(page, PagedKV):
            npg = chunk // page.page_size
            ids = jax.lax.dynamic_slice(
                page.table, (0, start // page.page_size), (b, npg))

            def gat(x):
                y = x[ids]                           # (B, npg, P, ...)
                return y.reshape(b, chunk, *x.shape[2:])

            return KVPage(
                data=gat(page.data),
                scale=None if page.scale is None else gat(page.scale),
                precision=page.precision, head_dim=page.head_dim,
                group=page.group)
        return jax.tree.map(lambda x: jax.lax.dynamic_slice_in_dim(
            x, start, chunk, axis=1), page)

    def update(carry, kf, vf, scores_mask):
        m, l, acc = carry
        scores = jnp.einsum("bhrsd,bchd->bhrsc", qh, kf,
                            preferred_element_type=jnp.float32) * inv_sqrt
        scores = jnp.where(scores_mask[:, None, None, :, :], scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhrsc,bchd->bhrsd", p, vf,
                        preferred_element_type=jnp.float32)
        return (m_new, l_new, acc * corr[..., None] + pv)

    def body(ci, carry):
        start = jnp.minimum(ci * chunk, t - chunk)   # clamp the last chunk
        kf = dequantize_kv(take(kp, start))          # (B, C, Hkv, hd) f32
        vf = dequantize_kv(take(vp, start))
        pos = start + jnp.arange(chunk)
        # rows re-read by a clamped start were handled by a prior chunk
        live = pos >= ci * chunk
        mask = (live[None, None, :]
                & (pos[None, None, :] < cache_limit[:, :, None]))  # (B,S,C)
        return update(carry, kf, vf, mask)

    m0 = jnp.full((b, hkv, rep, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, rep, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, rep, s, d), jnp.float32)
    carry = jax.lax.fori_loop(0, nc, body, (m0, l0, a0))
    if fresh is not None:
        fk, fv, base = fresh
        kf = dequantize_kv(_fresh_page(fk, kp))       # (B, Sf, Hkv, hd)
        vf = dequantize_kv(_fresh_page(fv, vp))
        pos_f = base[:, None] + jnp.arange(fk.shape[1])[None, :]  # (B, Sf)
        mask = pos_f[:, None, :] < limit[:, :, None]              # (B,S,Sf)
        carry = update(carry, kf, vf, mask)
    m, l, acc = carry
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).reshape(b, s, h, d).astype(q.dtype)


def _pallas(q, kp, vp, valid, kv_chunk: int, causal: bool,
            fresh=None, interpret: bool = False) -> jax.Array:
    b, s, h, d = q.shape
    hkv = kp.num_kv_heads
    rep = h // hkv
    paged = isinstance(kp, PagedKV)

    def flat(page):
        # dense: (B, S, ...) -> (B, S, F_store); paged pool: (N, P, ...) ->
        # (N, P, F_store) — the kernel's scalar-prefetched page table maps
        # grid steps to physical pages
        lead = page.data.shape[:2]
        data = page.data.reshape(*lead, -1)
        if page.scale is None:  # bf16 page: dummy unit scales, never read
            scale = jnp.ones((*lead, 1), jnp.bfloat16)
        else:
            scale = page.scale
        return data, scale

    kd, ks = flat(kp)
    vd, vs = flat(vp)
    qk = jnp.moveaxis(q.reshape(b, s, hkv, rep, d), 1, 3)  # (B,Hkv,rep,S,d)
    fresh_args = {}
    if fresh is not None:
        fk, fv, base = fresh
        sf = fk.shape[1]
        pad = (-sf) % 8                  # sublane-align the tiny row axis
        if pad:
            widths = ((0, 0), (0, pad), (0, 0), (0, 0))
            fk, fv = jnp.pad(fk, widths), jnp.pad(fv, widths)
        fkd, fks = flat(_fresh_page(fk, kp))
        fvd, fvs = flat(_fresh_page(fv, vp))
        fresh_args = dict(fresh_k_data=fkd, fresh_k_scale=fks,
                          fresh_v_data=fvd, fresh_v_scale=fvs,
                          base=base[:, None])
    out = decode_attn_pallas(
        qk, kd, ks, vd, vs, valid[:, None],
        precision=kp.precision, group=kp.group, head_dim=d,
        kv_chunk=kv_chunk, causal=causal, interpret=interpret,
        page_table=kp.table if paged else None,
        **fresh_args)
    return jnp.moveaxis(out, 3, 1).reshape(b, s, h, d).astype(q.dtype)


def decode_attention(q: jax.Array, k, v, *,
                     valid_len: Optional[jax.Array] = None,
                     causal: bool = True,
                     backend: Optional[str] = None,
                     kv_chunk: Optional[int] = None,
                     fresh_kv=None) -> jax.Array:
    """(Multi-)query GQA attention of q (B, S, H, hd) against a cached
    K/V (KVPage or raw (B, T, Hkv, hd)). ``valid_len`` (scalar or per-slot
    (B,)) counts valid cache rows INCLUDING the S freshly-written query
    rows; with ``causal=True`` query i additionally only sees rows
    ``< valid_len - S + 1 + i`` (S=1 reduces to the plain decode mask),
    with ``causal=False`` every query sees all valid rows (cross-attention
    over precomputed encoder K/V). ``backend`` overrides the process-wide
    selection for this call.

    ``fresh_kv=(fresh_k, fresh_v, base)`` — raw (B, Sf, Hkv, hd) side
    buffers plus a per-slot (B,) base position: row j acts exactly as if
    it had been written (quantize-on-insert) at cache position
    ``base + j``, and cache rows at positions >= base are masked stale.
    ``valid_len`` still counts ALL valid rows including the fresh ones.
    Returns (B, S, H, hd) in q's dtype."""
    backend = _backend if backend is None else backend
    if backend not in BACKENDS:
        raise ValueError(f"unknown decode-attn backend {backend!r}; "
                         f"one of {BACKENDS}")
    if kv_chunk is None:
        kv_chunk = _kv_chunk
    elif kv_chunk < 1:
        raise ValueError(f"kv_chunk must be >= 1, got {kv_chunk}")
    kp, vp = _page_of(k), _page_of(v)
    assert kp.precision == vp.precision and kp.group == vp.group, \
        "K and V cache pages must share precision/group"
    b, s, h, d = q.shape
    assert s >= 1, f"decode attention needs at least one query, got s={s}"
    if fresh_kv is not None:
        fk, fv, base = fresh_kv
        assert fk.shape == fv.shape and fk.ndim == 4, (fk.shape, fv.shape)
        fresh_kv = (fk, fv, jnp.broadcast_to(
            jnp.asarray(base, jnp.int32), (b,)))
    t_total = (kp.seq_len if isinstance(kp, PagedKV) else kp.data.shape[1])
    valid = _valid_vec(valid_len, b, t_total)
    if backend == "pallas" or (backend == "auto" and _use_pallas()):
        if backend == "pallas" and not _use_pallas():
            raise ValueError(
                f"decode-attn backend 'pallas' needs a TPU; running on "
                f"{jax.default_backend()!r} (use 'grouped' for the "
                f"identical-math jnp fallback)")
        return _pallas(q, kp, vp, valid, kv_chunk, causal, fresh_kv)
    if backend == "simple":
        return _simple(q, kp, vp, valid, causal, fresh_kv)
    return _grouped(q, kp, vp, valid, kv_chunk, causal, fresh_kv)
