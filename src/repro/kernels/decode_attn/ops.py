"""Public entry point for fused decode attention over a (quantized) KV cache.

``decode_attention(q, k, v, valid_len=...)`` is what the model stack calls
on the decode hot path. ``q`` is a single decode token (B, 1, H, hd) or a
short multi-query verify window (B, K+1, H, hd) — speculative decoding
scores all draft positions in one streaming pass with per-query causal
offset masking (docs/DESIGN.md §11). ``k``/``v`` may be:

* ``quant.kvcache.KVPage``   (int8 / packed int4 / bf16 + per-group scales)
* plain jax.Array            (raw bf16 cache, (B, S, Hkv, hd))

Backend selection mirrors ``qdot`` (kernels/qmatmul/ops.py):

* ``auto``    — (default) the Pallas kernel on TPU, else the ``grouped``
  jnp fallback. Both stream the cache in KV chunks with an online softmax
  and dequantize in-register — no (…, S_max) score tensor is ever
  materialized on the decode path.
* ``pallas``  — force the Pallas kernel (raises off-TPU rather than
  silently degrading).
* ``grouped`` — jnp fallback with the kernel's exact math (chunked online
  softmax; temp memory O(kv_chunk) per step).
* ``simple``  — dequantize-the-cache + dense-softmax oracle (materializes
  the (…, S) scores; parity baseline only).

Set process-wide via ``set_decode_attn_backend`` or the
``REPRO_DECODE_ATTN_BACKEND`` env var; the KV chunk width comes from
``REPRO_DECODE_KV_CHUNK`` (any width works for any cache length: a
non-dividing final chunk is read clamped/padded and the extra rows are
masked out). Both fallbacks are validated against ref.py
(tests/test_decode_attn.py, tests/test_spec_decode.py).
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.kernel import decode_attn_pallas
from repro.kernels.decode_attn.ref import decode_attn_ref
from repro.quant.kvcache import KVPage, dequantize_kv

BACKENDS = ("auto", "pallas", "grouped", "simple")
NEG_INF = -1e30
_backend = os.environ.get("REPRO_DECODE_ATTN_BACKEND", "auto")
_kv_chunk = int(os.environ.get("REPRO_DECODE_KV_CHUNK", "256"))


def set_decode_attn_backend(name: str) -> None:
    """Select the process-wide decode-attention backend (read at TRACE
    time — rebuild jitted executables, or pass ``backend=`` per call, to
    switch after tracing)."""
    if name not in BACKENDS:
        raise ValueError(f"unknown decode-attn backend {name!r}; "
                         f"one of {BACKENDS}")
    global _backend
    _backend = name


def get_decode_attn_backend() -> str:
    return _backend


def set_decode_kv_chunk(n: int) -> None:
    if n < 1:
        raise ValueError(f"kv chunk must be >= 1, got {n}")
    global _kv_chunk
    _kv_chunk = n


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _page_of(x) -> KVPage:
    """Normalize a cache operand to a KVPage (raw arrays become bf16-style
    pages with no scales)."""
    if isinstance(x, KVPage):
        return x
    return KVPage(data=x, scale=None, precision="bf16",
                  head_dim=x.shape[-1], group=x.shape[-1])


def _valid_vec(valid_len, b: int, s: int) -> jax.Array:
    if valid_len is None:
        return jnp.full((b,), s, jnp.int32)
    return jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))


def _simple(q, kp: KVPage, vp: KVPage, valid, causal: bool) -> jax.Array:
    return decode_attn_ref(q, dequantize_kv(kp), dequantize_kv(vp), valid,
                           causal=causal)


def _grouped(q, kp: KVPage, vp: KVPage, valid, kv_chunk: int,
             causal: bool) -> jax.Array:
    """Chunked online-softmax decode attention — the kernel's exact math in
    jnp. Chunks are carved out of the cache in place with dynamic slices
    (no reshaped/transposed copy of the full cache), so temp memory is
    O(B * Hkv * rep * S * kv_chunk), never O(S_max) — for ANY cache
    length: a non-dividing final chunk is read with a clamped start and
    the re-visited rows are masked out, so every row contributes exactly
    once."""
    b, s, h, d = q.shape
    t, hkv = kp.data.shape[1], kp.num_kv_heads
    rep = h // hkv
    chunk = min(kv_chunk, t)
    nc = -(-t // chunk)                              # ceil-div
    qh = jnp.moveaxis(q.reshape(b, s, hkv, rep, d), 1, 3)  # (B,Hkv,rep,S,d)
    qh = qh.astype(jnp.float32)
    inv_sqrt = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    if causal:
        # query i sees rows < valid - s + 1 + i
        limit = valid[:, None] - s + 1 + jnp.arange(s)[None, :]   # (B, S)
    else:
        limit = jnp.broadcast_to(valid[:, None], (b, s))

    def take(page, start):
        return jax.tree.map(lambda x: jax.lax.dynamic_slice_in_dim(
            x, start, chunk, axis=1), page)

    def body(ci, carry):
        m, l, acc = carry
        start = jnp.minimum(ci * chunk, t - chunk)   # clamp the last chunk
        kf = dequantize_kv(take(kp, start))          # (B, C, Hkv, hd) f32
        vf = dequantize_kv(take(vp, start))
        scores = jnp.einsum("bhrsd,bchd->bhrsc", qh, kf,
                            preferred_element_type=jnp.float32) * inv_sqrt
        pos = start + jnp.arange(chunk)
        # rows re-read by a clamped start were handled by a prior chunk
        fresh = pos >= ci * chunk
        mask = (fresh[None, None, :]
                & (pos[None, None, :] < limit[:, :, None]))       # (B, S, C)
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhrsc,bchd->bhrsd", p, vf,
                        preferred_element_type=jnp.float32)
        return (m_new, l_new, acc * corr[..., None] + pv)

    m0 = jnp.full((b, hkv, rep, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, rep, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, rep, s, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nc, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).reshape(b, s, h, d).astype(q.dtype)


def _pallas(q, kp: KVPage, vp: KVPage, valid, kv_chunk: int, causal: bool,
            interpret: bool = False) -> jax.Array:
    b, s, h, d = q.shape
    t, hkv = kp.data.shape[1], kp.num_kv_heads
    rep = h // hkv

    def flat(page):
        data = page.data.reshape(b, t, -1)
        if page.scale is None:  # bf16 page: dummy unit scales, never read
            scale = jnp.ones((b, t, 1), jnp.bfloat16)
        else:
            scale = page.scale
        return data, scale

    kd, ks = flat(kp)
    vd, vs = flat(vp)
    qk = jnp.moveaxis(q.reshape(b, s, hkv, rep, d), 1, 3)  # (B,Hkv,rep,S,d)
    out = decode_attn_pallas(
        qk, kd, ks, vd, vs, valid[:, None],
        precision=kp.precision, group=kp.group, head_dim=d,
        kv_chunk=kv_chunk, causal=causal, interpret=interpret)
    return jnp.moveaxis(out, 3, 1).reshape(b, s, h, d).astype(q.dtype)


def decode_attention(q: jax.Array, k, v, *,
                     valid_len: Optional[jax.Array] = None,
                     causal: bool = True,
                     backend: Optional[str] = None,
                     kv_chunk: Optional[int] = None) -> jax.Array:
    """(Multi-)query GQA attention of q (B, S, H, hd) against a cached
    K/V (KVPage or raw (B, T, Hkv, hd)). ``valid_len`` (scalar or per-slot
    (B,)) counts valid cache rows INCLUDING the S freshly-written query
    rows; with ``causal=True`` query i additionally only sees rows
    ``< valid_len - S + 1 + i`` (S=1 reduces to the plain decode mask),
    with ``causal=False`` every query sees all valid rows (cross-attention
    over precomputed encoder K/V). ``backend`` overrides the process-wide
    selection for this call. Returns (B, S, H, hd) in q's dtype."""
    backend = _backend if backend is None else backend
    if backend not in BACKENDS:
        raise ValueError(f"unknown decode-attn backend {backend!r}; "
                         f"one of {BACKENDS}")
    if kv_chunk is None:
        kv_chunk = _kv_chunk
    elif kv_chunk < 1:
        raise ValueError(f"kv_chunk must be >= 1, got {kv_chunk}")
    kp, vp = _page_of(k), _page_of(v)
    assert kp.precision == vp.precision and kp.group == vp.group, \
        "K and V cache pages must share precision/group"
    b, s, h, d = q.shape
    assert s >= 1, f"decode attention needs at least one query, got s={s}"
    valid = _valid_vec(valid_len, b, kp.data.shape[1])
    if backend == "pallas" or (backend == "auto" and _use_pallas()):
        if backend == "pallas" and not _use_pallas():
            raise ValueError(
                f"decode-attn backend 'pallas' needs a TPU; running on "
                f"{jax.default_backend()!r} (use 'grouped' for the "
                f"identical-math jnp fallback)")
        return _pallas(q, kp, vp, valid, kv_chunk, causal)
    if backend == "simple":
        return _simple(q, kp, vp, valid, causal)
    return _grouped(q, kp, vp, valid, kv_chunk, causal)
