"""Dense-math oracle for single-query GQA decode attention.

Materializes the full (B, Hkv, rep, 1, S) score tensor — the thing the
fused kernel and its chunked fallback exist to avoid — so it is the
ground truth the backends are validated against (tests/test_decode_attn.py).
Operates on raw (dequantized) caches only.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attn_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                    valid_len: Optional[jax.Array] = None) -> jax.Array:
    """q: (B, 1, H, hd); k/v: (B, S, Hkv, hd); valid_len: scalar or (B,)
    count of valid cache rows (None = all S). Returns (B, 1, H, hd)."""
    b, s, h, d = q.shape
    assert s == 1, "decode attention is single-query"
    t, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    qh = q.reshape(b, s, hkv, rep, d)
    scores = jnp.einsum("bshrd,bthd->bhrst", qh.astype(jnp.float32),
                        k.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    if valid_len is not None:
        vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))
        valid = jnp.arange(t)[None, :] < vl[:, None]
        scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhrst,bthd->bshrd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)
