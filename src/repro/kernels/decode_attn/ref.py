"""Dense-math oracle for (multi-)query GQA decode attention.

Materializes the full (B, Hkv, rep, S, T) score tensor — the thing the
fused kernel and its chunked fallback exist to avoid — so it is the
ground truth the backends are validated against (tests/test_decode_attn.py).
Operates on raw (dequantized) caches only.

Queries may be a single decode token (S=1) or a short verify window
(S = K+1 for speculative decoding, docs/DESIGN.md §11). With
``causal=True`` query i sits at absolute cache position
``valid_len - S + i`` and attends to rows ``<= valid_len - S + i``;
with ``causal=False`` (cross-attention verify) every query sees all
``valid_len`` rows.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attn_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                    valid_len: Optional[jax.Array] = None,
                    causal: bool = True) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, T, Hkv, hd); valid_len: scalar or (B,)
    count of valid cache rows INCLUDING the S freshly-written query rows
    (None = all T). Returns (B, S, H, hd)."""
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    qh = q.reshape(b, s, hkv, rep, d)
    scores = jnp.einsum("bshrd,bthd->bhrst", qh.astype(jnp.float32),
                        k.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    vl = (jnp.full((b,), t, jnp.int32) if valid_len is None
          else jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,)))
    if causal:
        # row limit per query: query i sees rows < vl - s + 1 + i
        limit = vl[:, None] - s + 1 + jnp.arange(s)[None, :]     # (B, S)
    else:
        limit = jnp.broadcast_to(vl[:, None], (b, s))
    valid = jnp.arange(t)[None, None, :] < limit[:, :, None]     # (B, S, T)
    scores = jnp.where(valid[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhrst,bthd->bshrd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)
