"""jit'd wrapper for the fused quantization kernel with CPU fallback."""

from __future__ import annotations

import jax

from repro.kernels.quantize.kernel import quantize_int8_pallas
from repro.kernels.quantize.ref import quantize_int8_ref


def quantize_int8(w: jax.Array, group: int = 128):
    if jax.default_backend() == "tpu" and w.ndim == 2:
        return quantize_int8_pallas(w, group=group)
    return quantize_int8_ref(w, group=group)
