"""Pure-jnp oracle for the fused group-wise int8 quantization kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8_ref(w: jax.Array, group: int = 128):
    """w: (N, K) -> (q int8 (N, K), scale f32 (N, K//group))."""
    n, k = w.shape
    g = w.astype(jnp.float32).reshape(n, k // group, group)
    absmax = jnp.max(jnp.abs(g), axis=-1)
    scale = absmax / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(g / safe[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(n, k), scale
