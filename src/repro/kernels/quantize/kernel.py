"""Pallas TPU kernel: fused group-wise absmax int8 quantization.

Used at EWQ-apply time: one HBM read of the bf16 weights, one write of the
int8 payload + scales — no intermediate f32 materialization in HBM. The
grid tiles (N, K) into (BN, BK) VMEM blocks with BK a multiple of the
quantization group so each block owns whole groups; absmax reduction and
rounding happen entirely in VMEM registers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 256
DEFAULT_BK = 512


def _quantize_kernel(w_ref, q_ref, s_ref, *, group: int):
    w = w_ref[...].astype(jnp.float32)            # (BN, BK)
    bn, bk = w.shape
    g = w.reshape(bn, bk // group, group)
    absmax = jnp.max(jnp.abs(g), axis=-1)         # (BN, BK/G)
    scale = absmax / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(g / safe[..., None]), -127, 127)
    q_ref[...] = q.reshape(bn, bk).astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("group", "bn", "bk", "interpret"))
def quantize_int8_pallas(w: jax.Array, *, group: int = 128,
                         bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                         interpret: bool = False):
    n, k = w.shape
    bn, bk = min(bn, n), min(bk, k)
    assert n % bn == 0 and k % bk == 0 and bk % group == 0
    kernel = functools.partial(_quantize_kernel, group=group)
    return pl.pallas_call(
        kernel,
        grid=(n // bn, k // bk),
        in_specs=[pl.BlockSpec((bn, bk), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bk // group), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), jnp.int8),
            jax.ShapeDtypeStruct((n, k // group), jnp.float32),
        ],
        interpret=interpret,
    )(w)
