"""Activation-sharding context: logical-dim constraints inside model code.

GSPMD propagation alone loses the batch sharding through scan carries (the
embed table's conflicting dims win), which replicates attention scores and
logits. Model code therefore marks activations with *logical* dims via
``constrain(x, ("batch", None, "model"))``; the mapping to mesh axes is
installed by ``activation_sharding(mesh)`` in the launch drivers. Outside
the context (single-device smoke tests) ``constrain`` is a no-op.

Logical dims:
  "batch"  -> ("pod", "data") / "data"   (the FSDP/DP axes)
  "model"  -> "model"                     (TP/EP axis)
  "expert" -> "model"
A dim is only sharded when its size divides the axis size.

Two more facilities live here because they must be visible inside model
code:

* ``unshard_fsdp(tree)`` — FSDP materialization point. Layer bodies call it
  on their (scan-sliced) parameters; each weight leaf is constrained to its
  TP-only spec (fsdp dims -> replicated), which makes GSPMD emit the
  per-layer all-gather in the forward and the matching reduce-scatter for
  the gradients — ZeRO-3 semantics with remat-aware re-gathering.

* ``cost_mode()`` / ``unroll_flag()`` — XLA's HloCostAnalysis counts a
  while-loop body ONCE regardless of trip count, so scans hide depth from
  cost_analysis. The dry-run's cost lowering enters ``cost_mode()``, which
  makes every model scan fully unroll (models pass ``unroll=unroll_flag()``
  to lax.scan); the dry-run lowers reduced-depth variants and extrapolates
  affinely in depth (see launch/dryrun.py).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


def _rules():
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def activation_sharding(mesh):
    """Install the logical-dim -> mesh-axis mapping. Tolerates meshes
    missing an axis (pure-DP serving mesh has no "model"; pure-TP no
    "data"): the absent logical dim maps to no axis (size 1 — always
    divides, always replicated)."""
    fsdp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    fsdp_t = fsdp if isinstance(fsdp, tuple) else (fsdp,)
    if not all(a in mesh.axis_names for a in fsdp_t):
        fsdp, fsdp_t = None, ()
    model_ax = "model" if "model" in mesh.axis_names else None
    model_sz = mesh.shape["model"] if model_ax else 1
    sizes = {
        "batch": int(np.prod([mesh.shape[a] for a in fsdp_t] or [1])),
        "model": model_sz,
        "expert": model_sz,
        "seq": model_sz,
    }
    axes = {"batch": fsdp, "model": model_ax, "expert": model_ax,
            "seq": model_ax}
    old = _rules()
    _STATE.rules = {"axes": axes, "sizes": sizes, "mesh": mesh}
    try:
        yield
    finally:
        _STATE.rules = old


def constrain(x: jax.Array, dims: Sequence[Optional[str]]) -> jax.Array:
    rules = _rules()
    if rules is None:
        return x
    assert len(dims) == x.ndim, (dims, x.shape)
    parts = []
    for name, size in zip(dims, x.shape):
        if name is None:
            parts.append(None)
        elif size % rules["sizes"][name] == 0:
            parts.append(rules["axes"][name])
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(x, P(*parts))


def data_shards() -> int:
    """Size of the data (batch) axes, 1 outside the context — used by the
    MoE grouped-local dispatch to align groups with data shards."""
    rules = _rules()
    return rules["sizes"]["batch"] if rules else 1


def model_shards() -> int:
    rules = _rules()
    return rules["sizes"]["model"] if rules else 1


def unshard_fsdp(tree):
    """FSDP materialization: constrain each weight leaf to its TP-only spec
    (fsdp dims replicated). No-op outside the activation_sharding context."""
    rules = _rules()
    if rules is None:
        return tree
    mesh = rules["mesh"]
    from repro.sharding.specs import fsdp_axes, param_specs

    fsdp = fsdp_axes(mesh)
    fsdp_set = set(fsdp) if isinstance(fsdp, tuple) else {fsdp}
    specs = param_specs(tree, mesh)

    def strip(spec):
        parts = []
        for ax in spec:
            if ax is None or ax in fsdp_set or (
                    isinstance(ax, tuple) and set(ax) & fsdp_set):
                parts.append(None)
            else:
                parts.append(ax)
        return P(*parts)

    def apply(leaf, spec):
        return jax.lax.with_sharding_constraint(leaf, strip(spec))

    return jax.tree.map(apply, tree, specs,
                        is_leaf=lambda x: isinstance(x, P))


@contextlib.contextmanager
def cost_mode():
    old = getattr(_STATE, "cost_mode", False)
    _STATE.cost_mode = True
    try:
        yield
    finally:
        _STATE.cost_mode = old


def in_cost_mode() -> bool:
    return getattr(_STATE, "cost_mode", False)


def unroll_flag():
    """Pass as lax.scan(..., unroll=unroll_flag())."""
    return True if in_cost_mode() else 1
