"""Named-axis sharding rules (DP/FSDP/TP/EP/SP) for every model family.

Mesh axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
The "pod" axis extends data parallelism (batch and FSDP shard over
("pod", "data")), so gradient all-reduces are hierarchical: intra-pod over
"data", inter-pod (DCN) over "pod".

Parameter policy (2D "FSDP+TP", MaxText-style):
  column-parallel weights (wq/wk/wv/w_gate/w_up/w_in, (out, in)):
      out -> "model", in -> fsdp axes
  row-parallel weights (wo/w_down/w_out, (out, in)):
      out -> fsdp axes, in -> "model"
  embeddings / lm head (V, D):  V -> "model", D -> fsdp axes
  MoE experts (E, F, D): E -> "model" (EP) when E % |model| == 0, else
      F/D -> "model" (expert TP); the other matrix dim -> fsdp axes
  norms / biases / scalars: replicated
  QTensor leaves: payload inherits the weight rule; per-group scales inherit
      the same dims (group axis divides the contraction axis).

Dims are sharded only when divisible by the axis size — otherwise that dim
is replicated (GSPMD would pad; we prefer predictable layouts).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P
from jax.tree_util import (DictKey, FlattenedIndexKey, GetAttrKey,
                           SequenceKey)

COLUMN_PARALLEL = ("wq", "wk", "wv", "w_gate", "w_up", "w_in")
ROW_PARALLEL = ("wo", "w_down", "w_out")
EMBED = ("tok", "head")


def _axis_size(mesh: Mesh, name) -> int:
    """Product of the named axes' sizes; absent axes contribute 1 (a
    pure-DP serving mesh has no "model" axis, a pure-TP mesh no "data")."""
    names = name if isinstance(name, tuple) else (name,)
    return int(np.prod([mesh.shape[n] for n in names
                        if n in mesh.axis_names] or [1]))


def _present(mesh: Mesh, name) -> bool:
    names = name if isinstance(name, tuple) else (name,)
    return all(n in mesh.axis_names for n in names)


def fsdp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if isinstance(k, DictKey):
            names.append(str(k.key))
        elif isinstance(k, GetAttrKey):
            names.append(k.name)
        elif isinstance(k, SequenceKey):
            names.append(f"[{k.idx}]")
        elif isinstance(k, FlattenedIndexKey):
            names.append(f"#{k.key}")
    return names


def _div(dim: int, mesh: Mesh, axis) -> Optional[Any]:
    """axis if present in the mesh and dim divisible by its size, else None
    (replicate)."""
    if axis is None or not _present(mesh, axis):
        return None
    return axis if dim % _axis_size(mesh, axis) == 0 else None


def _weight_spec(names: list[str], shape: tuple, mesh: Mesh,
                 fsdp: Any, is_scale: bool = False) -> P:
    """Spec for a (possibly layer-stacked, possibly expert-stacked) matrix."""
    leaf = None
    for n in reversed(names):
        if not n.startswith("#"):
            leaf = n
            break
    ndim = len(shape)

    # norms / biases / 1D leaves: replicate
    if ndim <= 1:
        return P()

    # Embedding / head tables: (V, D)
    if leaf in EMBED:
        return P(_div(shape[0], mesh, "model"), _div(shape[1], mesh, fsdp))

    # Determine trailing matrix dims; leading dims are layer/expert stacks.
    n_stack = ndim - 2
    stack_spec: list[Any] = [None] * n_stack

    is_expert = leaf in ("w_gate", "w_up", "w_down") and n_stack >= 1 and \
        names and any("moe" in n for n in names)
    if is_expert:
        # (L?, E, F/D, D/F): expert dim is the last stack dim.
        e = shape[n_stack - 1]
        if _div(e, mesh, "model") is not None:
            stack_spec[n_stack - 1] = "model"
            model_used = True
        else:
            model_used = False
        out_dim, in_dim = shape[-2], shape[-1]
        if leaf in ("w_gate", "w_up"):
            out_ax = "model" if not model_used else None
            spec = [_div(out_dim, mesh, out_ax) if out_ax else None,
                    _div(in_dim, mesh, fsdp)]
        else:  # w_down
            in_ax = "model" if not model_used else None
            spec = [_div(out_dim, mesh, fsdp),
                    _div(in_dim, mesh, in_ax) if in_ax else None]
        return P(*stack_spec, *spec)

    if leaf in COLUMN_PARALLEL:
        return P(*stack_spec, _div(shape[-2], mesh, "model"),
                 _div(shape[-1], mesh, fsdp))
    if leaf in ROW_PARALLEL:
        return P(*stack_spec, _div(shape[-2], mesh, fsdp),
                 _div(shape[-1], mesh, "model"))
    if leaf == "router":
        return P(*stack_spec, None, None)
    if leaf == "conv_w":
        return P(*stack_spec, _div(shape[-2], mesh, "model"), None)
    # default 2D leaf: fsdp on the larger dim
    return P(*stack_spec, _div(shape[-2], mesh, fsdp), None)


def param_specs(params: Any, mesh: Mesh, *, serving: bool = False) -> Any:
    """PartitionSpec pytree matching ``params`` (QTensor-aware).

    serving=True keeps weights TP-sharded only (replicated over the data
    axes): decode re-reads weights every step, so FSDP sharding would force
    a per-step, per-layer all-gather. Use only when params/TP fit HBM —
    launch/dryrun.py decides per arch (giant MoEs keep 2D sharding).
    """
    fsdp = None if serving else fsdp_axes(mesh)

    def spec_of(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        # QTensor children: #0 payload, #1 scale.
        if names and names[-1] == "#1":
            base = _weight_spec(names[:-1], shape, mesh, fsdp, is_scale=True)
            # scale has the same rank; group axis (last) may not divide.
            parts = list(base) + [None] * (len(shape) - len(base))
            parts = parts[:len(shape)]
            fixed = [ax if ax and shape[i] % _axis_size(mesh, ax) == 0
                     else None for i, ax in enumerate(parts)]
            return P(*fixed)
        if names and names[-1] == "#0":
            names = names[:-1]
        return _weight_spec(names, shape, mesh, fsdp)

    return jax.tree_util.tree_map_with_path(spec_of, params)


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    """tokens/labels (B, S) -> batch over (pod, data) when divisible."""
    fsdp = fsdp_axes(mesh)

    def spec_of(leaf):
        b = leaf.shape[0]
        return P(_div(b, mesh, fsdp), *([None] * (len(leaf.shape) - 1)))

    return jax.tree.map(spec_of, batch)


def cache_specs(cache: Any, mesh: Mesh) -> Any:
    """KV/SSM caches: batch dim over fsdp axes, head/state dims over model.

    Layouts handled (by rank + position conventions):
      KV:       (L, B, S, Hkv, hd)   — raw, or a KVPage's int8/int4 payload
                (same rank; the packed last dim is never sharded anyway)
      KV scale: (L, B, S, F/G)       — per-group scales of a quantized page:
                tiny (~1/group of the payload), so only the slot dim shards
                and the group dim stays replicated
      conv:     (L, B, W-1, C)
      state:    (L, B, H, P, N)
      pos:      scalar
    """
    fsdp = fsdp_axes(mesh)

    def spec_of(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        # KVPage payload/scale leaves appear as "#0"/"#1" (optionally below
        # a "[i]" page-tuple index) under the cache field's name.
        field = next((n for n in reversed(names)
                      if not (n.startswith("#") or n.startswith("["))), "")
        is_scale = bool(names) and names[-1] == "#1"
        if names and names[-1] == "#2":
            # PagedKV page table (L, B, n_log) int32 — tiny, consulted on
            # the host by the allocator: keep it replicated.
            return P()
        if field in ("k", "v", "cross_k", "cross_v"):
            if is_scale or len(shape) == 4:
                return P(None, _div(shape[1], mesh, fsdp), None, None)
            # Prefer KV-head sharding; when heads don't divide the model
            # axis (GQA kv=8 on |model|=16, MHA kv=36), shard the SEQUENCE
            # dim instead — replicating a 32k-deep cache 16x is what blew
            # decode memory to >100GiB/dev in the baseline sweep.
            if _div(shape[3], mesh, "model") is not None:
                return P(None, _div(shape[1], mesh, fsdp), None, "model",
                         None)
            return P(None, _div(shape[1], mesh, fsdp),
                     _div(shape[2], mesh, "model"), None, None)
        if field == "conv" and len(shape) == 4:
            return P(None, _div(shape[1], mesh, fsdp), None,
                     _div(shape[3], mesh, "model"))
        if field == "state" and len(shape) == 5:
            return P(None, _div(shape[1], mesh, fsdp),
                     _div(shape[2], mesh, "model"), None, None)
        # fallback: shard dim 1 (batch) if possible
        parts = [None] * len(shape)
        if len(shape) >= 2:
            parts[1] = _div(shape[1], mesh, fsdp)
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec_of, cache)


def opt_state_specs(opt_state, pspecs, mesh: Mesh):
    """Adam moments inherit parameter specs (ZeRO); count replicated."""
    from repro.optim.adamw import AdamWState
    from repro.quant.qtypes import QTensor

    def moment_spec(spec_leaf, moment_leaf):
        if isinstance(moment_leaf, QTensor):
            # int8 moments: payload inherits; scale replicated (simple).
            return QTensor(data=spec_leaf, scale=P(),
                           precision=moment_leaf.precision,
                           shape=moment_leaf.shape, group=moment_leaf.group)
        return spec_leaf

    is_q = lambda x: isinstance(x, QTensor)
    m_specs = jax.tree.map(moment_spec, pspecs, opt_state.m,
                           is_leaf=lambda x: isinstance(x, P))
    v_specs = jax.tree.map(moment_spec, pspecs, opt_state.v,
                           is_leaf=lambda x: isinstance(x, P))
    return AdamWState(count=P(), m=m_specs, v=v_specs)


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def serving_param_shardings(params: Any, mesh: Mesh) -> Any:
    """NamedSharding tree for TP-only serving placement of a (possibly
    segmented/quantized) parameter tree (docs/DESIGN.md §9).

    Also used for self-speculative DRAFT trees (docs/DESIGN.md §11):
    path-keyed rules give a shared leaf the same spec it got in the
    target tree, so ``device_put`` on an already-placed shared payload is
    a no-op (no duplicate device buffers) and only the draft-only int4
    copies actually move. Spec-decode verify activations need no new
    rules either: the (B, K+1, H, hd) multi-query q/out tensors ride the
    same ("batch", None, "model", None) constraints as single-query
    decode, and KV writes keep the ``cache_specs`` layout — the verify
    window only changes the (unsharded) sequence extent of the write."""
    return to_shardings(param_specs(params, mesh, serving=True), mesh)
