"""Sharded checkpointing with atomic step directories and elastic restore.

Layout:
  <dir>/step_000100/
      manifest.json         # tree structure, shapes, dtypes, shard counts
      shard_<host>.npz      # this host's param/opt shards (local addressable)
      .complete             # commit marker (atomic rename of tmp dir)

Features required at 1000+ node scale:
  * per-host shard files — no single-writer bottleneck;
  * atomic commit — a crash mid-save never corrupts the latest checkpoint
    (tmp dir + rename, ``.complete`` marker);
  * restore-time resharding — the target mesh may differ from the save-time
    mesh (elastic scaling): arrays are reassembled logically and re-sharded
    to the new mesh from the per-host pieces;
  * retention — keep the last K checkpoints, delete older ones only after a
    newer commit succeeds;
  * data-stream state (step, seed) rides along so restart resumes the exact
    deterministic batch sequence.

On this single-process environment each "host" is process 0 holding every
shard; the file format and the reshard-on-restore path are identical.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
import zlib
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

from repro.quant.qtypes import QTensor

# npz cannot store bfloat16 natively; carry it as uint16 bits + manifest dtype
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8}


class ArtifactCorruptionError(RuntimeError):
    """A checkpoint/artifact payload failed integrity verification. Names
    the bad leaf so a corrupt artifact is diagnosable at load time instead
    of surfacing as an opaque shape/dtype error (DESIGN.md §15)."""

    def __init__(self, leaf: str, detail: str):
        super().__init__(f"artifact payload corrupt at leaf {leaf!r}: "
                         f"{detail}")
        self.leaf = leaf


def _crc(arr: np.ndarray) -> int:
    """crc32 of the STORED byte payload (post-bitcast view)."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _check_crc(key: str, meta: dict, stored: list) -> None:
    """Verify per-leaf checksums stamped at save time. Pre-checksum
    checkpoints (no ``crc32`` in the manifest leaf) pass unverified."""
    want = meta.get("crc32")
    if want is None:
        return
    got = [_crc(a) for a in stored]
    if got != list(want):
        raise ArtifactCorruptionError(
            key, f"crc32 {got} != manifest {list(want)} — the payload "
            f"was damaged after save (truncated/flipped bytes)")


def _payload(data: dict, name: str, leaf_key: str) -> np.ndarray:
    arr = data.get(name)
    if arr is None:
        raise ArtifactCorruptionError(
            leaf_key, f"stored array {name!r} missing from the shard "
            f"files (truncated checkpoint?)")
    return arr


def _to_storable(arr: np.ndarray) -> np.ndarray:
    if str(arr.dtype) in _BITCAST:
        return arr.view(_BITCAST[str(arr.dtype)])
    return arr


def _from_storable(arr: np.ndarray, dtype: str) -> np.ndarray:
    if dtype in _BITCAST:
        return arr.view(getattr(ml_dtypes, dtype))
    return arr


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, QTensor))
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "name", getattr(
            k, "idx", k)))) for k in path)
        out.append((key, leaf))
    return out, treedef


def save(directory: str, step: int, tree: Any, *, extra: Optional[dict] = None,
         keep: int = 3, process_index: int = 0) -> str:
    """Atomically save ``tree`` (params/opt state pytree) at ``step``."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = pathlib.Path(tempfile.mkdtemp(dir=directory,
                                        prefix=f".tmp_step_{step:08d}_"))
    try:
        flat, _ = _flatten_with_paths(tree)
        arrays = {}
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        for key, leaf in flat:
            if isinstance(leaf, QTensor):
                scale = np.asarray(leaf.scale)
                data = np.asarray(leaf.data)
                arrays[f"{key}.__qdata"] = data
                arrays[f"{key}.__qscale"] = _to_storable(scale)
                manifest["leaves"][key] = {
                    "kind": "qtensor", "precision": leaf.precision,
                    "shape": list(leaf.shape), "group": leaf.group,
                    "scale_dtype": str(scale.dtype),
                    "crc32": [_crc(data),
                              _crc(arrays[f"{key}.__qscale"])]}
            else:
                arr = np.asarray(leaf)
                arrays[key] = _to_storable(arr)
                manifest["leaves"][key] = {
                    "kind": "array", "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc32": [_crc(arrays[key])]}
        np.savez(tmp / f"shard_{process_index}.npz", **arrays)
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
        (tmp / ".complete").touch()
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _retain(directory, keep)
    return str(final)


def _retain(directory: pathlib.Path, keep: int):
    steps = sorted(p for p in directory.glob("step_*") if
                   (p / ".complete").exists())
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = sorted(p for p in d.glob("step_*") if (p / ".complete").exists())
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def _load_shards(d: pathlib.Path) -> dict:
    """Read every shard file with bounded retry for transient I/O faults
    (flaky network filesystems; DESIGN.md §15). The chaos sites are
    imported lazily — serving/chaos.py is stdlib-only, no cycle — and let
    tests/CI inject a transient read failure (``artifact.read``) and a
    deterministic one-byte payload flip (``artifact.corrupt``) that the
    per-leaf checksums must catch."""
    from repro.runtime.fault import retry
    from repro.serving import chaos

    def read():
        chaos.fire("artifact.read")
        data = {}
        for shard_file in sorted(d.glob("shard_*.npz")):
            with np.load(shard_file) as z:
                for k in z.files:
                    data[k] = z[k]
        return data

    data = retry(read, attempts=3, base_delay=0.05,
                 retriable=(OSError, chaos.TransientFault))
    if data and chaos.deny("artifact.corrupt"):
        key = sorted(data)[0]
        arr = np.array(data[key])
        if arr.nbytes:
            arr.view(np.uint8).reshape(-1)[0] ^= 0xFF
            data[key] = arr
    return data


def restore(directory: str, tree_like: Any, *, step: Optional[int] = None,
            mesh=None, specs=None) -> tuple[Any, dict]:
    """Restore into the structure of ``tree_like``. When ``mesh``+``specs``
    are given, each array is device_put with its NamedSharding — restoring
    onto a different mesh than save-time (elastic re-mesh) just works
    because arrays are stored logically."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {directory}")
    d = directory / f"step_{step:08d}"
    if not (d / ".complete").exists():
        raise FileNotFoundError(f"checkpoint {d} incomplete")
    with open(d / "manifest.json") as f:
        manifest = json.load(f)
    data = _load_shards(d)

    flat, treedef = _flatten_with_paths(tree_like)
    leaves = []
    from jax.sharding import NamedSharding
    spec_flat = None
    if specs is not None:
        spec_list, _ = _flatten_with_paths(specs)
        spec_flat = {k: v for k, v in spec_list}

    for key, like in flat:
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        want_kind = "qtensor" if isinstance(like, QTensor) else "array"
        if meta["kind"] != want_kind:
            raise ValueError(
                f"{key}: checkpoint holds a {meta['kind']}, target expects "
                f"a {want_kind} — quantization group/plan mismatch between "
                f"the artifact manifest and the target model?")
        if meta["kind"] == "qtensor":
            qdata = _payload(data, f"{key}.__qdata", key)
            qscale = _payload(data, f"{key}.__qscale", key)
            _check_crc(key, meta, [qdata, qscale])
            leaf = QTensor(data=qdata,
                           scale=_from_storable(
                               qscale, meta.get("scale_dtype", "float32")),
                           precision=meta["precision"],
                           shape=tuple(meta["shape"]), group=meta["group"])
            if isinstance(like, QTensor) and \
                    leaf.data.shape != tuple(like.data.shape):
                raise ValueError(f"{key}: checkpoint qtensor data shape "
                                 f"{leaf.data.shape} != expected "
                                 f"{tuple(like.data.shape)}")
            if mesh is not None and spec_flat is not None and key in spec_flat:
                # spec leaf is a QTensor whose data/scale children are
                # PartitionSpecs (param_specs descends into QTensor nodes):
                # payload and per-group scales land sharded straight from
                # the host buffers — no replicated materialization.
                spec = spec_flat[key]
                leaf = QTensor(
                    data=jax.device_put(
                        leaf.data, NamedSharding(mesh, spec.data)),
                    scale=jax.device_put(
                        leaf.scale, NamedSharding(mesh, spec.scale)),
                    precision=leaf.precision, shape=leaf.shape,
                    group=leaf.group)
        else:
            stored = _payload(data, key, key)
            _check_crc(key, meta, [stored])
            arr = _from_storable(stored, meta["dtype"])
            want = getattr(like, "shape", None)
            if want is not None and arr.shape != tuple(want):
                raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                                 f"expected {tuple(want)}")
            if mesh is not None and spec_flat is not None and key in spec_flat:
                arr = jax.device_put(arr, NamedSharding(mesh, spec_flat[key]))
            leaf = arr
        leaves.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]


# ---------------------------------------------------------------------------
# Compiled-plan artifacts (quant/compiler.py)
#
# An artifact is a regular step_0 checkpoint of the compiled parameter tree
# (SegmentedParams stacks flatten into ordinary QTensor/array leaves) plus a
# top-level ``plan_manifest.json`` that records everything needed to rebuild
# the tree skeleton without raw weights: family, config name, the QuantPlan
# itself, group size, and the per-stack segment layout.
# ---------------------------------------------------------------------------

_ARTIFACT_MANIFEST = "plan_manifest.json"


def save_artifact(directory: str, tree: Any, manifest: dict) -> str:
    """Persist a compiled quantized-param tree + its plan manifest."""
    path = save(directory, 0, tree, extra={"plan_manifest": manifest},
                keep=1)
    tmp = pathlib.Path(directory) / (_ARTIFACT_MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=2)
    os.replace(tmp, pathlib.Path(directory) / _ARTIFACT_MANIFEST)
    return path


def is_artifact(directory: str) -> bool:
    d = pathlib.Path(directory)
    return (d / _ARTIFACT_MANIFEST).exists() and latest_step(d) is not None


def load_artifact_manifest(directory: str) -> dict:
    path = pathlib.Path(directory) / _ARTIFACT_MANIFEST
    if not path.exists():
        raise FileNotFoundError(f"no {_ARTIFACT_MANIFEST} in {directory}")
    with open(path) as f:
        return json.load(f)


def restore_artifact(directory: str, tree_like: Any, *, mesh=None,
                     specs=None) -> Any:
    """Restore the compiled tree into a (segmented/quantized) skeleton.

    With ``mesh`` + ``specs`` (a PartitionSpec tree matching ``tree_like``,
    e.g. ``param_specs(skeleton, mesh, serving=True)``), every leaf —
    including QTensor payload/scale pairs — is device_put to its
    NamedSharding as it is read, so a cold boot lands sharded."""
    tree, _ = restore(directory, tree_like, mesh=mesh, specs=specs)
    return tree
