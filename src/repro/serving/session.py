"""Serve-loop state machine: continuous batching with chunked prefill
interleaving and SLO-aware scheduling (docs/DESIGN.md §14).

``ServeSession`` owns everything one ``ServeEngine.serve`` run carries
between decode chunks — the scheduler, the slotted DecodeState, in-flight
chunked prefills, the decode-step clock and the latency accounting. One
serve *tick* is split into two phases:

* ``dispatch()``: host-side policy + device-side launches, NO blocking
  reads — expire/cancel/deadline sweeps, SLO preemption, admissions
  (monolithic prefill+insert, or reserve + chunked-prefill start),
  advancing one interleaved prefill chunk, then launching the next jitted
  decode chunk (JAX dispatch is async, so the chunk runs while the host
  moves on);
* ``harvest()``: the only device_get — read done/lengths from the chunk
  ``dispatch`` launched, mark first tokens, complete finished slots.

The split exists for DP replica serving (serving/replica.py): a router
dispatches EVERY replica's chunk before harvesting ANY of them, so the
replicas' device work overlaps instead of serializing behind each
other's blocking reads. Single-engine ``serve()`` just calls both phases
back to back — byte-identical behavior to the old inline loop.

Chunked prefill (Sarathi/SplitFuse-style): with ``prefill_chunk`` set,
an admitted request first RESERVES its slot and its prompt enters the
batch=1 prefill cache one chunk per tick, interleaved between decode
chunks, so a 2048-token prompt no longer stalls 15 running slots for its
whole prefill. The decode-step clock does NOT advance on prefill-only
ticks, keeping arrival_step semantics identical to monolithic serving.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro import obs
from repro.serving import chaos
from repro.serving.pool import OutOfPages
from repro.serving.scheduler import Request, Scheduler, SLOConfig


@dataclasses.dataclass(frozen=True)
class DegradeConfig:
    """Graceful-degradation policy under pool pressure (DESIGN.md §15).

    ``policy="ewq"`` spills the engine's KV precision down its entropy-
    ordered tier ladder (``ServeEngine.degrade_ladder``, grounded in the
    weight plan's / FastEWQ's layer-level decisions) when admission
    backpressure persists for ``patience`` consecutive ticks — each tier
    repacks the pool at constant bytes, so lower precision buys more
    pages — and promotes one tier back after ``cooldown`` stall-free
    ticks with at least ``headroom`` of the pool free. ``shrink_spec``
    additionally drops speculative decoding while degraded (draft rounds
    probe extra cache rows per slot)."""
    policy: str = "ewq"
    patience: int = 2
    cooldown: int = 16
    headroom: float = 0.5
    shrink_spec: bool = True


class ServeSession:
    """One continuous-batching run over a fixed request list."""

    def __init__(self, engine, requests, *, num_slots: int, chunk: int,
                 temperature: float = 0.0, key=None,
                 prefill_chunk: Optional[int] = None,
                 slo: Optional[SLOConfig] = None, replica_id: int = 0,
                 degrade: Optional[DegradeConfig] = None,
                 watchdog_s: Optional[float] = None):
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        if prefill_chunk is None:
            prefill_chunk = engine.prefill_chunk
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1 or None, got "
                             f"{prefill_chunk}")
        self.engine = engine
        self.chunk = chunk
        self.num_slots = num_slots
        self.temperature = temperature
        self.prefill_chunk = prefill_chunk
        self.slo = slo
        self.spec = engine.spec is not None
        self.sched = Scheduler(num_slots)
        # telemetry (docs/DESIGN.md §16): stamp the replica id on every
        # emitter BEFORE the first submit so request spans / pool instants
        # land on this replica's trace process from the start
        self.replica_id = replica_id
        self.sched.pid = replica_id
        if engine.pool is not None:
            engine.pool.pid = replica_id
        _tr = obs.tracer()
        if _tr is not None:
            _tr.set_process_name(replica_id, f"replica{replica_id}")
        self.device_times: list[float] = []   # fenced device s per chunk
        self.host_gaps: list[float] = []      # gap - device per chunk
        self._device_s: Optional[float] = None
        for r in requests:
            if self.spec:
                engine._spec_budget_check(len(r.prompt), r.max_new_tokens)
            else:
                assert len(r.prompt) + r.max_new_tokens <= engine.max_seq, \
                    r.rid
            self.sched.submit(r)
        self.state = engine.init_decode_state(
            num_slots, key if key is not None else jax.random.PRNGKey(0))
        if self.spec:
            self.fn = engine._spec_fn(chunk)
            self.draft_params = engine.draft_params
        else:
            self.fn = engine._chunk_fn(chunk)
        self.clock = 0
        self.occupancy: list[float] = []
        self.admissions = 0
        self.generated = 0
        self.prefill_chunks = 0
        self.spec_m = {"proposed": 0, "accepted": 0, "committed": 0,
                       "rounds": 0}
        self.tasks: dict = {}          # slot -> ChunkedPrefill (reserved)
        self.gaps: list[float] = []    # wall seconds per decode chunk
        self._chunk_t0: Optional[float] = None
        self._pending_spec = None
        self._dispatched = False
        # fault tolerance + graceful degradation (docs/DESIGN.md §15)
        self.watchdog_s = watchdog_s
        self.watchdog_trips = 0
        self.degrade = degrade if engine.pool is not None else None
        self._ladder = (engine.degrade_ladder() if self.degrade is not None
                        else [engine.kv_plan])
        self.tier = 0
        self.tier_steps = [0] * max(1, len(self._ladder))
        self.degraded_steps = 0
        self.transitions: list = []    # (clock, from_tier, to_tier)
        self._stall_ticks = 0
        self._calm_ticks = 0

    # -- progress ------------------------------------------------------------
    @property
    def done(self) -> bool:
        return self.sched.all_done()

    # -- tick phase 1: policy + launches --------------------------------------
    def dispatch(self) -> None:
        """Admissions, SLO enforcement, one interleaved prefill chunk, and
        the next decode-chunk launch. Never blocks on device results."""
        pf = obs.profile()
        if pf is not None:
            pf.tick(self.clock)
        tr = obs.tracer()
        if tr is None:
            self._dispatch()
            return
        tr.begin("tick/dispatch", self.replica_id)
        try:
            self._dispatch()
        finally:
            tr.end("tick/dispatch", self.replica_id)

    def _dispatch(self) -> None:
        eng, sched = self.engine, self.sched
        self._dispatched = False
        # chaos sites fire BEFORE any state mutation, so a transient fault
        # can retry this tick in place (serving/chaos.py)
        chaos.fire("replica.dispatch", tag=self.replica_id)
        chaos.fire("device.stall", tag=self.replica_id)
        now = time.perf_counter()
        sched.poll(self.clock, now)
        sched.expire(self.clock)
        self._enforce_running_drops()
        self._preempt_for_priority()
        stalled = self._admit(now)
        if self._degrade_tick(stalled) and stalled:
            stalled = self._admit(now)   # lower tier freed pages: retry now
        self._advance_prefills()
        if sched.num_active == 0:
            if self.tasks:
                return                 # prefill-only tick; clock frozen
            if stalled:
                if (self.degrade is not None
                        and self.tier + 1 < len(self._ladder)
                        and self._transition(self.tier + 1)):
                    return             # spilled a tier: re-admit next tick
                raise OutOfPages(
                    "admission deadlock: no active slots and the pool "
                    "cannot supply the next request's pages "
                    f"({eng.pool.num_pages} pages of "
                    f"{eng.pool.page_size} tokens) — size pool_pages "
                    "for the longest request")
            nxt = sched.next_arrival()
            if nxt is not None:
                self.clock = max(self.clock + 1, nxt)  # idle: fast-forward
            return
        self.occupancy.append(sched.num_active / self.num_slots)
        self._chunk_t0 = time.perf_counter()
        use_spec = self.spec and not (
            self.tier > 0 and self.degrade is not None
            and self.degrade.shrink_spec)
        if use_spec:
            self.state, self._pending_spec = self.fn(
                eng.params, self.draft_params, self.state)
        else:
            fn = self.fn if not self.spec else eng._chunk_fn(self.chunk)
            self.state = fn(eng.params, self.state)
        pf = obs.profile()
        if pf is not None and pf.device_fences:
            # fence right after the async launch: launch -> ready is the
            # device-compute share of this chunk; harvest subtracts it
            # from the dispatch->harvest gap to expose the host-side
            # scheduling overhead (docs/DESIGN.md §16)
            jax.block_until_ready(self.state.tokens)
            self._device_s = time.perf_counter() - self._chunk_t0
        self.clock += self.chunk
        self.tier_steps[self.tier] += self.chunk
        if self.tier:
            self.degraded_steps += self.chunk
        self._dispatched = True

    # -- graceful degradation (docs/DESIGN.md §15) ----------------------------
    def _degrade_tick(self, stalled: bool) -> bool:
        """Tier policy, one decision per tick: persistent backpressure
        spills down the ladder, sustained headroom promotes back up.
        Returns True when a transition happened."""
        if self.degrade is None or len(self._ladder) < 2:
            return False
        if stalled:
            self._stall_ticks += 1
            self._calm_ticks = 0
            if (self._stall_ticks >= self.degrade.patience
                    and self.tier + 1 < len(self._ladder)):
                return self._transition(self.tier + 1)
            return False
        self._stall_ticks = 0
        if self.tier == 0:
            return False
        pool = self.engine.pool
        if pool.pages_free / pool.num_pages < self.degrade.headroom:
            self._calm_ticks = 0
            return False
        self._calm_ticks += 1
        if self._calm_ticks >= self.degrade.cooldown:
            return self._transition(self.tier - 1)
        return False

    def _transition(self, tier: int) -> bool:
        """Repack the engine's pool at the target tier (False when the
        engine refuses — promotion without room for the live pages)."""
        tr = obs.tracer()
        t0 = tr.now_us() if tr is not None else 0.0
        state = self.engine.apply_kv_plan(self.state, self._ladder[tier])
        if state is None:
            return False
        self.state = state
        if tr is not None:
            tr.complete("engine/apply_kv_plan", t0, self.replica_id,
                        args={"from_tier": self.tier, "to_tier": tier})
        obs.instant("degrade/transition", self.replica_id,
                    args={"from_tier": self.tier, "to_tier": tier,
                          "clock": self.clock})
        self.transitions.append((self.clock, self.tier, tier))
        self.tier = tier
        self._stall_ticks = 0
        self._calm_ticks = 0
        return True

    # -- tick phase 2: the only blocking read ----------------------------------
    def harvest(self) -> None:
        """Read back the chunk ``dispatch`` launched and complete slots."""
        tr = obs.tracer()
        if tr is None:
            self._harvest()
            return
        tr.begin("tick/harvest", self.replica_id)
        try:
            self._harvest()
        finally:
            tr.end("tick/harvest", self.replica_id)

    def _harvest(self) -> None:
        if not self._dispatched:
            return
        chaos.fire("replica.harvest", tag=self.replica_id)
        self._dispatched = False
        eng, sched = self.engine, self.sched
        if self._pending_spec is not None:
            delta = {k_: int(v)
                     for k_, v in self._pending_spec._asdict().items()}
            for k_, v in delta.items():
                self.spec_m[k_] += v
            self._pending_spec = None
            obs.instant("spec/round", self.replica_id, obs.DECODE_TRACK,
                        args=delta)
        done_np, len_np = jax.device_get((self.state.done,
                                          self.state.lengths))
        now = time.perf_counter()
        if self._chunk_t0 is not None:
            gap = now - self._chunk_t0
            self.gaps.append(gap)
            tr = obs.tracer()
            if tr is not None or self._device_s is not None:
                args = {"steps": self.chunk, "tier": self.tier,
                        "tuned": eng.tuned}
                if self._device_s is not None:
                    host = max(0.0, gap - self._device_s)
                    self.device_times.append(self._device_s)
                    self.host_gaps.append(host)
                    args["device_ms"] = round(self._device_s * 1e3, 3)
                    args["host_gap_ms"] = round(host * 1e3, 3)
                    self._device_s = None
                if tr is not None:
                    tr.complete("decode/chunk", tr.now_us() - gap * 1e6,
                                self.replica_id, obs.DECODE_TRACK,
                                args=args)
            if self.watchdog_s is not None and gap > self.watchdog_s:
                # dispatch->harvest deadline overrun: an in-process stall
                # cannot be preempted, so it is surfaced (ServeStats
                # watchdog_trips) rather than aborted mid-read
                self.watchdog_trips += 1
        for slot, req in sched.active_slots():
            if len_np[slot] > len(req.prompt):
                sched.mark_first_token(slot, now)
            if not done_np[slot]:
                continue
            self._complete_slot(slot, req, int(len_np[slot]))

    def _complete_slot(self, slot: int, req: Request, n: int,
                       reason: Optional[str] = None) -> None:
        eng, sched = self.engine, self.sched
        row = np.asarray(jax.device_get(self.state.tokens[slot, :n]))
        lps = np.asarray(jax.device_get(
            self.state.logprobs[slot, len(req.prompt):n]))
        if reason is None:
            reason = ("eos" if eng.eos_id is not None and n > 0
                      and row[-1] == eng.eos_id else "length")
        sched.complete(slot, row, lps, reason, self.clock)
        self.state = eng.release(self.state, slot)
        self.generated += n - len(req.prompt)

    # -- SLO enforcement -------------------------------------------------------
    def _enforce_running_drops(self) -> None:
        """Cancellation / deadline sweep over reserved and decoding slots:
        the request finalizes (running aborts keep their partial tokens)
        and the slot + pool pages free leak-free."""
        eng, sched = self.engine, self.sched
        for slot, req in sched.reserved_slots():
            reason = sched.drop_reason(req, self.clock)
            if reason is None:
                continue
            task = self.tasks.pop(slot, None)
            if task is not None and task.match is not None \
                    and eng.pool is not None:
                eng.pool.unpin(task.match)
            sched.drop_reserved(slot, reason, self.clock)
        drops = [(slot, req, sched.drop_reason(req, self.clock))
                 for slot, req in sched.active_slots()
                 if sched.drop_reason(req, self.clock) is not None]
        if not drops:
            return
        len_np = jax.device_get(self.state.lengths)
        for slot, req, reason in drops:
            self._complete_slot(slot, req, int(len_np[slot]), reason=reason)

    def _preempt_for_priority(self) -> None:
        """Restart-style preemption: a strictly-higher-priority waiter may
        evict the lowest-priority decoding slot (its pages return through
        ``PoolSession.release``; the victim requeues and prefills again).
        Gated behind ``SLOConfig.preempt``."""
        if self.slo is None or not self.slo.preempt:
            return
        sched = self.sched
        while not sched.free_slots():
            head = sched.peek_ready(self.clock)
            if head is None:
                return
            victim = sched.preempt_victim(head.priority)
            if victim is None:
                return
            self.state = self.engine.release(self.state, victim)
            sched.preempt(victim)

    def _admission_gated(self, req: Request, now: float) -> bool:
        """TPOT-percentile admission gate: defer NEW work while running
        slots' measured per-token latency (rolling mean over the last
        ``admit_window`` chunks) exceeds the target. Priority-0 requests
        and requests already past their TTFT target are never deferred."""
        slo = self.slo
        if slo is None or slo.tpot_target_s is None or req.priority == 0:
            return False
        if self.sched.num_active == 0:
            return False    # never starve an idle engine
        if slo.ttft_target_s is not None:
            rw = self.sched.ready_wall(req.rid)
            if rw is not None and now - rw >= slo.ttft_target_s:
                return False
        window = self.gaps[-slo.admit_window:]
        if not window:
            return False
        return (sum(window) / len(window)) / self.chunk > slo.tpot_target_s

    # -- admissions --------------------------------------------------------------
    def _admit(self, now: float) -> bool:
        """Fill free slots from the ready queue. Returns True when pool
        backpressure stalled an admission (deadlock detection)."""
        eng, sched = self.engine, self.sched
        for slot in sched.free_slots():
            head = sched.peek_ready(self.clock)
            if head is None or self._admission_gated(head, now):
                break
            req = sched.next_ready(self.clock)
            if req is None:
                break
            if eng.pool is not None and (
                    chaos.deny("pool.oom", tag=self.replica_id)
                    or not eng.pool.can_admit(
                        eng.pool.pages_for(eng._slot_seq_budget(
                            len(req.prompt), req.max_new_tokens)))):
                # pool backpressure: not enough free/evictable pages for
                # the worst case — retry after a slot drains
                sched.requeue(req)
                return True
            # the TTFT clock starts at dequeue (reserve) so prefill time
            # (and the prefix cache skipping it) shows up in ttft_s
            sched.reserve(slot, req, self.clock, wall=time.perf_counter())
            if self.prefill_chunk is not None:
                self.tasks[slot] = eng.begin_prefill(
                    req.prompt, frames=req.frames, state=self.state)
                continue
            # monolithic: admission is baseline-identical even under spec
            # (the spec loop recognizes pos == lengths as a fresh slot and
            # takes the first candidate dist from these prefill logits)
            pf = eng.prefill_request(req.prompt, frames=req.frames,
                                     state=self.state)
            self._insert(slot, req, pf)
        return False

    def _insert(self, slot: int, req: Request, pf) -> bool:
        """Insert a finished prefill into its reserved slot; False if the
        pool refused (the request is back in the queue, nothing leaked)."""
        eng, sched = self.engine, self.sched
        temp = (req.temperature if req.temperature is not None
                else self.temperature)
        try:
            state = eng.insert(self.state, slot, pf, req.max_new_tokens,
                               temperature=temp, top_k=req.top_k,
                               top_p=req.top_p)
        except OutOfPages:
            # engine.insert unpinned the match and leaked nothing; put the
            # request back (its queue-delay clock resumes) and retry when
            # a slot drains
            sched.unreserve(slot)
            return False
        self.state = state
        # a refill = joining a batch that is already mid-decode
        if self.occupancy and sched.num_active > 0:
            self.admissions += 1
        sched.activate(slot)
        return True

    def _advance_prefills(self) -> None:
        """Advance every in-flight chunked prefill by ONE chunk per tick
        (the Sarathi schedule: a bounded slice of prefill work interleaved
        between decode chunks — in the steady state one long prompt is in
        flight, so a tick adds at most one prefill_chunk-token step);
        insert each task as soon as its prompt is fully in. ``tasks``
        preserves reservation order, so progress is FIFO."""
        for slot in list(self.tasks):
            task = self.tasks[slot]
            self.engine.advance_prefill(task, self.prefill_chunk)
            self.prefill_chunks += 1
            if not task.done:
                continue
            del self.tasks[slot]
            req = self.sched.reserved_request(slot)
            self._insert(slot, req, task.as_prefill())

    # -- teardown ------------------------------------------------------------
    def abort(self) -> list:
        """Tear down in-flight work leak-free and return the unfinished
        requests (replica failover / exception unwind, DESIGN.md §15):
        chunked-prefill prefix pins drop, every decoding slot's pages
        release, and the scheduler drains — the caller re-drives the
        survivors onto another session, where each re-prefills from its
        original prompt (greedy tokens unchanged). Finished outputs stay
        available through ``finalize``."""
        eng, sched = self.engine, self.sched
        for task in self.tasks.values():
            if task.match is not None and eng.pool is not None:
                eng.pool.unpin(task.match)
        self.tasks.clear()
        for slot, _req in sched.active_slots():
            self.state = eng.release(self.state, slot)
        survivors = sched.drain_unfinished()
        self._dispatched = False
        self._pending_spec = None
        if eng.pool is not None:
            eng.pool.check_invariants()
        return survivors

    # -- wrap-up -------------------------------------------------------------
    def finalize(self):
        """Sorted outputs + ServeStats (call once, after ``done``).

        The run's numbers publish into a fresh per-run registry
        (``obs/serve_metrics.py``) and ``ServeStats`` is reconstructed as
        a snapshot VIEW over it — one source of truth for the CLI report,
        the benchmark rows and the Prometheus/JSON expositions. When a
        process-wide registry is installed (``obs.metrics()``) the run
        merges into it, so sequential/parallel serves accumulate with
        Prometheus counter semantics."""
        from repro.obs.metrics import MetricsRegistry
        from repro.obs.serve_metrics import publish_session
        from repro.quant.compiler import kv_tier_labels
        from repro.serving.engine import ServeStats
        from repro.serving.spec.loop import obs_labels
        eng, sched = self.engine, self.sched
        outputs = sorted(sched.finished, key=lambda o: o.rid)
        pool_kw = None
        if eng.pool is not None:
            pool = eng.pool
            pool.check_invariants()    # engine teardown: zero leaked pages
            if self.tier:
                # sequential serves on this engine restart at tier 0 (the
                # next init_decode_state rebuilds the pool from kv_plan)
                eng.kv_plan = self._ladder[0]
            pool_kw = dict(
                pages_total=pool.num_pages,
                pages_peak=pool.peak_pages,
                page_size=pool.page_size,
                prefix_hits=pool.prefix_hits,
                prefix_hit_tokens=pool.prefix_hit_tokens,
                prompt_tokens=pool.prompt_tokens,
                cow_copies=pool.cow_copies,
                kv_bytes_peak=(pool.peak_pages * eng._page_bytes
                               + self.num_slots
                               * eng._nonpaged_bytes_per_slot()))
        local = MetricsRegistry()
        publish_session(
            local, replica=self.replica_id, outputs=outputs,
            occupancy=(float(np.mean(self.occupancy))
                       if self.occupancy else 0.0),
            num_chunks=len(self.occupancy), chunk=self.chunk,
            admissions=self.admissions, generated=self.generated,
            prefill_chunks=self.prefill_chunks, gaps=self.gaps,
            spec_m=self.spec_m,
            spec_labels=(obs_labels(eng.spec) if self.spec else None),
            watchdog_trips=self.watchdog_trips,
            degraded_steps=self.degraded_steps,
            transitions=len(self.transitions),
            tier_steps=self.tier_steps,
            tier_labels=kv_tier_labels(self._ladder),
            tuned=eng.tuned, pool=pool_kw,
            device_times=self.device_times, host_gaps=self.host_gaps)
        installed = obs.metrics()
        if installed is not None:
            installed.merge(local)
        pf = obs.profile()
        if pf is not None:
            pf.stop()
        return outputs, ServeStats.from_registry(local)

    def run(self):
        """Drain the stream to completion (single-engine serve loop). Any
        failure first tears the session down leak-free (``abort``), then
        propagates."""
        try:
            while not self.done:
                self.dispatch()
                self.harvest()
        except BaseException:
            self.abort()
            raise
        return self.finalize()
