"""Slot-based decode state for continuous batching.

The engine decodes a fixed number of *slots* in lockstep; each slot holds at
most one in-flight request. All per-slot bookkeeping lives in ``DecodeState``
— a pytree that is the carry of the engine's jitted ``lax.scan`` decode loop
— so a token step never leaves the device:

* ``tokens`` / ``logprobs`` are (B, S_max) ring-free buffers written at
  ``lengths[slot]`` via a masked scatter (done/empty slots never advance);
* ``cache`` is the model family's KV/SSM cache in the *slotted* layout
  (``pos`` is a (B,) per-slot vector — see Model.slotted_cache); under
  ``ServeEngine(kv_precision=...)`` its K/V fields are quantized KVPages
  (quant/kvcache.py) and admission quantizes the prefilled K/V on insert;
* admission (``insert_request``) overwrites one slot with a freshly
  prefilled request; eviction (``release_slot``) just drops the slot's
  active flag — the next insert overwrites every per-slot buffer.

Both helpers are traceable (the slot index may be a tracer), so the engine
jits them once per prompt length.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.model import Model


class DecodeState(NamedTuple):
    cache: Any              # family cache, slotted layout (pos: (B,) int32)
    last_logits: jax.Array  # (B, V_pad) f32 — logits after each slot's last token
    tokens: jax.Array       # (B, S_max) int32 — prompt + generated tokens
    lengths: jax.Array      # (B,) int32 — valid tokens in each row
    max_len: jax.Array      # (B,) int32 — slot stops once lengths reaches this
    done: jax.Array         # (B,) bool — finished generating
    active: jax.Array       # (B,) bool — slot holds a live request
    logprobs: jax.Array     # (B, S_max) f32 — chosen-token logprob per position
    key: jax.Array          # PRNG carry for temperature sampling
    # per-slot sampling controls — TRACED, so changing them never recompiles
    # the chunk fn (serving/sampling.py)
    temperature: jax.Array  # (B,) f32 — 0 = greedy
    top_k: jax.Array        # (B,) int32 — 0 = disabled
    top_p: jax.Array        # (B,) f32 — >= 1 = disabled

    @property
    def num_slots(self) -> int:
        return self.tokens.shape[0]


def init_state(model: Model, num_slots: int, max_seq: int,
               key: jax.Array, cache: Any = None) -> DecodeState:
    """All slots empty: inactive, done, zero-length. ``cache`` overrides
    the default slotted cache (paged engines pass a pool-backed one)."""
    return DecodeState(
        cache=cache if cache is not None
        else model.slotted_cache(num_slots, max_seq),
        last_logits=jnp.zeros((num_slots, model.cfg.padded_vocab),
                              jnp.float32),
        tokens=jnp.zeros((num_slots, max_seq), jnp.int32),
        lengths=jnp.zeros((num_slots,), jnp.int32),
        max_len=jnp.zeros((num_slots,), jnp.int32),
        done=jnp.ones((num_slots,), bool),
        active=jnp.zeros((num_slots,), bool),
        logprobs=jnp.zeros((num_slots, max_seq), jnp.float32),
        key=key,
        temperature=jnp.zeros((num_slots,), jnp.float32),
        top_k=jnp.zeros((num_slots,), jnp.int32),
        top_p=jnp.ones((num_slots,), jnp.float32))


def insert_request(model: Model, state: DecodeState, slot: jax.Array,
                   prompt: jax.Array, prompt_cache: Any,
                   last_logits: jax.Array, max_new: jax.Array,
                   temperature=jnp.float32(0.0), top_k=jnp.int32(0),
                   top_p=jnp.float32(1.0), page_rows=None) -> DecodeState:
    """Admit one prefilled request into ``slot``.

    ``prompt``: (P,) int32; ``prompt_cache``/``last_logits`` come from a
    batch=1 prefill (scalar cache pos == P). The whole slot row is reset so
    nothing leaks from the previous occupant. Sampling controls are traced
    scalars recorded per slot. ``page_rows``: (row, wrow) page-table rows
    from the pool allocator — required when the cache holds paged fields.
    """
    p = prompt.shape[0]
    tokens = state.tokens.at[slot].set(0)
    tokens = jax.lax.dynamic_update_slice(
        tokens, prompt[None].astype(jnp.int32), (slot, 0))
    return state._replace(
        cache=model.insert_cache_slot(state.cache, prompt_cache, slot,
                                      page_rows=page_rows),
        last_logits=state.last_logits.at[slot].set(
            last_logits.reshape(-1).astype(jnp.float32)),
        tokens=tokens,
        lengths=state.lengths.at[slot].set(p),
        max_len=state.max_len.at[slot].set(jnp.int32(p) + max_new),
        done=state.done.at[slot].set(False),
        active=state.active.at[slot].set(True),
        logprobs=state.logprobs.at[slot].set(0.0),
        temperature=state.temperature.at[slot].set(temperature),
        top_k=state.top_k.at[slot].set(top_k),
        top_p=state.top_p.at[slot].set(top_p))


def commit_tokens(state: DecodeState, cand: jax.Array, cand_lp: jax.Array,
                  counts: jax.Array) -> DecodeState:
    """Append up to K+1 tokens per slot in one shot (spec-decode commit).

    ``cand``/``cand_lp``: (B, K+1) candidate tokens and their chosen-token
    logprobs; ``counts``: (B,) how many leading candidates each slot
    commits (0 = none — done/empty slots). Candidates land at
    ``lengths[slot] + j`` via a masked scatter; ``lengths`` advances by
    ``counts``. The caller handles done flags and cache rollback.
    """
    b, kp1 = cand.shape
    s_max = state.tokens.shape[1]
    jidx = jnp.arange(kp1)
    wpos = state.lengths[:, None] + jidx[None, :]              # (B, K+1)
    write = jidx[None, :] < counts[:, None]
    tokens, logprobs = state.tokens, state.logprobs
    for j in range(kp1):                                       # static, small
        at = jnp.arange(s_max)[None, :] == wpos[:, j][:, None]
        w = at & write[:, j][:, None]
        tokens = jnp.where(w, cand[:, j][:, None], tokens)
        logprobs = jnp.where(w, cand_lp[:, j][:, None], logprobs)
    return state._replace(tokens=tokens, logprobs=logprobs,
                          lengths=state.lengths + counts.astype(jnp.int32))


def release_slot(state: DecodeState, slot: jax.Array) -> DecodeState:
    """Evict a finished request: the slot becomes admissible again."""
    return state._replace(done=state.done.at[slot].set(True),
                          active=state.active.at[slot].set(False))


# ---------------------------------------------------------------------------
# mesh placement (docs/DESIGN.md §9)
# ---------------------------------------------------------------------------

def state_specs(state: DecodeState, mesh) -> DecodeState:
    """PartitionSpec tree for a DecodeState on ``mesh``.

    The family cache follows ``sharding.specs.cache_specs`` (KV heads or the
    GQA sequence-shard fallback over "model", slot/batch dim over the data
    axes); the per-slot host-visible bookkeeping buffers (tokens, logprobs,
    lengths, masks, PRNG key) are tiny and stay replicated so the scheduler
    can read any slot without a cross-device gather.
    """
    from jax.sharding import PartitionSpec as P
    from repro.sharding.specs import cache_specs
    rep = jax.tree.map(lambda _: P(), state._replace(cache=None))
    return rep._replace(cache=cache_specs(state.cache, mesh))


def shard_state(state: DecodeState, mesh) -> DecodeState:
    """device_put a DecodeState to its mesh layout (engine entry point)."""
    from repro.sharding.specs import to_shardings
    return jax.device_put(state, to_shardings(state_specs(state, mesh), mesh))


def constrain_state(state: DecodeState, mesh) -> DecodeState:
    """with_sharding_constraint pinning a traced DecodeState to the same
    layout ``shard_state`` commits — applied at the end of the jitted chunk
    / insert bodies so the decode loop's carry layout reaches a fixed point
    (one compile, no resharding between chunks)."""
    from repro.sharding.specs import to_shardings
    sh = to_shardings(state_specs(state, mesh), mesh)
    return jax.tree.map(jax.lax.with_sharding_constraint, state, sh)
