"""Deterministic fault injection for the serving stack (DESIGN.md §15).

Every recovery path in the fault-tolerance layer — replica failover with
request re-drive, artifact-read retry, graceful degradation under pool
pressure — is exercised in CI by *injecting* the faults it guards against.
Injection must therefore be deterministic: the same ``FaultConfig`` (rules
+ seed) produces the same fault schedule on every run, so a chaos test can
assert token-identical greedy output against a fault-free baseline.

Named injection sites (the code under test calls ``fire``/``deny`` with
these; an inactive injector makes both free no-ops):

* ``replica.dispatch`` / ``replica.harvest`` — raised inside a replica's
  dispatch/harvest tick, *before* any state mutation, so a transient
  fault can be retried in place and a permanent one quarantines the
  replica (``serving/replica.py``).
* ``pool.oom`` — consulted by the admission gate (``deny``): a hit makes
  the paged pool report backpressure as if out of pages, driving the
  graceful-degradation ladder without actually shrinking the pool.
* ``device.stall`` — a slow-device hang: ``mode="stall"`` sleeps
  ``stall_s`` inside the dispatch tick (watchdog fodder), ``mode="raise"``
  raises like a collective timeout.
* ``artifact.read`` — raised inside the checkpoint shard reader
  (transient I/O); ``artifact.corrupt`` (``deny`` site) flips one byte of
  a loaded payload so checksum verification is exercised end to end.

Faults are matched per (site, tag) occurrence count (1-based), where the
tag is typically a replica id — ``FaultRule(site="replica.dispatch",
tag=1, at=(3,))`` kills replica 1 at *its* third dispatch, regardless of
how the replicas interleave. Probabilistic rules draw exactly one RNG
sample per occurrence from a seeded generator, so a given seed yields one
schedule no matter which rules are attached.

This module deliberately imports nothing from the serving stack (stdlib +
numpy only) so that low-level modules — ``checkpoint/ckpt.py``, the pool —
can call into it without import cycles.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro import obs

SITES = (
    "replica.dispatch",
    "replica.harvest",
    "pool.oom",
    "device.stall",
    "artifact.read",
    "artifact.corrupt",
)


class InjectedFault(RuntimeError):
    """A fault raised by the chaos harness (permanent unless subclassed)."""

    def __init__(self, site: str, occurrence: int, tag: Optional[int] = None,
                 transient: bool = False):
        where = site if tag is None else f"{site}[{tag}]"
        kind = "transient" if transient else "permanent"
        super().__init__(
            f"injected {kind} fault at {where} (occurrence {occurrence})")
        self.site = site
        self.occurrence = occurrence
        self.tag = tag
        self.transient = transient


class TransientFault(InjectedFault):
    """A retriable injected fault (flaky I/O, collective timeout)."""

    def __init__(self, site, occurrence, tag=None):
        super().__init__(site, occurrence, tag, transient=True)


@dataclass(frozen=True)
class FaultRule:
    """One fault schedule entry.

    ``at`` lists 1-based occurrence indices of (site, tag) calls that
    fault; ``prob`` adds seeded random faults on the remaining calls.
    ``count`` bounds total firings (0 = unlimited). ``tag=None`` matches
    any tag. ``mode="stall"`` sleeps ``stall_s`` instead of raising.
    """

    site: str
    at: tuple = ()
    prob: float = 0.0
    count: int = 1
    transient: bool = False
    tag: Optional[int] = None
    mode: str = "raise"          # "raise" | "stall"
    stall_s: float = 0.0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"known: {SITES}")
        if self.mode not in ("raise", "stall"):
            raise ValueError(f"unknown fault mode {self.mode!r}")


@dataclass(frozen=True)
class FaultConfig:
    """A seeded set of fault rules — one deterministic chaos schedule."""

    rules: tuple = ()
    seed: int = 0

    # CLI shorthand -> rules. Occurrence indices are tuned so smoke-scale
    # serves (a handful of requests, chunk 4) hit every recovery path.
    _SHORTHAND = {
        # kill replica 1 at its 3rd dispatch: mid-stream, decode underway
        "replica_fault": dict(site="replica.dispatch", tag=1, at=(3,)),
        # two retriable dispatch hiccups on replica 0
        "replica_transient": dict(site="replica.dispatch", tag=0, at=(2, 4),
                                  count=2, transient=True),
        # admission gate reports pool exhaustion on each replica's first
        # attempt: an idle engine cannot free pages, so the degradation
        # policy spills exactly one ewq tier (int8) and admits there;
        # real pool capacity governs afterwards
        "oom": dict(site="pool.oom", at=(1,), count=0),
        # one slow-device stall inside a dispatch tick
        "stall": dict(site="device.stall", at=(2,), mode="stall",
                      stall_s=0.05),
        # one transient artifact-read failure (retry path)
        "artifact": dict(site="artifact.read", at=(1,), transient=True),
    }

    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "FaultConfig":
        """Build a config from a comma-separated CLI spec.

        Each item is a shorthand name (``replica_fault``, ``oom``, ...)
        or ``site@occ[,occ...]`` with ``:`` separating items' options —
        kept simple on purpose; tests construct ``FaultRule`` directly.
        """
        rules = []
        for item in spec.split(","):
            item = item.strip()
            if not item:
                continue
            if item not in cls._SHORTHAND:
                raise ValueError(
                    f"unknown chaos shorthand {item!r}; known: "
                    f"{sorted(cls._SHORTHAND)}")
            rules.append(FaultRule(**cls._SHORTHAND[item]))
        return cls(rules=tuple(rules), seed=seed)


@dataclass
class ChaosInjector:
    """Deterministic occurrence-counting fault injector.

    Each ``fire``/``deny`` call advances the per-(site, tag) occurrence
    counter by exactly one and draws exactly one RNG sample per rule with
    ``prob > 0`` — determinism is independent of which rules matched.
    """

    config: FaultConfig
    _counts: dict = field(default_factory=dict)
    _fired: dict = field(default_factory=dict)
    log: list = field(default_factory=list)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.config.seed)

    def _occurrence(self, site: str, tag) -> int:
        key = (site, tag)
        self._counts[key] = self._counts.get(key, 0) + 1
        return self._counts[key]

    def poke(self, site: str, tag=None) -> Optional[FaultRule]:
        """Advance (site, tag) and return the matching rule, if any."""
        occ = self._occurrence(site, tag)
        hit = None
        for i, rule in enumerate(self.config.rules):
            if rule.site != site:
                continue
            if rule.tag is not None and rule.tag != tag:
                continue
            if rule.count and self._fired.get(i, 0) >= rule.count:
                continue
            fires = occ in rule.at
            if rule.prob > 0.0:
                # always one draw per matching call -> stable schedule
                fires = bool(self._rng.random() < rule.prob) or fires
            if fires and hit is None:
                self._fired[i] = self._fired.get(i, 0) + 1
                hit = rule
        if hit is not None:
            self.log.append((site, tag, occ))
            obs.instant("chaos/fire", tag if isinstance(tag, int) else 0,
                        args={"site": site, "occurrence": occ})
            obs.count("serve_chaos_faults_total", 1,
                      "chaos-injected faults fired, by site",
                      site=site, replica=str(tag))
        return hit

    def fire(self, site: str, tag=None) -> None:
        """Raise (or stall) if a rule matches this occurrence."""
        rule = self.poke(site, tag)
        if rule is None:
            return
        occ = self._counts[(site, tag)]
        if rule.mode == "stall":
            time.sleep(rule.stall_s)
            return
        if rule.transient:
            raise TransientFault(site, occ, tag)
        raise InjectedFault(site, occ, tag)

    def deny(self, site: str, tag=None) -> bool:
        """Non-raising site: True when a rule matches this occurrence."""
        return self.poke(site, tag) is not None


# ---------------------------------------------------------------------------
# Module-level active injector: production call sites stay one free branch.

_ACTIVE: Optional[ChaosInjector] = None


def install(injector: Optional[ChaosInjector]) -> Optional[ChaosInjector]:
    """Install (or clear, with None) the process-wide injector."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, injector
    return prev


def active() -> Optional[ChaosInjector]:
    return _ACTIVE


def fire(site: str, tag=None) -> None:
    if _ACTIVE is not None:
        _ACTIVE.fire(site, tag)


def deny(site: str, tag=None) -> bool:
    return _ACTIVE is not None and _ACTIVE.deny(site, tag)


@contextmanager
def chaos(config: FaultConfig):
    """Scoped injector installation (tests)."""
    injector = ChaosInjector(config)
    prev = install(injector)
    try:
        yield injector
    finally:
        install(prev)
