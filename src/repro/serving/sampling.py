"""Masked sampling for the chunked decode loop and the spec-decode
rejection resampler.

All controls are TRACED values, so changing sampling parameters never
retriggers XLA compilation (the engine compiles one chunk fn per
(chunk, num_slots) — temperature/top-k/top-p ride in ``DecodeState`` as
per-slot vectors):

* ``temperature`` — 0 means greedy (argmax);
* ``top_k``       — keep the k highest-probability tokens (0 disables);
* ``top_p``       — nucleus sampling: keep the smallest prefix of the
  probability-sorted vocab whose cumulative mass reaches p (>= 1.0
  disables; the top-1 token is always kept).

``masked_dist`` is the single source of truth for "the distribution a
request actually samples from": the spec-decode draft proposes from it and
the verifier's acceptance test + residual resampling use it for the target
(speculative sampling is only exact when p and q are the post-masking,
post-temperature distributions — docs/DESIGN.md §11).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30
_MIN_TEMP = 1e-6


def masked_dist(lp: jax.Array, temperature: jax.Array, top_k: jax.Array,
                top_p: jax.Array) -> jax.Array:
    """Masked + temperature-scaled log-distribution.

    ``lp``: (..., V) normalized log-probs; each control is broadcastable
    against ``lp[..., 0]`` (per-slot (B,) vectors for a (B, V) step;
    ``temp[:, None]`` etc. for a (B, K+1, V) verify window). Returns the
    normalized log-probs of the ACTUAL sampling distribution; greedy
    entries (temperature == 0) keep their unscaled masked dist (the argmax
    is mask/temperature-invariant)."""
    v = lp.shape[-1]
    shape = jnp.broadcast_shapes(lp.shape[:-1], jnp.shape(temperature),
                                 jnp.shape(top_k), jnp.shape(top_p))

    def ctl(x):
        return jnp.broadcast_to(x, shape)[..., None]        # (..., 1)

    lp = jnp.broadcast_to(lp, shape + (v,))
    temp, tk, tp = ctl(temperature), ctl(top_k), ctl(top_p)

    def apply_masks(lp_in):
        sorted_lp = jnp.sort(lp_in, axis=-1)[..., ::-1]     # descending
        # top-k: threshold at the k-th largest log-prob
        kth = jnp.take_along_axis(sorted_lp, jnp.clip(tk - 1, 0, v - 1),
                                  axis=-1)
        keep = (tk <= 0) | (lp_in >= kth)
        # top-p: keep the smallest prefix of sorted probs with mass >= p
        # (exclusive cumsum < p always keeps the top token)
        sp = jnp.exp(sorted_lp)
        cum = jnp.cumsum(sp, axis=-1) - sp
        n_keep = jnp.sum(cum < tp, axis=-1, keepdims=True)
        pth = jnp.take_along_axis(sorted_lp, jnp.clip(n_keep - 1, 0, v - 1),
                                  axis=-1)
        keep &= (tp >= 1.0) | (lp_in >= pth)
        return jnp.where(keep, lp_in, NEG_INF)

    # the O(V log V) sort/cumsum only runs when some entry actually masks —
    # a pure-greedy/plain-temperature stream pays one `any` per step, not a
    # full-vocab sort (controls are traced, so this is a runtime branch)
    need = jnp.any(top_k > 0) | jnp.any(jnp.asarray(top_p) < 1.0)
    masked = jax.lax.cond(need, apply_masks, lambda x: x, lp)
    scaled = jnp.where(temp > 0, masked / jnp.maximum(temp, _MIN_TEMP),
                       masked)
    return jax.nn.log_softmax(scaled, axis=-1)


def sample(key: jax.Array, dist: jax.Array, temperature: jax.Array
           ) -> jax.Array:
    """Draw one token per entry from a ``masked_dist`` output (..., V);
    greedy entries take the argmax. Returns (...,) int32."""
    stoch = jax.random.categorical(key, dist, axis=-1)
    return jnp.where(temperature > 0, stoch,
                     jnp.argmax(dist, axis=-1)).astype(jnp.int32)
