"""Continuous-batching serving engine with EWQ/FastEWQ-quantized weights.

Deployment story (the paper's §3.4/§4 pipeline, end-to-end):
  1. at startup, pick a QuantPlan — full EWQ (weights analyzed), FastEWQ
     (O(1), metadata only), or resource-fitted via cluster.fit_plan_to_hbm;
  2. quantize params per plan (block-granular mixed precision);
  3. serve: prefill fills the KV/SSM cache, decode runs against quantized
     weights (decode is weight-bytes-bound — exactly where int8/int4
     payloads pay off; see README.md §Serving and
     benchmarks/serve_throughput.py).

Engine structure:
  * the decode loop is ONE jitted ``lax.scan`` over a chunk of token steps
    (``_make_chunk_fn``): masked sampling, per-slot stop conditions (EOS /
    max-new-tokens), per-slot cache positions. No per-token Python
    dispatch; one compile per (chunk, num_slots, temperature).
  * ``serve`` runs continuous batching: between chunks the host-side
    Scheduler admits queued requests into freed slots (each admission is a
    batch=1 prefill + jitted slot insert) and harvests finished ones.
  * ``generate`` is a thin compatibility wrapper — a single fixed batch is
    one scheduler-free drain of the same chunked loop.

Prefill paths: transformer families use the fused apply(return_cache=True)
pass (works for segmented/quantized stacks too); SSM/hybrid prefill by
scanning decode steps over the prompt (their decode matches teacher-forced
forward exactly — tests/test_models_parity); enc-dec prefill additionally
encodes the request's frames and precomputes per-decoder-layer cross K/V
first (zero frames when a request carries none). The jitted prefill is
built once per engine and cached across calls.

Quantized weights come either from an in-memory plan (compiled at engine
construction via quant/compiler.py) or from a persisted artifact
(``ServeEngine.from_artifact`` — cold start with no raw weights and no
entropy analysis; docs/DESIGN.md §8).

Mesh-parallel serving (docs/DESIGN.md §9): pass ``mesh=`` and the engine
places the (quantized) weights with the TP-only serving specs
(``param_specs(serving=True)`` — QTensor payload/scale leaves included),
places the slotted decode caches with ``cache_specs`` (KV-head sharding or
the GQA sequence-shard fallback), and traces every jitted path (fused
prefill, chunked decode scan, slot insert/evict) under
``activation_sharding(mesh)`` so the model-code constraints resolve. A
mesh-less engine is byte-for-byte the old single-device path.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import QuantPlan
from repro.models.model import Model
from repro.serving import batch as B
from repro.serving.quantized import apply_plan_to_params
from repro.serving.scheduler import Request, RequestOutput, Scheduler

DEFAULT_CHUNK = 8


@dataclasses.dataclass
class GenerateResult:
    tokens: jax.Array          # (B, prompt+new)
    logprobs: jax.Array        # (B, new) chosen-token logprobs
    steps: int


@dataclasses.dataclass
class ServeStats:
    """Continuous-batching run statistics (benchmarks/serve_throughput.py)."""
    decode_steps: int          # jitted decode steps executed (chunks * chunk)
    generated_tokens: int      # tokens actually emitted across all requests
    occupancy: float           # mean fraction of active slots per chunk
    num_chunks: int
    admissions: int            # continuous-batching refills: requests
                               # admitted while others were mid-decode


class ServeEngine:
    def __init__(self, model: Model, params, *, max_seq: int,
                 plan: Optional[QuantPlan] = None, group: int = 128,
                 eos_id: Optional[int] = None, pad_id: int = 0,
                 mesh=None, kv_precision="bf16",
                 kv_group: Optional[int] = None):
        self.model = model
        self.cfg = model.cfg
        self.max_seq = max_seq
        self.plan = plan
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.mesh = mesh
        if plan is not None:
            params = apply_plan_to_params(model, params, plan, group)
        if mesh is not None:
            from repro.sharding.specs import serving_param_shardings
            # TP-only placement (a no-op resharding when the params already
            # arrived sharded, e.g. from_artifact(mesh=...)).
            params = jax.device_put(params,
                                    serving_param_shardings(params, mesh))
        self.params = params
        self.kv_plan = self._resolve_kv_plan(kv_precision, kv_group)
        self._decode = self._traced(jax.jit(model.decode_step))
        # built once, cached (enc-dec prefill also takes encoder frames)
        self._prefill = self._traced(jax.jit(self._prefill_encdec
                                             if self.cfg.family == "encdec"
                                             else self._prefill_impl))
        self._insert = self._traced(jax.jit(self._insert_impl))
        self._release = self._traced(jax.jit(self._release_impl))
        self._kv_wrap = self._traced(jax.jit(self._wrap_cache))
        self._chunk_fns: dict = {}

    # -- quantized KV cache (docs/DESIGN.md §10) -----------------------------
    def _resolve_kv_plan(self, kv_precision, kv_group):
        from repro.quant.kvcache import DEFAULT_KV_GROUP, KVPlan
        if isinstance(kv_precision, KVPlan):
            if kv_group is not None and kv_group != kv_precision.group:
                raise ValueError(
                    f"kv_group={kv_group} conflicts with the provided "
                    f"KVPlan's group={kv_precision.group}; the plan's "
                    f"group is part of the (possibly artifact-stamped) "
                    f"policy — rebuild the plan to change it")
            return kv_precision
        from repro.quant.compiler import compile_kv_plan
        return compile_kv_plan(self.cfg, self.plan, kv_precision,
                               kv_group or DEFAULT_KV_GROUP)

    def _kv_cuts(self) -> tuple:
        """Page boundaries = the weight stack's segment boundaries, so each
        cache page aligns 1:1 with a model scan segment."""
        key = {"dense": "layers", "moe": "layers",
               "encdec": "dec_layers"}.get(self.cfg.family)
        if key is None:
            return ()
        from repro.quant.apply import segment_slices
        return tuple(lo for _, lo, _ in
                     segment_slices(self.params[key])[1:])

    def _wrap_cache(self, cache):
        """Raw (bf16) family cache -> quantized-page layout per the KV
        plan; identity when serving with a bf16 cache. Traceable — the
        engine jits it once per cache shape (``self._kv_wrap``)."""
        if self.kv_plan is None:
            return cache
        from repro.quant.kvcache import quantize_model_cache
        return quantize_model_cache(cache, self.kv_plan, self._kv_cuts(),
                                    self.model.kv_cache_fields)

    # -- mesh plumbing -------------------------------------------------------
    def _ctx(self):
        """Mesh + activation-sharding context every jitted path traces (and
        runs) under; a null context without a mesh."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.sharding.ctx import activation_sharding
        stack = contextlib.ExitStack()
        stack.enter_context(self.mesh)
        stack.enter_context(activation_sharding(self.mesh))
        return stack

    def _traced(self, fn):
        """Wrap a jitted callable so tracing happens inside ``_ctx()``."""
        if self.mesh is None:
            return fn

        def wrapped(*args, **kw):
            with self._ctx():
                return fn(*args, **kw)

        return wrapped

    def _shard_state(self, state: B.DecodeState) -> B.DecodeState:
        return B.shard_state(state, self.mesh) if self.mesh is not None \
            else state

    @classmethod
    def from_artifact(cls, model: Model, directory: str, *, max_seq: int,
                      mesh=None, **kw) -> "ServeEngine":
        """Boot from a persisted compiled-plan artifact: quantized weights
        are restored directly — no raw weight loading, no entropy analysis,
        no re-quantization (quant/compiler.py). With ``mesh``, every leaf is
        device_put to its serving NamedSharding straight from the checkpoint
        file — a cold boot lands sharded without ever materializing a
        replicated copy."""
        from repro.quant.compiler import compile_kv_plan, load_artifact
        from repro.quant.kvcache import DEFAULT_KV_GROUP
        compiled = load_artifact(directory, model, mesh=mesh)
        if compiled.kv_plan is not None:
            # serve with the KV-cache policy stamped at compile time unless
            # the caller explicitly overrides it
            kw.setdefault("kv_precision", compiled.kv_plan)
        if kw.get("kv_precision") == "auto":
            # entropy-weighted selection needs the weight plan, which the
            # engine ctor doesn't see on this path (params arrive compiled)
            kw["kv_precision"] = compile_kv_plan(
                model.cfg, compiled.plan, "auto",
                kw.pop("kv_group", None) or DEFAULT_KV_GROUP)
        engine = cls(model, compiled.params, max_seq=max_seq, plan=None,
                     mesh=mesh, **kw)
        engine.plan = compiled.plan
        return engine

    # -- prefill -------------------------------------------------------------
    def _prefill_scan(self, prompts: jax.Array, cache):
        """Universal prefill: scan decode steps over prompt tokens."""

        def body(cache, tok):
            logits, cache = self.model.decode_step(self.params, cache,
                                                   tok[:, None])
            return cache, logits[:, 0]

        cache, logits = jax.lax.scan(body, cache, prompts.T)
        return cache, logits[-1]  # logits after last prompt token

    def _prefill_fused(self, prompts: jax.Array):
        """Transformer prefill: one fused forward emitting the KV cache."""
        from repro.models import transformer
        b, s = prompts.shape
        logits, _, cache = transformer.apply(
            self.params, prompts, self.cfg, remat=False, return_cache=True,
            last_only=True)
        pad = self.max_seq - s
        k = jnp.pad(cache.k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(cache.v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return cache._replace(k=k, v=v), logits[:, 0]

    def _prefill_impl(self, prompts: jax.Array):
        if self.cfg.family in ("dense", "moe"):
            return self._prefill_fused(prompts)
        return self._prefill_scan(prompts,
                                  self.model.init_cache(prompts.shape[0],
                                                        self.max_seq))

    def _prefill_encdec(self, prompts: jax.Array, frames: jax.Array):
        """Enc-dec prefill: encode frames, precompute per-decoder-layer
        cross K/V, then scan decode steps over the prompt."""
        from repro.models import encdec
        cache = self.model.init_cache(prompts.shape[0], self.max_seq)
        enc_out = encdec.encode(self.params, frames, self.cfg, remat=False)
        ck, cv = encdec.precompute_cross_kv(self.params, enc_out, self.cfg)
        cache = cache._replace(cross_k=ck, cross_v=cv)
        return self._prefill_scan(prompts, cache)

    def _default_frames(self, batch: int) -> jax.Array:
        from repro.models.common import dtype_of
        return jnp.zeros((batch, self.cfg.encoder_seq, self.cfg.d_model),
                         dtype_of(self.cfg))

    def prefill(self, prompts: jax.Array, frames=None):
        assert prompts.shape[1] <= self.max_seq
        if self.cfg.family == "encdec":
            if frames is None:
                frames = self._default_frames(prompts.shape[0])
            assert frames.shape[1] == self.cfg.encoder_seq
            return self._prefill(prompts, frames)
        assert frames is None, "frames only apply to enc-dec models"
        return self._prefill(prompts)

    # -- fused chunked decode loop -------------------------------------------
    def _make_chunk_fn(self, steps: int, temperature: float):
        """One jitted scan over ``steps`` token positions.

        Per step: masked sampling from each slot's last logits (done or
        empty slots emit pad and do not advance), scatter the chosen token
        and its logprob at ``lengths[slot]``, update per-slot stop
        conditions, then one batched decode_step for the next logits.
        """
        vocab = self.cfg.vocab_size
        eos_id, pad_id = self.eos_id, self.pad_id
        model = self.model

        def step(params, st, _):
            lp = jax.nn.log_softmax(
                st.last_logits[:, :vocab].astype(jnp.float32), -1)
            key, sub = jax.random.split(st.key)
            if temperature > 0:
                nxt = jax.random.categorical(sub, lp / temperature, axis=-1)
            else:
                nxt = jnp.argmax(lp, axis=-1)
            chosen_lp = jnp.take_along_axis(lp, nxt[:, None], 1)[:, 0]
            advance = st.active & ~st.done
            nxt = jnp.where(advance, nxt, pad_id).astype(jnp.int32)
            at = jnp.arange(st.tokens.shape[1])[None, :] == st.lengths[:, None]
            write = at & advance[:, None]
            tokens = jnp.where(write, nxt[:, None], st.tokens)
            logprobs = jnp.where(write, chosen_lp[:, None], st.logprobs)
            lengths = st.lengths + advance.astype(jnp.int32)
            done = st.done | (advance & (lengths >= st.max_len))
            if eos_id is not None:
                done = done | (advance & (nxt == eos_id))
            logits, cache = model.decode_step(params, st.cache, nxt[:, None])
            return B.DecodeState(
                cache=cache, last_logits=logits[:, 0].astype(jnp.float32),
                tokens=tokens, lengths=lengths, max_len=st.max_len,
                done=done, active=st.active, logprobs=logprobs, key=key), None

        mesh = self.mesh

        def run(params, state):
            state, _ = jax.lax.scan(
                lambda st, x: step(params, st, x), state, None, length=steps)
            if mesh is not None:
                # pin the carry layout so chunk N+1 reuses chunk N's compile
                state = B.constrain_state(state, mesh)
            return state

        return self._traced(jax.jit(run))

    def _chunk_fn(self, steps: int, temperature: float):
        key = (steps, float(temperature))
        if key not in self._chunk_fns:
            self._chunk_fns[key] = self._make_chunk_fn(steps, temperature)
        return self._chunk_fns[key]

    def _insert_impl(self, state, slot, prompt, prompt_cache, last_logits,
                     max_new):
        state = B.insert_request(self.model, state, slot, prompt,
                                 prompt_cache, last_logits, max_new)
        if self.mesh is not None:
            state = B.constrain_state(state, self.mesh)
        return state

    def _release_impl(self, state, slot):
        state = B.release_slot(state, slot)
        if self.mesh is not None:
            state = B.constrain_state(state, self.mesh)
        return state

    # -- generation (compat wrapper: single batch == one drain) ---------------
    def generate(self, prompts: jax.Array, max_new_tokens: int,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None,
                 chunk: Optional[int] = None,
                 frames: Optional[jax.Array] = None) -> GenerateResult:
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{max_new_tokens}")
        if chunk is not None and chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        b, p = prompts.shape
        total = p + max_new_tokens
        assert total <= self.max_seq, (total, self.max_seq)
        cache, last_logits = self.prefill(prompts, frames)
        cache = cache._replace(pos=jnp.full((b,), p, jnp.int32))
        # quantize-on-insert: prefill ran bf16; the decode carry is pages
        cache = self._kv_wrap(cache)
        tokens = jnp.zeros((b, self.max_seq), jnp.int32)
        tokens = jax.lax.dynamic_update_slice(
            tokens, prompts.astype(jnp.int32), (0, 0))
        state = B.DecodeState(
            cache=cache, last_logits=last_logits.astype(jnp.float32),
            tokens=tokens,
            lengths=jnp.full((b,), p, jnp.int32),
            max_len=jnp.full((b,), total, jnp.int32),
            done=jnp.zeros((b,), bool),
            active=jnp.ones((b,), bool),
            logprobs=jnp.zeros((b, self.max_seq), jnp.float32),
            key=key if key is not None else jax.random.PRNGKey(0))
        state = self._shard_state(state)
        chunk = max_new_tokens if chunk is None else min(chunk, max_new_tokens)
        fn = self._chunk_fn(chunk, temperature)
        steps = 0
        while True:
            state = fn(self.params, state)
            steps += chunk
            if steps >= max_new_tokens or bool(state.done.all()):
                break
        return GenerateResult(tokens=state.tokens[:, :total],
                              logprobs=state.logprobs[:, p:total],
                              steps=steps)

    def generate_stepwise(self, prompts: jax.Array, max_new_tokens: int,
                          temperature: float = 0.0,
                          key: Optional[jax.Array] = None,
                          frames: Optional[jax.Array] = None
                          ) -> GenerateResult:
        """Legacy per-token Python dispatch loop.

        Kept as the benchmark baseline (benchmarks/serve_throughput.py):
        identical math to ``generate``, but every token pays Python-side
        sampling-op dispatch plus a separate jitted decode dispatch.
        """
        b = prompts.shape[0]
        cache, last_logits = self.prefill(prompts, frames)
        toks = [prompts]
        logprobs = []
        logits = last_logits
        key = key if key is not None else jax.random.PRNGKey(0)
        for _ in range(max_new_tokens):
            lp = jax.nn.log_softmax(
                logits[:, :self.cfg.vocab_size].astype(jnp.float32), -1)
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, lp / temperature, axis=-1)
            else:
                nxt = jnp.argmax(lp, axis=-1)
            logprobs.append(jnp.take_along_axis(lp, nxt[:, None], 1)[:, 0])
            nxt = nxt[:, None].astype(jnp.int32)
            toks.append(nxt)
            step_logits, cache = self._decode(self.params, cache, nxt)
            logits = step_logits[:, 0]
        return GenerateResult(tokens=jnp.concatenate(toks, axis=1),
                              logprobs=jnp.stack(logprobs, axis=1),
                              steps=max_new_tokens)

    # -- continuous batching ---------------------------------------------------
    def serve(self, requests: Sequence[Request], *, num_slots: int = 8,
              chunk: int = DEFAULT_CHUNK, temperature: float = 0.0,
              key: Optional[jax.Array] = None
              ) -> tuple[list[RequestOutput], ServeStats]:
        """Drain a request stream with continuous batching.

        Between decode chunks, finished slots are harvested and queued
        requests (arrival_step <= clock) are admitted into freed slots.
        Returns outputs ordered by request id plus occupancy statistics.
        """
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        sched = Scheduler(num_slots)
        for r in requests:
            assert len(r.prompt) + r.max_new_tokens <= self.max_seq, r.rid
            sched.submit(r)
        state = B.init_state(
            self.model, num_slots, self.max_seq,
            key if key is not None else jax.random.PRNGKey(0))
        state = self._shard_state(state._replace(
            cache=self._kv_wrap(state.cache)))
        fn = self._chunk_fn(chunk, temperature)
        clock = 0
        occupancy: list[float] = []
        admissions = 0
        generated = 0
        while not sched.all_done():
            for slot in sched.free_slots():
                req = sched.next_ready(clock)
                if req is None:
                    break
                prompt = jnp.asarray(req.prompt, jnp.int32)
                frames = (jnp.asarray(req.frames)[None]
                          if req.frames is not None else None)
                cache1, logits1 = self.prefill(prompt[None], frames)
                state = self._insert(state, jnp.int32(slot), prompt, cache1,
                                     logits1, jnp.int32(req.max_new_tokens))
                # a refill = joining a batch that is already mid-decode
                if occupancy and sched.num_active > 0:
                    admissions += 1
                sched.assign(slot, req, clock)
            if sched.num_active == 0:
                nxt = sched.next_arrival()
                if nxt is None:
                    break
                clock = max(clock + 1, nxt)   # idle: fast-forward the clock
                continue
            occupancy.append(sched.num_active / num_slots)
            state = fn(self.params, state)
            clock += chunk
            done_np, len_np = jax.device_get((state.done, state.lengths))
            for slot, req in sched.active_slots():
                if not done_np[slot]:
                    continue
                n = int(len_np[slot])
                row = np.asarray(jax.device_get(state.tokens[slot, :n]))
                lps = np.asarray(jax.device_get(
                    state.logprobs[slot, len(req.prompt):n]))
                reason = ("eos" if self.eos_id is not None and n > 0
                          and row[-1] == self.eos_id else "length")
                sched.complete(slot, row, lps, reason, clock)
                state = self._release(state, jnp.int32(slot))
                generated += n - len(req.prompt)
        outputs = sorted(sched.finished, key=lambda o: o.rid)
        stats = ServeStats(
            decode_steps=len(occupancy) * chunk,
            generated_tokens=generated,
            occupancy=float(np.mean(occupancy)) if occupancy else 0.0,
            num_chunks=len(occupancy), admissions=admissions)
        return outputs, stats

    # -- diagnostics -----------------------------------------------------------
    def kv_bytes_per_slot(self) -> float:
        """Physical attention-cache bytes one decode slot holds at
        ``max_seq`` (K/V payloads + per-group scales; enc-dec includes the
        cross-attention cache; 0.0 for attention-free families).

        This is the per-request HBM cost that scales with
        ``num_slots x max_seq`` — the number the KV-cache quantization
        shrinks (docs/DESIGN.md §10)."""
        from repro.quant.kvcache import kv_field_nbytes
        cache = jax.eval_shape(
            lambda: self._wrap_cache(self.model.slotted_cache(1,
                                                              self.max_seq)))
        return float(sum(kv_field_nbytes(getattr(cache, name))
                         for name in self.model.kv_cache_fields))

    def weight_bytes(self) -> float:
        from repro.quant.apply import tree_nbytes
        from repro.quant.apply import SegmentedParams
        total = 0.0
        for v in jax.tree.leaves(
                self.params,
                is_leaf=lambda x: isinstance(x, SegmentedParams)):
            if isinstance(v, SegmentedParams):
                total += v.nbytes_effective()
            else:
                total += tree_nbytes(v)
        return total

    def weight_bytes_per_device(self) -> float:
        """Max physical weight bytes resident on any single device.

        Counts each leaf's addressable shards per device (a replicated leaf
        contributes its full size to every device; a TP-sharded one only its
        slice), so on a 1xN TP mesh this is what actually bounds HBM —
        the deployment-memory number the mesh benchmark rows report."""
        per_device: dict = {}
        for leaf in jax.tree.leaves(self.params):
            if isinstance(leaf, jax.Array):
                for s in leaf.addressable_shards:
                    dev = s.device.id
                    per_device[dev] = per_device.get(dev, 0.0) + s.data.nbytes
            else:
                arr = np.asarray(leaf)
                per_device[-1] = per_device.get(-1, 0.0) + arr.nbytes
        return max(per_device.values()) if per_device else 0.0
