"""Continuous-batching serving engine with EWQ/FastEWQ-quantized weights.

Deployment story (the paper's §3.4/§4 pipeline, end-to-end):
  1. at startup, pick a QuantPlan — full EWQ (weights analyzed), FastEWQ
     (O(1), metadata only), or resource-fitted via cluster.fit_plan_to_hbm;
  2. quantize params per plan (block-granular mixed precision);
  3. serve: prefill fills the KV/SSM cache, decode runs against quantized
     weights (decode is weight-bytes-bound — exactly where int8/int4
     payloads pay off; see README.md §Serving and
     benchmarks/serve_throughput.py).

Engine structure:
  * the decode loop is ONE jitted ``lax.scan`` over a chunk of token steps
    (``_make_chunk_fn``): masked sampling, per-slot stop conditions (EOS /
    max-new-tokens), per-slot cache positions. No per-token Python
    dispatch; one compile per (chunk, num_slots) — sampling controls
    (temperature / top-k / top-p) are traced per-slot state, never
    compile keys.
  * ``serve`` runs continuous batching: between chunks the host-side
    Scheduler admits queued requests into freed slots (each admission is a
    batch=1 prefill + jitted slot insert) and harvests finished ones.
  * ``generate`` is a thin compatibility wrapper — a single fixed batch is
    one scheduler-free drain of the same chunked loop.

Prefill paths: transformer families use the fused apply(return_cache=True)
pass (works for segmented/quantized stacks too); SSM/hybrid prefill by
scanning decode steps over the prompt (their decode matches teacher-forced
forward exactly — tests/test_models_parity); enc-dec prefill additionally
encodes the request's frames and precomputes per-decoder-layer cross K/V
first (zero frames when a request carries none). The jitted prefill is
built once per engine and cached across calls.

Quantized weights come either from an in-memory plan (compiled at engine
construction via quant/compiler.py) or from a persisted artifact
(``ServeEngine.from_artifact`` — cold start with no raw weights and no
entropy analysis; docs/DESIGN.md §8).

Mesh-parallel serving (docs/DESIGN.md §9): pass ``mesh=`` and the engine
places the (quantized) weights with the TP-only serving specs
(``param_specs(serving=True)`` — QTensor payload/scale leaves included),
places the slotted decode caches with ``cache_specs`` (KV-head sharding or
the GQA sequence-shard fallback), and traces every jitted path (fused
prefill, chunked decode scan, slot insert/evict) under
``activation_sharding(mesh)`` so the model-code constraints resolve. A
mesh-less engine is byte-for-byte the old single-device path.

Self-speculative decoding (docs/DESIGN.md §11): pass
``spec=SpecConfig(k=...)`` and decode runs draft-propose / target-verify
rounds instead of single-token steps — the entropy-ordered all-int4 draft
(compile_draft_plan; payloads shared with the target for blocks the plan
already quantized aggressively) proposes k tokens, the target scores the
whole window in one fused multi-query pass, and the per-slot cache
position rolls back to the accepted prefix inside the jitted scan. Greedy
spec serving is token-identical to the non-spec engine.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.policy import QuantPlan
from repro.models.model import Model
from repro.serving import batch as B
from repro.serving import sampling as S
from repro.serving.pool import (OutOfPages, PagedConfig, PoolSession,
                                PrefixMatch)
from repro.serving.quantized import apply_plan_to_params
from repro.serving.scheduler import (Request, RequestOutput, Scheduler,
                                     SLOConfig)
from repro.serving.spec import SpecConfig

DEFAULT_CHUNK = 8


@dataclasses.dataclass
class GenerateResult:
    tokens: jax.Array          # (B, prompt+new)
    logprobs: jax.Array        # (B, new) chosen-token logprobs
    steps: int


@dataclasses.dataclass
class Prefill:
    """One request's prefill result — everything ``insert`` needs to admit
    it into a decode slot (the disaggregated prefill/insert/generate API,
    docs/DESIGN.md §13)."""
    prompt: np.ndarray           # (P,) int32 host tokens
    cache: object                # batch=1 prefilled family cache (raw bf16)
    last_logits: jax.Array       # (1, V_pad) logits after the last token
    match: Optional[PrefixMatch] = None  # pinned prefix-cache match (paged)


@dataclasses.dataclass
class ChunkedPrefill:
    """An in-flight chunked prefill (docs/DESIGN.md §14): the request holds
    a reserved slot while its prompt enters the batch=1 prefill cache one
    ``prefill_chunk``-token slice per serve tick, interleaved between
    decode chunks so a long prompt never monopolizes the device. Becomes a
    plain ``Prefill`` (and is inserted) once ``pos`` covers the prompt."""
    prompt: np.ndarray           # (P,) int32 host tokens
    cache: object                # batch=1 family cache, filled to ``pos``
    last_logits: Optional[jax.Array]  # (1, V_pad) after the last chunk
    pos: int                     # prompt tokens already in the cache
    match: Optional[PrefixMatch] = None  # pinned prefix-cache match (paged)

    @property
    def done(self) -> bool:
        return self.pos >= len(self.prompt)

    def as_prefill(self) -> "Prefill":
        assert self.done and self.last_logits is not None
        return Prefill(prompt=self.prompt, cache=self.cache,
                       last_logits=self.last_logits, match=self.match)


@dataclasses.dataclass
class ServeStats:
    """Continuous-batching run statistics (benchmarks/serve_throughput.py)."""
    decode_steps: int          # jitted decode steps executed (chunks * chunk)
    generated_tokens: int      # tokens actually emitted across all requests
    occupancy: float           # mean fraction of active slots per chunk
    num_chunks: int
    admissions: int            # continuous-batching refills: requests
                               # admitted while others were mid-decode
    # request latency (wall-clock; chunk-granular attribution)
    ttft_p50_s: float = 0.0    # time to first token, admission -> first chunk
    ttft_p95_s: float = 0.0    #   that contains a generated token
    tpot_p50_s: float = 0.0    # per-output-token latency after the first
    tpot_p95_s: float = 0.0
    # open-loop queueing + SLO scheduling (docs/DESIGN.md §14)
    queue_delay_p50_s: float = 0.0  # ready -> dequeue wait, SEPARATE from
    queue_delay_p95_s: float = 0.0  #   ttft (which starts at dequeue)
    preemptions: int = 0       # restart-style evictions for higher priority
    timeouts: int = 0          # requests dropped by queue timeout
    cancelled: int = 0         # requests cancelled (queued or running)
    prefill_chunks: int = 0    # chunked-prefill advances interleaved
    # per-decode-chunk wall-clock gaps while slots were running: monolithic
    # prefill of a long prompt shows up as a multi-x spike in gap_max
    decode_gap_p50_s: float = 0.0
    decode_gap_p95_s: float = 0.0
    decode_gap_max_s: float = 0.0
    # speculative decoding (spec=SpecConfig(...) engines only)
    spec_rounds: int = 0       # draft-propose/verify rounds executed
    draft_proposed: int = 0    # draft tokens proposed to live slots
    draft_accepted: int = 0    # draft tokens verified AND committed
    acceptance_rate: float = 0.0   # accepted / proposed (realized uplift)
    tokens_per_round: float = 0.0  # committed tokens per live round
    # paged KV pool (paged=... engines only; docs/DESIGN.md §13)
    pool_pages_total: int = 0      # allocatable physical pages in the pool
    pool_pages_peak: int = 0       # high-water mark of pages in use
    pool_page_size: int = 0        # tokens per page
    prefix_hits: int = 0           # admissions that reused shared pages
    prefix_hit_tokens: int = 0     # prompt tokens served from shared pages
    prefix_hit_rate: float = 0.0   # hit tokens / total prompt tokens
    cow_copies: int = 0            # COW boundary pages materialized
    kv_bytes_peak: float = 0.0     # peak physical KV bytes actually held
    # kernels/autotune.py provenance: the tune-cache key whose config the
    # engine's executables were traced under, or "untuned"
    tuned: str = "untuned"
    # fault tolerance + graceful degradation (docs/DESIGN.md §15)
    replica_restarts: int = 0      # replicas quarantined and failed over
    redriven_requests: int = 0     # in-flight requests re-driven to survivors
    recovery_p95_s: float = 0.0    # p95 wall s, failure -> survivors resumed
    watchdog_trips: int = 0        # dispatch->harvest deadline overruns
    degraded_steps: int = 0        # decode steps run below tier 0
    degrade_transitions: int = 0   # KV tier changes (spills + promotions)
    kv_tier_steps: tuple = ()      # decode steps per degradation tier
    # the registry this snapshot was reconstructed from (docs/DESIGN.md
    # §16): carries the per-priority/per-tier label breakdowns the flat
    # fields above aggregate away. Excluded from ==/repr so stats stay
    # comparable across runs.
    registry: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)

    @classmethod
    def from_registry(cls, reg) -> "ServeStats":
        """Snapshot VIEW over a published metrics registry — the field
        mapping lives in ``obs/serve_metrics.py`` (single source of
        truth; the obs tests assert two-way coverage)."""
        from repro.obs.serve_metrics import stats_fields
        return cls(registry=reg, **stats_fields(reg))


class ServeEngine:
    def __init__(self, model: Model, params, *, max_seq: int,
                 plan: Optional[QuantPlan] = None, group: int = 128,
                 eos_id: Optional[int] = None, pad_id: int = 0,
                 mesh=None, kv_precision="bf16",
                 kv_group: Optional[int] = None,
                 spec: Optional[SpecConfig] = None,
                 autotune: bool = True,
                 paged=None,
                 prefill_chunk: Optional[int] = None):
        self.model = model
        self.cfg = model.cfg
        self.max_seq = max_seq
        self.plan = plan
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.mesh = mesh
        self.spec = spec
        # chunked prefill interleaving (docs/DESIGN.md §14): serve() splits
        # prompts into prefill_chunk-token slices scheduled between decode
        # chunks. None/0 keeps the monolithic whole-prompt prefill.
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1 or None, got "
                             f"{prefill_chunk}")
        self.prefill_chunk = prefill_chunk
        # paged KV pool (docs/DESIGN.md §13): True -> defaults, or a
        # PagedConfig. Only plain K/V participates — enc-dec cross K/V is
        # per-request (frames-dependent, nothing to share) and stays in the
        # dense quantized layout; SSM families have no KV at all, so the
        # pool is inert there and the API still works.
        self.paged = (PagedConfig() if paged is True else paged) or None
        self._paged_fields = (tuple(f for f in model.kv_cache_fields
                                    if f in ("k", "v"))
                              if self.paged is not None else ())
        self.pool: Optional[PoolSession] = None  # built by init_decode_state
        self._page_bytes = 0.0
        self._seed_fns: dict = {}
        self._draft = None         # compiled lazily (plan may be set late)
        self._draft_stamp = None   # artifact manifest "draft" (from_artifact)
        if plan is not None:
            params = apply_plan_to_params(model, params, plan, group)
        if mesh is not None:
            from repro.sharding.specs import serving_param_shardings
            # TP-only placement (a no-op resharding when the params already
            # arrived sharded, e.g. from_artifact(mesh=...)).
            params = jax.device_put(params,
                                    serving_param_shardings(params, mesh))
        self.params = params
        self.kv_plan = self._resolve_kv_plan(kv_precision, kv_group)
        # kernels/autotune.py: swap in the tuned chunk/tile config (if one
        # is cached for this device/family/precision) BEFORE the jitted
        # paths below trace — every knob is read at trace time. "untuned"
        # means library defaults; the stamp lands in ServeStats and saved
        # artifact manifests for provenance.
        self.tuned = "untuned"
        if autotune:
            from repro.kernels.autotune import kv_label, maybe_apply_tuned
            self.tuned = maybe_apply_tuned(self.cfg.family,
                                           kv_label(self.kv_plan))
        self._decode = self._traced(jax.jit(model.decode_step))
        # built once, cached (enc-dec prefill also takes encoder frames)
        self._prefill = self._traced(jax.jit(self._prefill_encdec
                                             if self.cfg.family == "encdec"
                                             else self._prefill_impl))
        self._insert = self._traced(jax.jit(self._insert_impl))
        self._release = self._traced(jax.jit(self._release_impl))
        self._kv_wrap = self._traced(jax.jit(self._wrap_cache))
        self._chunk_fns: dict = {}
        self._pchunk_fn = None     # chunked-prefill advance (built lazily)
        self._gather_fn = None     # pool-rows -> dense cache seed (paged)
        self._encdec_seed_fn = None

    # -- quantized KV cache (docs/DESIGN.md §10) -----------------------------
    def _resolve_kv_plan(self, kv_precision, kv_group):
        from repro.quant.kvcache import DEFAULT_KV_GROUP, KVPlan
        if isinstance(kv_precision, KVPlan):
            if kv_group is not None and kv_group != kv_precision.group:
                raise ValueError(
                    f"kv_group={kv_group} conflicts with the provided "
                    f"KVPlan's group={kv_precision.group}; the plan's "
                    f"group is part of the (possibly artifact-stamped) "
                    f"policy — rebuild the plan to change it")
            return kv_precision
        from repro.quant.compiler import compile_kv_plan
        return compile_kv_plan(self.cfg, self.plan, kv_precision,
                               kv_group or DEFAULT_KV_GROUP)

    def _kv_cuts(self) -> tuple:
        """Page boundaries = the weight stack's segment boundaries, so each
        cache page aligns 1:1 with a model scan segment."""
        key = {"dense": "layers", "moe": "layers",
               "encdec": "dec_layers"}.get(self.cfg.family)
        if key is None:
            return ()
        from repro.quant.apply import segment_slices
        return tuple(lo for _, lo, _ in
                     segment_slices(self.params[key])[1:])

    def _wrap_cache(self, cache):
        """Raw (bf16) family cache -> quantized-page layout per the KV
        plan; identity when serving with a bf16 cache. Traceable — the
        engine jits it once per cache shape (``self._kv_wrap``)."""
        if self.kv_plan is None:
            return cache
        from repro.quant.kvcache import quantize_model_cache
        return quantize_model_cache(cache, self.kv_plan, self._kv_cuts(),
                                    self.model.kv_cache_fields)

    # -- mesh plumbing -------------------------------------------------------
    def _ctx(self):
        """Mesh + activation-sharding context every jitted path traces (and
        runs) under; a null context without a mesh."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.sharding.ctx import activation_sharding
        stack = contextlib.ExitStack()
        stack.enter_context(self.mesh)
        stack.enter_context(activation_sharding(self.mesh))
        return stack

    def _traced(self, fn):
        """Wrap a jitted callable so tracing happens inside ``_ctx()``."""
        if self.mesh is None:
            return fn

        def wrapped(*args, **kw):
            with self._ctx():
                return fn(*args, **kw)

        return wrapped

    def _shard_state(self, state: B.DecodeState) -> B.DecodeState:
        return B.shard_state(state, self.mesh) if self.mesh is not None \
            else state

    @classmethod
    def from_artifact(cls, model: Model, directory: str, *, max_seq: int,
                      mesh=None, **kw) -> "ServeEngine":
        """Boot from a persisted compiled-plan artifact: quantized weights
        are restored directly — no raw weight loading, no entropy analysis,
        no re-quantization (quant/compiler.py). With ``mesh``, every leaf is
        device_put to its serving NamedSharding straight from the checkpoint
        file — a cold boot lands sharded without ever materializing a
        replicated copy."""
        from repro.quant.compiler import compile_kv_plan, load_artifact
        from repro.quant.kvcache import DEFAULT_KV_GROUP
        compiled = load_artifact(directory, model, mesh=mesh)
        if compiled.kv_plan is not None:
            # serve with the KV-cache policy stamped at compile time unless
            # the caller explicitly overrides it
            kw.setdefault("kv_precision", compiled.kv_plan)
        if kw.get("kv_precision") == "auto":
            # entropy-weighted selection needs the weight plan, which the
            # engine ctor doesn't see on this path (params arrive compiled)
            kw["kv_precision"] = compile_kv_plan(
                model.cfg, compiled.plan, "auto",
                kw.pop("kv_group", None) or DEFAULT_KV_GROUP)
        engine = cls(model, compiled.params, max_seq=max_seq, plan=None,
                     mesh=mesh, **kw)
        engine.plan = compiled.plan
        engine._draft_stamp = compiled.draft   # validated by _ensure_draft
        obs.instant("engine/from_artifact",
                    args={"directory": directory,
                          "family": model.cfg.family})
        return engine

    # -- prefill -------------------------------------------------------------
    def _prefill_scan(self, prompts: jax.Array, cache):
        """Universal prefill: scan decode steps over prompt tokens."""

        def body(cache, tok):
            logits, cache = self.model.decode_step(self.params, cache,
                                                   tok[:, None])
            return cache, logits[:, 0]

        cache, logits = jax.lax.scan(body, cache, prompts.T)
        return cache, logits[-1]  # logits after last prompt token

    def _prefill_fused(self, prompts: jax.Array):
        """Transformer prefill: one fused forward emitting the KV cache."""
        from repro.models import transformer
        b, s = prompts.shape
        logits, _, cache = transformer.apply(
            self.params, prompts, self.cfg, remat=False, return_cache=True,
            last_only=True)
        pad = self.max_seq - s
        k = jnp.pad(cache.k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(cache.v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return cache._replace(k=k, v=v), logits[:, 0]

    def _prefill_impl(self, prompts: jax.Array):
        if self.cfg.family in ("dense", "moe"):
            return self._prefill_fused(prompts)
        return self._prefill_scan(prompts,
                                  self.model.init_cache(prompts.shape[0],
                                                        self.max_seq))

    def _prefill_encdec(self, prompts: jax.Array, frames: jax.Array):
        """Enc-dec prefill: encode frames, precompute per-decoder-layer
        cross K/V, then scan decode steps over the prompt."""
        from repro.models import encdec
        cache = self.model.init_cache(prompts.shape[0], self.max_seq)
        enc_out = encdec.encode(self.params, frames, self.cfg, remat=False)
        ck, cv = encdec.precompute_cross_kv(self.params, enc_out, self.cfg)
        cache = cache._replace(cross_k=ck, cross_v=cv)
        return self._prefill_scan(prompts, cache)

    def _default_frames(self, batch: int) -> jax.Array:
        from repro.models.common import dtype_of
        return jnp.zeros((batch, self.cfg.encoder_seq, self.cfg.d_model),
                         dtype_of(self.cfg))

    def prefill(self, prompts: jax.Array, frames=None):
        assert prompts.shape[1] <= self.max_seq
        if self.cfg.family == "encdec":
            if frames is None:
                frames = self._default_frames(prompts.shape[0])
            assert frames.shape[1] == self.cfg.encoder_seq
            return self._prefill(prompts, frames)
        assert frames is None, "frames only apply to enc-dec models"
        return self._prefill(prompts)

    # -- paged KV pool + disaggregated API (docs/DESIGN.md §13) --------------
    def _pool_runs(self, raw) -> list:
        """Per-precision layer runs for a pool, aligned with the KV plan's
        page cuts (a single raw-dtype run when serving bf16 caches)."""
        l_total = raw.shape[0]
        if self.kv_plan is None:
            # bf16 pools still split at the weight stack's segment cuts:
            # decode scans per segment, and kv_segment hands each scan its
            # own pool (a full-stack pool would mismatch the leading axis)
            cuts = (0,) + tuple(c for c in self._kv_cuts()
                                if 0 < c < l_total) + (l_total,)
            return [("bf16", lo, hi) for lo, hi in zip(cuts[:-1], cuts[1:])]
        runs = self.kv_plan.pages(self._kv_cuts())
        assert runs[-1][2] == l_total, (runs, l_total)
        return runs

    def _paged_cache(self, num_slots: int, pool_pages: int):
        """Slotted family cache with the paged fields replaced by pools."""
        from repro.quant import paged as PG
        from repro.quant.kvcache import DEFAULT_KV_GROUP
        cache = self.model.slotted_cache(num_slots, self.max_seq)
        group = (self.kv_plan.group if self.kv_plan is not None
                 else DEFAULT_KV_GROUP)
        reps = {}
        for name in self._paged_fields:
            raw = getattr(cache, name)
            reps[name] = PG.init_pool_field(
                raw, self._pool_runs(raw), num_pages=pool_pages,
                page_size=self.paged.page_size, num_slots=num_slots,
                group=group)
        return cache._replace(**reps)

    def init_decode_state(self, num_slots: int,
                          key: Optional[jax.Array] = None) -> B.DecodeState:
        """Empty slotted decode state — the disaggregated API's entry
        point. Paged engines also (re)build the page pool and its host
        allocator here: one ``PoolSession`` per decode state, sized (by
        default) to the dense engine's reservation of
        ``num_slots * ceil(max_seq / page_size)`` pages — equal memory."""
        key = key if key is not None else jax.random.PRNGKey(0)
        cache = None
        if self._paged_fields:
            from repro.quant import paged as PG
            n_log = PG.logical_pages(self.max_seq, self.paged.page_size)
            pool_pages = self.paged.pool_pages or num_slots * n_log
            self.pool = PoolSession(pool_pages, self.paged.page_size, n_log,
                                    prefix_sharing=self.paged.prefix_sharing)
            cache = self._paged_cache(num_slots, pool_pages)
            self._page_bytes = sum(PG.page_nbytes(getattr(cache, name))
                                   for name in self._paged_fields)
        state = B.init_state(self.model, num_slots, self.max_seq, key,
                             cache=cache)
        # quantize any NON-paged KV fields (enc-dec cross K/V); pools pass
        # through untouched (quantize_model_cache skips page fields)
        state = state._replace(cache=self._kv_wrap(state.cache))
        return self._shard_state(state)

    # -- graceful degradation (docs/DESIGN.md §15) ---------------------------
    def degrade_ladder(self) -> list:
        """Entropy-ordered KV degradation tiers for this engine: tier 0 is
        the serving policy; deeper tiers spill cache precision down
        bf16→int8→int4 in the order the weight plan's entropy decisions
        (or FastEWQ, via the compiler) dictate. Empty for unpaged
        engines — degradation trades precision for pool pages."""
        if not self._paged_fields:
            return []
        from repro.quant.compiler import degrade_kv_ladder
        from repro.quant.kvcache import DEFAULT_KV_GROUP
        group = (self.kv_plan.group if self.kv_plan is not None
                 else DEFAULT_KV_GROUP)
        return degrade_kv_ladder(self.cfg, self.plan, self.kv_plan, group,
                                 cuts=self._kv_cuts())

    def apply_kv_plan(self, state: B.DecodeState, new_plan
                      ) -> Optional[B.DecodeState]:
        """Live engine-wide KV-precision transition at CONSTANT byte
        budget. Demoting (bf16→int8→int4) shrinks the page and buys
        proportionally more pages in the same bytes — exactly what
        relieves ``OutOfPages`` pressure; promoting shrinks the pool and
        is refused (returns None) while the live pages would not fit
        (cache-only prefix pages are flushed first). Every live page's
        payload is requantized in place — a demoted page holds the same
        values as if its request had been admitted at the lower tier —
        and the host allocator is rebuilt with refcounts, slot maps and
        the prefix cache remapped. Decode fns re-trace automatically on
        the new pool pytree structure."""
        from repro.quant import paged as PG
        from repro.quant.kvcache import DEFAULT_KV_GROUP
        pool = self.pool
        if pool is None or new_plan is self.kv_plan:
            return None
        num_slots = state.tokens.shape[0]
        old_plan, old_pages = self.kv_plan, pool.num_pages
        budget = old_pages * self._page_bytes
        self.kv_plan = new_plan
        try:
            proto = jax.eval_shape(
                lambda: self.model.slotted_cache(num_slots, self.max_seq))
            group = (new_plan.group if new_plan is not None
                     else DEFAULT_KV_GROUP)
            new_runs, raw_dtypes, page_bytes_new = {}, {}, 0.0
            for name in self._paged_fields:
                raw = getattr(proto, name)
                new_runs[name] = self._pool_runs(raw)
                raw_dtypes[name] = raw.dtype
                f = jax.eval_shape(
                    lambda r=raw, rs=new_runs[name]: PG.init_pool_field(
                        r, rs, num_pages=1,
                        page_size=self.paged.page_size,
                        num_slots=num_slots, group=group))
                page_bytes_new += PG.page_nbytes(f)
            new_pages = int(budget // page_bytes_new)

            def alive():
                return [pid for pid in range(1, old_pages + 1)
                        if pool._ref[pid] > 0]

            live = alive()
            if len(live) > new_pages and pool.prefix is not None:
                pool.flush_prefix()
                live = alive()
            if new_pages < 1 or len(live) > new_pages:
                self.kv_plan = old_plan
                return None
            perm = np.zeros(old_pages + 1, np.int32)
            if new_pages >= old_pages:
                perm[live] = live                      # growth: in place
            else:
                perm[live] = np.arange(1, len(live) + 1)  # compaction
            inv = np.zeros(new_pages + 1, np.int32)
            inv[perm[live]] = live

            def repack(cache):
                reps = {
                    name: PG.repack_pool_field(
                        getattr(cache, name), new_runs[name], perm=perm,
                        inv=inv, group=group, raw_dtype=raw_dtypes[name])
                    for name in self._paged_fields}
                return cache._replace(**reps)

            state = state._replace(
                cache=self._traced(jax.jit(repack))(state.cache))
        except Exception:
            self.kv_plan = old_plan
            raise
        self.pool = pool.rebuild(perm, new_pages)
        self._page_bytes = page_bytes_new
        return self._shard_state(state)

    def _slot_seq_budget(self, prompt_len: int, max_new: int) -> int:
        """Deepest cache row a request can write + 1 (spec verify probes
        ``k`` rows past the last committed token)."""
        k = self.spec.k if self.spec is not None else 0
        return min(self.max_seq, prompt_len + max_new + k)

    def _seed_fn(self, suffix_len: int):
        """Jitted prefix-hit prefill: gather the shared rows from the pool
        into a dense bf16 cache positioned at ``hit`` and scan decode steps
        over ONLY the suffix. One compile per suffix length."""
        if suffix_len not in self._seed_fns:
            model, max_seq = self.model, self.max_seq
            fields = self._paged_fields

            def run(params, pools, row, hit, suffix):
                from repro.quant import paged as PG
                from repro.quant.kvcache import dequantize_kv
                cache = model.init_cache(1, max_seq)
                reps = {}
                for name in fields:
                    field = pools[name]
                    parts = [dequantize_kv(PG.gather_rows(pg, row),
                                           getattr(cache, name).dtype)
                             for pg in (field if isinstance(field, tuple)
                                        else (field,))]
                    full = (jnp.concatenate(parts, 0) if len(parts) > 1
                            else parts[0])
                    reps[name] = full[:, :, :max_seq]
                cache = cache._replace(pos=jnp.asarray(hit, jnp.int32),
                                       **reps)

                def body(c, tok):
                    logits, c = model.decode_step(params, c, tok[:, None])
                    return c, logits[:, 0]

                cache, logits = jax.lax.scan(body, cache, suffix.T)
                return cache, logits[-1]

            self._seed_fns[suffix_len] = self._traced(jax.jit(run))
        return self._seed_fns[suffix_len]

    def _seed_prefill(self, prompt: np.ndarray, m: PrefixMatch, state):
        row = np.zeros(self.pool.n_log, np.int32)
        row[:len(m.full_ids)] = m.full_ids
        if m.donor is not None:
            row[len(m.full_ids)] = m.donor
        pools = {name: getattr(state.cache, name)
                 for name in self._paged_fields}
        suffix = jnp.asarray(prompt[m.hit:], jnp.int32)[None]
        fn = self._seed_fn(int(prompt.size) - m.hit)
        return fn(self.params, pools, jnp.asarray(row), jnp.int32(m.hit),
                  suffix)

    def prefill_request(self, prompt, frames=None, state=None) -> Prefill:
        """Disaggregated prefill of ONE request (1-D prompt).

        Paged engines with prefix sharing first match the prompt against
        the pool's prefix cache, PINNING any matched pages. On a hit,
        dense/MoE text requests skip the shared tokens outright: the
        seeded prefill (needs ``state`` for the pool arrays) reads the
        shared K/V back from the pool and only runs the model over the
        suffix. Other families still prefill in full (hybrid needs its
        conv/SSM state, enc-dec its frames) but the matched pages are
        still mapped — causal K/V depends only on the preceding tokens,
        so page sharing is valid for every attention family."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        match = None
        if self.pool is not None and self.pool.prefix is not None:
            match = self.pool.match(prompt)
            if (match.hit > 0 and frames is None and state is not None
                    and self.cfg.family in ("dense", "moe")):
                cache1, logits1 = self._seed_prefill(prompt, match, state)
                return Prefill(prompt=prompt, cache=cache1,
                               last_logits=logits1, match=match)
        frames_b = (jnp.asarray(frames)[None]
                    if frames is not None else None)
        cache1, logits1 = self.prefill(jnp.asarray(prompt)[None], frames_b)
        return Prefill(prompt=prompt, cache=cache1, last_logits=logits1,
                       match=match)

    # -- chunked prefill interleaving (docs/DESIGN.md §14) -------------------
    def _prefill_chunk_fn(self):
        """Jitted one-chunk prefill advance: extend a batch=1 cache by the
        chunk's tokens. Transformer/enc-dec families score the whole chunk
        in ONE multi-query decode_step (the same per-query causal-offset
        masking the spec verify window uses), so a c-token chunk costs one
        kernel launch, not c; SSM/hybrid scan single-token steps — bit-
        identical to their monolithic scan prefill (their recurrent state
        has no fused multi-token form). jit recompiles per distinct chunk
        length, which is bounded: prefill_chunk plus per-prompt remainders.
        """
        if self._pchunk_fn is None:
            model = self.model
            if self.cfg.family in ("dense", "moe", "encdec"):
                def run(params, cache, toks):
                    logits, cache = model.decode_step(params, cache, toks)
                    return cache, logits[:, -1]
            else:
                def run(params, cache, toks):
                    def body(c, tok):
                        logits, c = model.decode_step(params, c, tok[:, None])
                        return c, logits[:, 0]
                    cache, logits = jax.lax.scan(body, cache, toks.T)
                    return cache, logits[-1]
            self._pchunk_fn = self._traced(jax.jit(run))
        return self._pchunk_fn

    def _pool_gather_fn(self):
        """Jitted prefix-hit seed: gather the matched shared rows from the
        pool into a dense bf16 batch=1 cache positioned at ``hit`` — the
        chunked twin of ``_seed_fn``, minus the suffix scan (the chunk loop
        covers the suffix)."""
        if self._gather_fn is None:
            model, max_seq = self.model, self.max_seq
            fields = self._paged_fields

            def run(pools, row, hit):
                from repro.quant import paged as PG
                from repro.quant.kvcache import dequantize_kv
                cache = model.init_cache(1, max_seq)
                reps = {}
                for name in fields:
                    field = pools[name]
                    parts = [dequantize_kv(PG.gather_rows(pg, row),
                                           getattr(cache, name).dtype)
                             for pg in (field if isinstance(field, tuple)
                                        else (field,))]
                    full = (jnp.concatenate(parts, 0) if len(parts) > 1
                            else parts[0])
                    reps[name] = full[:, :, :max_seq]
                return cache._replace(pos=jnp.asarray(hit, jnp.int32),
                                      **reps)

            self._gather_fn = self._traced(jax.jit(run))
        return self._gather_fn

    def _encdec_seed(self, frames_b: jax.Array):
        """Jitted enc-dec seed for a chunked prefill: encode the frames and
        precompute the per-decoder-layer cross K/V once; the decoder-side
        prompt then enters chunk by chunk."""
        if self._encdec_seed_fn is None:
            model, max_seq = self.model, self.max_seq

            def run(params, frames):
                from repro.models import encdec
                cache = model.init_cache(1, max_seq)
                enc_out = encdec.encode(params, frames, self.cfg,
                                        remat=False)
                ck, cv = encdec.precompute_cross_kv(params, enc_out,
                                                    self.cfg)
                return cache._replace(cross_k=ck, cross_v=cv)

            self._encdec_seed_fn = self._traced(jax.jit(run))
        return self._encdec_seed_fn(self.params, frames_b)

    def begin_prefill(self, prompt, frames=None, state=None
                      ) -> ChunkedPrefill:
        """Start a chunked prefill (disaggregated API): returns the
        ChunkedPrefill task to be advanced with ``advance_prefill`` between
        decode chunks. Prefix-cache hits (paged dense/MoE, like
        ``prefill_request``) seed the cache from the pool's shared rows and
        only the suffix runs through the model — the match's pages stay
        PINNED for the task's lifetime (``insert`` transfers the pins;
        abandon via ``pool.unpin`` on cancellation)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        match = None
        if self.pool is not None and self.pool.prefix is not None:
            match = self.pool.match(prompt)
            if (match.hit > 0 and frames is None and state is not None
                    and self.cfg.family in ("dense", "moe")):
                row = np.zeros(self.pool.n_log, np.int32)
                row[:len(match.full_ids)] = match.full_ids
                if match.donor is not None:
                    row[len(match.full_ids)] = match.donor
                pools = {name: getattr(state.cache, name)
                         for name in self._paged_fields}
                cache = self._pool_gather_fn()(pools, jnp.asarray(row),
                                               jnp.int32(match.hit))
                return ChunkedPrefill(prompt=prompt, cache=cache,
                                      last_logits=None, pos=match.hit,
                                      match=match)
        if self.cfg.family == "encdec":
            frames_b = (jnp.asarray(frames)[None] if frames is not None
                        else self._default_frames(1))
            assert frames_b.shape[1] == self.cfg.encoder_seq
            cache = self._encdec_seed(frames_b)
        else:
            assert frames is None, "frames only apply to enc-dec models"
            cache = self.model.init_cache(1, self.max_seq)
        return ChunkedPrefill(prompt=prompt, cache=cache, last_logits=None,
                              pos=0, match=match)

    def advance_prefill(self, cp: ChunkedPrefill,
                        budget: int) -> ChunkedPrefill:
        """Run ONE prefill chunk of up to ``budget`` prompt tokens (called
        between decode chunks). Mutates and returns ``cp``."""
        assert not cp.done
        c = min(int(budget), len(cp.prompt) - cp.pos)
        toks = jnp.asarray(cp.prompt[cp.pos:cp.pos + c], jnp.int32)[None]
        cache, last = self._prefill_chunk_fn()(self.params, cp.cache, toks)
        cp.cache, cp.last_logits = cache, last
        cp.pos += c
        return cp

    def insert(self, state: B.DecodeState, slot: int, pf: Prefill,
               max_new: int, *, temperature: float = 0.0, top_k: int = 0,
               top_p: float = 1.0) -> B.DecodeState:
        """Admit a prefilled request into ``slot`` (disaggregated API).

        Paged engines allocate the slot's pages here (shared prefix pages
        are mapped, not copied; the COW boundary page is materialized by
        the insert scatter) and raise ``OutOfPages`` — with the match's
        pins released and nothing leaked — when the pool cannot serve the
        request; callers should ``Scheduler.requeue`` and retry after a
        slot drains."""
        page_rows = None
        p = int(pf.prompt.size)
        if self.pool is not None:
            need = self.pool.pages_for(self._slot_seq_budget(p, max_new))
            row, wrow = self.pool.admit(slot, pf.prompt, need, pf.match)
            page_rows = (jnp.asarray(row), jnp.asarray(wrow))
        state = self._insert(state, jnp.int32(slot),
                             jnp.asarray(pf.prompt, jnp.int32), pf.cache,
                             pf.last_logits, jnp.int32(max_new),
                             jnp.float32(temperature), jnp.int32(top_k),
                             jnp.float32(top_p), page_rows)
        if self.pool is not None:
            self.pool.register(slot, pf.prompt, p)
        return state

    def decode_chunk(self, state: B.DecodeState, steps: int = DEFAULT_CHUNK):
        """Run ``steps`` jitted decode steps over every active slot
        (disaggregated API). Spec engines run ``steps`` propose/verify
        rounds and return ``(state, round_metrics)``; plain engines return
        the new state."""
        if self.spec is not None:
            return self._spec_fn(steps)(self.params, self.draft_params,
                                        state)
        return self._chunk_fn(steps)(self.params, state)

    def release(self, state: B.DecodeState, slot: int) -> B.DecodeState:
        """Evict a finished request and return its pages to the pool
        (shared pages survive while the prefix cache or other slots still
        reference them)."""
        state = self._release(state, jnp.int32(slot))
        if self.pool is not None:
            self.pool.release(int(slot))
        return state

    # -- fused chunked decode loop -------------------------------------------
    def _make_chunk_fn(self, steps: int):
        """One jitted scan over ``steps`` token positions.

        Per step: masked sampling from each slot's last logits (done or
        empty slots emit pad and do not advance), scatter the chosen token
        and its logprob at ``lengths[slot]``, update per-slot stop
        conditions, then one batched decode_step for the next logits.

        Sampling controls (temperature / top-k / top-p) ride in the state
        as TRACED per-slot vectors (serving/sampling.py), so there is
        exactly one compile per (chunk, num_slots) — changing sampling
        params never retriggers XLA compilation.
        """
        vocab = self.cfg.vocab_size
        eos_id, pad_id = self.eos_id, self.pad_id
        model = self.model

        def step(params, st, _):
            lp = jax.nn.log_softmax(
                st.last_logits[:, :vocab].astype(jnp.float32), -1)
            key, sub = jax.random.split(st.key)
            dist = S.masked_dist(lp, st.temperature, st.top_k, st.top_p)
            nxt = S.sample(sub, dist, st.temperature)
            chosen_lp = jnp.take_along_axis(lp, nxt[:, None], 1)[:, 0]
            advance = st.active & ~st.done
            nxt = jnp.where(advance, nxt, pad_id).astype(jnp.int32)
            at = jnp.arange(st.tokens.shape[1])[None, :] == st.lengths[:, None]
            write = at & advance[:, None]
            tokens = jnp.where(write, nxt[:, None], st.tokens)
            logprobs = jnp.where(write, chosen_lp[:, None], st.logprobs)
            lengths = st.lengths + advance.astype(jnp.int32)
            done = st.done | (advance & (lengths >= st.max_len))
            if eos_id is not None:
                done = done | (advance & (nxt == eos_id))
            logits, cache = model.decode_step(params, st.cache, nxt[:, None])
            return st._replace(
                cache=cache, last_logits=logits[:, 0].astype(jnp.float32),
                tokens=tokens, lengths=lengths, done=done,
                logprobs=logprobs, key=key), None

        mesh = self.mesh

        def run(params, state):
            state, _ = jax.lax.scan(
                lambda st, x: step(params, st, x), state, None, length=steps)
            if mesh is not None:
                # pin the carry layout so chunk N+1 reuses chunk N's compile
                state = B.constrain_state(state, mesh)
            return state

        return self._traced(jax.jit(run))

    def _chunk_fn(self, steps: int):
        if steps not in self._chunk_fns:
            self._chunk_fns[steps] = self._make_chunk_fn(steps)
        return self._chunk_fns[steps]

    # -- self-speculative decoding (docs/DESIGN.md §11) ----------------------
    def _ensure_draft(self):
        """Compile the all-int4 draft lazily (engine.plan may be assigned
        after construction, e.g. ``from_artifact``)."""
        if self._draft is None:
            from repro.quant.compiler import compile_draft_plan
            draft = compile_draft_plan(self.model, self.params, self.plan,
                                       self.spec.draft_group,
                                       draft_layers=self.spec.draft_layers)
            stamp = self._draft_stamp
            if (stamp and stamp.get("group") == self.spec.draft_group
                    and stamp.get("draft_layers") == self.spec.draft_layers):
                # cold boot must re-derive the exact stamped draft; a
                # different draft_group is an explicit operator override
                if list(draft.precisions) != stamp.get("precisions"):
                    raise ValueError(
                        "artifact draft stamp mismatch: re-derived draft "
                        f"precisions {list(draft.precisions)} != stamped "
                        f"{stamp.get('precisions')} — the artifact's plan "
                        "and the serving engine's plan disagree")
            if self.mesh is not None:
                from repro.sharding.specs import serving_param_shardings
                # shared leaves are already placed (no-op); only the
                # draft-only int4 copies actually move
                draft.params = jax.device_put(
                    draft.params,
                    serving_param_shardings(draft.params, self.mesh))
            self._draft = draft
        return self._draft

    @property
    def draft_params(self):
        # the ngram draft proposes from committed context — no draft model
        # exists; the round's propose branch never reads these params
        if self.spec is not None and self.spec.draft_source == "ngram":
            return self.params
        return self._ensure_draft().params

    def draft_overhead_bytes(self) -> float:
        """Draft-only weight bytes (blocks the plan left raw/int8, re-
        quantized to int4 for the draft); everything else is shared with
        the target byte-for-byte."""
        if self.spec is not None and self.spec.draft_source == "ngram":
            return 0.0
        return float(self._ensure_draft().overhead_bytes)

    def _spec_fn(self, rounds: int):
        key = ("spec", rounds)
        if key not in self._chunk_fns:
            from repro.serving.spec import make_spec_round
            fused = (self.spec.fused_propose
                     and self.model.supports_fused_propose)
            if self.spec.draft_layers is not None and not fused:
                raise ValueError(
                    f"spec draft_layers needs the fused propose path; "
                    f"family {self.model.cfg.family!r} does not support it")
            run = make_spec_round(self.model, self.spec.k, rounds,
                                  self.eos_id, self.mesh,
                                  fused_propose=fused,
                                  draft_source=self.spec.draft_source)
            self._chunk_fns[key] = self._traced(jax.jit(run))
        return self._chunk_fns[key]

    def _spec_budget_check(self, prompt_len: int, max_new: int):
        """Spec verify writes k+1 cache rows starting at each slot's
        position; the deepest speculative write is ``max_len - 1 + k``,
        which must stay inside the cache."""
        need = prompt_len + max_new + self.spec.k
        assert need <= self.max_seq, \
            (f"speculative serving needs max_seq >= prompt + max_new + k "
             f"= {need} (k={self.spec.k} verify headroom); max_seq is "
             f"{self.max_seq}")

    def _insert_impl(self, state, slot, prompt, prompt_cache, last_logits,
                     max_new, temperature, top_k, top_p, page_rows=None):
        state = B.insert_request(self.model, state, slot, prompt,
                                 prompt_cache, last_logits, max_new,
                                 temperature, top_k, top_p,
                                 page_rows=page_rows)
        if self.mesh is not None:
            state = B.constrain_state(state, self.mesh)
        return state

    def _release_impl(self, state, slot):
        state = B.release_slot(state, slot)
        if self._paged_fields:
            from repro.quant import paged as PG
            reps = {name: PG.release_slot_pages(getattr(state.cache, name),
                                                slot)
                    for name in self._paged_fields}
            state = state._replace(cache=state.cache._replace(**reps))
        if self.mesh is not None:
            state = B.constrain_state(state, self.mesh)
        return state

    # -- generation (compat wrapper: single batch == one drain) ---------------
    def _batch_state(self, prompts, frames, max_new_tokens, temperature,
                     top_k, top_p, key) -> B.DecodeState:
        """Fixed-batch DecodeState for generate()'s decode modes (identical
        for spec and baseline: full-prompt prefill, ``pos == lengths`` —
        the spec loop recognizes that as a *fresh* slot)."""
        b, p = prompts.shape
        cache, last_logits = self.prefill(prompts, frames)
        cache = cache._replace(pos=jnp.full((b,), p, jnp.int32))
        # quantize-on-insert: prefill ran bf16; the decode carry is pages
        cache = self._kv_wrap(cache)
        tokens = jnp.zeros((b, self.max_seq), jnp.int32)
        tokens = jax.lax.dynamic_update_slice(
            tokens, prompts.astype(jnp.int32), (0, 0))
        return B.DecodeState(
            cache=cache, last_logits=last_logits.astype(jnp.float32),
            tokens=tokens,
            lengths=jnp.full((b,), p, jnp.int32),
            max_len=jnp.full((b,), p + max_new_tokens, jnp.int32),
            done=jnp.zeros((b,), bool),
            active=jnp.ones((b,), bool),
            logprobs=jnp.zeros((b, self.max_seq), jnp.float32),
            key=key if key is not None else jax.random.PRNGKey(0),
            temperature=jnp.full((b,), temperature, jnp.float32),
            top_k=jnp.full((b,), top_k, jnp.int32),
            top_p=jnp.full((b,), top_p, jnp.float32))

    def _slice_prefill(self, cache, i: int):
        """Batch prefill cache -> the batch=1 slice ``insert`` expects."""
        axes = self.model.cache_batch_axes

        def one(leaf, axis):
            leaf = jnp.asarray(leaf)
            if leaf.ndim == 0:      # scalar pos is shared across the batch
                return leaf
            return jax.lax.dynamic_slice_in_dim(leaf, i, 1, axis=axis)

        return type(cache)(*(one(l, a) for l, a in zip(cache, axes)))

    def _batch_state_paged(self, prompts, frames, max_new_tokens,
                           temperature, top_k, top_p, key) -> B.DecodeState:
        """generate()'s paged twin of ``_batch_state``: the SAME batched
        prefill (numerics identical to dense), then each row is admitted
        through the pool so the decode carry reads/writes pages."""
        b = prompts.shape[0]
        state = self.init_decode_state(b, key)
        cache, last_logits = self.prefill(prompts, frames)
        prompts_np = np.asarray(prompts).astype(np.int32)
        for i in range(b):
            pf = Prefill(prompt=prompts_np[i],
                         cache=self._slice_prefill(cache, i),
                         last_logits=last_logits[i:i + 1])
            state = self.insert(state, i, pf, max_new_tokens,
                                temperature=temperature, top_k=top_k,
                                top_p=top_p)
        return state

    def generate(self, prompts: jax.Array, max_new_tokens: int,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None,
                 chunk: Optional[int] = None,
                 frames: Optional[jax.Array] = None,
                 top_k: int = 0, top_p: float = 1.0) -> GenerateResult:
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{max_new_tokens}")
        if chunk is not None and chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        b, p = prompts.shape
        total = p + max_new_tokens
        spec = self.spec is not None
        if spec:
            self._spec_budget_check(p, max_new_tokens)
        else:
            assert total <= self.max_seq, (total, self.max_seq)
        if self._paged_fields:
            state = self._batch_state_paged(prompts, frames, max_new_tokens,
                                            temperature, top_k, top_p, key)
        else:
            state = self._batch_state(prompts, frames, max_new_tokens,
                                      temperature, top_k, top_p, key)
        state = self._shard_state(state)
        chunk = max_new_tokens if chunk is None else min(chunk, max_new_tokens)
        if spec:
            # each live round commits >= 1 token, so max_new rounds suffice
            fn = self._spec_fn(chunk)
            draft_params = self.draft_params
            rounds = 0
            while True:
                state, m = fn(self.params, draft_params, state)
                rounds += chunk
                if bool(state.done.all()) or rounds >= max_new_tokens:
                    break
            steps = rounds
        else:
            fn = self._chunk_fn(chunk)
            steps = 0
            while True:
                state = fn(self.params, state)
                steps += chunk
                if steps >= max_new_tokens or bool(state.done.all()):
                    break
        return GenerateResult(tokens=state.tokens[:, :total],
                              logprobs=state.logprobs[:, p:total],
                              steps=steps)

    def generate_stepwise(self, prompts: jax.Array, max_new_tokens: int,
                          temperature: float = 0.0,
                          key: Optional[jax.Array] = None,
                          frames: Optional[jax.Array] = None
                          ) -> GenerateResult:
        """Legacy per-token Python dispatch loop.

        Kept as the benchmark baseline (benchmarks/serve_throughput.py):
        identical math to ``generate``, but every token pays Python-side
        sampling-op dispatch plus a separate jitted decode dispatch.
        """
        b = prompts.shape[0]
        cache, last_logits = self.prefill(prompts, frames)
        toks = [prompts]
        logprobs = []
        logits = last_logits
        key = key if key is not None else jax.random.PRNGKey(0)
        for _ in range(max_new_tokens):
            lp = jax.nn.log_softmax(
                logits[:, :self.cfg.vocab_size].astype(jnp.float32), -1)
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, lp / temperature, axis=-1)
            else:
                nxt = jnp.argmax(lp, axis=-1)
            logprobs.append(jnp.take_along_axis(lp, nxt[:, None], 1)[:, 0])
            nxt = nxt[:, None].astype(jnp.int32)
            toks.append(nxt)
            step_logits, cache = self._decode(self.params, cache, nxt)
            logits = step_logits[:, 0]
        return GenerateResult(tokens=jnp.concatenate(toks, axis=1),
                              logprobs=jnp.stack(logprobs, axis=1),
                              steps=max_new_tokens)

    # -- continuous batching ---------------------------------------------------
    def serve(self, requests: Sequence[Request], *, num_slots: int = 8,
              chunk: int = DEFAULT_CHUNK, temperature: float = 0.0,
              key: Optional[jax.Array] = None,
              prefill_chunk: Optional[int] = None,
              slo: Optional["SLOConfig"] = None,
              degrade=None
              ) -> tuple[list[RequestOutput], ServeStats]:
        """Drain a request stream with continuous batching.

        Between decode chunks, finished slots are harvested and queued
        requests (arrival_step <= clock) are admitted into freed slots —
        highest priority first, FIFO within a class. Returns outputs
        ordered by request id plus occupancy/latency statistics.

        ``prefill_chunk`` (or the engine-level knob) turns on chunked
        prefill interleaving: prompts enter the cache in prefill_chunk-
        token slices scheduled between decode chunks, so a long prompt
        never stalls the running slots for its whole prefill (greedy
        output is token-identical to monolithic prefill). ``slo`` adds
        TPOT-gated admission and priority preemption (docs/DESIGN.md §14);
        request-level deadlines / timeouts / cancellation are honored
        either way.

        Per-request sampling controls (``Request.temperature/top_k/top_p``)
        override the call-level ``temperature`` default; they are traced,
        so a stream mixing greedy and nucleus requests still compiles one
        chunk fn. With ``spec=SpecConfig(...)`` each chunk runs ``chunk``
        draft-propose/verify ROUNDS (1..k+1 tokens committed per live
        round) and the stats report acceptance counters.
        """
        from repro.serving.session import ServeSession
        return ServeSession(self, requests, num_slots=num_slots,
                            chunk=chunk, temperature=temperature, key=key,
                            prefill_chunk=prefill_chunk, slo=slo,
                            degrade=degrade).run()

    # -- diagnostics -----------------------------------------------------------
    def kv_bytes_per_slot(self) -> float:
        """Physical attention-cache bytes one decode slot holds at
        ``max_seq`` (K/V payloads + per-group scales; enc-dec includes the
        cross-attention cache; 0.0 for attention-free families).

        This is the per-request HBM cost that scales with
        ``num_slots x max_seq`` — the number the KV-cache quantization
        shrinks (docs/DESIGN.md §10)."""
        from repro.quant.kvcache import kv_field_nbytes
        cache = jax.eval_shape(
            lambda: self._wrap_cache(self.model.slotted_cache(1,
                                                              self.max_seq)))
        return float(sum(kv_field_nbytes(getattr(cache, name))
                         for name in self.model.kv_cache_fields))

    def _nonpaged_bytes_per_slot(self) -> float:
        """Per-slot bytes of KV fields NOT served from the pool (enc-dec
        cross K/V); 0.0 when everything is paged or there is no KV."""
        from repro.quant.kvcache import kv_field_nbytes
        names = [n for n in self.model.kv_cache_fields
                 if n not in self._paged_fields]
        if not names:
            return 0.0
        cache = jax.eval_shape(
            lambda: self._wrap_cache(self.model.slotted_cache(1,
                                                              self.max_seq)))
        return float(sum(kv_field_nbytes(getattr(cache, n)) for n in names))

    def kv_bytes_allocated(self, num_slots: int = 1) -> float:
        """Physical attention-cache bytes actually held right now.

        Dense engines reserve every slot at full depth up front, so this
        is just ``num_slots * kv_bytes_per_slot()``. Paged engines charge
        only the pool pages currently referenced (shared prefix pages
        counted ONCE — that is the whole point) plus the dense reservation
        of any non-paged KV fields (enc-dec cross K/V)."""
        if self.pool is None:
            return num_slots * self.kv_bytes_per_slot()
        return (self.pool.pages_in_use * self._page_bytes
                + num_slots * self._nonpaged_bytes_per_slot())

    @staticmethod
    def _tree_weight_bytes(params) -> float:
        from repro.quant.apply import tree_nbytes
        from repro.quant.apply import SegmentedParams
        total = 0.0
        for v in jax.tree.leaves(
                params,
                is_leaf=lambda x: isinstance(x, SegmentedParams)):
            if isinstance(v, SegmentedParams):
                total += v.nbytes_effective()
            else:
                total += tree_nbytes(v)
        return total

    def weight_bytes(self) -> float:
        return self._tree_weight_bytes(self.params)

    def draft_weight_bytes(self) -> float:
        """Effective bytes ONE draft decode step reads (shared int4
        payloads + draft-only copies) — the numerator of the
        weight-bytes-per-committed-token uplift estimate: decode is
        weight-bytes-bound, so spec serving reads
        ``(target + k * draft) / tokens_per_round`` bytes per token vs
        ``target`` for the baseline."""
        if self.spec is not None and self.spec.draft_source == "ngram":
            return 0.0
        return self._tree_weight_bytes(self.draft_params)

    def weight_bytes_per_device(self) -> float:
        """Max physical weight bytes resident on any single device.

        Counts each leaf's addressable shards per device (a replicated leaf
        contributes its full size to every device; a TP-sharded one only its
        slice), so on a 1xN TP mesh this is what actually bounds HBM —
        the deployment-memory number the mesh benchmark rows report."""
        per_device: dict = {}
        for leaf in jax.tree.leaves(self.params):
            if isinstance(leaf, jax.Array):
                for s in leaf.addressable_shards:
                    dev = s.device.id
                    per_device[dev] = per_device.get(dev, 0.0) + s.data.nbytes
            else:
                arr = np.asarray(leaf)
                per_device[-1] = per_device.get(-1, 0.0) + arr.nbytes
        return max(per_device.values()) if per_device else 0.0
