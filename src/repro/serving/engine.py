"""Batched serving engine with EWQ/FastEWQ-quantized weights.

Deployment story (the paper's §3.4/§4 pipeline, end-to-end):
  1. at startup, pick a QuantPlan — full EWQ (weights analyzed), FastEWQ
     (O(1), metadata only), or resource-fitted via cluster.fit_plan_to_hbm;
  2. quantize params per plan (block-granular mixed precision);
  3. serve: prefill fills the KV/SSM cache, greedy/temperature decode steps
     run against quantized weights (decode is weight-bytes-bound — exactly
     where int8/int4 payloads pay off, see EXPERIMENTS.md §Perf).

Prefill paths: transformer families use the fused apply(return_cache=True);
SSM/hybrid/enc-dec prefill by scanning decode steps over the prompt (their
decode matches teacher-forced forward exactly — tests/test_models_parity).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPlan
from repro.models.model import Model
from repro.serving.quantized import apply_plan_to_params


@dataclasses.dataclass
class GenerateResult:
    tokens: jax.Array          # (B, prompt+new)
    logprobs: jax.Array        # (B, new) chosen-token logprobs
    steps: int


class ServeEngine:
    def __init__(self, model: Model, params, *, max_seq: int,
                 plan: Optional[QuantPlan] = None, group: int = 128):
        self.model = model
        self.cfg = model.cfg
        self.max_seq = max_seq
        self.plan = plan
        if plan is not None:
            params = apply_plan_to_params(model, params, plan, group)
        self.params = params
        self._decode = jax.jit(model.decode_step)

    # -- prefill -------------------------------------------------------------
    def _prefill_scan(self, prompts: jax.Array):
        """Universal prefill: scan decode steps over prompt tokens."""
        b, s = prompts.shape
        cache = self.model.init_cache(b, self.max_seq)

        def body(cache, tok):
            logits, cache = self.model.decode_step(self.params, cache,
                                                   tok[:, None])
            return cache, logits[:, 0]

        cache, logits = jax.lax.scan(body, cache, prompts.T)
        return cache, logits[-1]  # logits after last prompt token

    def prefill(self, prompts: jax.Array):
        return jax.jit(self._prefill_scan)(prompts)

    # -- generation ------------------------------------------------------------
    def generate(self, prompts: jax.Array, max_new_tokens: int,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None) -> GenerateResult:
        b = prompts.shape[0]
        cache, last_logits = self.prefill(prompts)
        toks = [prompts]
        logprobs = []
        logits = last_logits
        key = key if key is not None else jax.random.PRNGKey(0)
        for i in range(max_new_tokens):
            lp = jax.nn.log_softmax(
                logits[:, :self.cfg.vocab_size].astype(jnp.float32), -1)
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, lp / temperature, axis=-1)
            else:
                nxt = jnp.argmax(lp, axis=-1)
            logprobs.append(jnp.take_along_axis(lp, nxt[:, None], 1)[:, 0])
            toks.append(nxt[:, None].astype(jnp.int32))
            step_logits, cache = self._decode(self.params, cache,
                                              nxt[:, None].astype(jnp.int32))
            logits = step_logits[:, 0]
        return GenerateResult(tokens=jnp.concatenate(toks, axis=1),
                              logprobs=jnp.stack(logprobs, axis=1),
                              steps=max_new_tokens)

    # -- diagnostics -----------------------------------------------------------
    def weight_bytes(self) -> float:
        from repro.quant.apply import tree_nbytes
        from repro.quant.apply import SegmentedParams
        total = 0.0
        for v in jax.tree.leaves(
                self.params,
                is_leaf=lambda x: isinstance(x, SegmentedParams)):
            if isinstance(v, SegmentedParams):
                total += v.nbytes_effective()
            else:
                total += tree_nbytes(v)
        return total
