"""DP x TP replica serving: a router over per-replica ServeEngines
(docs/DESIGN.md §14).

``launch/mesh.py`` has parsed ``data,model`` mesh shapes since the mesh
serving work landed, but every serving path to date was TP-only — the
data axis never carried traffic. ``ReplicaServe`` puts it to work the way
deployments actually use it: the mesh is split into one submesh per data-
axis index (``split_data_replicas``), each submesh gets its OWN engine —
weights device_put per submesh (DP replication), private slotted decode
state, private page pool — and a host-side router partitions the request
stream across replicas with load-aware dispatch (least outstanding
prompt+decode tokens, in arrival order, deterministic).

The serve loop interleaves the replicas' session ticks in two passes —
dispatch every replica's decode chunk, THEN harvest every replica — so
one replica's blocking device read never serializes the others' compute:
JAX dispatch is async, and by the time replica 0's harvest blocks,
replicas 1..R-1 already have their chunks in flight.

Each replica runs its own decode-step clock (it advances only when that
replica decodes), so ``arrival_step`` is interpreted per replica; wall-
clock latency stats remain globally honest. Greedy decoding is
deterministic per request, so a DP x TP serve is token-identical to the
same requests on one TP-only engine — the CI parity anchor.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import numpy as np

from repro import obs
from repro.serving import chaos
from repro.serving.engine import ServeEngine, ServeStats
from repro.serving.pool import OutOfPages
from repro.serving.scheduler import Request, RequestOutput, SLOConfig
from repro.serving.session import ServeSession


@dataclasses.dataclass(frozen=True)
class FailoverConfig:
    """Replica health + failover policy (docs/DESIGN.md §15).

    A replica tick (dispatch or harvest) that raises a ``TransientFault``
    retries in place up to ``retries`` times with ``backoff_s`` sleep
    between attempts; any other failure quarantines the replica — its
    session tears down leak-free (``ServeSession.abort``) and every
    unfinished request re-drives onto the surviving replicas, where it
    re-prefills from its original prompt (greedy tokens unchanged).
    ``max_restarts`` bounds quarantines (default R - 1: the last replica
    standing must not fail); ``watchdog_s`` arms the per-replica
    dispatch→harvest deadline (overruns surface as ``watchdog_trips``)."""
    retries: int = 2
    backoff_s: float = 0.0
    max_restarts: Optional[int] = None
    watchdog_s: Optional[float] = None


@dataclasses.dataclass
class ReplicaStats:
    """Aggregate + per-replica serve statistics."""
    replicas: int
    aggregate: ServeStats          # merged view (percentiles recomputed
                                   # over ALL requests, counters summed)
    per_replica: list              # list[ServeStats], one per replica
    assignments: list              # requests routed to each replica
    occupancy_per_replica: list    # mean active-slot fraction per replica


class ReplicaServe:
    """Serve one request stream across R replica engines."""

    def __init__(self, engines: Sequence[ServeEngine]):
        if not engines:
            raise ValueError("ReplicaServe needs at least one engine")
        self.engines = list(engines)

    @classmethod
    def build(cls, model, params, *, mesh, max_seq: int,
              **engine_kw) -> "ReplicaServe":
        """One engine per data-axis submesh of ``mesh``. Each engine
        device_puts the (quantized) weights to its own submesh — that IS
        the DP replication; a mesh without a data axis yields a single
        TP-only replica."""
        from repro.launch.mesh import split_data_replicas
        return cls([ServeEngine(model, params, mesh=m, max_seq=max_seq,
                                **engine_kw)
                    for m in split_data_replicas(mesh)])

    @property
    def num_replicas(self) -> int:
        return len(self.engines)

    def route(self, requests: Sequence[Request]) -> list[list[Request]]:
        """Load-aware dispatch: walk the stream in arrival order and send
        each request to the replica with the least outstanding work
        (projected prompt + decode tokens). Deterministic — ties go to the
        lowest replica id."""
        buckets: list[list[Request]] = [[] for _ in self.engines]
        load = [0] * len(self.engines)
        order = sorted(requests, key=lambda r: (r.arrival_step, r.rid))
        for r in order:
            i = min(range(len(load)), key=lambda j: (load[j], j))
            buckets[i].append(r)
            load[i] += len(r.prompt) + r.max_new_tokens
        return buckets

    def serve(self, requests: Sequence[Request], *, num_slots: int = 8,
              chunk: int = 8, temperature: float = 0.0, key=None,
              prefill_chunk: Optional[int] = None,
              slo: Optional[SLOConfig] = None,
              failover: Optional[FailoverConfig] = None,
              degrade=None
              ) -> tuple[list[RequestOutput], ReplicaStats]:
        """Drain the stream across all replicas; ``num_slots`` is PER
        replica (total concurrency = R * num_slots). Outputs merge back
        in request-id order.

        With ``failover`` set, a replica whose tick faults permanently is
        quarantined: its session aborts leak-free and its unfinished
        requests re-drive onto the surviving replicas (DESIGN.md §15).
        Transient faults retry in place. Without ``failover``, failures
        propagate as before. ``degrade`` (a ``session.DegradeConfig``)
        arms per-replica graceful degradation under pool pressure."""
        key = key if key is not None else jax.random.PRNGKey(0)
        buckets = self.route(requests)
        sessions = [
            ServeSession(eng, bucket, num_slots=num_slots, chunk=chunk,
                         temperature=temperature,
                         key=jax.random.fold_in(key, i),
                         prefill_chunk=prefill_chunk, slo=slo,
                         replica_id=i, degrade=degrade,
                         watchdog_s=(failover.watchdog_s
                                     if failover is not None else None))
            for i, (eng, bucket) in enumerate(zip(self.engines, buckets))]
        alive = [True] * len(sessions)
        restarts, redriven = 0, 0
        recovery: list[float] = []
        failovers: list[tuple] = []    # (replica, recovery_s, orphans)

        def tick(i: int, phase: str) -> bool:
            """One session phase under the failover policy; False means
            the replica must be quarantined."""
            s = sessions[i]
            fn = s.dispatch if phase == "dispatch" else s.harvest
            attempts = failover.retries if failover is not None else 0
            while True:
                try:
                    fn()
                    return True
                except chaos.TransientFault:
                    if attempts <= 0:
                        if failover is None:
                            raise
                        return False
                    attempts -= 1   # sites fire before state mutation, so
                    if failover.backoff_s:       # the tick retries in place
                        time.sleep(failover.backoff_s)
                except OutOfPages:
                    raise   # admission deadlock is a sizing error on every
                            # identical replica — re-driving cannot help
                except Exception:
                    if failover is None:
                        raise
                    return False

        def quarantine(i: int) -> None:
            nonlocal restarts, redriven
            tr = obs.tracer()
            span_t0 = tr.now_us() if tr is not None else 0.0
            t0 = time.perf_counter()
            orphans = sessions[i].abort()
            alive[i] = False
            restarts += 1
            targets = [j for j in range(len(sessions)) if alive[j]]
            budget = (failover.max_restarts
                      if failover.max_restarts is not None
                      else len(sessions) - 1)
            if not targets or restarts > budget:
                raise RuntimeError(
                    f"replica failover exhausted: {restarts} replicas "
                    f"failed (budget {budget}), {len(orphans)} requests "
                    f"stranded")
            load = {j: 0 for j in targets}
            for req in orphans:          # load-aware re-drive, like route()
                j = min(targets, key=lambda t: (load[t], t))
                sessions[j].sched.submit(dataclasses.replace(
                    req, arrival_step=sessions[j].clock))
                load[j] += len(req.prompt) + req.max_new_tokens
                redriven += 1
            dt = time.perf_counter() - t0
            recovery.append(dt)
            failovers.append((i, dt, len(orphans)))
            # failover telemetry goes to the INSTALLED sinks directly —
            # it is a router-level event no per-session publish covers
            # (docs/DESIGN.md §16)
            if tr is not None:
                tr.complete("replica/failover", span_t0, i,
                            args={"orphans": len(orphans),
                                  "survivors": len(targets)})
            obs.count("serve_replica_restarts_total", 1, replica=str(i))
            obs.count("serve_redriven_requests_total", len(orphans),
                      replica=str(i))
            obs.observe("serve_recovery_seconds", dt, replica=str(i))

        while any(alive[i] and not s.done
                  for i, s in enumerate(sessions)):
            for i, s in enumerate(sessions):  # launch every live replica...
                if alive[i] and not s.done and not tick(i, "dispatch"):
                    quarantine(i)
            for i, s in enumerate(sessions):  # ...then block on each in turn
                if alive[i] and not tick(i, "harvest"):
                    quarantine(i)             # (no-op unless it dispatched)
        results = [s.finalize() for s in sessions]
        outputs = sorted((o for outs, _ in results for o in outs),
                         key=lambda o: o.rid)
        per_replica = [st for _, st in results]
        aggregate = dataclasses.replace(
            _merge_stats(outputs, per_replica),
            replica_restarts=restarts, redriven_requests=redriven,
            recovery_p95_s=(float(np.percentile(recovery, 95))
                            if recovery else 0.0),
            registry=_merge_registries(per_replica, failovers))
        return outputs, ReplicaStats(
            replicas=len(self.engines),
            aggregate=aggregate,
            per_replica=per_replica,
            assignments=[len(b) for b in buckets],
            occupancy_per_replica=[st.occupancy for st in per_replica])


def _merge_registries(per_replica: list[ServeStats],
                      failovers: list[tuple]):
    """Roll the per-replica run registries into one, then add the
    router-level failover events no per-session publish covers. The
    result rides on the aggregate's ``registry`` field, so the DP
    exposition carries per-replica labels."""
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.serve_metrics import SCHEMA
    merged = MetricsRegistry()
    for st in per_replica:
        if st.registry is not None:
            merged.merge(st.registry)
    for i, dt, orphans in failovers:
        r = str(i)
        merged.counter("serve_replica_restarts_total",
                       SCHEMA["serve_replica_restarts_total"][1]
                       ).inc(1, replica=r)
        merged.counter("serve_redriven_requests_total",
                       SCHEMA["serve_redriven_requests_total"][1]
                       ).inc(orphans, replica=r)
        merged.histogram("serve_recovery_seconds",
                         SCHEMA["serve_recovery_seconds"][1]
                         ).observe(dt, replica=r)
    return merged


def _merge_stats(outputs: list, per_replica: list[ServeStats]) -> ServeStats:
    """Global view: latency percentiles recomputed over the merged request
    outputs (a per-replica percentile of percentiles would be wrong),
    counters and token totals summed, occupancy weighted by chunks."""

    def pct(vals, q):
        return float(np.percentile(vals, q)) if vals else 0.0

    ttfts = [o.ttft_s for o in outputs if o.ttft_s is not None]
    tpots = [o.tpot_s for o in outputs if o.tpot_s is not None]
    qdels = [o.queue_delay_s for o in outputs if o.queue_delay_s is not None]
    chunks = sum(st.num_chunks for st in per_replica)
    proposed = sum(st.draft_proposed for st in per_replica)
    rounds = sum(st.spec_rounds for st in per_replica)
    committed = sum(st.tokens_per_round * st.spec_rounds
                    for st in per_replica)
    return ServeStats(
        decode_steps=sum(st.decode_steps for st in per_replica),
        generated_tokens=sum(st.generated_tokens for st in per_replica),
        occupancy=(sum(st.occupancy * st.num_chunks for st in per_replica)
                   / chunks if chunks else 0.0),
        num_chunks=chunks,
        admissions=sum(st.admissions for st in per_replica),
        ttft_p50_s=pct(ttfts, 50), ttft_p95_s=pct(ttfts, 95),
        tpot_p50_s=pct(tpots, 50), tpot_p95_s=pct(tpots, 95),
        queue_delay_p50_s=pct(qdels, 50), queue_delay_p95_s=pct(qdels, 95),
        preemptions=sum(st.preemptions for st in per_replica),
        timeouts=sum(st.timeouts for st in per_replica),
        cancelled=sum(st.cancelled for st in per_replica),
        prefill_chunks=sum(st.prefill_chunks for st in per_replica),
        decode_gap_p50_s=max((st.decode_gap_p50_s for st in per_replica),
                             default=0.0),
        decode_gap_p95_s=max((st.decode_gap_p95_s for st in per_replica),
                             default=0.0),
        decode_gap_max_s=max((st.decode_gap_max_s for st in per_replica),
                             default=0.0),
        spec_rounds=rounds,
        draft_proposed=proposed,
        draft_accepted=sum(st.draft_accepted for st in per_replica),
        acceptance_rate=(sum(st.draft_accepted for st in per_replica)
                         / proposed if proposed else 0.0),
        tokens_per_round=(committed / rounds if rounds else 0.0),
        pool_pages_total=sum(st.pool_pages_total for st in per_replica),
        pool_pages_peak=sum(st.pool_pages_peak for st in per_replica),
        pool_page_size=max((st.pool_page_size for st in per_replica),
                           default=0),
        prefix_hits=sum(st.prefix_hits for st in per_replica),
        prefix_hit_tokens=sum(st.prefix_hit_tokens for st in per_replica),
        cow_copies=sum(st.cow_copies for st in per_replica),
        kv_bytes_peak=sum(st.kv_bytes_peak for st in per_replica),
        tuned=per_replica[0].tuned if per_replica else "untuned",
        watchdog_trips=sum(st.watchdog_trips for st in per_replica),
        degraded_steps=sum(st.degraded_steps for st in per_replica),
        degrade_transitions=sum(st.degrade_transitions
                                for st in per_replica),
        kv_tier_steps=_sum_tiers([st.kv_tier_steps for st in per_replica]))


def _sum_tiers(tiers: list) -> tuple:
    """Elementwise sum of per-replica tier-step histograms (ragged: a
    replica that never degraded reports fewer tiers)."""
    width = max((len(t) for t in tiers), default=0)
    return tuple(sum(t[i] for t in tiers if i < len(t))
                 for i in range(width))
