"""Request lifecycle for the continuous-batching engine.

A request moves queued -> assigned (slot) -> finished. The scheduler is
pure host-side bookkeeping — all tensor state lives in
``serving.batch.DecodeState``; the engine consults the scheduler between
decode chunks to admit ready requests into freed slots and to harvest
finished ones. Time is measured in decode steps (the engine's clock
advances by ``chunk`` per jitted chunk), so ``arrival_step`` simulates a
request stream without wall-clock dependence.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int
    arrival_step: int = 0         # decode-step clock at which it may be admitted
    frames: Optional[np.ndarray] = None  # (S_enc, D) encoder frames (enc-dec)
    # per-request sampling controls (serving/sampling.py) — traced by the
    # engine, so mixing them in one stream never recompiles the chunk fn
    temperature: Optional[float] = None  # None: use serve()'s default
    top_k: int = 0                       # 0: disabled
    top_p: float = 1.0                   # >= 1: disabled


@dataclasses.dataclass
class RequestOutput:
    rid: int
    tokens: np.ndarray            # (P + generated,) int32
    prompt_len: int
    logprobs: np.ndarray          # (generated,) f32 chosen-token logprobs
    finish_reason: str            # "eos" | "length"
    admitted_step: int
    finished_step: int
    # wall-clock latency (chunk-granular: the engine marks the first chunk
    # whose harvest shows generated tokens; None when never marked)
    ttft_s: Optional[float] = None       # admission -> first generated token
    tpot_s: Optional[float] = None       # per-token after the first

    @property
    def generated(self) -> np.ndarray:
        return self.tokens[self.prompt_len:]


class Scheduler:
    """Admission queue + slot table over a fixed number of decode slots."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self._queue: list[tuple[int, int, Request]] = []  # (arrival, rid, req)
        self._slots: list[Optional[Request]] = [None] * num_slots
        self._admitted_step: dict[int, int] = {}
        self._admitted_wall: dict[int, float] = {}
        self._first_token_wall: dict[int, float] = {}
        self.finished: list[RequestOutput] = []

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        heapq.heappush(self._queue, (req.arrival_step, req.rid, req))

    def next_ready(self, clock: int) -> Optional[Request]:
        """Pop the earliest queued request that has arrived by ``clock``."""
        if self._queue and self._queue[0][0] <= clock:
            return heapq.heappop(self._queue)[2]
        return None

    def next_arrival(self) -> Optional[int]:
        return self._queue[0][0] if self._queue else None

    def requeue(self, req: Request) -> None:
        """Push a dequeued request back (admission backpressure — e.g. the
        paged pool cannot supply its pages until a slot drains)."""
        heapq.heappush(self._queue, (req.arrival_step, req.rid, req))

    # -- slots --------------------------------------------------------------
    def assign(self, slot: int, req: Request, clock: int,
               wall: Optional[float] = None) -> None:
        """``wall`` lets the engine start the TTFT clock when the request
        is DEQUEUED (before its prefill), not when the slot is filled —
        otherwise prefill time (and the prefix-cache's skipping of it)
        would be invisible in ttft_s."""
        assert self._slots[slot] is None, f"slot {slot} busy"
        self._slots[slot] = req
        self._admitted_step[req.rid] = clock
        self._admitted_wall[req.rid] = (time.perf_counter()
                                        if wall is None else wall)

    def mark_first_token(self, slot: int, t: float) -> None:
        """Record the wall time of the first chunk whose harvest shows
        generated tokens for ``slot`` (TTFT attribution; idempotent)."""
        req = self._slots[slot]
        if req is not None and req.rid not in self._first_token_wall:
            self._first_token_wall[req.rid] = t

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self._slots) if r is None]

    def active_slots(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self._slots) if r is not None]

    def complete(self, slot: int, tokens: np.ndarray, logprobs: np.ndarray,
                 finish_reason: str, clock: int) -> RequestOutput:
        req = self._slots[slot]
        assert req is not None
        self._slots[slot] = None
        admit_wall = self._admitted_wall.pop(req.rid, None)
        first_wall = self._first_token_wall.pop(req.rid, None)
        ttft = tpot = None
        if admit_wall is not None and first_wall is not None:
            ttft = first_wall - admit_wall
            n_after_first = len(tokens) - len(req.prompt) - 1
            if n_after_first > 0:   # single-token outputs have no tpot
                tpot = (time.perf_counter() - first_wall) / n_after_first
        out = RequestOutput(
            rid=req.rid, tokens=tokens, prompt_len=len(req.prompt),
            logprobs=logprobs, finish_reason=finish_reason,
            admitted_step=self._admitted_step.pop(req.rid),
            finished_step=clock, ttft_s=ttft, tpot_s=tpot)
        self.finished.append(out)
        return out

    # -- progress -----------------------------------------------------------
    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self._slots)

    @property
    def num_pending(self) -> int:
        return len(self._queue)

    def all_done(self) -> bool:
        return not self._queue and self.num_active == 0


def synthetic_stream(num_requests: int, *, vocab_size: int, prompt_len: int,
                     max_new_tokens: int, arrival_rate: float = 0.0,
                     seed: int = 0) -> list[Request]:
    """Deterministic request stream for benchmarks and tests.

    ``arrival_rate`` is requests per decode step; 0 means all requests are
    available at step 0 (pure batch drain). Generated lengths vary +-25%
    around ``max_new_tokens`` so slots free up at different times and
    mid-run admission is exercised.
    """
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(num_requests):
        prompt = rng.randint(0, vocab_size, size=(prompt_len,)).astype(np.int32)
        lo = max(1, int(max_new_tokens * 0.75))
        hi = max(lo + 1, int(max_new_tokens * 1.25) + 1)
        arrival = 0 if arrival_rate <= 0 else int(i / arrival_rate)
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=int(rng.randint(lo, hi)),
                            arrival_step=arrival))
    return reqs
