"""Request lifecycle for the continuous-batching engine.

A request moves queued -> ready -> (reserved) -> assigned (slot) ->
finished. The scheduler is pure host-side bookkeeping — all tensor state
lives in ``serving.batch.DecodeState``; the engine consults the scheduler
between decode chunks to admit ready requests into freed slots and to
harvest finished ones. Time is measured in decode steps (the engine's
clock advances by ``chunk`` per jitted chunk), so ``arrival_step``
simulates a request stream without wall-clock dependence.

SLO-aware scheduling (docs/DESIGN.md §14): the queue is priority-ordered
(two heaps — future arrivals by arrival step, ready requests by
``(priority, arrival, submit order)``), requests carry optional queue
timeouts / absolute deadlines / cancellation points, and a running
request can be PREEMPTED (restart-style: its slot and pages are released,
the request re-enters the ready queue and prefills again on its next
admission). Queueing delay (ready -> dequeue) is tracked separately from
TTFT (dequeue -> first token): a request that waits ten chunks for a slot
but prefills instantly has a large queue delay and a small TTFT.

The *reserved* state backs chunked prefill interleaving
(serving/session.py): a slot whose request is still prefilling chunk by
chunk holds the slot but is not yet decoding, so it must not count as an
active slot (its DecodeState row still says done) nor be harvested.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Optional

import numpy as np

from repro import obs


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Service-level-objective knobs for the serve loop (policy lives in
    serving/session.py; this is just the declaration).

    ``ttft_target_s``: admission is never deferred for a request that has
    already queued longer than this (late requests jump the TPOT gate).
    ``tpot_target_s``: defer admitting NEW work while the measured
    per-token latency of running slots (rolling mean over the last
    ``admit_window`` decode chunks) exceeds this — running requests drain
    first, then admissions resume. Priority-0 requests are never gated.
    ``preempt``: allow a strictly-higher-priority waiter to evict a
    running lower-priority slot (restart-style; pages released through
    ``PoolSession``, request requeued leak-free).
    """
    ttft_target_s: Optional[float] = None
    tpot_target_s: Optional[float] = None
    preempt: bool = False
    admit_window: int = 8


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int
    arrival_step: int = 0         # decode-step clock at which it may be admitted
    frames: Optional[np.ndarray] = None  # (S_enc, D) encoder frames (enc-dec)
    # per-request sampling controls (serving/sampling.py) — traced by the
    # engine, so mixing them in one stream never recompiles the chunk fn
    temperature: Optional[float] = None  # None: use serve()'s default
    top_k: int = 0                       # 0: disabled
    top_p: float = 1.0                   # >= 1: disabled
    # SLO attributes (docs/DESIGN.md §14)
    priority: int = 1                    # 0 = most urgent; ties break FIFO
    queue_timeout_steps: Optional[int] = None  # drop if not admitted by then
    deadline_steps: Optional[int] = None       # abort (even running) after
                                               # arrival + deadline steps
    cancel_at_step: Optional[int] = None       # simulated client cancel


@dataclasses.dataclass
class RequestOutput:
    rid: int
    tokens: np.ndarray            # (P + generated,) int32
    prompt_len: int
    logprobs: np.ndarray          # (generated,) f32 chosen-token logprobs
    finish_reason: str            # "eos" | "length" | "timeout" |
                                  # "cancelled" | "deadline"
    admitted_step: int            # -1: dropped before ever holding a slot
    finished_step: int
    # wall-clock latency (chunk-granular: the engine marks the first chunk
    # whose harvest shows generated tokens; None when never marked)
    ttft_s: Optional[float] = None       # dequeue -> first generated token
    tpot_s: Optional[float] = None       # per-token after the first
    # queueing delay, reported separately from TTFT: ready -> dequeue
    queue_delay_s: Optional[float] = None
    queue_delay_steps: Optional[int] = None
    priority: int = 1
    preempted: int = 0            # times this request lost its slot

    @property
    def generated(self) -> np.ndarray:
        return self.tokens[self.prompt_len:]


class Scheduler:
    """Priority admission queue + slot table over fixed decode slots."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        # trace pid (docs/DESIGN.md §16): the owning session stamps its
        # replica id so request-lifecycle spans land on the right process
        self.pid = 0
        # future arrivals, by simulated arrival step
        self._arrivals: list[tuple[int, int, Request]] = []
        # arrived and admissible, by (priority, arrival, fifo seq)
        self._ready: list[tuple[int, int, int, Request]] = []
        self._seq = 0
        self._slots: list[Optional[Request]] = [None] * num_slots  # decoding
        self._reserved: dict[int, Request] = {}                    # prefilling
        self._cancelled: set[int] = set()
        self._ready_wall: dict[int, float] = {}
        self._admitted_step: dict[int, int] = {}
        self._admitted_wall: dict[int, float] = {}
        self._first_token_wall: dict[int, float] = {}
        self._queue_delay: dict[int, tuple[int, Optional[float]]] = {}
        self._preempt_count: dict[int, int] = {}
        self.finished: list[RequestOutput] = []
        self.preemptions = 0
        self.timeouts = 0
        self.cancels = 0

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        heapq.heappush(self._arrivals, (req.arrival_step, req.rid, req))

    def cancel(self, rid: int) -> None:
        """Client-side cancellation: takes effect at the next tick whether
        the request is queued, prefilling, or decoding."""
        self._cancelled.add(rid)

    def poll(self, clock: int, wall: Optional[float] = None) -> None:
        """Move requests whose arrival step has come into the ready queue
        (recording the wall time the queue-delay clock starts from)."""
        wall = time.perf_counter() if wall is None else wall
        while self._arrivals and self._arrivals[0][0] <= clock:
            _, rid, req = heapq.heappop(self._arrivals)
            self._push_ready(req)
            self._ready_wall.setdefault(rid, wall)

    def _push_ready(self, req: Request) -> None:
        self._seq += 1
        heapq.heappush(self._ready,
                       (req.priority, req.arrival_step, self._seq, req))
        # every path into the ready queue (arrival, requeue, preemption,
        # failed insert) opens/reopens the request's "queued" span
        obs.request_phase(self.pid, req.rid, "queued",
                          args={"priority": req.priority})

    def drop_reason(self, req: Request, clock: int,
                    queued: bool = False) -> Optional[str]:
        """Why ``req`` must stop now (None: keep going). Queue timeouts
        only apply while queued; deadlines and cancellation always do."""
        if (req.rid in self._cancelled
                or (req.cancel_at_step is not None
                    and clock >= req.cancel_at_step)):
            return "cancelled"
        if (req.deadline_steps is not None
                and clock - req.arrival_step >= req.deadline_steps):
            return "deadline"
        if (queued and req.queue_timeout_steps is not None
                and clock - req.arrival_step >= req.queue_timeout_steps):
            return "timeout"
        return None

    def expire(self, clock: int) -> None:
        """Finalize queued requests that timed out / were cancelled / can
        no longer meet their deadline — they leave the queue without ever
        holding a slot."""
        kept = []
        for pri, arr, seq, req in self._ready:
            reason = self.drop_reason(req, clock, queued=True)
            if reason is None:
                kept.append((pri, arr, seq, req))
            else:
                self._finish_unadmitted(req, reason, clock)
        if len(kept) != len(self._ready):
            heapq.heapify(kept)
            self._ready = kept
        kept_a = []
        for a, r, q in self._arrivals:
            reason = self.drop_reason(q, clock, queued=True)
            if reason is None:
                kept_a.append((a, r, q))
            else:
                self._finish_unadmitted(q, reason, clock)
        if len(kept_a) != len(self._arrivals):
            heapq.heapify(kept_a)
            self._arrivals = kept_a

    def _finish_unadmitted(self, req: Request, reason: str,
                           clock: int) -> None:
        obs.request_done(self.pid, req.rid, "finish",
                         args={"reason": reason})
        self._count_drop(reason)
        self._ready_wall.pop(req.rid, None)
        self.finished.append(RequestOutput(
            rid=req.rid, tokens=np.asarray(req.prompt, np.int32),
            prompt_len=len(req.prompt),
            logprobs=np.zeros((0,), np.float32), finish_reason=reason,
            admitted_step=-1, finished_step=clock,
            queue_delay_s=None, queue_delay_steps=clock - req.arrival_step,
            priority=req.priority,
            preempted=self._preempt_count.pop(req.rid, 0)))

    def _count_drop(self, reason: str) -> None:
        if reason == "cancelled":
            self.cancels += 1
        elif reason == "timeout":
            self.timeouts += 1

    def next_ready(self, clock: int) -> Optional[Request]:
        """Pop the highest-priority ready request (FIFO within a class),
        finalizing any expired entries encountered on the way."""
        self.poll(clock)
        while self._ready:
            req = heapq.heappop(self._ready)[3]
            reason = self.drop_reason(req, clock, queued=True)
            if reason is not None:
                self._finish_unadmitted(req, reason, clock)
                continue
            return req
        return None

    def peek_ready(self, clock: int) -> Optional[Request]:
        """Highest-priority ready request without dequeuing it (the SLO
        admission gate inspects priority and queueing age)."""
        self.poll(clock)
        while self._ready:
            req = self._ready[0][3]
            reason = self.drop_reason(req, clock, queued=True)
            if reason is None:
                return req
            heapq.heappop(self._ready)
            self._finish_unadmitted(req, reason, clock)
        return None

    def ready_wall(self, rid: int) -> Optional[float]:
        return self._ready_wall.get(rid)

    def next_arrival(self) -> Optional[int]:
        """Earliest pending arrival step; ready requests count as already
        arrived (step 0 effectively)."""
        if self._ready:
            return self._ready[0][1]
        return self._arrivals[0][0] if self._arrivals else None

    def requeue(self, req: Request) -> None:
        """Push a dequeued request back (admission backpressure — e.g. the
        paged pool cannot supply its pages until a slot drains). The
        queue-delay clock keeps running from the original ready time."""
        self._push_ready(req)

    # -- slots --------------------------------------------------------------
    def reserve(self, slot: int, req: Request, clock: int,
                wall: Optional[float] = None) -> None:
        """Dequeue ``req`` into ``slot`` for (possibly chunked) prefill.
        The queue-delay clock stops here; the TTFT clock starts here —
        ``wall`` lets the engine stamp the dequeue time BEFORE prefill so
        prefill cost (and the prefix cache skipping it) shows in ttft_s."""
        assert self._slots[slot] is None and slot not in self._reserved, \
            f"slot {slot} busy"
        wall = time.perf_counter() if wall is None else wall
        obs.request_phase(self.pid, req.rid, "prefill",
                          args={"slot": slot})
        self._reserved[slot] = req
        self._admitted_step[req.rid] = clock
        self._admitted_wall[req.rid] = wall
        ready_wall = self._ready_wall.pop(req.rid, None)
        self._queue_delay[req.rid] = (
            clock - req.arrival_step,
            None if ready_wall is None else max(0.0, wall - ready_wall))

    def activate(self, slot: int) -> None:
        """Prefill finished and the request was inserted: the slot starts
        decoding (counts toward occupancy, eligible for harvest)."""
        req = self._reserved.pop(slot)
        assert self._slots[slot] is None, f"slot {slot} busy"
        self._slots[slot] = req
        obs.request_phase(self.pid, req.rid, "decode",
                          args={"slot": slot})

    def assign(self, slot: int, req: Request, clock: int,
               wall: Optional[float] = None) -> None:
        """Monolithic admission: reserve + activate in one step."""
        self.reserve(slot, req, clock, wall=wall)
        self.activate(slot)

    def unreserve(self, slot: int, requeue: bool = True) -> Request:
        """Abandon a reservation (e.g. the pool could not supply pages at
        insert time): the request re-enters the ready queue with its
        original queue-delay clock, nothing is recorded."""
        req = self._reserved.pop(slot)
        self._admitted_step.pop(req.rid, None)
        wall = self._admitted_wall.pop(req.rid, None)
        delay = self._queue_delay.pop(req.rid, None)
        if requeue:
            # restore the ready-time so the eventual admission reports the
            # full wait, not just the tail after this failed attempt
            if delay is not None and delay[1] is not None and wall is not None:
                self._ready_wall[req.rid] = wall - delay[1]
            self._push_ready(req)  # reopens the queued span
        else:
            obs.request_done(self.pid, req.rid, "finish",
                             args={"reason": "unreserved"})
        return req

    def reserved_slots(self) -> list[tuple[int, Request]]:
        return sorted(self._reserved.items())

    def reserved_request(self, slot: int) -> Request:
        return self._reserved[slot]

    def drop_reserved(self, slot: int, reason: str, clock: int) -> Request:
        """A prefilling request was cancelled / deadlined: finalize it
        with no generated tokens (the caller unpins any prefix match)."""
        req = self._reserved.pop(slot)
        obs.request_done(self.pid, req.rid, "finish",
                         args={"reason": reason})
        self._count_drop(reason)
        delay = self._queue_delay.pop(req.rid, (None, None))
        self.finished.append(RequestOutput(
            rid=req.rid, tokens=np.asarray(req.prompt, np.int32),
            prompt_len=len(req.prompt),
            logprobs=np.zeros((0,), np.float32), finish_reason=reason,
            admitted_step=self._admitted_step.pop(req.rid, -1),
            finished_step=clock,
            queue_delay_s=delay[1], queue_delay_steps=delay[0],
            priority=req.priority,
            preempted=self._preempt_count.pop(req.rid, 0)))
        self._admitted_wall.pop(req.rid, None)
        return req

    def preempt(self, slot: int) -> Request:
        """Evict a DECODING request (restart-style): it loses its slot and
        all progress, re-enters the ready queue at its own priority, and
        will prefill from scratch when re-admitted. The caller releases
        the slot's tensor/pool state."""
        req = self._slots[slot]
        assert req is not None, f"slot {slot} empty"
        self._slots[slot] = None
        self._admitted_step.pop(req.rid, None)
        self._admitted_wall.pop(req.rid, None)
        self._first_token_wall.pop(req.rid, None)
        delay = self._queue_delay.pop(req.rid, None)
        # the next admission's queue delay spans the preemption wait too
        if delay is not None and delay[1] is not None:
            self._ready_wall[req.rid] = time.perf_counter()
        self._preempt_count[req.rid] = self._preempt_count.get(req.rid, 0) + 1
        self.preemptions += 1
        obs.request_done(self.pid, req.rid, "preempt",
                         args={"slot": slot})
        self._push_ready(req)      # reopens the queued span
        return req

    def preempt_victim(self, priority: int) -> Optional[int]:
        """Slot to evict for a waiter at ``priority``: the lowest-priority
        decoding slot strictly below it; ties prefer the most recently
        admitted (least progress lost). None when no slot qualifies."""
        best = None
        for i, req in enumerate(self._slots):
            if req is None or req.priority <= priority:
                continue
            key = (req.priority, self._admitted_step.get(req.rid, 0), i)
            if best is None or key > best[0]:
                best = (key, i)
        return None if best is None else best[1]

    def mark_first_token(self, slot: int, t: float) -> None:
        """Record the wall time of the first chunk whose harvest shows
        generated tokens for ``slot`` (TTFT attribution; idempotent)."""
        req = self._slots[slot]
        if req is not None and req.rid not in self._first_token_wall:
            self._first_token_wall[req.rid] = t

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self._slots)
                if r is None and i not in self._reserved]

    def active_slots(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self._slots) if r is not None]

    def complete(self, slot: int, tokens: np.ndarray, logprobs: np.ndarray,
                 finish_reason: str, clock: int) -> RequestOutput:
        req = self._slots[slot]
        assert req is not None
        self._slots[slot] = None
        obs.request_done(self.pid, req.rid, "finish",
                         args={"reason": finish_reason})
        if finish_reason in ("cancelled", "timeout", "deadline"):
            self._count_drop(finish_reason)
        admit_wall = self._admitted_wall.pop(req.rid, None)
        first_wall = self._first_token_wall.pop(req.rid, None)
        ttft = tpot = None
        if admit_wall is not None and first_wall is not None:
            ttft = first_wall - admit_wall
            n_after_first = len(tokens) - len(req.prompt) - 1
            if n_after_first > 0:   # single-token outputs have no tpot
                tpot = (time.perf_counter() - first_wall) / n_after_first
        delay = self._queue_delay.pop(req.rid, (None, None))
        out = RequestOutput(
            rid=req.rid, tokens=tokens, prompt_len=len(req.prompt),
            logprobs=logprobs, finish_reason=finish_reason,
            admitted_step=self._admitted_step.pop(req.rid),
            finished_step=clock, ttft_s=ttft, tpot_s=tpot,
            queue_delay_s=delay[1], queue_delay_steps=delay[0],
            priority=req.priority,
            preempted=self._preempt_count.pop(req.rid, 0))
        self.finished.append(out)
        return out

    # -- progress -----------------------------------------------------------
    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self._slots)

    @property
    def num_reserved(self) -> int:
        return len(self._reserved)

    @property
    def num_pending(self) -> int:
        return len(self._arrivals) + len(self._ready)

    def all_done(self) -> bool:
        return (not self._arrivals and not self._ready
                and self.num_active == 0 and not self._reserved)

    def drain_unfinished(self) -> list[Request]:
        """Pull every unfinished request — queued, ready, prefilling or
        decoding — out of the scheduler, clearing its bookkeeping. The
        failover path (serving/replica.py, DESIGN.md §15) re-drives the
        result onto a surviving replica: each request re-prefills from
        its original prompt there, so its greedy tokens are unchanged.
        Finished outputs stay."""
        out = [req for _, _, req in self._arrivals]
        out += [req for _, _, _, req in self._ready]
        out += list(self._reserved.values())
        out += [req for req in self._slots if req is not None]
        self._arrivals = []
        self._ready = []
        self._reserved.clear()
        self._slots = [None] * self.num_slots
        for req in out:
            # closes whatever phase span is open; re-drive opens a fresh
            # queued span on the surviving replica's pid
            obs.request_done(self.pid, req.rid, "redrive")
        for req in out:
            for d in (self._ready_wall, self._admitted_step,
                      self._admitted_wall, self._first_token_wall,
                      self._queue_delay):
                d.pop(req.rid, None)
        return sorted(out, key=lambda r: r.rid)


def synthetic_stream(num_requests: int, *, vocab_size: int, prompt_len: int,
                     max_new_tokens: int, arrival_rate: float = 0.0,
                     seed: int = 0, poisson: bool = False,
                     priorities=None) -> list[Request]:
    """Deterministic request stream for benchmarks and tests.

    ``arrival_rate`` is requests per decode step; 0 means all requests are
    available at step 0 (pure batch drain). ``poisson=True`` draws seeded
    exponential inter-arrival gaps with mean ``1/arrival_rate`` instead of
    the fixed spacing — the open-loop load model (docs/DESIGN.md §14):
    arrivals do not wait for completions, so queueing delay grows without
    bound past the saturation rate. ``priorities`` (optional) is cycled
    over the stream (e.g. ``(0, 1, 1, 1)`` for 25% interactive traffic).
    Generated lengths vary +-25% around ``max_new_tokens`` so slots free
    up at different times and mid-run admission is exercised.
    """
    rng = np.random.RandomState(seed)
    reqs = []
    t = 0.0
    for i in range(num_requests):
        prompt = rng.randint(0, vocab_size, size=(prompt_len,)).astype(np.int32)
        lo = max(1, int(max_new_tokens * 0.75))
        hi = max(lo + 1, int(max_new_tokens * 1.25) + 1)
        if arrival_rate <= 0:
            arrival = 0
        elif poisson:
            t += rng.exponential(1.0 / arrival_rate) if i > 0 else 0.0
            arrival = int(t)
        else:
            arrival = int(i / arrival_rate)
        pri = 1 if priorities is None else int(priorities[i % len(priorities)])
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=int(rng.randint(lo, hi)),
                            arrival_step=arrival, priority=pri))
    return reqs
