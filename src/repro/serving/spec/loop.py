"""Self-speculative decode loop: draft-propose / target-verify rounds.

One spec round, entirely on device (no host round-trips mid-chunk; the
engine scans ``chunk`` rounds inside ONE jitted call):

1. **Propose** — the all-int4 draft (shares payloads with the target for
   already-aggressive blocks) runs K single-token decode steps starting
   from each slot's *pending* token, sampling K proposals from its masked
   distribution q. The draft reads and writes a throwaway functional copy
   of the SAME cache — its writes are discarded, so no draft-side KV
   memory, no draft prefill, no cache-sync protocol. (With
   ``draft_source="ngram"`` the proposals instead come from prompt
   lookup — no draft model runs at all; q becomes the one-hot of the
   copied tokens.)
2. **Verify** — the target scores the (K+1)-token window
   ``[pending, x_1..x_K]`` in one multi-query decode pass
   (``Model.spec_verify`` — fused causal-offset attention for
   transformer/enc-dec; a checkpointing scan for SSM/hybrid), yielding the
   target distribution p_i for every draft position plus the bonus
   position.
3. **Accept** — greedy slots accept the longest prefix with
   ``x_i == argmax p_i`` (token-identical to the non-spec engine by
   construction); sampling slots run standard speculative rejection
   sampling (accept w.p. min(1, p_i(x)/q_i(x)); on first rejection
   resample from the normalized residual ``max(p - q, 0)``; bonus token
   from p_{K+1} when everything is accepted). Each live slot commits
   between 1 and K+1 tokens per round — never fewer than the baseline.
4. **Rollback/commit** — ``Model.spec_commit`` moves each slot's
   ``cache_pos`` to its committed length (rows past it stay in memory,
   masked invalid — position arithmetic over raw or quantized KVPages)
   and selects the per-slot SSM state snapshot where the family carries
   sequential summaries.

Invariant between rounds (per slot): ``cache_pos == lengths - 1`` and the
*pending* token ``tokens[lengths - 1]`` — the newest committed token —
has no cache row yet; the next round's verify writes it. Admission is
EXACTLY the baseline's (full-prompt prefill, ``cache_pos == lengths``):
such *fresh* slots have no row gap but no post-pending distribution
either, so their first round takes the candidate-0 distribution from the
slot's ``last_logits`` (the bf16 prefill logits — bit-identical to what
the baseline samples its first token from, which is what makes greedy
parity EXACT even over a quantized KV cache) and verifies the window
``[x_1..x_K]`` instead of ``[pending, x_1..x_K]``. Freshness is derived,
not stored: ``cache_pos == lengths`` iff the slot was admitted and has
not committed a spec round yet.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.serving import batch as B
from repro.serving.sampling import masked_dist, sample

NEG_INF = -1e30
_TINY = 1e-38


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Self-speculative serving knobs.

    ``k`` — draft tokens proposed per round (the verify window is k+1
    positions wide). ``draft_group`` — quantization group for the
    draft-only int4 copies of raw/int8 blocks. ``fused_propose`` — run the
    draft through the read-only fused propose path (zero draft-side cache
    writes, docs/DESIGN.md §12) on families that support it; the two-pass
    throwaway-cache propose is the fallback (and the parity oracle).
    ``draft_layers`` — truncate the draft to the first N layers (early-exit
    drafting; verification keeps greedy output exact regardless of draft
    quality). Requires ``fused_propose`` and a dense/MoE family.
    ``draft_source`` — "model" runs the int4 self-draft; "ngram" proposes
    by prompt lookup (match the context's trailing bigram, copy the k
    tokens that followed it): zero draft-side model calls, so a round
    costs ~one fused multi-query verify step — the regime where spec pays
    off even on a FLOPs-bound backend. Verification is identical either
    way, so greedy output never depends on the draft source."""
    k: int = 4
    draft_group: int = 128
    fused_propose: bool = True
    draft_layers: int | None = None
    draft_source: str = "model"

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"spec k must be >= 1, got {self.k}")
        if self.draft_source not in ("model", "ngram"):
            raise ValueError(f"draft_source must be 'model' or 'ngram', "
                             f"got {self.draft_source!r}")
        if self.draft_source == "ngram" and self.draft_layers is not None:
            raise ValueError("draft_layers only applies to the model "
                             "draft; the ngram draft runs no model")
        if self.draft_layers is not None:
            if self.draft_layers < 1:
                raise ValueError(f"draft_layers must be >= 1, got "
                                 f"{self.draft_layers}")
            if not self.fused_propose:
                raise ValueError(
                    "draft_layers needs fused_propose=True: the two-pass "
                    "propose runs the draft through decode_step, whose "
                    "cache segmentation must match the full target stack")


def obs_labels(cfg: SpecConfig) -> dict:
    """Metric labels for the spec counters (obs/serve_metrics.py): the
    two knobs that change the acceptance/throughput trade-off."""
    return {"k": str(cfg.k), "source": cfg.draft_source}


class SpecMetrics(NamedTuple):
    """Per-chunk device-side counters (summed over rounds and slots)."""
    proposed: jax.Array    # draft tokens proposed to live slots
    accepted: jax.Array    # draft tokens verified AND committed
    committed: jax.Array   # tokens committed (incl. bonus/correction)
    rounds: jax.Array      # rounds with at least one live slot

    @staticmethod
    def zeros() -> "SpecMetrics":
        z = jnp.zeros((), jnp.int32)
        return SpecMetrics(z, z, z, z)


def spec_round(model, params, draft_params, state: B.DecodeState, k: int,
               eos_id, *, fused_propose: bool = False,
               draft_source: str = "model"
               ) -> tuple[B.DecodeState, SpecMetrics]:
    """One draft-propose / target-verify / accept / rollback round."""
    vocab = model.cfg.vocab_size
    b = state.num_slots
    live = state.active & ~state.done
    # fresh = just admitted (baseline-style full prefill): no pending row
    # gap, candidate-0 dist comes from the slot's prefill last_logits
    fresh = state.cache.pos == state.lengths
    pend_idx = jnp.clip(state.lengths - 1, 0, None)
    pending = jnp.take_along_axis(state.tokens, pend_idx[:, None], 1)[:, 0]
    key, pkey, ukey, zkey = jax.random.split(state.key, 4)

    # -- 1) draft propose: K single-token steps ----------------------------
    # (fresh slots process their last prompt token once more, at pos ==
    # lengths — a slightly stale q on the admission round only; q is the
    # proposal distribution, so this affects acceptance, never correctness)
    def draft_dist(logits):
        lp = jax.nn.log_softmax(
            logits[:, 0, :vocab].astype(jnp.float32), -1)
        return masked_dist(lp, state.temperature, state.top_k, state.top_p)

    if draft_source == "ngram":
        # prompt-lookup propose: match the trailing bigram [prev, pending]
        # against earlier committed context and copy the k tokens that
        # followed the latest match. The proposal is a contiguous slice of
        # tokens that already exist — no sequential draft dependency, no
        # model call — so the whole round costs ~one multi-query verify.
        # q is the one-hot of the copied tokens: stochastic slots accept
        # x_i w.p. p_i(x_i) and resample from clip(p - onehot, 0) on
        # rejection — exact speculative sampling with a deterministic q.
        toks = state.tokens
        L = toks.shape[1]
        prev_idx = jnp.clip(state.lengths - 2, 0, None)
        prev = jnp.take_along_axis(toks, prev_idx[:, None], 1)[:, 0]
        pos = jnp.arange(L)[None, :]
        shifted = jnp.concatenate([toks[:, :1], toks[:, :-1]], axis=1)
        hit = ((toks == pending[:, None]) & (shifted == prev[:, None])
               & (pos >= 1) & (pos < (state.lengths - 1)[:, None]))
        j = jnp.max(jnp.where(hit, pos, -1), axis=1)       # (B,) -1 = miss
        src = j[:, None] + 1 + jnp.arange(k)[None, :]      # (B, K)
        x = jnp.take_along_axis(toks, jnp.clip(src, 0, L - 1), 1)
        # miss, or the match runs off the committed context: fall back to
        # re-proposing the pending token — verification rejects a bad
        # proposal for free and the round still commits >= 1 token
        valid = (j[:, None] >= 0) & (src < state.lengths[:, None])
        x = jnp.where(valid, x, pending[:, None]).astype(jnp.int32)
        q_bt = jnp.where(jax.nn.one_hot(x, vocab, dtype=bool),
                         0.0, NEG_INF).astype(jnp.float32)
    elif fused_propose:
        # fused path (docs/DESIGN.md §12): the draft reads the cache and
        # writes each step's k/v into small raw side buffers swept by the
        # SAME online softmax — no throwaway cache copy, no k*L
        # quantize-and-scatter writes. The buffers span the draft's layer
        # count, which may be a truncated prefix of the target's.
        from repro.models.common import dtype_of
        from repro.quant.apply import segment_slices
        cfg = model.cfg
        n_draft = segment_slices(draft_params["layers"])[-1][2]
        buf_shape = (n_draft, b, k, cfg.num_kv_heads, cfg.head_dim)
        fk0 = jnp.zeros(buf_shape, dtype_of(cfg))
        fv0 = jnp.zeros(buf_shape, dtype_of(cfg))

        def propose_body(carry, sub):
            fk, fv, cnt, tok = carry
            logits, fk, fv = model.draft_propose_step(
                draft_params, state.cache, fk, fv, cnt, tok[:, None])
            q = draft_dist(logits)
            nxt = sample(sub, q, state.temperature)
            return (fk, fv, cnt + 1, nxt), (nxt, q)

        _, (xs, qlps) = jax.lax.scan(
            propose_body, (fk0, fv0, jnp.int32(0), pending),
            jax.random.split(pkey, k))
    else:
        def propose_body(carry, sub):
            dcache, tok = carry
            logits, dcache = model.decode_step(draft_params, dcache,
                                               tok[:, None])
            q = draft_dist(logits)
            nxt = sample(sub, q, state.temperature)
            return (dcache, nxt), (nxt, q)

        _, (xs, qlps) = jax.lax.scan(propose_body, (state.cache, pending),
                                     jax.random.split(pkey, k))
    if draft_source != "ngram":
        x = xs.T                                          # (B, K)
        q_bt = jnp.moveaxis(qlps, 0, 1)                   # (B, K, V)

    # -- 2) target verify: one multi-query pass over the window ------------
    # stale slots rewrite their pending row first; fresh slots start at x_1
    # (their trailing window slot is a duplicate whose row/dist are unused)
    stale_q = jnp.concatenate([pending[:, None], x], axis=1)
    fresh_q = jnp.concatenate([x, x[:, -1:]], axis=1)
    qtoks = jnp.where(fresh[:, None], fresh_q, stale_q).astype(jnp.int32)
    logits, snap = model.spec_verify(params, state.cache, qtoks)
    lv = jax.nn.log_softmax(
        logits[:, :, :vocab].astype(jnp.float32), -1)     # (B, K+1, V)
    # candidate-j dist: stale = after qtoks[j]; fresh = prefill last_logits
    # for j=0 (EXACTLY what the baseline samples its first token from —
    # greedy parity over quantized caches hinges on this), then after x_j
    lp0 = jax.nn.log_softmax(
        state.last_logits[:, :vocab].astype(jnp.float32), -1)
    lp_raw = jnp.where(
        fresh[:, None, None],
        jnp.concatenate([lp0[:, None], lv[:, :k]], axis=1), lv)
    p = masked_dist(lp_raw, state.temperature[:, None],
                    state.top_k[:, None], state.top_p[:, None])

    # -- 3) longest-prefix acceptance + rejection resampling --------------
    y = jnp.argmax(p, axis=-1).astype(jnp.int32)          # (B, K+1)
    px = jnp.take_along_axis(p[:, :k], x[..., None], -1)[..., 0]
    qx = jnp.take_along_axis(q_bt, x[..., None], -1)[..., 0]
    u = jax.random.uniform(ukey, x.shape)
    stoch_acc = jnp.log(jnp.maximum(u, _TINY)) < (px - qx)  # u < p/q
    greedy_acc = x == y[:, :k]
    acc = jnp.where((state.temperature > 0)[:, None], stoch_acc, greedy_acc)
    a = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)  # (B,)

    # correction (a < K) / bonus (a == K) token from the residual at a
    pa = jnp.take_along_axis(p, a[:, None, None], 1)[:, 0]          # (B, V)
    q_ext = jnp.concatenate(
        [q_bt, jnp.full((b, 1, vocab), NEG_INF, q_bt.dtype)], axis=1)
    qa = jnp.take_along_axis(q_ext, a[:, None, None], 1)[:, 0]
    resid = jnp.clip(jnp.exp(pa) - jnp.exp(qa), 0.0, None)
    rsum = jnp.sum(resid, axis=-1, keepdims=True)
    resid = jnp.where(rsum > 0, resid / jnp.maximum(rsum, _TINY),
                      jnp.exp(pa))
    z_st = jax.random.categorical(zkey, jnp.log(resid + _TINY), axis=-1)
    z_gr = jnp.take_along_axis(y, a[:, None], 1)[:, 0]
    z = jnp.where(state.temperature > 0, z_st, z_gr).astype(jnp.int32)

    # committed candidates: x_1..x_a then the correction/bonus z
    jidx = jnp.arange(k + 1)[None, :]
    x_pad = jnp.concatenate([x, x[:, -1:]], axis=1)
    cand = jnp.where(jidx == a[:, None], z[:, None], x_pad)
    # chosen-token logprobs under the UNMASKED target dist — exactly what
    # the baseline chunk loop records
    cand_lp = jnp.take_along_axis(lp_raw, cand[..., None], -1)[..., 0]

    # -- 4) commit count: acceptance, token budget, first EOS -------------
    budget = jnp.clip(state.max_len - state.lengths, 0, None)
    c = jnp.minimum(a + 1, budget)
    if eos_id is not None:
        is_eos = cand == eos_id
        eos_cut = jnp.where(is_eos.any(1),
                            jnp.argmax(is_eos, axis=1) + 1, k + 1)
        c = jnp.minimum(c, eos_cut)
    c = jnp.where(live, c, 0).astype(jnp.int32)

    state2 = B.commit_tokens(state, cand, cand_lp, c)
    done = state.done | (live & (state2.lengths >= state.max_len))
    if eos_id is not None:
        done = done | (live & (is_eos & (jidx < c[:, None])).any(1))

    # rows/state to keep: fresh slots never fed their pending token, so the
    # cache advances one row less than the commit count (the last committed
    # token becomes the next round's pending — invariant pos = lengths - 1)
    rows = jnp.maximum(c - fresh.astype(jnp.int32), 0)
    cache2 = model.spec_commit(snap, rows)
    state2 = state2._replace(cache=cache2, done=done, key=key)

    live32 = live.astype(jnp.int32)
    # draft tokens actually COMMITTED: the last committed candidate is the
    # correction/bonus (not a draft token) only when nothing cut the window
    # short (c == a+1); acceptance_rate therefore predicts the realized
    # bytes-per-token uplift, not the pre-truncation verifier verdicts
    drafts_committed = c - (c > a).astype(jnp.int32)
    metrics = SpecMetrics(
        proposed=jnp.sum(live32) * k,
        accepted=jnp.sum(jnp.where(live, drafts_committed, 0)),
        committed=jnp.sum(c),
        rounds=jnp.any(live).astype(jnp.int32))
    return state2, metrics


def make_spec_round(model, k: int, rounds: int, eos_id, mesh=None,
                    fused_propose: bool = False,
                    draft_source: str = "model"):
    """Build the body the engine jits: ``rounds`` spec rounds in one scan
    (per-slot rollback stays inside the scan — no host sync mid-chunk)."""

    def run(params, draft_params, state: B.DecodeState):
        def body(carry, _):
            st, m = carry
            st2, m2 = spec_round(model, params, draft_params, st, k, eos_id,
                                 fused_propose=fused_propose,
                                 draft_source=draft_source)
            return (st2, jax.tree.map(jnp.add, m, m2)), None

        (state, metrics), _ = jax.lax.scan(
            body, (state, SpecMetrics.zeros()), None, length=rounds)
        if mesh is not None:
            state = B.constrain_state(state, mesh)
        return state, metrics

    return run
