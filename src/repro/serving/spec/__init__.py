"""Entropy-guided self-speculative decoding (docs/DESIGN.md §11).

The quantized model drafts for itself: an entropy-ordered all-int4
variant of the served weights (quant/compiler.compile_draft_plan — blocks
the plan already pushed to int4 share payloads byte-for-byte) proposes K
tokens per round, and the mixed-precision target scores the whole window
in one fused multi-query decode pass, accepting the longest matching
prefix and rolling the KV cache back by pure position arithmetic.
"""

from repro.serving.spec.loop import (SpecConfig, SpecMetrics,
                                     make_spec_round, spec_round)

__all__ = ["SpecConfig", "SpecMetrics", "make_spec_round", "spec_round"]
