"""EWQ-quantized serving: plan application + dry-run input builders.

This is the paper's deployment story as a first-class serving feature:
weights are quantized per the EWQ/FastEWQ plan (block-granular mixed
precision), logits stay full quality for high-entropy blocks, and decode —
which is weight-bytes-bound — reads int8/int4 payloads instead of bf16.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.policy import BlockDecision, QuantPlan
from repro.models.model import Model
from repro.quant.compiler import compile_plan


def fastewq_metadata_plan(cfg: ModelConfig, variant: str = "8bit-mixed",
                          quant_fraction: float = 0.41) -> QuantPlan:
    """O(1) plan from architecture metadata only (no weights) — the FastEWQ
    deployment path. Mirrors the trained classifier's dominant feature
    (exec_index): the trailing ``quant_fraction`` of transformer blocks are
    selected, int8 by default; the final block drops to int4 under the
    "4bit/8bit" variant (paper §6.3). When the trained FastEWQ classifier
    is available (repro/core/fastewq.py) it replaces this closed form; the
    closed form equals the classifier's majority behavior on the paper's
    dataset and keeps the dry-run dependency-free.
    """
    blocks = []
    n_layers = cfg.num_layers + (cfg.num_encoder_layers or 0)
    extra = 1 if cfg.family == "hybrid" else 0
    total = 1 + n_layers + extra  # embedding block + layers (+ shared)
    n_quant = max(1, int(round(n_layers * quant_fraction)))
    first_quant = 1 + (n_layers - n_quant)
    for i in range(total):
        if i == 0:
            prec = "raw"  # embedding stays raw in the fast variants
        elif i >= first_quant and i <= n_layers:
            last = i == n_layers
            prec = ("int4" if (variant.startswith("4bit") and last)
                    else "int8")
        elif i > n_layers:  # hybrid shared block
            prec = "int8"
        else:
            prec = "raw"
        blocks.append(BlockDecision(block_index=i, exec_index=i + 1,
                                    entropy=float("nan"), num_parameters=0,
                                    precision=prec))
    return QuantPlan(decisions=blocks, mu=float("nan"), sigma=float("nan"),
                     threshold=float("nan"), x_factor=1.0)


def plan_for_variant(model: Model, params, variant: str,
                     fast: bool = False) -> Optional[QuantPlan]:
    """Variant string -> QuantPlan (None for "raw").

    ``fast`` selects the FastEWQ metadata-only path; otherwise the weights
    are entropy-analyzed (full EWQ). Shared by launch/serve.py, examples
    and benchmarks so they agree on the variant vocabulary.
    """
    if variant == "raw":
        return None
    if fast:
        return fastewq_metadata_plan(model.cfg, variant)
    from repro.core.planner import plan_model
    return plan_model(model, params, variant=variant)


def apply_plan_to_params(model: Model, params, plan: QuantPlan,
                         group: int = 128):
    """Quantize a model's params per an EWQ plan (block order matches
    Model.block_params: [embed] + layers [+ shared / enc+dec]).

    Thin wrapper over the family-universal plan compiler
    (quant/compiler.py, docs/DESIGN.md §8): every family — including hybrid
    and enc-dec under mixed per-layer plans — yields segmented quantized
    stacks; there is no raw fallback."""
    return compile_plan(model, params, plan, group).params


def explicit_plan(cfg: ModelConfig, layer_precisions: list[str],
                  variant: str = "8bit-mixed",
                  shared_precision: str = "raw") -> QuantPlan:
    """Plan with explicit per-layer precisions (embed stays raw) — used by
    the dry-run's two-stack (raw/quant) affine cost extrapolation and the
    compiler's parity tests. For enc-dec, ``layer_precisions`` covers the
    encoder stack followed by the decoder stack; for hybrid, the trailing
    shared block takes ``shared_precision``."""
    n_layers = cfg.num_layers + (cfg.num_encoder_layers or 0)
    assert len(layer_precisions) == n_layers
    ds = [BlockDecision(block_index=0, exec_index=1, entropy=float("nan"),
                        num_parameters=0, precision="raw")]
    for i, p in enumerate(layer_precisions):
        ds.append(BlockDecision(block_index=i + 1, exec_index=i + 2,
                                entropy=float("nan"), num_parameters=0,
                                precision=p))
    if cfg.family == "hybrid":
        ds.append(BlockDecision(block_index=len(ds), exec_index=len(ds) + 1,
                                entropy=float("nan"), num_parameters=0,
                                precision=shared_precision))
    return QuantPlan(decisions=ds, mu=float("nan"), sigma=float("nan"),
                     threshold=float("nan"), x_factor=1.0)


def quantize_decode_inputs(model: Model, shape: ShapeConfig, variant: str,
                           plan: Optional[QuantPlan] = None):
    """Dry-run builder: abstract EWQ-quantized params + cache + tokens."""
    from repro.launch.steps import decode_inputs, make_decode_step
    plan = plan or fastewq_metadata_plan(model.cfg, variant)
    # abstract params must enter eval_shape as ARGUMENTS (tracers support
    # slicing; bare ShapeDtypeStructs do not)
    params_q = jax.eval_shape(
        lambda p: apply_plan_to_params(model, p, plan),
        model.abstract_params())
    cache, tokens = decode_inputs(model, shape)
    return make_decode_step(model), (params_q, cache, tokens)
