"""Host-side page allocator + prefix cache for the paged KV pool
(docs/DESIGN.md §13).

``PoolSession`` owns the free-list / refcounts for ONE engine's pool of
physical KV pages. It is pure host bookkeeping — the device arrays live
in the engine's decode state (quant/kvcache.PagedKV); this class only
decides WHICH physical page each slot's logical page maps to. Page ids
are 1-based: physical page 0 is the sacrificial dump page and is never
handed out.

Refcount invariants:

* every admitted slot holds one reference on each physical page its page
  table maps (shared prefix pages included);
* the prefix cache holds one reference of its own on each registered
  page, so a shared page survives its donor slot's release;
* a page returns to the free list exactly when its count reaches 0.

Copy-on-write prefix sharing: prompts are matched page-by-page against
previously admitted prompts (exact token match per full page). Matching
FULL pages are mapped read-only into the new slot (refcount bumped, never
re-written: decode writes only touch positions >= prompt_len, and a
shared page always ends before the donor's prompt_len). The first
divergent / partial page is the COW boundary: its tokens are copied into
a freshly allocated private page at insert time (``cow_copies`` counts
these). The hit is capped at ``prompt_len - 1`` so at least one prompt
token always runs through the model to produce the next-token logits.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional

import numpy as np

from repro import obs


class OutOfPages(RuntimeError):
    """The pool cannot supply the pages a request needs (admission-time
    backpressure — the caller should retry after a slot is released)."""


@dataclasses.dataclass(frozen=True)
class PagedConfig:
    """Engine-level paged-pool knobs.

    ``pool_pages=None`` sizes the pool to the dense engine's reservation
    (num_slots * ceil(max_seq / page_size) pages — equal memory), which
    makes the paged win purely allocation-side: short requests leave the
    spare pages to extra concurrent slots."""
    page_size: int = 64
    pool_pages: Optional[int] = None
    prefix_sharing: bool = True


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Result of matching a prompt against the prefix cache. ``full_ids``
    are physical pages mapped verbatim (pinned); ``donor`` optionally
    contributes its first ``donor_tokens`` rows to seed the COW boundary
    page. ``hit = len(full_ids) * P + donor_tokens`` prompt tokens skip
    prefill."""
    hit: int = 0
    full_ids: tuple[int, ...] = ()
    donor: Optional[int] = None
    donor_tokens: int = 0


class PrefixCache:
    """Token-exact page-granular prefix index.

    ``_children[prefix_tokens][page_tokens] -> page_id`` maps a known
    prompt prefix to the physical page holding its next P tokens. The LRU
    order is kept per (prefix, page) entry; eviction only removes entries
    whose page no live slot maps (refcount 1 — the cache's own)."""

    def __init__(self) -> None:
        self._children: dict[tuple, dict[tuple, int]] = {}
        self._lru: OrderedDict[tuple[tuple, tuple], int] = OrderedDict()

    def __len__(self) -> int:
        return len(self._lru)

    def match(self, tokens: tuple, page_size: int) -> PrefixMatch:
        p = len(tokens)
        prefix: tuple = ()
        full: list[int] = []
        i = 0
        while i + page_size <= p:
            page = tokens[i:i + page_size]
            entry = self._children.get(prefix, {})
            pid = entry.get(page)
            if pid is None:
                break
            full.append(pid)
            self._lru.move_to_end((prefix, page))
            prefix = prefix + page
            i += page_size
        # best partial-overlap donor for the COW boundary page
        donor, donor_t = None, 0
        rest = tokens[i:]
        if rest:
            for page, pid in self._children.get(prefix, {}).items():
                t = 0
                for a, b in zip(rest, page):
                    if a != b:
                        break
                    t += 1
                if t > donor_t:
                    donor, donor_t = pid, t
        hit = len(full) * page_size + donor_t
        if hit >= p:  # keep >= 1 prompt token for the model to prefill
            hit = p - 1
            over = hit - len(full) * page_size
            if over < 0:  # whole prompt sat in full pages: demote the last
                donor, donor_t = full.pop(), hit - len(full) * page_size
            else:
                donor_t = over
                if donor_t == 0:
                    donor = None
        return PrefixMatch(hit=hit, full_ids=tuple(full), donor=donor,
                           donor_tokens=donor_t)

    def register(self, tokens: tuple, prompt_len: int, row: np.ndarray,
                 page_size: int) -> list[int]:
        """Index every FULL prompt page of a freshly admitted slot. Returns
        the page ids newly referenced by the cache (caller increfs them)."""
        new_refs: list[int] = []
        prefix: tuple = ()
        for j in range(prompt_len // page_size):
            page = tuple(tokens[j * page_size:(j + 1) * page_size])
            entry = self._children.setdefault(prefix, {})
            if page not in entry:
                entry[page] = int(row[j])
                self._lru[(prefix, page)] = int(row[j])
                new_refs.append(int(row[j]))
            else:
                self._lru.move_to_end((prefix, page))
            prefix = prefix + page
        return new_refs

    def evict_lru(self, refcounts: np.ndarray) -> Optional[int]:
        """Drop the least-recently-used entry whose page only the cache
        still references; returns the page id to decref (or None)."""
        for key, pid in self._lru.items():
            if refcounts[pid] == 1:
                prefix, page = key
                del self._lru[key]
                entry = self._children.get(prefix)
                if entry is not None:
                    entry.pop(page, None)
                    if not entry:
                        del self._children[prefix]
                return pid
        return None

    def evictable(self, refcounts: np.ndarray) -> int:
        return sum(1 for pid in self._lru.values() if refcounts[pid] == 1)

    def remap(self, perm: np.ndarray) -> "PrefixCache":
        """Clone onto a remapped physical page space (``perm[old] = new``),
        preserving LRU order — live pool repack, DESIGN.md §15."""
        pc = PrefixCache()
        pc._children = {
            prefix: {page: int(perm[pid]) for page, pid in entry.items()}
            for prefix, entry in self._children.items()}
        pc._lru = OrderedDict(
            (key, int(perm[pid])) for key, pid in self._lru.items())
        return pc


class PoolSession:
    """Free-list + refcount allocator for one engine's page pool."""

    def __init__(self, num_pages: int, page_size: int, n_log: int,
                 prefix_sharing: bool = True) -> None:
        assert num_pages >= 1 and page_size >= 1 and n_log >= 1
        self.num_pages = num_pages
        self.page_size = page_size
        self.n_log = n_log
        # pop() hands out low ids first (cosmetic, but makes tests legible)
        self._free = list(range(num_pages, 0, -1))
        self._ref = np.zeros(num_pages + 1, np.int64)  # [0] = dump, unused
        self._slot_pages: dict[int, list[int]] = {}
        self.prefix = PrefixCache() if prefix_sharing else None
        # trace pid (docs/DESIGN.md §16): the owning session stamps its
        # replica id so prefix-hit / COW instants land on its process
        self.pid = 0
        # stats
        self.peak_pages = 0
        self.cow_copies = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.prompt_tokens = 0
        self.admitted = 0

    # -- accounting --------------------------------------------------------

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def pages_for(self, seq_len: int) -> int:
        """Pages a request needs to cover ``seq_len`` tokens."""
        return min(-(-seq_len // self.page_size), self.n_log)

    def can_admit(self, num_pages: int) -> bool:
        """Worst-case (no prefix hit) admission check: free pages plus
        cache-only pages we may evict."""
        avail = len(self._free)
        if self.prefix is not None:
            avail += self.prefix.evictable(self._ref)
        return num_pages <= avail

    # -- refcount plumbing -------------------------------------------------

    def _incref(self, pid: int) -> None:
        assert pid != 0
        self._ref[pid] += 1

    def _decref(self, pid: int) -> None:
        assert pid != 0 and self._ref[pid] > 0, (pid, self._ref[pid])
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            self._free.append(pid)

    def _alloc(self) -> int:
        if not self._free and self.prefix is not None:
            evicted = self.prefix.evict_lru(self._ref)
            if evicted is not None:
                self._decref(evicted)
        if not self._free:
            raise OutOfPages(
                f"page pool exhausted: {self.num_pages} pages all "
                f"referenced (no evictable prefix entries)")
        pid = self._free.pop()
        self._ref[pid] = 1
        return pid

    # -- admission protocol ------------------------------------------------

    def match(self, tokens) -> PrefixMatch:
        """Match a prompt against the prefix cache and PIN the matched
        pages (incref) so they survive until ``admit``/``unpin``. Call
        once per request, before prefill."""
        if self.prefix is None:
            return PrefixMatch()
        m = self.prefix.match(tuple(int(t) for t in tokens), self.page_size)
        for pid in m.full_ids:
            self._incref(pid)
        if m.donor is not None:
            self._incref(m.donor)
        return m

    def unpin(self, m: PrefixMatch) -> None:
        """Drop the pins ``match`` took (admission failed / abandoned)."""
        for pid in m.full_ids:
            self._decref(pid)
        if m.donor is not None:
            self._decref(m.donor)

    def admit(self, slot: int, tokens, num_pages: int,
              m: Optional[PrefixMatch] = None
              ) -> tuple[np.ndarray, np.ndarray]:
        """Allocate the private pages of a request and build its page-table
        row. Returns ``(row, wrow)``, both (n_log,) int32: ``row`` is the
        slot's logical->physical map (0 past its allocation); ``wrow``
        redirects the shared (read-only) prefix pages to the dump page so
        the insert scatter cannot touch them. Raises ``OutOfPages`` with
        the match unpinned and nothing leaked."""
        m = m or PrefixMatch()
        assert slot not in self._slot_pages, f"slot {slot} already admitted"
        n_shared = len(m.full_ids)
        assert n_shared <= num_pages <= self.n_log, (n_shared, num_pages)
        private: list[int] = []
        try:
            for _ in range(num_pages - n_shared):
                private.append(self._alloc())
        except OutOfPages:
            for pid in private:
                self._decref(pid)
            self.unpin(m)
            raise
        if m.donor is not None:
            self._decref(m.donor)   # its rows are copied, not mapped
            self.cow_copies += 1
            obs.instant("pool/cow-copy", self.pid, args={"slot": slot})
        row = np.zeros(self.n_log, np.int32)
        wrow = np.zeros(self.n_log, np.int32)
        row[:n_shared] = m.full_ids          # pinned refs transfer to slot
        row[n_shared:num_pages] = private
        wrow[n_shared:num_pages] = private   # shared pages -> dump on write
        self._slot_pages[slot] = list(row[:num_pages])
        self.admitted += 1
        self.prompt_tokens += len(tokens)
        if m.hit:
            self.prefix_hits += 1
            self.prefix_hit_tokens += m.hit
            obs.instant("pool/prefix-hit", self.pid,
                        args={"slot": slot, "tokens": m.hit})
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        return row, wrow

    def register(self, slot: int, tokens, prompt_len: int) -> None:
        """Index the slot's full prompt pages for future prefix sharing
        (call after the insert has written them)."""
        if self.prefix is None:
            return
        row = np.asarray(self._slot_pages[slot], np.int32)
        toks = tuple(int(t) for t in tokens)[:prompt_len]
        for pid in self.prefix.register(toks, prompt_len, row,
                                        self.page_size):
            self._incref(pid)

    def release(self, slot: int) -> None:
        """Return the slot's page references (shared pages survive while
        the prefix cache or other slots still hold them)."""
        for pid in self._slot_pages.pop(slot):
            self._decref(pid)

    def flush_prefix(self) -> int:
        """Evict every cache-only prefix entry (pages no live slot maps).
        Promotion back up the degradation ladder shrinks the pool at
        constant bytes — cached-but-unmapped pages are the first to go."""
        n = 0
        while self.prefix is not None:
            pid = self.prefix.evict_lru(self._ref)
            if pid is None:
                break
            self._decref(pid)
            n += 1
        return n

    def rebuild(self, perm: np.ndarray, num_pages_new: int) -> "PoolSession":
        """Clone this allocator onto a remapped physical page space (live
        KV-precision repack resizes the pool at constant bytes, DESIGN.md
        §15). ``perm[old_pid] = new_pid`` for live pages, 0 for dead ones;
        refcounts, slot maps, the prefix cache and stats all carry over."""
        ns = PoolSession(num_pages_new, self.page_size, self.n_log,
                         prefix_sharing=self.prefix is not None)
        ref = np.zeros(num_pages_new + 1, np.int64)
        for old in range(1, self.num_pages + 1):
            if self._ref[old] > 0:
                new = int(perm[old])
                assert 1 <= new <= num_pages_new, (old, new, num_pages_new)
                ref[new] = self._ref[old]
        ns._ref = ref
        ns._free = [pid for pid in range(num_pages_new, 0, -1)
                    if ref[pid] == 0]
        ns._slot_pages = {
            slot: [int(perm[pid]) for pid in pages]
            for slot, pages in self._slot_pages.items()}
        if self.prefix is not None:
            ns.prefix = self.prefix.remap(perm)
        ns.peak_pages = self.peak_pages
        ns.pid = self.pid
        ns.cow_copies = self.cow_copies
        ns.prefix_hits = self.prefix_hits
        ns.prefix_hit_tokens = self.prefix_hit_tokens
        ns.prompt_tokens = self.prompt_tokens
        ns.admitted = self.admitted
        ns.check_invariants()
        return ns

    def check_invariants(self) -> None:
        """Debug/test hook: refcounts, free list and slot maps agree."""
        assert self._ref[0] == 0, "dump page must never be referenced"
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        for pid in range(1, self.num_pages + 1):
            if pid in free:
                assert self._ref[pid] == 0, (pid, self._ref[pid])
            else:
                assert self._ref[pid] > 0, (pid, self._ref[pid])
        held = np.zeros_like(self._ref)
        for pages in self._slot_pages.values():
            for pid in pages:
                held[pid] += 1
        if self.prefix is not None:
            for pid in self.prefix._lru.values():
                held[pid] += 1
        held[0] = 0
        assert np.array_equal(held, self._ref), (held, self._ref)
