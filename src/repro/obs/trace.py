"""Span tracer exporting Chrome ``trace_event`` JSON (docs/DESIGN.md §16).

Records the serving stack's per-request lifecycle and engine-level timing
as explicit begin/end (``B``/``E``) spans, complete (``X``) spans and
instant (``i``) events, written as a ``{"traceEvents": [...]}`` object
that Perfetto / chrome://tracing load directly.

Track mapping:

* ``pid`` = replica id. Process metadata names each ``replica<r>``.
* ``tid 0`` = the replica's ENGINE track: ``tick/dispatch`` /
  ``tick/harvest`` spans (one pair per ``ServeSession`` tick),
  ``engine/apply_kv_plan`` repack spans, ``replica/failover`` spans and
  ``degrade/transition`` / chaos instants.
* ``tid 1`` = the DECODE track: one ``decode/chunk`` X-span per launched
  chunk (dispatch -> harvest wall; args carry the tier, the autotune
  stamp and — with profiler fences armed — the device/host split).
* ``tid REQ_TRACK_BASE + rid`` = one track per REQUEST: its
  ``request/queued`` → ``request/prefill`` → ``request/decode`` phases
  are strictly sequential, so they form balanced B/E pairs; phase
  boundaries (finish/cancel/preempt/re-drive) land as instants on the
  same track.

Request phases are driven through ``request_phase``/``request_done``, a
tiny per-(pid, rid) state machine that closes the previous phase before
opening the next — span balance holds by construction, and
``open_spans()`` returning empty is the leak-freedom assertion the obs
tests pin under cancellation, preemption, OutOfPages backpressure and
chaos-driven failover.

Timestamps are microseconds since the tracer was constructed (Chrome's
``ts`` unit), from ``time.perf_counter``. Import-light (stdlib only) so
any serving layer can emit without cycles.
"""

from __future__ import annotations

import json
import time
from typing import Optional

ENGINE_TRACK = 0
DECODE_TRACK = 1
REQ_TRACK_BASE = 1000


class Tracer:
    def __init__(self):
        self._t0 = time.perf_counter()
        self.events: list[dict] = []
        # (pid, tid) -> stack of open span names (B/E balance bookkeeping)
        self._open: dict[tuple, list[str]] = {}
        # (pid, rid) -> current request phase
        self._req: dict[tuple, str] = {}
        self._named_pids: set = set()

    # -- clock ---------------------------------------------------------------
    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    # -- metadata ------------------------------------------------------------
    def set_process_name(self, pid: int, name: str) -> None:
        if pid in self._named_pids:
            return
        self._named_pids.add(pid)
        self.events.append({"name": "process_name", "ph": "M", "pid": pid,
                            "tid": 0, "args": {"name": name}})
        for tid, tname in ((ENGINE_TRACK, "engine"),
                           (DECODE_TRACK, "decode")):
            self.events.append({"name": "thread_name", "ph": "M",
                                "pid": pid, "tid": tid,
                                "args": {"name": tname}})

    # -- spans ---------------------------------------------------------------
    def begin(self, name: str, pid: int = 0, tid: int = ENGINE_TRACK,
              cat: str = "serve", args: Optional[dict] = None) -> None:
        ev = {"name": name, "ph": "B", "pid": pid, "tid": tid,
              "ts": self.now_us(), "cat": cat}
        if args:
            ev["args"] = args
        self.events.append(ev)
        self._open.setdefault((pid, tid), []).append(name)

    def end(self, name: str, pid: int = 0, tid: int = ENGINE_TRACK,
            args: Optional[dict] = None) -> None:
        stack = self._open.get((pid, tid), [])
        assert stack and stack[-1] == name, \
            (f"span misnesting on pid={pid} tid={tid}: ending {name!r}, "
             f"open stack {stack}")
        stack.pop()
        ev = {"name": name, "ph": "E", "pid": pid, "tid": tid,
              "ts": self.now_us()}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def complete(self, name: str, t0_us: float, pid: int = 0,
                 tid: int = ENGINE_TRACK, cat: str = "serve",
                 args: Optional[dict] = None) -> None:
        """A finished span in one event (``ph: "X"``): start at ``t0_us``
        (from ``now_us``), duration measured to now."""
        ev = {"name": name, "ph": "X", "pid": pid, "tid": tid,
              "ts": t0_us, "dur": max(0.0, self.now_us() - t0_us),
              "cat": cat}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, pid: int = 0, tid: int = ENGINE_TRACK,
                args: Optional[dict] = None) -> None:
        ev = {"name": name, "ph": "i", "pid": pid, "tid": tid,
              "ts": self.now_us(), "s": "t", "cat": "serve"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # -- per-request lifecycle state machine ----------------------------------
    def request_phase(self, pid: int, rid: int, phase: str,
                      args: Optional[dict] = None) -> None:
        """Move request ``rid`` into ``phase`` (queued/prefill/decode):
        the previous phase span (if any) ends first, so the request track
        is always a flat sequence of balanced spans."""
        tid = REQ_TRACK_BASE + rid
        prev = self._req.pop((pid, rid), None)
        if prev is not None:
            self.end(f"request/{prev}", pid, tid)
        self.begin(f"request/{phase}", pid, tid, cat="request", args=args)
        self._req[(pid, rid)] = phase

    def request_done(self, pid: int, rid: int, event: str,
                     args: Optional[dict] = None) -> None:
        """Terminal (or migrating) lifecycle event: close the open phase
        and mark the boundary — ``finish``, ``preempt``, ``redrive``."""
        tid = REQ_TRACK_BASE + rid
        prev = self._req.pop((pid, rid), None)
        if prev is not None:
            self.end(f"request/{prev}", pid, tid)
        self.instant(f"request/{event}", pid, tid, args=args)

    # -- inspection / export ---------------------------------------------------
    def open_spans(self) -> list[tuple]:
        """Every still-open (pid, tid, name) — empty iff leak-free."""
        return [(pid, tid, name)
                for (pid, tid), stack in sorted(self._open.items())
                for name in stack]

    def abandon(self, pid: int, tid: int,
                reason: str = "abandoned") -> None:
        """Force-close every open span on one track (exception unwind /
        replica quarantine keeps the trace loadable)."""
        for name in reversed(self._open.get((pid, tid), []).copy()):
            self.end(name, pid, tid, args={"reason": reason})

    def to_json(self) -> dict:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)

    def counts(self) -> dict:
        """Event counts by (name, ph) — the trace-schema tests and the CI
        validator read these instead of re-deriving them."""
        out: dict[tuple, int] = {}
        for ev in self.events:
            k = (ev["name"], ev["ph"])
            out[k] = out.get(k, 0) + 1
        return out
