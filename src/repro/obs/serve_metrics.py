"""The serving metric schema: one mapping table between the serve loop's
run data, the metrics registry, and ``ServeStats`` (docs/DESIGN.md §16).

``publish_session`` writes everything a ``ServeSession`` run produced
into a registry (counters/gauges/histograms with ``replica``/
``priority``/``tier`` labels); ``stats_fields`` reads a registry back
into the ``ServeStats`` constructor kwargs. ``ServeSession.finalize``
composes the two, which makes the registry the single source of truth:
the dataclass the CLI prints, the benchmark rows, and the Prometheus/
JSON expositions are all views over the same published numbers, so they
cannot drift.

Every pre-existing ``ServeStats`` field has a metric here (the obs test
suite asserts the coverage both ways). Latency histograms additionally
carry the per-priority-class breakdown PR 8's aggregate stats hid:
``quantile("serve_ttft_seconds", 95, priority="0")`` answers the
priority-inversion question directly.
"""

from __future__ import annotations

from typing import Optional

# metric name -> (kind, help). The schema is data, not code, so coverage
# tests can diff it against ServeStats' fields.
SCHEMA = {
    "serve_decode_steps_total":
        ("counter", "jitted decode steps executed (chunks x chunk)"),
    "serve_generated_tokens_total":
        ("counter", "tokens emitted across all requests"),
    "serve_decode_chunks_total":
        ("counter", "jitted decode chunks launched"),
    "serve_admissions_total":
        ("counter", "continuous-batching refills admitted mid-decode"),
    "serve_requests_total":
        ("counter", "finished requests by finish reason"),
    "serve_preemptions_total":
        ("counter", "restart-style evictions for higher priority"),
    "serve_timeouts_total":
        ("counter", "requests dropped by queue timeout"),
    "serve_cancelled_total":
        ("counter", "requests cancelled (queued or running)"),
    "serve_prefill_chunks_total":
        ("counter", "interleaved chunked-prefill advances"),
    "serve_spec_rounds_total":
        ("counter", "draft-propose/verify rounds executed"),
    "serve_draft_proposed_total":
        ("counter", "draft tokens proposed to live slots"),
    "serve_draft_accepted_total":
        ("counter", "draft tokens verified and committed"),
    "serve_draft_committed_total":
        ("counter", "tokens committed by spec rounds (incl. bonus)"),
    "serve_prefix_hits_total":
        ("counter", "admissions that reused shared prefix pages"),
    "serve_prefix_hit_tokens_total":
        ("counter", "prompt tokens served from shared pages"),
    "serve_prompt_tokens_total":
        ("counter", "prompt tokens across admitted requests"),
    "serve_cow_copies_total":
        ("counter", "COW boundary pages materialized"),
    "serve_watchdog_trips_total":
        ("counter", "dispatch->harvest deadline overruns"),
    "serve_degraded_steps_total":
        ("counter", "decode steps run below KV tier 0"),
    "serve_degrade_transitions_total":
        ("counter", "KV tier changes (spills + promotions)"),
    "serve_kv_tier_steps_total":
        ("counter", "decode steps per KV degradation tier"),
    "serve_replica_restarts_total":
        ("counter", "replicas quarantined and failed over"),
    "serve_redriven_requests_total":
        ("counter", "in-flight requests re-driven to survivors"),
    "serve_chaos_faults_total":
        ("counter", "chaos-injected faults fired, by site"),
    "serve_occupancy_ratio":
        ("gauge", "mean fraction of active slots per chunk"),
    "serve_pool_pages":
        ("gauge", "paged KV pool pages by kind (total/peak)"),
    "serve_pool_page_size_tokens":
        ("gauge", "tokens per KV page"),
    "serve_kv_bytes_peak":
        ("gauge", "peak physical KV bytes held"),
    "serve_tuned_info":
        ("gauge", "autotune cache key the engine was traced under"),
    "serve_ttft_seconds":
        ("histogram", "time to first token (dequeue -> first token)"),
    "serve_tpot_seconds":
        ("histogram", "per-output-token latency after the first"),
    "serve_queue_delay_seconds":
        ("histogram", "ready -> dequeue wait (separate from TTFT)"),
    "serve_decode_gap_seconds":
        ("histogram", "dispatch -> harvest wall per decode chunk"),
    "serve_device_time_seconds":
        ("histogram", "device compute per chunk (profiler fences)"),
    "serve_host_gap_seconds":
        ("histogram", "host scheduling gap per chunk (profiler fences)"),
    "serve_recovery_seconds":
        ("histogram", "replica failure -> survivors resumed"),
}

# ServeStats field -> the metric it is reconstructed from (coverage is
# asserted by tests/test_obs.py; derived ratios map to their inputs)
STATS_FIELD_METRICS = {
    "decode_steps": "serve_decode_steps_total",
    "generated_tokens": "serve_generated_tokens_total",
    "occupancy": "serve_occupancy_ratio",
    "num_chunks": "serve_decode_chunks_total",
    "admissions": "serve_admissions_total",
    "ttft_p50_s": "serve_ttft_seconds",
    "ttft_p95_s": "serve_ttft_seconds",
    "tpot_p50_s": "serve_tpot_seconds",
    "tpot_p95_s": "serve_tpot_seconds",
    "queue_delay_p50_s": "serve_queue_delay_seconds",
    "queue_delay_p95_s": "serve_queue_delay_seconds",
    "preemptions": "serve_preemptions_total",
    "timeouts": "serve_timeouts_total",
    "cancelled": "serve_cancelled_total",
    "prefill_chunks": "serve_prefill_chunks_total",
    "decode_gap_p50_s": "serve_decode_gap_seconds",
    "decode_gap_p95_s": "serve_decode_gap_seconds",
    "decode_gap_max_s": "serve_decode_gap_seconds",
    "spec_rounds": "serve_spec_rounds_total",
    "draft_proposed": "serve_draft_proposed_total",
    "draft_accepted": "serve_draft_accepted_total",
    "acceptance_rate": "serve_draft_accepted_total",
    "tokens_per_round": "serve_draft_committed_total",
    "pool_pages_total": "serve_pool_pages",
    "pool_pages_peak": "serve_pool_pages",
    "pool_page_size": "serve_pool_page_size_tokens",
    "prefix_hits": "serve_prefix_hits_total",
    "prefix_hit_tokens": "serve_prefix_hit_tokens_total",
    "prefix_hit_rate": "serve_prompt_tokens_total",
    "cow_copies": "serve_cow_copies_total",
    "kv_bytes_peak": "serve_kv_bytes_peak",
    "tuned": "serve_tuned_info",
    "replica_restarts": "serve_replica_restarts_total",
    "redriven_requests": "serve_redriven_requests_total",
    "recovery_p95_s": "serve_recovery_seconds",
    "watchdog_trips": "serve_watchdog_trips_total",
    "degraded_steps": "serve_degraded_steps_total",
    "degrade_transitions": "serve_degrade_transitions_total",
    "kv_tier_steps": "serve_kv_tier_steps_total",
}


def _c(reg, name):
    return reg.counter(name, SCHEMA[name][1])


def _g(reg, name):
    return reg.gauge(name, SCHEMA[name][1])


def _h(reg, name):
    return reg.histogram(name, SCHEMA[name][1])


def publish_session(reg, *, replica: int, outputs, occupancy: float,
                    num_chunks: int, chunk: int, admissions: int,
                    generated: int, prefill_chunks: int, gaps,
                    spec_m: dict, spec_labels: Optional[dict],
                    watchdog_trips: int, degraded_steps: int,
                    transitions: int, tier_steps, tier_labels,
                    tuned: str, pool: Optional[dict] = None,
                    device_times=(), host_gaps=(),
                    recovery=(), restarts: int = 0,
                    redriven: int = 0) -> None:
    """Write one serve run into ``reg``. ``outputs`` are RequestOutputs
    (duck-typed — this module imports nothing from serving); ``pool`` is
    the page-pool reading dict or None for unpaged engines."""
    r = str(replica)
    _c(reg, "serve_decode_steps_total").inc(num_chunks * chunk, replica=r)
    _c(reg, "serve_generated_tokens_total").inc(generated, replica=r)
    _c(reg, "serve_decode_chunks_total").inc(num_chunks, replica=r)
    _c(reg, "serve_admissions_total").inc(admissions, replica=r)
    _c(reg, "serve_prefill_chunks_total").inc(prefill_chunks, replica=r)
    _c(reg, "serve_watchdog_trips_total").inc(watchdog_trips, replica=r)
    _c(reg, "serve_degraded_steps_total").inc(degraded_steps, replica=r)
    _c(reg, "serve_degrade_transitions_total").inc(transitions, replica=r)
    _g(reg, "serve_occupancy_ratio").set(occupancy, replica=r)
    _g(reg, "serve_tuned_info").set(1.0, key=tuned, replica=r)
    tiers = _c(reg, "serve_kv_tier_steps_total")
    for i, steps in enumerate(tier_steps):
        label = (tier_labels[i] if tier_labels is not None
                 and i < len(tier_labels) else str(i))
        tiers.inc(steps, replica=r, tier=str(i), precision=label)

    reqs = _c(reg, "serve_requests_total")
    preempts = _c(reg, "serve_preemptions_total")
    timeouts = _c(reg, "serve_timeouts_total")
    cancels = _c(reg, "serve_cancelled_total")
    ttft = _h(reg, "serve_ttft_seconds")
    tpot = _h(reg, "serve_tpot_seconds")
    qdel = _h(reg, "serve_queue_delay_seconds")
    for o in outputs:
        p = str(o.priority)
        reqs.inc(1, replica=r, reason=o.finish_reason, priority=p)
        if o.preempted:
            preempts.inc(o.preempted, replica=r, priority=p)
        if o.finish_reason == "timeout":
            timeouts.inc(1, replica=r, priority=p)
        elif o.finish_reason == "cancelled":
            cancels.inc(1, replica=r, priority=p)
        if o.ttft_s is not None:
            ttft.observe(o.ttft_s, replica=r, priority=p)
        if o.tpot_s is not None:
            tpot.observe(o.tpot_s, replica=r, priority=p)
        if o.queue_delay_s is not None:
            qdel.observe(o.queue_delay_s, replica=r, priority=p)

    gap = _h(reg, "serve_decode_gap_seconds")
    for g_ in gaps:
        gap.observe(g_, replica=r)
    dev = _h(reg, "serve_device_time_seconds")
    for d in device_times:
        dev.observe(d, replica=r)
    hg = _h(reg, "serve_host_gap_seconds")
    for h_ in host_gaps:
        hg.observe(h_, replica=r)

    sl = dict(spec_labels or {})
    _c(reg, "serve_spec_rounds_total").inc(spec_m["rounds"], replica=r, **sl)
    _c(reg, "serve_draft_proposed_total").inc(spec_m["proposed"],
                                              replica=r, **sl)
    _c(reg, "serve_draft_accepted_total").inc(spec_m["accepted"],
                                              replica=r, **sl)
    _c(reg, "serve_draft_committed_total").inc(spec_m["committed"],
                                               replica=r, **sl)

    if pool is not None:
        pages = _g(reg, "serve_pool_pages")
        pages.set(pool["pages_total"], replica=r, kind="total")
        pages.set(pool["pages_peak"], replica=r, kind="peak")
        _g(reg, "serve_pool_page_size_tokens").set(pool["page_size"],
                                                   replica=r)
        _g(reg, "serve_kv_bytes_peak").set(pool["kv_bytes_peak"], replica=r)
        _c(reg, "serve_prefix_hits_total").inc(pool["prefix_hits"],
                                               replica=r)
        _c(reg, "serve_prefix_hit_tokens_total").inc(
            pool["prefix_hit_tokens"], replica=r)
        _c(reg, "serve_prompt_tokens_total").inc(pool["prompt_tokens"],
                                                 replica=r)
        _c(reg, "serve_cow_copies_total").inc(pool["cow_copies"], replica=r)

    rec = _h(reg, "serve_recovery_seconds")
    for s in recovery:
        rec.observe(s, replica=r)
    if restarts:
        _c(reg, "serve_replica_restarts_total").inc(restarts, replica=r)
    if redriven:
        _c(reg, "serve_redriven_requests_total").inc(redriven, replica=r)


def stats_fields(reg) -> dict:
    """Reconstruct the ``ServeStats`` constructor kwargs from a published
    registry — the dataclass is a snapshot VIEW, not a second source."""
    proposed = reg.total("serve_draft_proposed_total")
    accepted = reg.total("serve_draft_accepted_total")
    committed = reg.total("serve_draft_committed_total")
    rounds = reg.total("serve_spec_rounds_total")
    prompt_tokens = reg.total("serve_prompt_tokens_total")
    hit_tokens = reg.total("serve_prefix_hit_tokens_total")
    gap = reg.get("serve_decode_gap_seconds")
    rec = reg.get("serve_recovery_seconds")

    def pool_gauge(name, **labels):
        m = reg.get(name)
        if m is None:
            return 0
        v = m.value(**labels)
        return v if v is not None else m.total()

    tuned = "untuned"
    m = reg.get("serve_tuned_info")
    if m is not None:
        keys = m.labeled("key")
        if keys:
            tuned = sorted(keys)[0]
    tiers: tuple = ()
    m = reg.get("serve_kv_tier_steps_total")
    if m is not None:
        by_tier = m.labeled("tier")
        if by_tier:
            width = max(int(t) for t in by_tier) + 1
            tiers = tuple(int(by_tier.get(str(i), 0))
                          for i in range(width))
    pool_pages = reg.get("serve_pool_pages")

    def pages(kind):
        if pool_pages is None:
            return 0
        vals = pool_pages.labeled("kind")
        return int(vals.get(kind, 0))

    return dict(
        decode_steps=int(reg.total("serve_decode_steps_total")),
        generated_tokens=int(reg.total("serve_generated_tokens_total")),
        occupancy=float(reg.total("serve_occupancy_ratio")),
        num_chunks=int(reg.total("serve_decode_chunks_total")),
        admissions=int(reg.total("serve_admissions_total")),
        ttft_p50_s=reg.quantile("serve_ttft_seconds", 50),
        ttft_p95_s=reg.quantile("serve_ttft_seconds", 95),
        tpot_p50_s=reg.quantile("serve_tpot_seconds", 50),
        tpot_p95_s=reg.quantile("serve_tpot_seconds", 95),
        queue_delay_p50_s=reg.quantile("serve_queue_delay_seconds", 50),
        queue_delay_p95_s=reg.quantile("serve_queue_delay_seconds", 95),
        preemptions=int(reg.total("serve_preemptions_total")),
        timeouts=int(reg.total("serve_timeouts_total")),
        cancelled=int(reg.total("serve_cancelled_total")),
        prefill_chunks=int(reg.total("serve_prefill_chunks_total")),
        decode_gap_p50_s=reg.quantile("serve_decode_gap_seconds", 50),
        decode_gap_p95_s=reg.quantile("serve_decode_gap_seconds", 95),
        decode_gap_max_s=(gap.max() if gap is not None else 0.0),
        spec_rounds=int(rounds),
        draft_proposed=int(proposed),
        draft_accepted=int(accepted),
        acceptance_rate=(accepted / proposed if proposed else 0.0),
        tokens_per_round=(committed / rounds if rounds else 0.0),
        pool_pages_total=pages("total"),
        pool_pages_peak=pages("peak"),
        pool_page_size=int(pool_gauge("serve_pool_page_size_tokens")),
        prefix_hits=int(reg.total("serve_prefix_hits_total")),
        prefix_hit_tokens=int(hit_tokens),
        prefix_hit_rate=(hit_tokens / prompt_tokens
                         if prompt_tokens else 0.0),
        cow_copies=int(reg.total("serve_cow_copies_total")),
        kv_bytes_peak=float(pool_gauge("serve_kv_bytes_peak")),
        tuned=tuned,
        replica_restarts=int(reg.total("serve_replica_restarts_total")),
        redriven_requests=int(reg.total("serve_redriven_requests_total")),
        recovery_p95_s=(rec.quantile(95) if rec is not None
                        and rec.count() else 0.0),
        watchdog_trips=int(reg.total("serve_watchdog_trips_total")),
        degraded_steps=int(reg.total("serve_degraded_steps_total")),
        degrade_transitions=int(reg.total("serve_degrade_transitions_total")),
        kv_tier_steps=tiers,
    )
