"""Metrics registry: counters, gauges and fixed-bucket histograms with
labels, exported as Prometheus text exposition and stable JSON
(docs/DESIGN.md §16).

The registry is the single source of truth for serving statistics:
``ServeSession.finalize`` publishes every number it used to accumulate in
ad-hoc ``ServeStats`` fields into a per-run registry, and ``ServeStats``
is reconstructed as a snapshot *view* over it
(``ServeStats.from_registry``) — the CLI renderer, the benchmark rows and
the Prometheus/JSON exports all read the same snapshot, so they cannot
drift apart.

Conventions (DESIGN.md §16):

* metric names are ``serve_``-prefixed snake_case; counters end in
  ``_total``, unit-carrying metrics end in the unit (``_seconds``,
  ``_tokens``, ``_bytes``);
* label keys are drawn from a small fixed vocabulary — ``replica``,
  ``priority``, ``tier``, ``site``, ``kind``, ``key``, ``family`` — and
  label values are strings;
* histograms keep their raw samples alongside the fixed buckets so exact
  percentiles (``quantile``) match what ``np.percentile`` over the
  original latency lists would report; the Prometheus exposition carries
  the cumulative buckets.

This module deliberately imports nothing from the serving stack (stdlib +
numpy only), mirroring ``serving/chaos.py``, so every layer — pool,
scheduler, compiler — can publish into it without import cycles.
"""

from __future__ import annotations

import json
import math
from typing import Iterable, Optional

import numpy as np

# Prometheus-style latency buckets (seconds). Fixed so expositions from
# different runs/replicas merge bucket-for-bucket.
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)
# raw-sample cap per label set: serving runs observe a few samples per
# request/chunk, far below this; the cap only bounds pathological loops
MAX_SAMPLES = 65536

_TYPES = ("counter", "gauge", "histogram")


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class Metric:
    """One named metric family holding per-label-set series."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: dict[tuple, float] = {}

    # -- write ---------------------------------------------------------------
    def _slot(self, labels: dict) -> tuple:
        return _label_key(labels)

    # -- read ----------------------------------------------------------------
    def value(self, **labels) -> Optional[float]:
        return self._series.get(_label_key(labels))

    def total(self) -> float:
        return float(sum(self._series.values()))

    def series(self) -> dict[tuple, float]:
        return dict(self._series)

    def labeled(self, key: str) -> dict[str, float]:
        """Collapse the series onto one label key: value-of-``key`` ->
        summed value (e.g. per-tier step counts)."""
        out: dict[str, float] = {}
        for ls, v in self._series.items():
            d = dict(ls)
            if key in d:
                out[d[key]] = out.get(d[key], 0.0) + v
        return out

    # -- exposition ----------------------------------------------------------
    def _sample_lines(self) -> list[str]:
        lines = []
        for ls in sorted(self._series):
            lbl = ("{" + ",".join(f'{k}="{v}"' for k, v in ls) + "}"
                   if ls else "")
            lines.append(f"{self.name}{lbl} "
                         f"{_fmt_value(self._series[ls])}")
        return lines

    def expose(self) -> list[str]:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {self.help}")
        lines.append(f"# TYPE {self.name} {self.kind}")
        lines.extend(self._sample_lines())
        return lines

    def snapshot(self) -> dict:
        return {
            "type": self.kind,
            "help": self.help,
            "samples": [{"labels": dict(ls), "value": v}
                        for ls, v in sorted(self._series.items())],
        }

    def merge_from(self, other: "Metric") -> None:
        for ls, v in other._series.items():
            self._series[ls] = self._series.get(ls, 0.0) + v


class Counter(Metric):
    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {value})")
        k = self._slot(labels)
        self._series[k] = self._series.get(k, 0.0) + value


class Gauge(Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[self._slot(labels)] = float(value)

    def inc(self, value: float = 1.0, **labels) -> None:
        k = self._slot(labels)
        self._series[k] = self._series.get(k, 0.0) + value

    def merge_from(self, other: "Metric") -> None:
        # gauges are level readings, not flows: last write wins
        self._series.update(other._series)


class Histogram(Metric):
    """Fixed-bucket histogram that also retains raw samples so exact
    quantiles survive the registry migration (ServeStats percentiles must
    match ``np.percentile`` over the original lists)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        super().__init__(name, help)
        self.buckets = tuple(sorted(set(float(b) for b in buckets)))
        self._counts: dict[tuple, list[int]] = {}   # per-bucket (+Inf last)
        self._sum: dict[tuple, float] = {}
        self._n: dict[tuple, int] = {}
        self._samples: dict[tuple, list[float]] = {}

    def observe(self, value: float, **labels) -> None:
        k = self._slot(labels)
        if k not in self._counts:
            self._counts[k] = [0] * (len(self.buckets) + 1)
            self._sum[k] = 0.0
            self._n[k] = 0
            self._samples[k] = []
        counts = self._counts[k]
        for i, b in enumerate(self.buckets):
            if value <= b:
                counts[i] += 1
                break
        else:
            counts[-1] += 1
        self._sum[k] += float(value)
        self._n[k] += 1
        if len(self._samples[k]) < MAX_SAMPLES:
            self._samples[k].append(float(value))

    # -- read ----------------------------------------------------------------
    def _matching(self, labels: dict) -> list[tuple]:
        """Label sets whose labels are a superset of ``labels`` (so
        ``quantile(50)`` aggregates across replicas/priorities while
        ``quantile(50, priority="0")`` narrows to one class)."""
        want = set(_label_key(labels))
        return [k for k in self._n if want <= set(k)]

    def samples(self, **labels) -> list[float]:
        out: list[float] = []
        for k in self._matching(labels):
            out.extend(self._samples[k])
        return out

    def quantile(self, q: float, **labels) -> float:
        vals = self.samples(**labels)
        return float(np.percentile(vals, q)) if vals else 0.0

    def max(self, **labels) -> float:
        vals = self.samples(**labels)
        return max(vals) if vals else 0.0

    def count(self, **labels) -> int:
        return int(sum(self._n[k] for k in self._matching(labels)))

    def sum(self, **labels) -> float:
        return float(sum(self._sum[k] for k in self._matching(labels)))

    def label_values(self, key: str) -> list[str]:
        vals = {dict(k).get(key) for k in self._n}
        return sorted(v for v in vals if v is not None)

    # -- exposition ----------------------------------------------------------
    def _sample_lines(self) -> list[str]:
        lines = []
        for ls in sorted(self._n):
            base = ",".join(f'{k}="{v}"' for k, v in ls)
            cum = 0
            for i, b in enumerate(self.buckets):
                cum += self._counts[ls][i]
                le = f'le="{_fmt_value(b)}"'
                lbl = "{" + (base + "," if base else "") + le + "}"
                lines.append(f"{self.name}_bucket{lbl} {cum}")
            cum += self._counts[ls][-1]
            lbl = "{" + (base + "," if base else "") + 'le="+Inf"' + "}"
            lines.append(f"{self.name}_bucket{lbl} {cum}")
            sfx = "{" + base + "}" if base else ""
            lines.append(f"{self.name}_sum{sfx} "
                         f"{_fmt_value(self._sum[ls])}")
            lines.append(f"{self.name}_count{sfx} {self._n[ls]}")
        return lines

    def snapshot(self) -> dict:
        return {
            "type": self.kind,
            "help": self.help,
            "buckets": list(self.buckets),
            "samples": [{
                "labels": dict(ls),
                "count": self._n[ls],
                "sum": self._sum[ls],
                "bucket_counts": list(self._counts[ls]),
            } for ls in sorted(self._n)],
        }

    def merge_from(self, other: "Metric") -> None:
        assert isinstance(other, Histogram)
        if other.buckets != self.buckets:
            raise ValueError(f"histogram {self.name}: bucket mismatch")
        for ls in other._n:
            if ls not in self._counts:
                self._counts[ls] = [0] * (len(self.buckets) + 1)
                self._sum[ls] = 0.0
                self._n[ls] = 0
                self._samples[ls] = []
            self._counts[ls] = [a + b for a, b in
                                zip(self._counts[ls], other._counts[ls])]
            self._sum[ls] += other._sum[ls]
            self._n[ls] += other._n[ls]
            room = MAX_SAMPLES - len(self._samples[ls])
            if room > 0:
                self._samples[ls].extend(other._samples[ls][:room])


class MetricsRegistry:
    """Create-or-get metric families; exposition over the whole set."""

    def __init__(self):
        self._metrics: dict[str, Metric] = {}

    def _get(self, cls, name: str, help: str, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, not {cls.kind}")
        if help and not m.help:
            m.help = help   # a live emitter created it help-less first
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    # -- convenience reads (0-defaults keep ServeStats reconstruction terse)
    def total(self, name: str) -> float:
        m = self._metrics.get(name)
        return m.total() if m is not None else 0.0

    def quantile(self, name: str, q: float, **labels) -> float:
        m = self._metrics.get(name)
        if m is None:
            return 0.0
        assert isinstance(m, Histogram), name
        return m.quantile(q, **labels)

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold another registry in: counters/histograms add, gauges take
        the other's level. Cross-run accumulation (Prometheus semantics)
        and per-replica -> global roll-up both go through here."""
        for name, m in other._metrics.items():
            mine = self._get(type(m), name, m.help,
                             **({"buckets": m.buckets}
                                if isinstance(m, Histogram) else {}))
            mine.merge_from(m)

    # -- exposition ----------------------------------------------------------
    def to_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: list[str] = []
        for name in sorted(self._metrics):
            lines.extend(self._metrics[name].expose())
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> dict:
        """Stable JSON-serializable view (sorted names, sorted labels)."""
        return {name: self._metrics[name].snapshot()
                for name in sorted(self._metrics)}

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def write_prometheus(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_prometheus())

    def write_json(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())
