"""Observability facade: process-wide tracer / metrics / profiler
(docs/DESIGN.md §16).

Everything is OFF by default. The serving stack emits through the
module-level helpers below; with nothing installed each call is one
``None`` check and an immediate return — the same disabled-path
discipline as ``serving/chaos.py``, budgeted at <1% serve throughput
(``benchmarks/serve_throughput.py`` ``serve/obs/*`` rows keep it
honest). Hot per-tick paths hold the ``tracer()`` handle once and branch
on it so even the argument packing is skipped when tracing is off.

Usage::

    from repro import obs
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.trace import Tracer

    obs.install(tracer=Tracer(), metrics=MetricsRegistry())
    try:
        engine.serve(requests, ...)
    finally:
        tr, mx, _ = obs.install(None, None, None)
    tr.write("trace.json"); mx.write_prometheus("metrics.prom")

or scoped, for tests::

    with obs.capture() as (tr, mx):
        engine.serve(requests, ...)
    assert tr.open_spans() == []
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.profile import ProfileHooks
from repro.obs.trace import (DECODE_TRACK, ENGINE_TRACK, REQ_TRACK_BASE,
                             Tracer)

__all__ = [
    "Tracer", "MetricsRegistry", "ProfileHooks",
    "ENGINE_TRACK", "DECODE_TRACK", "REQ_TRACK_BASE",
    "install", "capture", "tracer", "metrics", "profile", "enabled",
    "request_phase", "request_done", "instant", "count", "observe",
]

_KEEP = object()

_TRACER: Optional[Tracer] = None
_METRICS: Optional[MetricsRegistry] = None
_PROFILE: Optional[ProfileHooks] = None


def install(tracer=_KEEP, metrics=_KEEP, profile=_KEEP):
    """Install (or clear, with None) process-wide sinks; omitted kwargs
    keep the current sink. Returns the previous (tracer, metrics,
    profile) triple so callers can restore it."""
    global _TRACER, _METRICS, _PROFILE
    prev = (_TRACER, _METRICS, _PROFILE)
    if tracer is not _KEEP:
        _TRACER = tracer
    if metrics is not _KEEP:
        _METRICS = metrics
    if profile is not _KEEP:
        _PROFILE = profile
    return prev


def tracer() -> Optional[Tracer]:
    return _TRACER


def metrics() -> Optional[MetricsRegistry]:
    return _METRICS


def profile() -> Optional[ProfileHooks]:
    return _PROFILE


def enabled() -> bool:
    return _TRACER is not None or _METRICS is not None


@contextmanager
def capture(tracer: Optional[Tracer] = None,
            metrics: Optional[MetricsRegistry] = None,
            profile: Optional[ProfileHooks] = None):
    """Scoped installation (tests): fresh tracer + registry by default."""
    tr = tracer if tracer is not None else Tracer()
    mx = metrics if metrics is not None else MetricsRegistry()
    prev = install(tr, mx, profile)
    try:
        yield tr, mx
    finally:
        install(*prev)


# ---------------------------------------------------------------------------
# Free no-op emitters: production call sites stay one None check when off.

def request_phase(pid: int, rid: int, phase: str, args=None) -> None:
    if _TRACER is not None:
        _TRACER.request_phase(pid, rid, phase, args)


def request_done(pid: int, rid: int, event: str, args=None) -> None:
    if _TRACER is not None:
        _TRACER.request_done(pid, rid, event, args)


def instant(name: str, pid: int = 0, tid: int = ENGINE_TRACK,
            args=None) -> None:
    if _TRACER is not None:
        _TRACER.instant(name, pid, tid, args)


def count(name: str, value: float = 1.0, help: str = "",
          **labels) -> None:
    """Increment a counter on the INSTALLED registry (live events that no
    per-run publish covers: replica failover, re-drives)."""
    if _METRICS is not None:
        _METRICS.counter(name, help).inc(value, **labels)


def observe(name: str, value: float, help: str = "", **labels) -> None:
    """Observe into a histogram on the installed registry."""
    if _METRICS is not None:
        _METRICS.histogram(name, help).observe(value, **labels)
