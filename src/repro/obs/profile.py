"""Profiler hooks: device-time fences and ``jax.profiler`` capture
windows (docs/DESIGN.md §16).

Two opt-in mechanisms, both armed by installing a ``ProfileHooks`` via
``obs.install(profile=...)``:

* **Device fences** (``device_fences=True``): the serve loop adds a
  ``jax.block_until_ready`` fence right after launching each decode
  chunk, splitting PR 8's dispatch→harvest ``decode-gap`` wall into
  *device compute* (launch → arrays ready) and *host scheduling gap*
  (ready → harvest read). The split lands in the ``decode/chunk`` trace
  span args and in the ``serve_device_time_seconds`` /
  ``serve_host_gap_seconds`` histograms. The fence serializes the host
  against the device — it is a measurement mode, not a serving mode, so
  it is never on by default.

* **Capture windows** (``steps=(A, B)``, CLI ``--profile-steps A:B``):
  ``jax.profiler.start_trace`` fires when the decode-step clock reaches
  A and stops at B (or at session teardown), writing an XPlane/Perfetto
  trace under ``trace_dir``. Start/stop failures degrade to a warning —
  profiler availability varies by backend and must never take serving
  down.

Disabled cost: the serve loop consults one module-level ``None`` check
per site (``obs.profile()``), the same discipline as ``serving/chaos``.
"""

from __future__ import annotations

from typing import Optional


class ProfileHooks:
    def __init__(self, steps: Optional[tuple] = None,
                 trace_dir: str = "/tmp/repro-profile",
                 device_fences: bool = True):
        if steps is not None:
            a, b = steps
            if not (0 <= a < b):
                raise ValueError(f"profile window must be 0 <= A < B, "
                                 f"got {a}:{b}")
        self.steps = steps
        self.trace_dir = trace_dir
        self.device_fences = device_fences
        self._capturing = False
        self.windows = 0              # capture windows actually recorded

    @classmethod
    def parse(cls, spec: str, trace_dir: str = "/tmp/repro-profile",
              device_fences: bool = True) -> "ProfileHooks":
        """``"A:B"`` -> a capture window over decode steps [A, B)."""
        try:
            a, b = (int(x) for x in spec.split(":"))
        except ValueError:
            raise ValueError(f"--profile-steps wants A:B, got {spec!r}")
        return cls(steps=(a, b), trace_dir=trace_dir,
                   device_fences=device_fences)

    # -- capture window -------------------------------------------------------
    def tick(self, clock: int) -> None:
        """Advance the capture window against the decode-step clock.
        Called once per dispatch; idempotent outside the window.

        The clock advances by ``chunk`` per tick, so the window triggers
        on *crossing*: capture starts at the first tick with
        ``clock >= A`` and stops at the first subsequent tick with
        ``clock >= B``. A window narrower than one chunk stride still
        records at least one tick instead of silently missing."""
        if self.steps is None:
            return
        a, b = self.steps
        if not self._capturing:
            if clock >= a:
                self._start()
        elif clock >= b:
            self.stop()

    def _start(self) -> None:
        import jax
        try:
            jax.profiler.start_trace(self.trace_dir)
            self._capturing = True
        except Exception as e:   # profiler availability is backend-dependent
            import warnings
            warnings.warn(f"jax.profiler.start_trace failed: {e}")
            self.steps = None    # don't retry every tick

    def stop(self) -> None:
        """Close an open capture window (also called at session teardown
        so a window that spans the end of the stream still flushes)."""
        if not self._capturing:
            return
        import jax
        self._capturing = False
        self.steps = None        # one window per arm; never re-open
        self.windows += 1
        try:
            jax.profiler.stop_trace()
        except Exception as e:
            import warnings
            warnings.warn(f"jax.profiler.stop_trace failed: {e}")
