"""One renderer for serve statistics, driven by the metrics snapshot
(docs/DESIGN.md §16).

``launch/serve.py`` grew one ad-hoc ``print`` block per serving feature
across PRs 5–9 (serving / queueing / chunked-prefill / replicas / fault /
degradation). Those strings, the benchmark derivations, and any JSON
export each reached into ``ServeStats`` separately — three chances to
drift. This module is now the only place serve numbers are formatted:
``ServeStats`` is itself a view over the published registry
(``obs/serve_metrics.py``), so every line below — and the per-priority
breakdown only the registry carries — renders from the same snapshot the
Prometheus/JSON exports serialize.

The line formats are pinned: CI greps ``fault tolerance: 1 replica
restarts`` and the chaos-parity strings, so changes here are contract
changes.
"""

from __future__ import annotations

from typing import Optional


def _ms(v: float) -> str:
    return f"{v * 1e3:.0f}ms"


def serve_report(stats, *, wall_s: float, num_requests: int, chunk: int,
                 queueing: bool = False, prefill_chunk: int = 0,
                 replicas: Optional[dict] = None,
                 fault: bool = False, chaos_fired=None,
                 spec: bool = False, paged: Optional[dict] = None,
                 per_priority: bool = True) -> list[str]:
    """Render the serve stat block as lines. ``replicas`` carries the
    DP context (``replicas``/``mesh_shape``/``assignments``/
    ``occupancy``), ``paged`` the dense-reservation comparison context
    (``num_slots``/``kv_bytes_per_slot``/``max_seq``)."""
    lines = [
        f"served {num_requests} requests in {wall_s:.1f}s "
        f"({stats.generated_tokens / wall_s:.1f} tok/s): "
        f"{stats.num_chunks} chunks x {chunk} steps, "
        f"occupancy {stats.occupancy:.1%}, "
        f"{stats.admissions} mid-run admissions, "
        f"ttft p50 {_ms(stats.ttft_p50_s)} / "
        f"p95 {_ms(stats.ttft_p95_s)}, "
        f"tpot p50 {stats.tpot_p50_s * 1e3:.1f}ms"]
    if queueing:
        lines.append(
            f"queueing: delay p50 {_ms(stats.queue_delay_p50_s)} "
            f"/ p95 {_ms(stats.queue_delay_p95_s)}, "
            f"{stats.preemptions} preemptions, "
            f"{stats.timeouts} timeouts, {stats.cancelled} cancelled, "
            f"decode gap p95 {stats.decode_gap_p95_s * 1e3:.1f}ms / "
            f"max {stats.decode_gap_max_s * 1e3:.1f}ms")
        if per_priority:
            lines.extend(priority_report(stats.registry))
    if prefill_chunk:
        lines.append(f"chunked prefill: {stats.prefill_chunks} interleaved "
                     f"chunks of {prefill_chunk} tokens")
    if replicas is not None:
        occ = ", ".join(
            f"r{i}: {n} reqs, occ {o:.1%}"
            for i, (n, o) in enumerate(zip(replicas["assignments"],
                                           replicas["occupancy"])))
        lines.append(f"dp replicas: {replicas['replicas']} x "
                     f"{replicas['mesh_shape']} ({occ})")
    if fault:
        lines.append(
            f"fault tolerance: {stats.replica_restarts} replica restarts, "
            f"{stats.redriven_requests} requests re-driven, "
            f"recovery p95 {stats.recovery_p95_s * 1e3:.1f}ms, "
            f"{stats.watchdog_trips} watchdog trips")
        tiers = ", ".join(f"tier{i}: {n} steps"
                          for i, n in enumerate(stats.kv_tier_steps))
        lines.append(f"degradation: {stats.degrade_transitions} "
                     f"transitions, {stats.degraded_steps} degraded steps "
                     f"({tiers or 'no tier ladder'})")
        if chaos_fired:
            fired = ", ".join(
                f"{site}#{occ}" + (f"[r{tag}]" if tag is not None else "")
                for site, tag, occ in chaos_fired)
            lines.append(f"chaos fired: {fired}")
    if spec:
        lines.append(
            f"spec: acceptance {stats.acceptance_rate:.1%} "
            f"({stats.draft_accepted}/{stats.draft_proposed}), "
            f"{stats.tokens_per_round:.2f} tokens/round over "
            f"{stats.spec_rounds} rounds")
    if paged is not None:
        dense_resv = paged["num_slots"] * paged["kv_bytes_per_slot"]
        lines.append(
            f"paged pool: peak {stats.pool_pages_peak}"
            f"/{stats.pool_pages_total} pages x "
            f"{stats.pool_page_size} tokens, "
            f"prefix hits {stats.prefix_hits} "
            f"({stats.prefix_hit_tokens} prompt tokens skipped, "
            f"{stats.prefix_hit_rate:.1%} hit rate), "
            f"cow copies {stats.cow_copies}")
        lines.append(
            f"kv memory: peak {stats.kv_bytes_peak / 2**20:.2f} MiB "
            f"paged vs {dense_resv / 2**20:.2f} MiB dense reservation "
            f"({paged['num_slots']} slots x "
            f"{paged['kv_bytes_per_slot'] / 2**20:.2f} MiB at "
            f"max_seq={paged['max_seq']})")
    return lines


def priority_report(reg) -> list[str]:
    """Per-priority-class latency breakdown (SLO scheduling admits by
    priority; aggregate percentiles hide priority inversions). Empty
    unless the registry saw more than one class."""
    if reg is None:
        return []
    m = reg.get("serve_requests_total")
    if m is None:
        return []
    by_pri = m.labeled("priority")
    if len(by_pri) < 2:
        return []
    lines = []
    for p in sorted(by_pri, key=lambda v: int(v)):
        lines.append(
            f"  priority {p}: {int(by_pri[p])} reqs, "
            f"queue delay p50 "
            f"{_ms(reg.quantile('serve_queue_delay_seconds', 50, priority=p))}"
            f" / p95 "
            f"{_ms(reg.quantile('serve_queue_delay_seconds', 95, priority=p))}"
            f", ttft p50 "
            f"{_ms(reg.quantile('serve_ttft_seconds', 50, priority=p))}"
            f" / p95 "
            f"{_ms(reg.quantile('serve_ttft_seconds', 95, priority=p))}, "
            f"tpot p50 "
            f"{reg.quantile('serve_tpot_seconds', 50, priority=p) * 1e3:.1f}"
            f"ms")
    return lines


def derived(stats, wall_s: float) -> dict:
    """Throughput derivations shared by the CLI line and the benchmark
    rows (one formula, not N copies)."""
    return {
        "tok_s": stats.generated_tokens / wall_s if wall_s else 0.0,
        "us_per_tok": (wall_s / stats.generated_tokens * 1e6
                       if stats.generated_tokens else 0.0),
    }
