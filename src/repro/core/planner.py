"""EWQ planner: model params -> block entropies -> QuantPlan (paper §3).

Works on the framework's standard param layout (see repro/models/model.py):
blocks are exposed by ``Model.block_params(params)`` as an ordered list of
{name: array} dicts — [embedding_block?, layer_0, ..., layer_{L-1}] — with
exec_index starting at 1 for the embedding block (paper Table 8 convention).
"""

from __future__ import annotations

from typing import Any, Callable, Mapping, Sequence

from repro.core import entropy as E
from repro.core import policy as P


def analyze(blocks: Sequence[Mapping[str, Any]], *, mode: str = "paper",
            eps: float = E.DEFAULT_EPS,
            first_exec_index: int = 1) -> list[E.BlockEntropy]:
    return E.analyze_blocks(blocks, mode=mode, eps=eps,
                            first_exec_index=first_exec_index)


def plan(blocks: Sequence[Mapping[str, Any]], *, variant: str = "4bit/8bit",
         x_factor: float = 1.0, mode: str = "paper",
         eps: float = E.DEFAULT_EPS) -> P.QuantPlan:
    """Produce a QuantPlan with one of the paper's §6.2 variants.

    variant:
      "raw"         — no quantization
      "4bit"        — uniform int4 (global quantization baseline)
      "8bit"        — uniform int8 (global quantization baseline)
      "8bit-mixed"  — H <= mu -> int8 else raw
      "4bit/8bit"   — H <= T -> int4; T < H <= mu -> int8; else raw
      "ternary/4bit"— edge variant: H <= T -> ternary; T < H <= mu -> int4
    """
    ents = analyze(blocks, mode=mode, eps=eps)
    if variant == "raw":
        return P.decide_uniform(ents, "raw")
    if variant == "4bit":
        return P.decide_uniform(ents, "int4")
    if variant == "8bit":
        return P.decide_uniform(ents, "int8")
    if variant == "8bit-mixed":
        return P.decide_8bit_mixed(ents)
    if variant == "4bit/8bit":
        return P.decide(ents, x_factor=x_factor, aggressive="int4")
    if variant == "ternary/4bit":
        pl = P.decide(ents, x_factor=x_factor, aggressive="ternary")
        # 8-bit tier becomes 4-bit in the edge configuration (paper §3.4).
        return pl.with_precisions(
            ["int4" if p == "int8" else p for p in pl.precisions()])
    raise ValueError(f"unknown variant {variant!r}")


def plan_model(model, params, *, variant: str = "4bit/8bit",
               x_factor: float = 1.0, mode: str = "paper",
               eps: float = E.DEFAULT_EPS) -> P.QuantPlan:
    """Convenience: EWQ plan for a Model instance (see models/model.py)."""
    return plan(model.block_params(params), variant=variant,
                x_factor=x_factor, mode=mode, eps=eps)


def plan_kv(cfg, plan: "P.QuantPlan | None" = None, *,
            kv_precision: str = "auto", group: int | None = None):
    """KV-cache precision plan for a model config (docs/DESIGN.md §10).

    Extends the block-entropy decision from weights to the serving KV
    cache: with ``kv_precision="auto"`` each attention layer's cache
    precision is derived from that layer's existing entropy decision in
    ``plan`` (low entropy -> int4 cache, mid -> int8, high/raw -> bf16);
    "int8"/"int4" force a uniform cache; "bf16" returns None. The result
    feeds ``ServeEngine(kv_precision=...)`` and is stamped into compiled
    artifacts by quant/compiler.py."""
    from repro.quant.compiler import compile_kv_plan
    from repro.quant.kvcache import DEFAULT_KV_GROUP
    return compile_kv_plan(cfg, plan, kv_precision,
                           group or DEFAULT_KV_GROUP)
