"""Resource-constrained block distribution — paper Algorithms 1 and 2.

Machines have memory X_i and disk Y_i; Z_i = min(X_i, Y_i); the cluster
budget is R = sum(Z_i). Algorithm 1 starts from the EWQ quantization
decision, then promotes blocks (toward raw, highest-entropy first) while the
model fits, or demotes (toward 1.58-bit, lowest-entropy first) until it
fits, and finally places blocks on machines first-fit by descending size.

Algorithm 2 (FastEWQ) does the same keyed on exec_index instead of entropy.

``fit_plan_to_hbm`` is the TPU-native adaptation (docs/DESIGN.md §3): the same
promote/demote loop run against a per-device HBM budget for a sharded
deployment (blocks are sharded, precision is the degree of freedom).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.policy import (BlockDecision, QuantPlan, bytes_per_param,
                               demote, promote)


@dataclasses.dataclass(frozen=True)
class Machine:
    name: str
    memory_bytes: float  # X_i
    disk_bytes: float    # Y_i

    @property
    def budget(self) -> float:  # Z_i
        return min(self.memory_bytes, self.disk_bytes)


def cluster_budget(machines: Sequence[Machine]) -> float:
    return sum(m.budget for m in machines)


def _plan_bytes(plan: QuantPlan, raw_bits: float) -> float:
    return plan.total_bytes(raw_bits)


def optimize_distribution(plan: QuantPlan, machines: Sequence[Machine], *,
                          raw_bits: float = 16.0) -> dict:
    """Algorithm 1. Returns {plan, placement, fits, total_bytes, budget}."""
    budget = cluster_budget(machines)
    decisions = list(plan.decisions)
    unquant_bytes = sum(d.num_parameters for d in decisions) * raw_bits / 8.0

    # Step 0: deploy unquantized when it fits.
    if unquant_bytes <= budget:
        final = plan.with_precisions(["raw"] * len(decisions))
        return _place(final, machines, raw_bits, budget)

    # Step 1: start from the EWQ decision (given in `plan`), then promote
    # highest-entropy blocks while resources allow.
    work = list(plan.decisions)
    size = sum(d.nbytes(raw_bits) for d in work)
    if size <= budget:
        for d in sorted(work, key=lambda d: -d.entropy):
            while d.precision != "raw":
                cand = dataclasses.replace(d, precision=promote(d.precision))
                delta = cand.nbytes(raw_bits) - d.nbytes(raw_bits)
                if size + delta > budget:
                    break
                size += delta
                work[d.block_index] = cand
                d = cand
    else:
        # Step 2: demote lowest-entropy blocks down to ternary until fit.
        for d in sorted(work, key=lambda d: d.entropy):
            while size > budget and d.precision != "ternary":
                cand = dataclasses.replace(d, precision=demote(d.precision))
                size += cand.nbytes(raw_bits) - d.nbytes(raw_bits)
                work[d.block_index] = cand
                d = cand
            if size <= budget:
                break

    final = dataclasses.replace(plan, decisions=work)
    return _place(final, machines, raw_bits, budget)


def fastewq_resource_adjust(plan: QuantPlan, machines: Sequence[Machine], *,
                            raw_bits: float = 16.0) -> dict:
    """Algorithm 2 steps 3-4: adjust the classifier's 8-bit preselection by
    exec_index under the resource budget, then place."""
    budget = cluster_budget(machines)
    work = list(plan.decisions)
    size = sum(d.nbytes(raw_bits) for d in work)
    if size < budget:
        # Promote lowest exec_index quantized blocks to raw while it fits.
        for d in sorted((d for d in work if d.quantized),
                        key=lambda d: d.exec_index):
            cand = dataclasses.replace(d, precision="raw")
            delta = cand.nbytes(raw_bits) - d.nbytes(raw_bits)
            if size + delta > budget:
                break
            size += delta
            work[d.block_index] = cand
    else:
        # Downgrade highest exec_index blocks 8->4->1.58 until fit.
        for d in sorted((d for d in work if d.quantized),
                        key=lambda d: -d.exec_index):
            while size > budget and d.precision != "ternary":
                cand = dataclasses.replace(d, precision=demote(d.precision))
                size += cand.nbytes(raw_bits) - d.nbytes(raw_bits)
                work[d.block_index] = cand
                d = cand
            if size <= budget:
                break
    final = dataclasses.replace(plan, decisions=work)
    return _place(final, machines, raw_bits, budget)


def _place(plan: QuantPlan, machines: Sequence[Machine], raw_bits: float,
           budget: float) -> dict:
    """First-fit-decreasing placement of blocks onto machines by Z_i."""
    remaining = {m.name: m.budget for m in machines}
    placement: dict[str, list[int]] = {m.name: [] for m in machines}
    ok = True
    for d in sorted(plan.decisions, key=lambda d: -d.nbytes(raw_bits)):
        b = d.nbytes(raw_bits)
        target = None
        for name in sorted(remaining, key=lambda n: -remaining[n]):
            if remaining[name] >= b:
                target = name
                break
        if target is None:
            ok = False
            continue
        remaining[target] -= b
        placement[target].append(d.block_index)
    total = plan.total_bytes(raw_bits)
    return {"plan": plan, "placement": placement, "fits": ok and
            total <= budget, "total_bytes": total, "budget": budget}


def fit_plan_to_hbm(plan: QuantPlan, *, hbm_bytes_per_device: float,
                    devices: int, reserved_fraction: float = 0.25,
                    raw_bits: float = 16.0) -> QuantPlan:
    """TPU-native variant: same promote/demote loop against the sharded
    per-device weight budget (activations/caches get ``reserved_fraction``)."""
    budget = hbm_bytes_per_device * (1 - reserved_fraction) * devices
    machines = [Machine("device", budget, budget)]
    return optimize_distribution(plan, machines,
                                 raw_bits=raw_bits)["plan"]
