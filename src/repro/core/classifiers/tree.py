"""CART decision tree (gini impurity) — numpy, no sklearn in this env."""

from __future__ import annotations

import numpy as np


class _Node:
    __slots__ = ("feature", "threshold", "left", "right", "proba")

    def __init__(self):
        self.feature = -1
        self.threshold = 0.0
        self.left = None
        self.right = None
        self.proba = None  # leaf class distribution


def _gini(counts: np.ndarray) -> float:
    n = counts.sum()
    if n == 0:
        return 0.0
    p = counts / n
    return 1.0 - float((p * p).sum())


class DecisionTree:
    def __init__(self, max_depth: int = 8, min_samples_leaf: int = 1,
                 max_features: int | None = None, rng=None):
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.rng = rng or np.random.default_rng(0)

    def fit(self, x: np.ndarray, y: np.ndarray) -> "DecisionTree":
        x = np.asarray(x, np.float64)
        y = np.asarray(y, np.int64)
        self.n_classes_ = int(y.max()) + 1 if len(y) else 2
        self.n_features_ = x.shape[1]
        self.feature_importances_ = np.zeros(self.n_features_)
        self.root_ = self._build(x, y, 0)
        s = self.feature_importances_.sum()
        if s > 0:
            self.feature_importances_ /= s
        return self

    def _leaf(self, y):
        node = _Node()
        counts = np.bincount(y, minlength=self.n_classes_)
        node.proba = counts / max(counts.sum(), 1)
        return node

    def _build(self, x, y, depth):
        if (depth >= self.max_depth or len(y) < 2 * self.min_samples_leaf
                or len(np.unique(y)) == 1):
            return self._leaf(y)

        n, d = x.shape
        feats = np.arange(d)
        if self.max_features and self.max_features < d:
            feats = self.rng.choice(d, self.max_features, replace=False)

        parent_counts = np.bincount(y, minlength=self.n_classes_)
        parent_gini = _gini(parent_counts)
        best = (None, -1, 0.0)  # (gain, feature, threshold)

        for f in feats:
            order = np.argsort(x[:, f], kind="stable")
            xs, ys = x[order, f], y[order]
            left = np.zeros(self.n_classes_)
            right = parent_counts.astype(np.float64).copy()
            for i in range(n - 1):
                left[ys[i]] += 1
                right[ys[i]] -= 1
                if xs[i] == xs[i + 1]:
                    continue
                nl, nr = i + 1, n - i - 1
                if nl < self.min_samples_leaf or nr < self.min_samples_leaf:
                    continue
                gain = parent_gini - (nl * _gini(left) + nr * _gini(right)) / n
                if best[0] is None or gain > best[0]:
                    best = (gain, f, 0.5 * (xs[i] + xs[i + 1]))

        if best[0] is None or best[0] <= 1e-12:
            return self._leaf(y)

        gain, f, thr = best
        self.feature_importances_[f] += gain * len(y)
        node = _Node()
        node.feature, node.threshold = int(f), float(thr)
        mask = x[:, f] <= thr
        node.left = self._build(x[mask], y[mask], depth + 1)
        node.right = self._build(x[~mask], y[~mask], depth + 1)
        return node

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float64)
        out = np.zeros((len(x), self.n_classes_))
        for i, row in enumerate(x):
            node = self.root_
            while node.proba is None:
                node = node.left if row[node.feature] <= node.threshold \
                    else node.right
            out[i] = node.proba
        return out

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.predict_proba(x).argmax(axis=1)
