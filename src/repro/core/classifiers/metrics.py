"""Classification metrics + paired statistics (paper Tables 3/4/5/11-13)."""

from __future__ import annotations

import math

import numpy as np


def confusion(y_true, y_pred) -> dict:
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    tp = int(((y_true == 1) & (y_pred == 1)).sum())
    tn = int(((y_true == 0) & (y_pred == 0)).sum())
    fp = int(((y_true == 0) & (y_pred == 1)).sum())
    fn = int(((y_true == 1) & (y_pred == 0)).sum())
    return {"tp": tp, "tn": tn, "fp": fp, "fn": fn}


def classification_report(y_true, y_pred) -> dict:
    """Per-class precision/recall/F1/support + accuracy + macro/weighted."""
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    out = {"classes": {}}
    supports = []
    for c in (0, 1):
        tp = ((y_true == c) & (y_pred == c)).sum()
        fp = ((y_true != c) & (y_pred == c)).sum()
        fn = ((y_true == c) & (y_pred != c)).sum()
        prec = tp / max(tp + fp, 1)
        rec = tp / max(tp + fn, 1)
        f1 = 2 * prec * rec / max(prec + rec, 1e-12)
        support = int((y_true == c).sum())
        supports.append(support)
        out["classes"][c] = {"precision": float(prec), "recall": float(rec),
                             "f1": float(f1), "support": support}
    out["accuracy"] = float((y_true == y_pred).mean())
    cs = out["classes"]
    out["macro_avg"] = {k: float(np.mean([cs[c][k] for c in (0, 1)]))
                        for k in ("precision", "recall", "f1")}
    w = np.array(supports) / max(sum(supports), 1)
    out["weighted_avg"] = {k: float(sum(w[i] * cs[c][k]
                                        for i, c in enumerate((0, 1))))
                           for k in ("precision", "recall", "f1")}
    return out


def roc_curve(y_true, scores):
    """Returns (fpr, tpr, thresholds) sorted by descending score."""
    y_true = np.asarray(y_true)
    scores = np.asarray(scores, np.float64)
    order = np.argsort(-scores, kind="stable")
    y = y_true[order]
    tps = np.cumsum(y == 1)
    fps = np.cumsum(y == 0)
    p = max((y_true == 1).sum(), 1)
    n = max((y_true == 0).sum(), 1)
    tpr = np.concatenate([[0.0], tps / p])
    fpr = np.concatenate([[0.0], fps / n])
    return fpr, tpr, np.concatenate([[np.inf], scores[order]])


def auc(y_true, scores) -> float:
    fpr, tpr, _ = roc_curve(y_true, scores)
    return float(np.trapezoid(tpr, fpr))


# ---------------------------------------------------------------------------
# Paired statistics (paper §6.3.1)
# ---------------------------------------------------------------------------

def _t_sf(t: float, df: int) -> float:
    """Two-sided p-value for Student's t via the incomplete beta function
    (continued-fraction evaluation; no scipy dependency)."""
    x = df / (df + t * t)
    p = _betainc(df / 2.0, 0.5, x)
    return float(min(max(p, 0.0), 1.0))


def _betainc(a: float, b: float, x: float) -> float:
    if x <= 0:
        return 0.0
    if x >= 1:
        return 1.0
    lbeta = (math.lgamma(a + b) - math.lgamma(a) - math.lgamma(b)
             + a * math.log(x) + b * math.log(1 - x))
    front = math.exp(lbeta)
    if x < (a + 1) / (a + b + 2):
        return front * _betacf(a, b, x) / a
    return 1.0 - front * _betacf(b, a, 1 - x) / b


def _betacf(a: float, b: float, x: float) -> float:
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c, d = 1.0, 1.0 - qab * x / qap
    d = 1.0 / (d if abs(d) > 1e-30 else 1e-30)
    h = d
    for m in range(1, 200):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        d = 1.0 / (d if abs(d) > 1e-30 else 1e-30)
        c = 1.0 + aa / (c if abs(c) > 1e-30 else 1e-30)
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        d = 1.0 / (d if abs(d) > 1e-30 else 1e-30)
        c = 1.0 + aa / (c if abs(c) > 1e-30 else 1e-30)
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < 1e-12:
            break
    return h


def paired_t_test(a, b) -> dict:
    """Paired t-test: t = mean(d) / (std(d)/sqrt(n)); two-sided p."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    d = a - b
    n = len(d)
    sd = d.std(ddof=1)
    if sd == 0 or n < 2:
        return {"t": 0.0, "p": 1.0, "mean_diff": float(d.mean())}
    t = d.mean() / (sd / np.sqrt(n))
    return {"t": float(t), "p": _t_sf(abs(t), n - 1),
            "mean_diff": float(d.mean())}


def cohens_d(a, b) -> float:
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    na, nb = len(a), len(b)
    sp = np.sqrt(((na - 1) * a.var(ddof=1) + (nb - 1) * b.var(ddof=1))
                 / max(na + nb - 2, 1))
    if sp == 0:
        return 0.0
    return float((a.mean() - b.mean()) / sp)


def significance_label(p: float) -> str:
    if p < 0.05:
        return "significant"
    if p < 0.10:
        return "marginally significant"
    return "not significant"


def effect_size_label(d: float) -> str:
    d = abs(d)
    if d < 0.2:
        return "negligible"
    if d < 0.5:
        return "small"
    if d < 0.8:
        return "medium"
    return "large"
