"""k-nearest neighbors (euclidean, majority vote)."""

from __future__ import annotations

import numpy as np


class KNN:
    def __init__(self, k: int = 7):
        self.k = k

    def fit(self, x: np.ndarray, y: np.ndarray) -> "KNN":
        self.x_ = np.asarray(x, np.float64)
        self.y_ = np.asarray(y, np.int64)
        self.n_classes_ = int(self.y_.max()) + 1
        return self

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float64)
        d2 = ((x[:, None, :] - self.x_[None, :, :]) ** 2).sum(-1)
        idx = np.argsort(d2, axis=1)[:, :self.k]
        out = np.zeros((len(x), self.n_classes_))
        for i, nbrs in enumerate(idx):
            out[i] = np.bincount(self.y_[nbrs], minlength=self.n_classes_)
        return out / self.k

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.predict_proba(x).argmax(axis=1)
