"""Gradient-boosted trees (logistic loss) — the paper's XGB stand-in."""

from __future__ import annotations

import numpy as np

from repro.core.classifiers.tree import DecisionTree


class _RegressionStump:
    """Depth-limited regression tree on residuals (squared-error splits)."""

    def __init__(self, max_depth=3, min_samples_leaf=5):
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf

    def fit(self, x, g):
        self.tree_ = self._build(np.asarray(x, np.float64),
                                 np.asarray(g, np.float64), 0)
        return self

    def _build(self, x, g, depth):
        if depth >= self.max_depth or len(g) < 2 * self.min_samples_leaf:
            return ("leaf", g.mean() if len(g) else 0.0)
        n, d = x.shape
        parent_sse = ((g - g.mean()) ** 2).sum()
        best = (None, -1, 0.0)
        for f in range(d):
            order = np.argsort(x[:, f], kind="stable")
            xs, gs = x[order, f], g[order]
            csum = np.cumsum(gs)
            csq = np.cumsum(gs * gs)
            total, total_sq = csum[-1], csq[-1]
            for i in range(self.min_samples_leaf - 1,
                           n - self.min_samples_leaf):
                if xs[i] == xs[i + 1]:
                    continue
                nl = i + 1
                nr = n - nl
                sse_l = csq[i] - csum[i] ** 2 / nl
                sse_r = (total_sq - csq[i]) - (total - csum[i]) ** 2 / nr
                gain = parent_sse - sse_l - sse_r
                if best[0] is None or gain > best[0]:
                    best = (gain, f, 0.5 * (xs[i] + xs[i + 1]))
        if best[0] is None or best[0] <= 1e-12:
            return ("leaf", g.mean() if len(g) else 0.0)
        _, f, thr = best
        mask = x[:, f] <= thr
        return ("node", f, thr, self._build(x[mask], g[mask], depth + 1),
                self._build(x[~mask], g[~mask], depth + 1))

    def predict(self, x):
        x = np.asarray(x, np.float64)
        out = np.empty(len(x))
        for i, row in enumerate(x):
            node = self.tree_
            while node[0] == "node":
                _, f, thr, l, r = node
                node = l if row[f] <= thr else r
            out[i] = node[1]
        return out


class GradientBoosting:
    def __init__(self, n_estimators: int = 100, lr: float = 0.1,
                 max_depth: int = 3):
        self.n_estimators = n_estimators
        self.lr = lr
        self.max_depth = max_depth

    def fit(self, x: np.ndarray, y: np.ndarray) -> "GradientBoosting":
        x = np.asarray(x, np.float64)
        y = np.asarray(y, np.float64)
        p = np.clip(y.mean(), 1e-6, 1 - 1e-6)
        self.f0_ = np.log(p / (1 - p))
        f = np.full(len(y), self.f0_)
        self.stumps_ = []
        for _ in range(self.n_estimators):
            prob = 1.0 / (1.0 + np.exp(-f))
            residual = y - prob  # negative gradient of logloss
            stump = _RegressionStump(max_depth=self.max_depth).fit(x, residual)
            self.stumps_.append(stump)
            f = f + self.lr * stump.predict(x)
        return self

    def decision_function(self, x):
        f = np.full(len(x), self.f0_)
        for stump in self.stumps_:
            f = f + self.lr * stump.predict(x)
        return f

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        p1 = 1.0 / (1.0 + np.exp(-self.decision_function(x)))
        return np.stack([1 - p1, p1], axis=1)

    def predict(self, x: np.ndarray) -> np.ndarray:
        return (self.decision_function(x) >= 0).astype(np.int64)
