"""StandardScaler (paper §4.2): per-feature z-scoring fit on the train set."""

from __future__ import annotations

import numpy as np


class StandardScaler:
    def fit(self, x: np.ndarray) -> "StandardScaler":
        x = np.asarray(x, np.float64)
        self.mean_ = x.mean(axis=0)
        self.scale_ = x.std(axis=0)
        self.scale_ = np.where(self.scale_ == 0, 1.0, self.scale_)
        return self

    def transform(self, x: np.ndarray) -> np.ndarray:
        return (np.asarray(x, np.float64) - self.mean_) / self.scale_

    def fit_transform(self, x: np.ndarray) -> np.ndarray:
        return self.fit(x).transform(x)
