"""Gaussian naive Bayes."""

from __future__ import annotations

import numpy as np


class GaussianNB:
    def fit(self, x: np.ndarray, y: np.ndarray) -> "GaussianNB":
        x = np.asarray(x, np.float64)
        y = np.asarray(y, np.int64)
        self.classes_ = np.unique(y)
        self.mu_ = np.stack([x[y == c].mean(0) for c in self.classes_])
        self.var_ = np.stack([x[y == c].var(0) + 1e-9 for c in self.classes_])
        self.prior_ = np.array([(y == c).mean() for c in self.classes_])
        return self

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float64)
        ll = (-0.5 * (np.log(2 * np.pi * self.var_)[None]
                      + (x[:, None, :] - self.mu_[None]) ** 2
                      / self.var_[None]).sum(-1)
              + np.log(self.prior_)[None])
        ll -= ll.max(axis=1, keepdims=True)
        p = np.exp(ll)
        return p / p.sum(axis=1, keepdims=True)

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.predict_proba(x).argmax(axis=1)
