"""Linear classifiers: logistic regression and linear SVM (hinge loss).

Full-batch gradient descent with L2 regularization — ample for 700-row
tabular data (paper §4.4).
"""

from __future__ import annotations

import numpy as np


class LogisticRegression:
    def __init__(self, lr: float = 0.1, steps: int = 2000, l2: float = 1e-3):
        self.lr, self.steps, self.l2 = lr, steps, l2

    def fit(self, x: np.ndarray, y: np.ndarray) -> "LogisticRegression":
        x = np.asarray(x, np.float64)
        y = np.asarray(y, np.float64)
        n, d = x.shape
        self.w_ = np.zeros(d)
        self.b_ = 0.0
        for _ in range(self.steps):
            z = x @ self.w_ + self.b_
            p = 1.0 / (1.0 + np.exp(-z))
            g = p - y
            self.w_ -= self.lr * (x.T @ g / n + self.l2 * self.w_)
            self.b_ -= self.lr * g.mean()
        return self

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        p1 = 1.0 / (1.0 + np.exp(-(np.asarray(x, np.float64) @ self.w_
                                   + self.b_)))
        return np.stack([1 - p1, p1], axis=1)

    def predict(self, x: np.ndarray) -> np.ndarray:
        return (self.predict_proba(x)[:, 1] >= 0.5).astype(np.int64)


class LinearSVM:
    def __init__(self, lr: float = 0.05, steps: int = 3000, c: float = 1.0):
        self.lr, self.steps, self.c = lr, steps, c

    def fit(self, x: np.ndarray, y: np.ndarray) -> "LinearSVM":
        x = np.asarray(x, np.float64)
        ys = np.where(np.asarray(y) > 0, 1.0, -1.0)
        n, d = x.shape
        self.w_ = np.zeros(d)
        self.b_ = 0.0
        for _ in range(self.steps):
            margin = ys * (x @ self.w_ + self.b_)
            active = margin < 1.0
            gw = self.w_ - self.c * (ys[active, None] * x[active]).sum(0) / n
            gb = -self.c * ys[active].sum() / n
            self.w_ -= self.lr * gw
            self.b_ -= self.lr * gb
        return self

    def decision_function(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(x, np.float64) @ self.w_ + self.b_

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        # Platt-free squashing for ROC purposes.
        z = self.decision_function(x)
        p1 = 1.0 / (1.0 + np.exp(-z))
        return np.stack([1 - p1, p1], axis=1)

    def predict(self, x: np.ndarray) -> np.ndarray:
        return (self.decision_function(x) >= 0).astype(np.int64)
