"""Random forest — the paper's chosen FastEWQ classifier (80% held-out acc)."""

from __future__ import annotations

import numpy as np

from repro.core.classifiers.tree import DecisionTree


class RandomForest:
    def __init__(self, n_estimators: int = 100, max_depth: int = 8,
                 min_samples_leaf: int = 1, max_features: str | int = "sqrt",
                 seed: int = 0):
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.seed = seed

    def fit(self, x: np.ndarray, y: np.ndarray) -> "RandomForest":
        x = np.asarray(x, np.float64)
        y = np.asarray(y, np.int64)
        rng = np.random.default_rng(self.seed)
        n, d = x.shape
        mf = (max(1, int(np.sqrt(d))) if self.max_features == "sqrt"
              else self.max_features or d)
        self.trees_ = []
        for _ in range(self.n_estimators):
            idx = rng.integers(0, n, n)  # bootstrap
            tree = DecisionTree(max_depth=self.max_depth,
                                min_samples_leaf=self.min_samples_leaf,
                                max_features=mf,
                                rng=np.random.default_rng(rng.integers(2**31)))
            self.trees_.append(tree.fit(x[idx], y[idx]))
        self.n_classes_ = self.trees_[0].n_classes_
        imp = np.mean([t.feature_importances_ for t in self.trees_], axis=0)
        s = imp.sum()
        self.feature_importances_ = imp / s if s > 0 else imp
        return self

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        return np.mean([t.predict_proba(x) for t in self.trees_], axis=0)

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.predict_proba(x).argmax(axis=1)
