"""FastEWQ training dataset builder (paper §4.1).

Each row describes one transformer block:
  (model_name, num_blocks, exec_index, num_parameters,
   quantization_type, quantized)

Rows are produced by running the FULL EWQ weight analysis on reduced-config
instantiations of the assigned architecture families (briefly trained so the
weight distributions differentiate — random init gives near-degenerate
entropy spread), exactly mirroring how the paper built its 700-row dataset
from public checkpoints.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

FEATURES = ("num_parameters", "exec_index", "num_blocks")


@dataclasses.dataclass(frozen=True)
class BlockRow:
    model_name: str
    num_blocks: int
    exec_index: int
    num_parameters: int
    quantization_type: str  # "raw" | "8-bit" | "4-bit"
    quantized: int          # 0 | 1


def rows_from_plan(model_name: str, plan) -> list[BlockRow]:
    n = len(plan.decisions)
    out = []
    for d in plan.decisions:
        qt = {"raw": "raw", "int8": "8-bit", "int4": "4-bit",
              "int3": "4-bit", "ternary": "4-bit"}[d.precision]
        out.append(BlockRow(model_name=model_name, num_blocks=n,
                            exec_index=d.exec_index,
                            num_parameters=d.num_parameters,
                            quantization_type=qt,
                            quantized=int(d.precision != "raw")))
    return out


def to_xy(rows: Sequence[BlockRow]):
    x = np.array([[r.num_parameters, r.exec_index, r.num_blocks]
                  for r in rows], np.float64)
    y = np.array([r.quantized for r in rows], np.int64)
    return x, y


def train_test_split(x, y, test_frac: float = 0.3, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = len(y)
    idx = rng.permutation(n)
    n_test = int(round(n * test_frac))
    te, tr = idx[:n_test], idx[n_test:]
    return x[tr], y[tr], x[te], y[te]


def build_dataset(*, steps: int = 60, seeds: Sequence[int] = (0,),
                  archs: Sequence[str] | None = None,
                  scale_overrides: dict | None = None) -> list[BlockRow]:
    """Train each reduced arch briefly on synthetic data, run EWQ, collect
    block rows. CPU-sized; used by tests and benchmarks (cached results in
    benchmarks/results/fastewq_dataset.json for reuse)."""
    import jax
    from repro.configs.registry import ARCHS, get_config
    from repro.core.planner import plan_model
    from repro.data.synthetic import synthetic_batch
    from repro.models.model import build
    from repro.configs.base import RunConfig
    from repro.launch.steps import make_optimizer
    from repro.train.step import make_train_step

    rows: list[BlockRow] = []
    for arch in (archs or ARCHS):
        for seed in seeds:
            cfg = get_config(arch, smoke=True)
            # deepen the reduced configs so each model contributes a
            # realistic number of block rows (paper: 700 rows)
            depth = {"hybrid": 8, "encdec": 6}.get(
                get_config(arch, smoke=True).family, 9)
            cfg = dataclasses.replace(cfg, num_layers=depth)
            if scale_overrides:
                cfg = dataclasses.replace(cfg, **scale_overrides)
            model = build(cfg)
            params = model.init(jax.random.PRNGKey(seed))
            run = RunConfig(steps=steps, learning_rate=1e-3, warmup_steps=5,
                            remat=False)
            opt = make_optimizer(run)
            opt_state = opt.init(params)
            step = jax.jit(make_train_step(model, opt, run))
            for i in range(steps):
                batch = synthetic_batch(cfg, batch=8, seq=64, step=i,
                                        seed=seed)
                params, opt_state, _ = step(params, opt_state, batch)
            plan = plan_model(model, params, variant="4bit/8bit")
            rows.extend(rows_from_plan(f"{cfg.name}-s{seed}", plan))
    return rows
