"""FastEWQ (paper §4): O(1) quantization decisions from architecture
metadata — no weight download.

Features per block: (num_parameters, exec_index, num_blocks). A classifier
(random forest by default, per the paper's model selection) predicts
quantized/not; Algorithm 2 then assigns precision levels by exec_index under
resource constraints (repro/core/cluster.py).
"""

from __future__ import annotations

import dataclasses
import json
import pickle
from typing import Sequence

import numpy as np

from repro.core.classifiers.boosted import GradientBoosting
from repro.core.classifiers.gnb import GaussianNB
from repro.core.classifiers.knn import KNN
from repro.core.classifiers.linear import LinearSVM, LogisticRegression
from repro.core.classifiers.metrics import (auc, classification_report,
                                            confusion)
from repro.core.classifiers.rf import RandomForest
from repro.core.classifiers.scaler import StandardScaler
from repro.core.dataset import FEATURES, BlockRow, to_xy, train_test_split
from repro.core.policy import BlockDecision, QuantPlan

CLASSIFIERS = {
    "logistic regression": lambda: LogisticRegression(),
    "SVM": lambda: LinearSVM(),
    "random forest": lambda: RandomForest(n_estimators=80, max_depth=8),
    "XGB": lambda: GradientBoosting(n_estimators=80),
    "kNN": lambda: KNN(k=7),
    "Gaussian naive Bayes": lambda: GaussianNB(),
}


@dataclasses.dataclass
class FastEWQ:
    """Trained FastEWQ classifier + scaler."""
    scaler: StandardScaler
    clf: object
    name: str = "random forest"

    def predict_quantized(self, num_parameters, exec_index, num_blocks):
        x = np.atleast_2d(np.array(
            [num_parameters, exec_index, num_blocks], np.float64))
        return int(self.clf.predict(self.scaler.transform(x))[0])

    def plan(self, block_sizes: Sequence[int], *, start_exec_index: int = 1,
             variant: str = "8bit-mixed") -> QuantPlan:
        """O(1)-per-block plan from metadata only (paper Algorithm 2 phase 1:
        classify; phase 2 initializes quantized blocks at 8-bit — resource
        adjustment is cluster.fastewq_resource_adjust)."""
        n = len(block_sizes)
        decisions = []
        for i, size in enumerate(block_sizes):
            exec_index = start_exec_index + i
            q = self.predict_quantized(size, exec_index, n)
            prec = "int8" if q else "raw"
            decisions.append(BlockDecision(
                block_index=i, exec_index=exec_index, entropy=float("nan"),
                num_parameters=int(size), precision=prec))
        if variant.startswith("4bit") and decisions:
            # the highest-exec-index quantized block drops to int4 (§6.3)
            for d in reversed(decisions):
                if d.quantized:
                    decisions[d.block_index] = dataclasses.replace(
                        d, precision="int4")
                    break
        return QuantPlan(decisions=decisions, mu=float("nan"),
                         sigma=float("nan"), threshold=float("nan"),
                         x_factor=1.0)

    def save(self, path: str):
        with open(path, "wb") as f:
            pickle.dump(self, f)

    def kv_spill_order(self, block_sizes: Sequence[int], *,
                       start_exec_index: int = 1) -> list:
        """Layer order for graceful KV degradation (DESIGN.md §15).

        Same O(1) metadata classification as ``plan``: blocks FastEWQ
        marks quantizable spill their KV precision down a tier FIRST
        (their activations tolerate coarser representation — the layer-
        level entropy signal the classifier encodes), and within each
        class later exec indices spill before earlier ones, mirroring
        §6.3's rule that the deepest quantized block is the first to
        drop to 4-bit. Returns block indices, first-to-spill first.
        """
        n = len(block_sizes)
        ranked = []
        for i, size in enumerate(block_sizes):
            q = self.predict_quantized(size, start_exec_index + i, n)
            ranked.append((0 if q else 1, -(start_exec_index + i), i))
        return [i for _, _, i in sorted(ranked)]

    @staticmethod
    def load(path: str) -> "FastEWQ":
        with open(path, "rb") as f:
            return pickle.load(f)


def train_fastewq(rows: Sequence[BlockRow], *, classifier: str = "random forest",
                  full_dataset: bool = False, seed: int = 0) -> FastEWQ:
    """``full_dataset=True`` = the paper's overfitted 'fast' variant (99%
    train acc, centralized knowledge base); False = 70/30 'fast train'."""
    x, y = to_xy(rows)
    if full_dataset:
        xtr, ytr = x, y
    else:
        xtr, ytr, _, _ = train_test_split(x, y, 0.3, seed)
    scaler = StandardScaler()
    clf = CLASSIFIERS[classifier]()
    clf.fit(scaler.fit_transform(xtr), ytr)
    return FastEWQ(scaler=scaler, clf=clf, name=classifier)


def evaluate_all_classifiers(rows: Sequence[BlockRow], *, seed: int = 0):
    """Paper Tables 3 + 5 + ROC-AUC for all six classifiers."""
    x, y = to_xy(rows)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.3, seed)
    scaler = StandardScaler()
    xtr_s = scaler.fit_transform(xtr)
    xte_s = scaler.transform(xte)
    out = {}
    for name, make in CLASSIFIERS.items():
        clf = make()
        clf.fit(xtr_s, ytr)
        pred = clf.predict(xte_s)
        scores = clf.predict_proba(xte_s)[:, 1]
        rep = classification_report(yte, pred)
        rep["confusion"] = confusion(yte, pred)
        rep["auc"] = auc(yte, scores)
        if hasattr(clf, "feature_importances_"):
            rep["feature_importances"] = dict(
                zip(FEATURES, map(float, clf.feature_importances_)))
        out[name] = rep
    return out


def feature_ablation(rows: Sequence[BlockRow], *, seed: int = 0) -> dict:
    """Paper §4.3 ablation: drop one feature, report RF accuracy."""
    x, y = to_xy(rows)
    out = {}
    for drop in [None, *range(x.shape[1])]:
        cols = [i for i in range(x.shape[1]) if i != drop]
        xtr, ytr, xte, yte = train_test_split(x[:, cols], y, 0.3, seed)
        sc = StandardScaler()
        clf = RandomForest(n_estimators=80, max_depth=8)
        clf.fit(sc.fit_transform(xtr), ytr)
        acc = float((clf.predict(sc.transform(xte)) == yte).mean())
        key = "all" if drop is None else f"without_{FEATURES[drop]}"
        out[key] = acc
    return out
