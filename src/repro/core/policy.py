"""Block selection criteria and quantization plans (paper §3.3).

Threshold: T = mu_H - X * sigma_H   (X >= 0, default 1.0)
Decision:  H <= T          -> "int4"  (or "ternary" when aggressive)
           T < H <= mu_H   -> "int8"
           H > mu_H        -> "raw"
"""

from __future__ import annotations

import dataclasses
import json
from typing import Sequence

import numpy as np

from repro.core.entropy import BlockEntropy, entropy_stats

# Precision identifiers, ordered from most to least aggressive.
PRECISIONS = ("ternary", "int3", "int4", "int8", "raw")
BITS = {"ternary": 1.58, "int3": 3.0, "int4": 4.0, "int8": 8.0, "raw": 16.0}
# Promotion order used by Algorithm 1 (towards raw).
_PROMOTE = {"ternary": "int4", "int3": "int4", "int4": "int8", "int8": "raw", "raw": "raw"}
_DEMOTE = {"raw": "int8", "int8": "int4", "int4": "ternary", "int3": "ternary",
           "ternary": "ternary"}


def promote(p: str) -> str:
    return _PROMOTE[p]


def demote(p: str) -> str:
    return _DEMOTE[p]


def bytes_per_param(precision: str, raw_bits: float = 16.0) -> float:
    """Bytes per parameter at a precision. ``raw`` follows the model dtype
    (bf16 = 16 bits by default). int4/ternary include per-group scale
    overhead (group=128, fp16 scale -> +0.125 bits/param)."""
    if precision == "raw":
        return raw_bits / 8.0
    overhead_bits = 16.0 / 128.0  # one fp16 scale per 128-param group
    return (BITS[precision] + overhead_bits) / 8.0


@dataclasses.dataclass(frozen=True)
class BlockDecision:
    block_index: int
    exec_index: int
    entropy: float
    num_parameters: int
    precision: str  # element of PRECISIONS

    @property
    def quantized(self) -> bool:
        return self.precision != "raw"

    def nbytes(self, raw_bits: float = 16.0) -> float:
        return self.num_parameters * bytes_per_param(self.precision, raw_bits)


@dataclasses.dataclass
class QuantPlan:
    """A full-model quantization plan: one decision per block.

    ``decisions`` is ordered by block_index (model order). ``by_priority``
    yields the paper's ascending-entropy ordering (quantize-first priority).
    """
    decisions: list[BlockDecision]
    mu: float
    sigma: float
    threshold: float
    x_factor: float

    # ---- views -----------------------------------------------------------
    def by_priority(self) -> list[BlockDecision]:
        return sorted(self.decisions, key=lambda d: d.entropy)

    def precisions(self) -> list[str]:
        return [d.precision for d in self.decisions]

    def counts(self) -> dict[str, int]:
        out = {p: 0 for p in PRECISIONS}
        for d in self.decisions:
            out[d.precision] += 1
        return out

    def total_bytes(self, raw_bits: float = 16.0) -> float:
        return sum(d.nbytes(raw_bits) for d in self.decisions)

    def raw_bytes(self, raw_bits: float = 16.0) -> float:
        return sum(d.num_parameters * raw_bits / 8.0 for d in self.decisions)

    def reduction(self, raw_bits: float = 16.0) -> float:
        raw = self.raw_bytes(raw_bits)
        return 0.0 if raw == 0 else 1.0 - self.total_bytes(raw_bits) / raw

    def with_precisions(self, precisions: Sequence[str]) -> "QuantPlan":
        assert len(precisions) == len(self.decisions)
        ds = [dataclasses.replace(d, precision=p)
              for d, p in zip(self.decisions, precisions)]
        return dataclasses.replace(self, decisions=ds)

    # ---- (de)serialization ------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "mu": self.mu, "sigma": self.sigma, "threshold": self.threshold,
            "x_factor": self.x_factor,
            "decisions": [dataclasses.asdict(d) for d in self.decisions],
        }, indent=2)

    @staticmethod
    def from_json(s: str) -> "QuantPlan":
        obj = json.loads(s)
        ds = [BlockDecision(**d) for d in obj["decisions"]]
        return QuantPlan(decisions=ds, mu=obj["mu"], sigma=obj["sigma"],
                         threshold=obj["threshold"], x_factor=obj["x_factor"])


def decide(entropies: Sequence[BlockEntropy], *, x_factor: float = 1.0,
           aggressive: str = "int4") -> QuantPlan:
    """Paper §3.3 quantization decision.

    aggressive: precision for blocks with H <= T ("int4", "int3" or "ternary").
    """
    assert aggressive in ("int4", "int3", "ternary")
    mu, sigma = entropy_stats([b.entropy for b in entropies])
    t = mu - x_factor * sigma
    ds = []
    for b in entropies:
        if b.entropy <= t:
            p = aggressive
        elif b.entropy <= mu:
            p = "int8"
        else:
            p = "raw"
        ds.append(BlockDecision(block_index=b.block_index,
                                exec_index=b.exec_index, entropy=b.entropy,
                                num_parameters=b.num_parameters, precision=p))
    return QuantPlan(decisions=ds, mu=mu, sigma=sigma, threshold=t,
                     x_factor=x_factor)


def decide_8bit_mixed(entropies: Sequence[BlockEntropy]) -> QuantPlan:
    """Paper §6.2 '8bit mixed' variant: H <= mu -> int8, else raw."""
    mu, sigma = entropy_stats([b.entropy for b in entropies])
    ds = [BlockDecision(block_index=b.block_index, exec_index=b.exec_index,
                        entropy=b.entropy, num_parameters=b.num_parameters,
                        precision="int8" if b.entropy <= mu else "raw")
          for b in entropies]
    return QuantPlan(decisions=ds, mu=mu, sigma=sigma, threshold=mu, x_factor=0.0)


def decide_uniform(entropies: Sequence[BlockEntropy], precision: str) -> QuantPlan:
    """Global (uniform) quantization baseline — a special case of the plan."""
    assert precision in PRECISIONS
    mu, sigma = entropy_stats([b.entropy for b in entropies] or [0.0])
    ds = [BlockDecision(block_index=b.block_index, exec_index=b.exec_index,
                        entropy=b.entropy, num_parameters=b.num_parameters,
                        precision=precision)
          for b in entropies]
    return QuantPlan(decisions=ds, mu=mu, sigma=sigma, threshold=float("inf"),
                     x_factor=0.0)
