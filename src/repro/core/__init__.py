# The paper's primary contribution: EWQ entropy analysis, selection policy,
# planner, FastEWQ classifier and cluster-distribution algorithms.
from repro.core import entropy, planner, policy  # noqa: F401
