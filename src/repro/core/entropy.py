"""Entropy analysis of weight matrices and transformer blocks (paper §3.1-3.2).

The paper defines, for a weight matrix W with n parameters:

    p_i = softmax(flatten(W))_i
    H(W) = -sum_i p_i * log(p_i + eps)          (eps ~ 1e-2 for stability)

and for a block containing matrices {W_i}:

    H_block = sum_i |W_i| * H(W_i) / sum_i |W_i|

Two numerically-equivalent implementations are provided:

* ``mode="paper"``  — literal formula (materializes softmax), bit-faithful to
  the paper including the eps inside the log.
* ``mode="stream"`` — closed form H = lse(w) - E_p[w] computed with online
  (chunked) logsumexp / weighted sums; never materializes p. This is the
  form the Pallas kernel (repro/kernels/entropy) implements for TPU; eps=0.

For eps -> 0 both agree; tests assert closeness for small eps.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_EPS = 0.01


def matrix_entropy_paper(w: jax.Array, eps: float = DEFAULT_EPS) -> jax.Array:
    """Literal paper formula: H = -sum p log(p + eps), p = softmax(flat(w))."""
    flat = w.reshape(-1).astype(jnp.float32)
    p = jax.nn.softmax(flat)
    return -jnp.sum(p * jnp.log(p + eps))


def matrix_entropy_stream(w: jax.Array, chunk: int = 1 << 20) -> jax.Array:
    """Closed form H = logsumexp(w) - sum(w * e^w)/sum(e^w), streamed in chunks.

    Online update keeps (running max m, running Z = sum e^{w-m},
    running S = sum w * e^{w-m}) and merges chunks the usual
    online-logsumexp way.  Equivalent to the paper formula at eps=0.
    """
    flat = w.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % chunk
    flat = jnp.pad(flat, (0, pad), constant_values=-jnp.inf)
    chunks = flat.reshape(-1, chunk)

    def body(carry, x):
        m, z, s = carry
        cm = jnp.max(x)
        new_m = jnp.maximum(m, cm)
        # Rescale old accumulators to the new max.
        scale = jnp.exp(m - new_m)
        e = jnp.exp(x - new_m)
        # w * e^w terms: -inf pad contributes exp(-inf)=0; 0 * inf -> nan, so
        # mask the weighted term explicitly.
        we = jnp.where(jnp.isfinite(x), x * e, 0.0)
        return (new_m, z * scale + jnp.sum(e), s * scale + jnp.sum(we)), None

    init = (jnp.float32(-jnp.inf), jnp.float32(0.0), jnp.float32(0.0))
    (m, z, s), _ = jax.lax.scan(body, init, chunks)
    lse = m + jnp.log(z)
    mean_w = s / z
    return lse - mean_w


def matrix_entropy(w: jax.Array, *, mode: str = "paper",
                   eps: float = DEFAULT_EPS) -> jax.Array:
    if mode == "paper":
        return matrix_entropy_paper(w, eps=eps)
    if mode == "stream":
        return matrix_entropy_stream(w)
    if mode == "kernel":  # Pallas path; imported lazily to avoid cycles.
        from repro.kernels.entropy.ops import matrix_entropy as kernel_entropy
        return kernel_entropy(w)
    raise ValueError(f"unknown entropy mode: {mode}")


@dataclasses.dataclass(frozen=True)
class BlockEntropy:
    """Entropy record for one transformer block."""
    block_index: int          # 0-based model-definition index
    exec_index: int           # paper-style execution index (embedding block=1, first transformer block=2)
    entropy: float            # weighted H_block
    num_parameters: int       # sum of |W_i|
    per_matrix: dict[str, tuple[float, int]]  # name -> (H, size)


def block_entropy_from_matrices(
    mats: Mapping[str, jax.Array], *, mode: str = "paper",
    eps: float = DEFAULT_EPS,
) -> tuple[float, int, dict[str, tuple[float, int]]]:
    """Weighted block entropy over named weight matrices.

    Only >=2D arrays (Linear / Embedding weights) participate, matching the
    paper ("quantization applied to the Linear and Embedding layers");
    vectors (biases, norm scales) are excluded.
    """
    per: dict[str, tuple[float, int]] = {}
    total = 0
    acc = 0.0
    for name, w in sorted(mats.items()):
        if w.ndim < 2:
            continue
        size = int(np.prod(w.shape))
        h = float(matrix_entropy(w, mode=mode, eps=eps))
        per[name] = (h, size)
        total += size
        acc += h * size
    if total == 0:
        return 0.0, 0, per
    return acc / total, total, per


def flatten_block_params(tree: Any, prefix: str = "") -> dict[str, jax.Array]:
    """Flatten a (nested) param dict into {dotted_name: array}."""
    out: dict[str, jax.Array] = {}
    if isinstance(tree, Mapping):
        for k, v in tree.items():
            out.update(flatten_block_params(v, f"{prefix}{k}."))
    else:
        out[prefix[:-1]] = tree
    return out


def analyze_blocks(
    blocks: Sequence[Mapping[str, jax.Array]], *, mode: str = "paper",
    eps: float = DEFAULT_EPS, first_exec_index: int = 2,
) -> list[BlockEntropy]:
    """Per-block entropy for a sequence of block param dicts.

    ``first_exec_index=2`` matches the paper's convention that exec_index 1
    is the token-embedding block and transformer blocks start at 2.
    """
    out = []
    for i, blk in enumerate(blocks):
        mats = flatten_block_params(blk)
        h, n, per = block_entropy_from_matrices(mats, mode=mode, eps=eps)
        out.append(BlockEntropy(block_index=i, exec_index=first_exec_index + i,
                                entropy=h, num_parameters=n, per_matrix=per))
    return out


def entropy_stats(entropies: Sequence[float]) -> tuple[float, float]:
    """(mu, sigma) over block entropies — population std per paper §3.3.2."""
    arr = np.asarray(entropies, dtype=np.float64)
    return float(arr.mean()), float(arr.std())
