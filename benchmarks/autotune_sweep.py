"""Kernel-knob autotune sweep: measure, pick, persist (kernels/autotune.py).

For each (family, kv-precision) pair, benchmark the fused decode loop of a
briefly-trained smoke-scale ServeEngine under every candidate TunedConfig
(decode-attention kv_chunk widths; megakernel tiles join the grid on TPU),
keep the fastest, and write it to the autotune cache keyed
``device_kind|family|precision|backend``. Engines built afterwards — in
this process or any later one on the same device kind — pick the tuned
config up automatically at trace time and stamp its key into ServeStats
and saved artifact manifests.

Each candidate builds a FRESH engine: every knob is read at trace time,
so re-using jitted executables would silently benchmark the first config
seven times.

Run directly or for CI: ``python -m benchmarks.autotune_sweep --smoke``
(grouped CPU fallback; one family, two precisions, writes + reloads the
cache so the round-trip is exercised). ``--cache PATH`` overrides the
``REPRO_AUTOTUNE_CACHE`` / ``~/.cache/repro/autotune.json`` default.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels.autotune import (AutotuneCache, autotune,
                                    default_candidates, maybe_apply_tuned,
                                    tune_key)
from repro.serving.engine import ServeEngine

FAMILY_ARCHS = {"dense": "llama3.2-3b", "ssm": "mamba2-780m",
                "hybrid": "zamba2-2.7b", "encdec": "whisper-medium"}
PROMPT_LEN = 16
BATCH = 4
MAX_SEQ = 512   # deep enough that the cache sweep dominates decode


def _prompts(cfg, batch=BATCH, seed=7):
    return jax.random.randint(jax.random.PRNGKey(seed), (batch, PROMPT_LEN),
                              0, cfg.vocab_size, dtype=jnp.int32)


def _bench_decode(model, params, kvp, max_new, reps):
    """Candidate cost: best-of-reps fused-decode wall time on a FRESH
    engine (autotune=False — the sweep already applied the candidate and
    a cache hit must not overwrite it mid-measurement)."""
    def bench(_config):
        engine = ServeEngine(model, params, max_seq=MAX_SEQ,
                             kv_precision=kvp, autotune=False)
        prompts = _prompts(model.cfg)
        fn = lambda: engine.generate(prompts, max_new,
                                     chunk=min(8, max_new)).tokens
        fn()  # compile
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best
    return bench


def run(smoke: bool = False, families=None, precisions=None,
        cache_path=None) -> list[tuple]:
    families = families or (("dense",) if smoke else tuple(FAMILY_ARCHS))
    precisions = precisions or (("int8", "int4") if smoke
                                else ("bf16", "int8", "int4"))
    max_new = 8 if smoke else 32
    reps = 1 if smoke else 3
    steps = 20 if smoke else None
    cache = AutotuneCache(cache_path)
    rows = []
    summary: dict = {"cache_path": cache.path, "entries": {}}
    # library defaults, captured before any candidate is applied (each
    # autotune() leaves its winner applied, so reading the knobs inside
    # the loop would compare against the previous family's winner)
    from repro.kernels import autotune as at
    base_snap = at.snapshot()
    default_kv = base_snap["decode_kv_chunk"]
    for family in families:
        cfg, model, params = common.get_trained(FAMILY_ARCHS[family],
                                                steps=steps)
        for kvp in precisions:
            key = tune_key(family, kvp)
            cands = default_candidates(kvp)
            best, results = autotune(
                key, _bench_decode(model, params, kvp, max_new, reps),
                cands, cache=cache)
            costs = [r["cost_s"] for r in results]
            tokens = BATCH * max_new
            best_s, worst_s = min(costs), max(costs)
            # tuned-vs-default delta: the candidate whose sweep width
            # equals the untuned library default (grids always include a
            # mid width; int4's wider grid may not — fall back to worst)
            default_cost = next(
                (r["cost_s"] for r in results
                 if r["config"].get("decode_kv_chunk") == default_kv),
                worst_s)
            rows.append((
                f"autotune/{family}/{kvp}", best_s / tokens * 1e6,
                f"{tokens/best_s:.1f} tok/s best {best.to_dict()} "
                f"vs default {tokens/default_cost:.1f} tok/s "
                f"({default_cost/best_s:.2f}x) over {len(cands)} candidates"))
            summary["entries"][key] = {
                "best": best.to_dict(), "tok_s": tokens / best_s,
                "tok_s_default": tokens / default_cost,
                "tuned_vs_default": default_cost / best_s,
                "candidates": results,
            }
    path = cache.save()
    at.restore(base_snap)
    # round-trip check: a fresh engine on this device must resolve every
    # key we just wrote (CI asserts on this row)
    reloaded = AutotuneCache(cache.path)
    ok = all(reloaded.get(k) is not None for k in summary["entries"])
    applied = maybe_apply_tuned(families[0], precisions[0], path=cache.path)
    rows.append(("autotune/cache/roundtrip", 0.0,
                 f"{'ok' if ok and applied != 'untuned' else 'FAIL'} "
                 f"{len(summary['entries'])} entries at {path} "
                 f"(reloaded stamp: {applied})"))
    common.save_json("autotune_sweep.json", summary)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--families", default=None,
                    help="comma list from dense,ssm,hybrid,encdec")
    ap.add_argument("--precisions", default=None,
                    help="comma list from bf16,int8,int4")
    ap.add_argument("--cache", default=None, help="cache JSON path")
    a = ap.parse_args()
    fams = tuple(a.families.split(",")) if a.families else None
    precs = tuple(a.precisions.split(",")) if a.precisions else None
    print("name,us_per_call,derived")
    common.emit(run(smoke=a.smoke, families=fams, precisions=precs,
                    cache_path=a.cache))
