"""Paper Table 1: mixed-precision motivation (similarity / consistency).

Proxies: similarity = greedy-decode token agreement with the raw model on
held-out prompts; consistency = mean self-agreement between two independent
temperature-0.7 samples from the same quantized model.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import plan_model
from repro.serving.engine import ServeEngine

from benchmarks import common

CONFIGS = [
    ("mixed_8bit60_4bit40", "4bit/8bit"),
    ("fully_8bit", "8bit"),
    ("fully_4bit", "4bit"),
]


def run():
    arch = common.BENCH_ARCHS[0]
    cfg, model, params = common.get_trained(arch)
    prompts = jax.random.randint(jax.random.PRNGKey(11), (8, 12), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    new = 12
    raw_engine = ServeEngine(model, params, max_seq=40)
    raw_out = raw_engine.generate(prompts, new)
    rows, table = [], []
    for name, variant in CONFIGS:
        plan = plan_model(model, params, variant=variant)
        eng = ServeEngine(model, params, max_seq=40, plan=plan)
        t0 = time.perf_counter()
        out = eng.generate(prompts, new)
        us = (time.perf_counter() - t0) / (8 * new) * 1e6
        sim = float((out.tokens[:, -new:] == raw_out.tokens[:, -new:]).mean())
        s1 = eng.generate(prompts, new, temperature=0.7,
                          key=jax.random.PRNGKey(1))
        s2 = eng.generate(prompts, new, temperature=0.7,
                          key=jax.random.PRNGKey(2))
        cons = float((s1.tokens[:, -new:] == s2.tokens[:, -new:]).mean())
        table.append({"configuration": name, "similarity": round(sim, 3),
                      "consistency": round(cons, 3)})
        rows.append((f"table1/{name}", us,
                     f"similarity={sim:.3f};consistency={cons:.3f}"))
    common.save_json("table1_mixed.json", table)
    return rows


def main():
    common.emit(run())


if __name__ == "__main__":
    main()
