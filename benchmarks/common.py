"""Shared benchmark harness: trained reduced models + cached artifacts.

Tables reuse one briefly-trained model per arch (cached as a framework
checkpoint under benchmarks/results/models/<arch>) and the FastEWQ dataset
built from EWQ analyses of all 10 assigned archs (cached as JSON).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import RunConfig
from repro.configs.registry import get_config
from repro.core.dataset import BlockRow, build_dataset
from repro.data.synthetic import DataLoader
from repro.models.model import build
from repro.train.loop import evaluate, train

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
BENCH_ARCHS = ("llama3.2-3b", "yi-9b", "mamba2-780m")
TRAIN_STEPS = 150
# Held-out eval = SAME seed (same synthetic language), disjoint step range.
EVAL_STEP_OFFSET = 100_000


def bench_config(arch: str):
    cfg = get_config(arch, smoke=True)
    # 6 layers so mixed plans have room to differentiate
    return dataclasses.replace(cfg, num_layers=6)


def model_dir(arch: str, steps: int | None = None) -> pathlib.Path:
    """Raw-weights checkpoint cache dir for a briefly-trained bench model."""
    tag = arch.replace("/", "_")
    if steps is not None and steps != TRAIN_STEPS:
        tag += f"_s{steps}"
    return RESULTS / "models" / tag


def get_trained(arch: str, steps: int | None = None):
    """(cfg, model, params) — trained once, checkpoint-cached."""
    steps = TRAIN_STEPS if steps is None else steps
    cfg = bench_config(arch)
    model = build(cfg)
    cdir = model_dir(arch, steps)
    if ckpt.latest_step(cdir) is not None:
        params, _ = ckpt.restore(cdir, model.abstract_params())
        params = jax.tree.map(jnp.asarray, params)
        return cfg, model, params
    run = RunConfig(steps=steps, learning_rate=2e-3, warmup_steps=10,
                    remat=False)
    res = train(cfg, run, batch=16, seq=64, log_fn=lambda s: None)
    ckpt.save(cdir, steps, res["params"], extra={})
    return cfg, model, res["params"]


def eval_metrics(model, params, *, steps: int = 6, batch: int = 16,
                 seq: int = 64):
    """(top-1 accuracy, perplexity, us_per_eval_call) on held-out stream."""
    from repro.train.step import make_loss_fn
    loss_fn = jax.jit(make_loss_fn(model, remat=False))
    loader = DataLoader(model.cfg, global_batch=batch, seq=seq, seed=0,
                        start_step=EVAL_STEP_OFFSET)

    @jax.jit
    def acc_fn(params, batch):
        logits, _ = model.apply(params, batch, remat=False)
        pred = jnp.argmax(logits[..., :model.cfg.vocab_size], -1)
        return jnp.mean((pred == batch["labels"]).astype(jnp.float32))

    losses, accs = [], []
    t0 = None
    for i in range(steps):
        b = next(loader)
        if i == 1:
            t0 = time.perf_counter()  # skip compile step
        losses.append(float(loss_fn(params, b)[0]))
        accs.append(float(acc_fn(params, b)))
    dt_us = (time.perf_counter() - t0) / max(steps - 1, 1) * 1e6
    mean_loss = float(np.mean(losses))
    return {"accuracy": float(np.mean(accs)),
            "perplexity": float(np.exp(mean_loss)),
            "loss": mean_loss, "us_per_call": dt_us}


def quantized_metrics(model, params, plan, **kw):
    from repro.serving.quantized import apply_plan_to_params
    pq = apply_plan_to_params(model, params, plan)
    return eval_metrics(model, pq, **kw)


def plan_sizes_mib(model, params, plan) -> float:
    """Effective transformer-block bytes under a plan (MiB)."""
    from repro.quant.apply import SegmentedParams, tree_nbytes
    from repro.serving.quantized import apply_plan_to_params
    pq = apply_plan_to_params(model, params, plan)
    total = 0.0
    for key in ("layers", "enc_layers", "dec_layers", "shared", "embed"):
        if key in pq:
            v = pq[key]
            total += (v.nbytes_effective() if isinstance(v, SegmentedParams)
                      else tree_nbytes(v))
    return total / 2**20


def fastewq_rows(force: bool = False) -> list[BlockRow]:
    """EWQ-labelled dataset over all 10 archs (cached)."""
    path = RESULTS / "fastewq_dataset.json"
    if path.exists() and not force:
        rows = [BlockRow(**r) for r in json.load(open(path))]
        return rows
    rows = build_dataset(steps=30, seeds=(0, 1))
    RESULTS.mkdir(parents=True, exist_ok=True)
    json.dump([dataclasses.asdict(r) for r in rows], open(path, "w"))
    return rows


def save_json(name: str, obj):
    RESULTS.mkdir(parents=True, exist_ok=True)
    with open(RESULTS / name, "w") as f:
        json.dump(obj, f, indent=2, default=float)


def emit(rows: list[tuple]):
    """Print ``name,us_per_call,derived`` CSV rows (run.py contract)."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
