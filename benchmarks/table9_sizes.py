"""Paper Table 9: average transformer-block size (GB) per precision —
computed analytically for the FULL assigned configs (no allocation)."""

from __future__ import annotations

import time

from repro.configs.registry import ARCHS, get_config
from repro.core.policy import bytes_per_param

from benchmarks import common


def run():
    rows, table = [], []
    for arch in ARCHS:
        cfg = get_config(arch)
        t0 = time.perf_counter()
        total_layers = cfg.num_layers + (cfg.num_encoder_layers or 0)
        layer_params = (cfg.param_count()
                        - cfg.padded_vocab * cfg.d_model
                        * (1 if cfg.tie_embeddings or cfg.family in
                           ("encdec", "hybrid", "ssm") else 2)) / total_layers
        sizes = {p: layer_params * bytes_per_param(p) / 2**30
                 for p in ("raw", "int8", "int4")}
        us = (time.perf_counter() - t0) * 1e6
        table.append({"model": cfg.name, "blocks": total_layers,
                      "raw_gb": round(sizes["raw"], 4),
                      "8bit_gb": round(sizes["int8"], 4),
                      "4bit_gb": round(sizes["int4"], 4)})
        rows.append((f"table9/{cfg.name}", us,
                     f"raw={sizes['raw']:.3f}GB;int8={sizes['int8']:.3f}GB;"
                     f"int4={sizes['int4']:.3f}GB"))
    common.save_json("table9_sizes.json", table)
    return rows


def main():
    common.emit(run())


if __name__ == "__main__":
    main()
