"""Paper §6.3.1 + Table 13: composite scores (log ppl - acc) and paired
statistics (t-test, Cohen's d) between fast / fast-train variants."""

from __future__ import annotations

import json
import math
import time

import numpy as np

from repro.core.classifiers.metrics import (cohens_d, effect_size_label,
                                            paired_t_test,
                                            significance_label)

from benchmarks import common


def _composite(entries):
    # Composite Score = w1*log(ppl) - w2*acc  (w1 = w2 = 1)
    return [math.log(e["perplexity"]) - e["accuracy"] for e in entries]


def run():
    path = common.RESULTS / "table7_fastewq.json"
    if not path.exists():
        from benchmarks import table7_fastewq
        table7_fastewq.run()
    table7 = json.load(open(path))

    by_variant = {}
    for e in table7:
        by_variant.setdefault(e["variant"], []).append(e)
    for v in by_variant:
        by_variant[v].sort(key=lambda e: e["model"])

    pairs = [
        ("fast 8bit mixed", "fast 4bit/8bit mixed"),
        ("fast train 8bit mixed", "fast train 4bit/8bit mixed"),
        ("fast 8bit mixed", "fast train 8bit mixed"),
        ("fast 4bit/8bit mixed", "fast train 4bit/8bit mixed"),
    ]
    rows, table = [], []
    for a, b in pairs:
        t0 = time.perf_counter()
        ca = _composite(by_variant[a])
        cb = _composite(by_variant[b])
        tt = paired_t_test(ca, cb)
        d = cohens_d(np.array(ca), np.array(cb))
        us = (time.perf_counter() - t0) * 1e6
        entry = {
            "comparison": f"{a} vs {b}",
            "abs_diff": round(float(np.mean(np.abs(np.array(ca)
                                                   - np.array(cb)))), 5),
            "t": round(tt["t"], 4), "p": round(tt["p"], 4),
            "significance": significance_label(tt["p"]),
            "cohens_d": round(d, 5), "effect": effect_size_label(d),
        }
        table.append(entry)
        rows.append((f"table13/{a.replace(' ', '_')}_vs_{b.replace(' ', '_')}",
                     us, f"p={tt['p']:.3f};d={d:.4f};{entry['significance']}"))
    common.save_json("table13_stats.json", table)
    return rows


def main():
    common.emit(run())


if __name__ == "__main__":
    main()
