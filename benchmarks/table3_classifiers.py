"""Paper Tables 3/5 + Fig 6: classification report, confusion matrices and
ROC-AUC for all six from-scratch classifiers, plus the §4.3 feature
importance and ablation."""

from __future__ import annotations

import time

from repro.core.fastewq import evaluate_all_classifiers, feature_ablation

from benchmarks import common


def run():
    ds = common.fastewq_rows()
    t0 = time.perf_counter()
    reports = evaluate_all_classifiers(ds)
    us = (time.perf_counter() - t0) * 1e6 / len(reports)
    ablation = feature_ablation(ds)
    common.save_json("table3_classifiers.json",
                     {"reports": reports, "ablation": ablation,
                      "dataset_rows": len(ds)})
    rows = []
    for name, rep in reports.items():
        c = rep["confusion"]
        rows.append((f"table3/{name.replace(' ', '_')}", us,
                     f"acc={rep['accuracy']:.3f};auc={rep['auc']:.3f};"
                     f"tn={c['tn']};fn={c['fn']};fp={c['fp']};tp={c['tp']}"))
    imp = reports["random forest"].get("feature_importances", {})
    rows.append(("table3/rf_feature_importance", us,
                 ";".join(f"{k}={v:.3f}" for k, v in imp.items())))
    rows.append(("table3/ablation", us,
                 ";".join(f"{k}={v:.3f}" for k, v in ablation.items())))
    return rows


def main():
    common.emit(run())


if __name__ == "__main__":
    main()
