"""§Roofline report: render the dry-run JSONL into the per-cell table."""

from __future__ import annotations

import json
import pathlib
import time

from benchmarks import common

DRYRUN = common.RESULTS / "dryrun.jsonl"


def load(tag: str = "baseline", mesh: str = "16x16"):
    if not DRYRUN.exists():
        return []
    recs = [json.loads(l) for l in open(DRYRUN)]
    # last record wins per (arch, shape, mesh, tag, quant)
    best = {}
    for r in recs:
        key = (r["arch"], r["shape"], r["mesh"], r["tag"], r.get("quant"))
        best[key] = r
    return [r for (a, s, m, t, q), r in best.items()
            if t == tag and m == mesh]


def run(tag: str = "baseline", mesh: str = "16x16"):
    recs = load(tag, mesh)
    if not recs:
        # an empty table is indistinguishable from a healthy no-op unless
        # it says WHY it is empty — name the filter that matched nothing
        # (and what the file does hold) instead of printing zero rows
        if not DRYRUN.exists():
            reason = f"no dryrun log at {DRYRUN}"
        else:
            seen = {(r["tag"], r["mesh"])
                    for r in (json.loads(l) for l in open(DRYRUN))}
            reason = (f"no records for tag={tag!r} mesh={mesh!r} in "
                      f"{DRYRUN.name}; present: "
                      + (", ".join(f"{t}/{m}" for t, m in sorted(seen))
                         or "none"))
        return [("roofline/empty", 0.0, reason)]
    rows = []
    nonzero = 0
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] == "skipped":
            rows.append((name, 0.0, "SKIP:" + r["reason"][:40]))
            continue
        if r["status"] != "ok":
            rows.append((name, 0.0, "ERROR:" + r["error"][:60]))
            continue
        dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        nonzero += 1
        rows.append((name, dom * 1e6,
                     f"bound={r['bound']};"
                     f"tc={r['t_compute_s']:.4f};tm={r['t_memory_s']:.4f};"
                     f"tx={r['t_collective_s']:.4f};"
                     f"useful={r['useful_flop_frac']:.2f};"
                     f"peakGiB={r.get('peak_bytes_per_dev', 0)/2**30:.1f}"))
    rows.append(("roofline/summary", 0.0,
                 f"{nonzero} modeled rows of {len(rows)} records "
                 f"(tag={tag}, mesh={mesh})"))
    return rows


def main():
    common.emit(run())


if __name__ == "__main__":
    main()
