"""§Roofline report: render the dry-run JSONL into the per-cell table."""

from __future__ import annotations

import json
import pathlib
import time

from benchmarks import common

DRYRUN = common.RESULTS / "dryrun.jsonl"


def load(tag: str = "baseline", mesh: str = "16x16"):
    if not DRYRUN.exists():
        return []
    recs = [json.loads(l) for l in open(DRYRUN)]
    # last record wins per (arch, shape, mesh, tag, quant)
    best = {}
    for r in recs:
        key = (r["arch"], r["shape"], r["mesh"], r["tag"], r.get("quant"))
        best[key] = r
    return [r for (a, s, m, t, q), r in best.items()
            if t == tag and m == mesh]


def run():
    rows = []
    for r in sorted(load(), key=lambda r: (r["arch"], r["shape"])):
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] == "skipped":
            rows.append((name, 0.0, "SKIP:" + r["reason"][:40]))
            continue
        if r["status"] != "ok":
            rows.append((name, 0.0, "ERROR:" + r["error"][:60]))
            continue
        dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        rows.append((name, dom * 1e6,
                     f"bound={r['bound']};"
                     f"tc={r['t_compute_s']:.4f};tm={r['t_memory_s']:.4f};"
                     f"tx={r['t_collective_s']:.4f};"
                     f"useful={r['useful_flop_frac']:.2f};"
                     f"peakGiB={r.get('peak_bytes_per_dev', 0)/2**30:.1f}"))
    return rows


def main():
    common.emit(run())


if __name__ == "__main__":
    main()
