# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

Runs the per-paper-table benchmarks at reduced (CPU) scale and the roofline
report derived from the dry-run artifacts. Each table module also caches a
JSON rendering under benchmarks/results/.

  PYTHONPATH=src python -m benchmarks.run [table ...]
"""

from __future__ import annotations

import sys
import traceback

from benchmarks import (common, roofline_report, serve_throughput,
                        table1_mixed, table3_classifiers, table6_ewq,
                        table7_fastewq, table8_selection, table9_sizes,
                        table13_stats, table14_summary, table_fig1_entropy)

TABLES = {
    "serve": serve_throughput,
    "fig1": table_fig1_entropy,
    "table1": table1_mixed,
    "table3": table3_classifiers,
    "table6": table6_ewq,
    "table7": table7_fastewq,
    "table8": table8_selection,
    "table9": table9_sizes,
    "table13": table13_stats,
    "table14": table14_summary,
    "roofline": roofline_report,
}


def main() -> None:
    names = sys.argv[1:] or list(TABLES)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod = TABLES[name]
        try:
            common.emit(mod.run())
        except Exception as e:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
