"""Serving throughput + cold start across all four model families.

Two sweeps over briefly-trained smoke-scale models:

1. **Variant sweep** (llama3.2-3b): for raw bf16 | EWQ 8bit-mixed |
   EWQ 4bit/8bit, decode tokens/sec for
     * ``stepwise`` — legacy per-token Python loop (one jitted decode
       dispatch + host sync per token);
     * ``fused``    — the jitted ``lax.scan`` chunked loop;
     * ``stream``   — continuous batching over a simulated request stream
       (occupancy and mid-run admissions reported).

2. **Family sweep** (dense | ssm | hybrid | encdec) under the mixed
   "4bit/8bit" plan — the regime where hybrid/enc-dec previously fell back
   to raw weights: per-family effective weight bytes vs raw, fused decode
   throughput, and **cold-start time** with vs without a compiled-plan
   artifact (docs/DESIGN.md §8):
     * no artifact — restore raw weights + EWQ entropy analysis + plan
       compile/quantize + engine warmup;
     * artifact    — ``ServeEngine.from_artifact`` (quantized checkpoint +
       plan manifest) + engine warmup.

3. **Mesh sweep** (docs/DESIGN.md §9) — when more than one device is
   visible (CI forces 8 virtual CPU devices via
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8``): fused decode
   tok/s and **per-device weight bytes** for the single-device engine vs
   1xN / 2x(N/2) (data, model) serving meshes, under the mixed plan.

4. **KV-cache sweep** (docs/DESIGN.md §10) — decode-attention
   microbenchmark at a deeper ``max_seq``: fused decode tok/s and
   **KV MiB/slot** for the bf16 cache (materialized-score decode path) vs
   the int8 / int4 quantized cache (fused streaming decode attention; on
   CPU this runs the ``grouped`` online-softmax fallback — the tok/s rows
   are the CPU-fallback numbers CI sees, alongside greedy-token agreement
   vs the bf16 baseline).

5. **Spec-decode sweep** (docs/DESIGN.md §11) — self-speculative serving
   at k in {2, 4} vs the single-query non-spec engine under the mixed
   plan: continuous-batching tok/s uplift, draft acceptance rate,
   accepted tokens per verify round, draft-only weight overhead, and
   greedy-token agreement (must be 1.0 — the spec path is token-identical
   by construction).

6. **Paged-pool sweep** (docs/DESIGN.md §13) — the paged quantized KV
   pool vs contiguous per-slot reservations: continuous-batching tok/s and
   peak KV bytes on a shared-prefix stream (with greedy token agreement vs
   the dense engine), long-prompt prefill wall on a COW prefix-cache hit
   vs cold through the disaggregated prefill/insert API, and an
   equal-memory concurrency row at ``max_seq=2048`` — short requests
   served from a pool sized to the dense reservation of ``NUM_SLOTS``
   slots sustain >= 4x the concurrent slots.

7. **SLO / open-loop sweep** (docs/DESIGN.md §14) — chunked-prefill
   interleaving vs the monolithic prefill stall (per-chunk TPOT of
   running slots while a 1024-token prompt prefills mid-stream), Poisson
   open-loop arrivals with queueing delay reported separately from TTFT,
   and a priority/cancellation/preemption run on a paged engine with
   pool invariants asserted afterwards.

8. **DP x TP replica sweep** (docs/DESIGN.md §14) — the same stream on a
   TP-only (1, N) mesh vs (2, N/2) ``data,model`` split into two replicas
   behind the load-aware router: tok/s, per-replica occupancy and
   assignments, greedy token agreement (must be 1.0).

9. **Fault-tolerance sweep** (docs/DESIGN.md §15) — the stack under
   injected faults: 1-of-2 replica loss mid-stream (failover + request
   re-drive; throughput retained, recovery p95, greedy agreement must
   stay 1.0) and ewq graceful degradation under injected pool exhaustion
   (degraded vs nominal tok/s, KV tier histogram, zero lost requests).

10. **Observability sweep** (docs/DESIGN.md §16) — the same
    continuous-batching stream with no telemetry sinks installed vs fully
    traced (span tracer + metrics registry): traced overhead must stay
    under 2%, and the disabled hook (one ``None`` check per site) is
    microbenchmarked directly to show the off path costs ~nothing.

Smoke-scale (CPU) defaults; run directly, via ``benchmarks/run.py serve``,
or at reduced size for CI: ``python -m benchmarks.serve_throughput --smoke``.
"""

from __future__ import annotations

import shutil
import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.checkpoint import ckpt
from repro.core.planner import plan_model
from repro.quant.compiler import save_artifact
from repro.serving.engine import ServeEngine
from repro.serving.quantized import plan_for_variant
from repro.serving.scheduler import synthetic_stream

ARCH = "llama3.2-3b"
VARIANTS = ("raw", "8bit-mixed", "4bit/8bit")
FAMILY_ARCHS = (("dense", "llama3.2-3b"), ("ssm", "mamba2-780m"),
                ("hybrid", "zamba2-2.7b"), ("encdec", "whisper-medium"))
FAMILY_VARIANT = "4bit/8bit"
BATCH = 4
PROMPT_LEN = 16
MAX_NEW = 32
CHUNK = 16
# stream simulation
NUM_REQUESTS = 12
NUM_SLOTS = 4
ARRIVAL_RATE = 0.25   # requests per decode step
SMOKE_TRAIN_STEPS = 20


def _time(fn, reps: int = 3) -> float:
    """Best-of-``reps`` wall time after a warmup/compile call."""
    fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _prompts(cfg, batch, seed=7):
    return jax.random.randint(jax.random.PRNGKey(seed), (batch, PROMPT_LEN),
                              0, cfg.vocab_size, dtype=jnp.int32)


def _variant_rows(max_new: int, reps: int, summary: dict,
                  steps: int | None = None,
                  variants: tuple = VARIANTS) -> list[tuple]:
    cfg, model, params = common.get_trained(ARCH, steps=steps)
    prompts = _prompts(cfg, BATCH)
    rows = []
    for variant in variants:
        plan = plan_for_variant(model, params, variant)
        engine = ServeEngine(model, params, plan=plan,
                             max_seq=PROMPT_LEN + int(max_new * 1.25) + 1)
        tokens = BATCH * max_new

        dt_step = _time(lambda: engine.generate_stepwise(prompts, max_new)
                        .tokens, reps)
        dt_fused = _time(lambda: engine.generate(prompts, max_new,
                                                 chunk=CHUNK).tokens, reps)
        tps_step = tokens / dt_step
        tps_fused = tokens / dt_fused

        requests = synthetic_stream(
            NUM_REQUESTS, vocab_size=cfg.vocab_size, prompt_len=PROMPT_LEN,
            max_new_tokens=max_new, arrival_rate=ARRIVAL_RATE, seed=0)
        # warm the serve path (chunk fn, batch=1 prefill, insert/release
        # compiles) so the timed run is steady-state like the rows above
        engine.serve(requests[:2], num_slots=NUM_SLOTS, chunk=CHUNK)
        t0 = time.perf_counter()
        _, stats = engine.serve(requests, num_slots=NUM_SLOTS, chunk=CHUNK)
        dt_stream = time.perf_counter() - t0
        tps_stream = stats.generated_tokens / dt_stream

        tag = variant.replace("/", "-")
        rows.append((f"serve/{tag}/stepwise", dt_step / tokens * 1e6,
                     f"{tps_step:.1f} tok/s"))
        rows.append((f"serve/{tag}/fused", dt_fused / tokens * 1e6,
                     f"{tps_fused:.1f} tok/s speedup {tps_fused/tps_step:.2f}x"))
        rows.append((f"serve/{tag}/stream", dt_stream / max(
            stats.generated_tokens, 1) * 1e6,
            f"{tps_stream:.1f} tok/s occupancy {stats.occupancy:.2f} "
            f"admissions {stats.admissions} "
            f"ttft p50/p95 {stats.ttft_p50_s*1e3:.0f}/"
            f"{stats.ttft_p95_s*1e3:.0f}ms "
            f"tpot p50/p95 {stats.tpot_p50_s*1e3:.1f}/"
            f"{stats.tpot_p95_s*1e3:.1f}ms"))
        summary["variants"][variant] = {
            "weight_mib": engine.weight_bytes() / 2**20,
            "tok_s_stepwise": tps_step, "tok_s_fused": tps_fused,
            "fused_speedup": tps_fused / tps_step,
            "tok_s_stream": tps_stream, "occupancy": stats.occupancy,
            "mid_run_admissions": stats.admissions,
            "decode_steps": stats.decode_steps,
            "ttft_p50_s": stats.ttft_p50_s, "ttft_p95_s": stats.ttft_p95_s,
            "tpot_p50_s": stats.tpot_p50_s, "tpot_p95_s": stats.tpot_p95_s,
        }
    return rows


def _family_rows(max_new: int, reps: int, steps: int | None,
                 summary: dict) -> list[tuple]:
    rows = []
    for family, arch in FAMILY_ARCHS:
        cfg, model, _ = common.get_trained(arch, steps=steps)
        max_seq = PROMPT_LEN + max_new + 2
        prompts = _prompts(cfg, 2)
        cdir = common.model_dir(arch, steps)
        adir = common.RESULTS / "artifacts" / arch.replace("/", "_")

        # -- cold start WITHOUT artifact: raw weights -> plan -> quantize ----
        t0 = time.perf_counter()
        params, _ = ckpt.restore(cdir, model.abstract_params())
        params = jax.tree.map(jnp.asarray, params)
        plan = plan_model(model, params, variant=FAMILY_VARIANT)
        compiled = model.compile_plan(params, plan)
        engine = ServeEngine(model, compiled.params, max_seq=max_seq)
        jax.block_until_ready(engine.generate(prompts, 2).tokens)
        cold_raw = time.perf_counter() - t0

        # -- cold start WITH artifact: quantized checkpoint + manifest -------
        shutil.rmtree(adir, ignore_errors=True)
        save_artifact(str(adir), compiled)
        t0 = time.perf_counter()
        engine_a = ServeEngine.from_artifact(model, str(adir),
                                             max_seq=max_seq)
        jax.block_until_ready(engine_a.generate(prompts, 2).tokens)
        cold_art = time.perf_counter() - t0

        raw_bytes = sum(x.size * x.dtype.itemsize
                        for x in jax.tree.leaves(params))
        eff = engine_a.weight_bytes()
        dt_fused = _time(lambda: engine_a.generate(
            prompts, max_new, chunk=min(CHUNK, max_new)).tokens, reps)
        tps = 2 * max_new / dt_fused

        rows.append((f"serve/family/{family}/fused", dt_fused / (
            2 * max_new) * 1e6,
            f"{tps:.1f} tok/s weights {eff/2**20:.2f} MiB eff "
            f"({raw_bytes/2**20:.2f} raw)"))
        rows.append((f"serve/family/{family}/cold_boot", cold_art * 1e6,
                     f"artifact {cold_art:.2f}s vs raw-path {cold_raw:.2f}s "
                     f"({cold_raw/max(cold_art, 1e-9):.1f}x)"))
        summary["families"][family] = {
            "arch": arch, "variant": FAMILY_VARIANT,
            "weight_mib_effective": eff / 2**20,
            "weight_mib_raw": raw_bytes / 2**20,
            "plan_counts": plan.counts(),
            "tok_s_fused": tps,
            "cold_start_s_no_artifact": cold_raw,
            "cold_start_s_artifact": cold_art,
        }
    return rows


def _mesh_rows(max_new: int, reps: int, steps: int | None,
               summary: dict) -> list[tuple]:
    """Sharded serving: tok/s + per-device weight bytes per mesh layout."""
    n_dev = len(jax.devices())
    if n_dev < 2:
        return [("serve/mesh/skipped", 0.0,
                 f"1 device visible (set XLA_FLAGS="
                 f"--xla_force_host_platform_device_count=8 for mesh rows)")]
    from repro.launch.mesh import make_mesh
    shapes = [(1, n_dev)]
    if n_dev % 2 == 0 and n_dev > 2:
        shapes.append((2, n_dev // 2))
    cfg, model, params = common.get_trained(ARCH, steps=steps)
    plan = plan_for_variant(model, params, FAMILY_VARIANT)
    # quantize once; every engine below serves the same compiled weights
    qparams = model.compile_plan(params, plan).params
    prompts = _prompts(cfg, BATCH)
    max_seq = PROMPT_LEN + max_new + 1
    tokens = BATCH * max_new
    rows = []

    def bench(engine, name, baseline_bytes=None):
        dt = _time(lambda: engine.generate(
            prompts, max_new, chunk=min(CHUNK, max_new)).tokens, reps)
        per_dev = engine.weight_bytes_per_device()
        note = f"{tokens/dt:.1f} tok/s {per_dev/2**20:.2f} MiB/dev"
        if baseline_bytes:
            note += f" ({baseline_bytes/per_dev:.1f}x less than 1-dev)"
        rows.append((f"serve/mesh/{name}/fused", dt / tokens * 1e6, note))
        summary["mesh"][name] = {
            "tok_s_fused": tokens / dt,
            "weight_bytes_per_device": per_dev,
            "devices": 1 if baseline_bytes is None else n_dev}
        return per_dev

    single = ServeEngine(model, qparams, max_seq=max_seq)
    base = bench(single, "1dev")
    for shape in shapes:
        mesh = make_mesh(shape, ("data", "model"))
        engine = ServeEngine(model, qparams, max_seq=max_seq, mesh=mesh)
        bench(engine, f"{shape[0]}x{shape[1]}", baseline_bytes=base)
    return rows


def _kv_rows(max_new: int, reps: int, steps: int | None,
             summary: dict) -> list[tuple]:
    """Quantized-KV-cache decode microbenchmark: tok/s + KV MiB/slot for
    bf16 vs int8 vs int4 caches at a serving-depth max_seq."""
    cfg, model, params = common.get_trained(ARCH, steps=steps)
    max_seq = 512            # deep enough that the cache dominates state
    prompts = _prompts(cfg, BATCH)
    tokens = BATCH * max_new
    rows = []
    base_bytes = None
    base_tokens = None
    precisions = ("bf16", "int8", "int4")
    engines, outs = {}, {}
    best = {kvp: float("inf") for kvp in precisions}
    for kvp in precisions:
        engines[kvp] = ServeEngine(model, params, max_seq=max_seq,
                                   kv_precision=kvp)
        outs[kvp] = engines[kvp].generate(prompts, max_new,
                                          chunk=min(CHUNK, max_new))  # warm
    # interleave the reps round-robin (rep r times bf16, int8, int4
    # back-to-back) so machine-state drift across the sweep biases no
    # precision — the int4-vs-int8 race is tens of percent at most
    for _ in range(max(reps, 1)):
        for kvp in precisions:
            t0 = time.perf_counter()
            jax.block_until_ready(engines[kvp].generate(
                prompts, max_new, chunk=min(CHUNK, max_new)).tokens)
            best[kvp] = min(best[kvp], time.perf_counter() - t0)
    for kvp in precisions:
        engine, out, dt = engines[kvp], outs[kvp], best[kvp]
        tps = tokens / dt
        bps = engine.kv_bytes_per_slot()
        if kvp == "bf16":
            base_bytes, base_tokens = bps, out.tokens
            note = (f"{tps:.1f} tok/s kv {bps/2**20:.3f} MiB/slot "
                    f"(materialized-score baseline)")
        else:
            agree = float((out.tokens[:, PROMPT_LEN:]
                           == base_tokens[:, PROMPT_LEN:]).mean())
            note = (f"{tps:.1f} tok/s (grouped cpu fallback) kv "
                    f"{bps/2**20:.3f} MiB/slot ({base_bytes/bps:.2f}x less) "
                    f"greedy agree {agree:.2f}")
        rows.append((f"serve/kv/{kvp}/fused", dt / tokens * 1e6, note))
        summary["kv_cache"][kvp] = {
            "tok_s_fused": tps,
            "kv_bytes_per_slot": bps,
            "kv_reduction_vs_bf16": (base_bytes / bps) if base_bytes else 1.0,
            "max_seq": max_seq,
        }
    return rows


def _spec_rows(max_new: int, reps: int, steps: int | None,
               summary: dict) -> list[tuple]:
    """Self-speculative serving vs the non-spec engine: tok/s uplift +
    acceptance at k in {2, 4} (docs/DESIGN.md §11)."""
    from repro.serving.spec import SpecConfig
    cfg, model, params = common.get_trained(ARCH, steps=steps)
    plan = plan_for_variant(model, params, FAMILY_VARIANT)
    qparams = model.compile_plan(params, plan).params
    ks = (2, 4)
    requests = synthetic_stream(
        NUM_REQUESTS, vocab_size=cfg.vocab_size, prompt_len=PROMPT_LEN,
        max_new_tokens=max_new, arrival_rate=ARRIVAL_RATE, seed=0)
    # generated lengths vary +-25%; size the cache for the deepest request
    # plus the verify-window headroom (engine asserts)
    max_seq = max(len(r.prompt) + r.max_new_tokens
                  for r in requests) + max(ks)
    rows = []

    def timed_serve(engine):
        engine.serve(requests[:2], num_slots=NUM_SLOTS, chunk=2)  # warm
        best = None
        for _ in range(max(reps, 1)):
            t0 = time.perf_counter()
            outputs, stats = engine.serve(requests, num_slots=NUM_SLOTS,
                                          chunk=2)
            dt = time.perf_counter() - t0
            if best is None or dt < best[2]:
                best = (outputs, stats, dt)
        return best

    base = ServeEngine(model, qparams, max_seq=max_seq)
    base.plan = plan
    base_out, base_stats, base_dt = timed_serve(base)
    base_tps = base_stats.generated_tokens / base_dt
    rows.append(("serve/spec/baseline/stream",
                 base_dt / max(base_stats.generated_tokens, 1) * 1e6,
                 f"{base_tps:.1f} tok/s (single-query engine)"))
    summary["spec"]["baseline"] = {"tok_s_stream": base_tps}

    for k in ks:
        engine = ServeEngine(model, qparams, max_seq=max_seq,
                             spec=SpecConfig(k=k))
        engine.plan = plan
        outputs, stats, dt = timed_serve(engine)
        tps = stats.generated_tokens / dt
        agree = float(all(
            (a.tokens == b.tokens).all() for a, b in zip(base_out, outputs)))
        acc_per_round = (stats.draft_accepted / max(stats.spec_rounds, 1))
        # decode is weight-bytes-bound (README §Serving): the deployment
        # uplift is bytes-read-per-committed-token — one target read plus k
        # int4-draft reads amortized over tokens_per_round. CPU smoke is
        # FLOPs-bound, so the wall-clock column understates this.
        w_t, w_d = engine.weight_bytes(), engine.draft_weight_bytes()
        bw_ratio = ((w_t + k * w_d)
                    / max(stats.tokens_per_round, 1e-9)) / w_t
        rows.append((f"serve/spec/k{k}/stream",
                     dt / max(stats.generated_tokens, 1) * 1e6,
                     f"{tps:.1f} tok/s ({tps/base_tps:.2f}x vs non-spec "
                     f"cpu-flops-bound; weight-bytes/token "
                     f"{bw_ratio:.2f}x of baseline) "
                     f"acceptance {stats.acceptance_rate:.2f} "
                     f"{stats.tokens_per_round:.2f} tok/round "
                     f"greedy agree {agree:.2f}"))
        summary["spec"][f"k{k}"] = {
            "tok_s_stream": tps,
            "uplift_vs_baseline": tps / base_tps,
            "weight_bytes_per_token_vs_baseline": bw_ratio,
            "acceptance_rate": stats.acceptance_rate,
            "tokens_per_round": stats.tokens_per_round,
            "accepted_tokens_per_round": acc_per_round,
            "draft_overhead_mib": engine.draft_overhead_bytes() / 2**20,
            "greedy_agree": agree,
            "ttft_p50_s": stats.ttft_p50_s, "ttft_p95_s": stats.ttft_p95_s,
            "tpot_p50_s": stats.tpot_p50_s, "tpot_p95_s": stats.tpot_p95_s,
        }

    # prompt-lookup (ngram) draft at k=2: zero draft-side model calls, so
    # a round costs ~one fused multi-query verify step — the draft source
    # that makes spec pay off even FLOPs-bound. Measured on a saturated
    # deeper stream (all arrivals queued, 3x max_new) so the comparison
    # reads decode throughput, not arrival-gated idle time; the non-spec
    # baseline is re-timed on the SAME stream.
    deep = synthetic_stream(
        NUM_REQUESTS, vocab_size=cfg.vocab_size, prompt_len=PROMPT_LEN,
        max_new_tokens=3 * max_new, arrival_rate=100.0, seed=0)
    deep_seq = max(len(r.prompt) + r.max_new_tokens for r in deep) + max(ks)

    def timed_deep(engine):
        engine.serve(deep[:2], num_slots=NUM_SLOTS, chunk=2)  # warm
        best = None
        for _ in range(max(reps, 1)):
            t0 = time.perf_counter()
            outputs, stats = engine.serve(deep, num_slots=NUM_SLOTS, chunk=2)
            dt = time.perf_counter() - t0
            if best is None or dt < best[2]:
                best = (outputs, stats, dt)
        return best

    dbase = ServeEngine(model, qparams, max_seq=deep_seq)
    dbase.plan = plan
    dbase_out, dbase_stats, dbase_dt = timed_deep(dbase)
    dbase_tps = dbase_stats.generated_tokens / dbase_dt
    engine = ServeEngine(model, qparams, max_seq=deep_seq,
                         spec=SpecConfig(k=2, draft_source="ngram"))
    engine.plan = plan
    outputs, stats, dt = timed_deep(engine)
    tps = stats.generated_tokens / dt
    agree = float(all(
        (a.tokens == b.tokens).all() for a, b in zip(dbase_out, outputs)))
    rows.append(("serve/spec/k2-ngram/stream",
                 dt / max(stats.generated_tokens, 1) * 1e6,
                 f"{tps:.1f} tok/s prompt-lookup draft vs {dbase_tps:.1f} "
                 f"tok/s non-spec on the same saturated stream "
                 f"({tps/dbase_tps:.2f}x) "
                 f"acceptance {stats.acceptance_rate:.2f} "
                 f"{stats.tokens_per_round:.2f} tok/round "
                 f"greedy agree {agree:.2f}"))
    summary["spec"]["k2_ngram"] = {
        "tok_s_stream": tps,
        "baseline_tok_s_stream": dbase_tps,
        "uplift_vs_baseline": tps / dbase_tps,
        "acceptance_rate": stats.acceptance_rate,
        "tokens_per_round": stats.tokens_per_round,
        "greedy_agree": agree,
    }
    return rows


def _fused_rows(max_new: int, reps: int, steps: int | None,
                summary: dict) -> list[tuple]:
    """Fused-vs-unfused and tuned-vs-default deltas (docs/DESIGN.md §12):

    * ``serve/fused/kv-*``  — int8/int4 KV decode through the streaming
      grouped online-softmax sweep vs the unfused ``simple`` backend
      (materialize the whole bf16 cache view every step), with greedy
      token agreement between the two.
    * ``serve/tuned/kv-*``  — inline ``kernels.autotune`` sweep of the
      decode kv_chunk grid; best vs the untuned library default. The
      winning configs persist to ``RESULTS/autotune_bench.json`` (the
      user-level cache is benchmarks/autotune_sweep.py's job).
    * ``serve/fused/spec-k2`` — fused draft-propose (one cache sweep per
      round) vs the two-pass throwaway-cache propose, same stream.
    """
    from repro.kernels import autotune as at
    from repro.kernels.decode_attn import ops as dops
    from repro.serving.spec import SpecConfig
    cfg, model, params = common.get_trained(ARCH, steps=steps)
    max_seq = 512            # serving depth: the cache sweep dominates
    prompts = _prompts(cfg, BATCH)
    tokens = BATCH * max_new
    rows = []
    snap = at.snapshot()
    prev_backend = dops._backend
    try:
        for kvp in ("int8", "int4"):
            def bench(_config=None, kvp=kvp):
                engine = ServeEngine(model, params, max_seq=max_seq,
                                     kv_precision=kvp, autotune=False)
                run = lambda: engine.generate(
                    prompts, max_new, chunk=min(CHUNK, max_new))
                out = run()
                best = float("inf")
                for _ in range(reps):
                    t0 = time.perf_counter()
                    jax.block_until_ready(run().tokens)
                    best = min(best, time.perf_counter() - t0)
                return best, out.tokens

            dops.configure_decode_attn(backend="simple")
            dt_un, toks_un = bench()
            dops.configure_decode_attn(backend="grouped")
            dt_f, toks_f = bench()
            agree = float((toks_f[:, PROMPT_LEN:]
                           == toks_un[:, PROMPT_LEN:]).mean())
            rows.append((
                f"serve/fused/kv-{kvp}", dt_f / tokens * 1e6,
                f"{tokens/dt_f:.1f} tok/s fused streaming vs "
                f"{tokens/dt_un:.1f} tok/s unfused materialize "
                f"({dt_un/dt_f:.2f}x) greedy agree {agree:.2f}"))

            # tuned-vs-default: sweep the decode kv_chunk grid under the
            # fused backend and compare the winner to the library default
            key = at.tune_key("dense", kvp)
            cache = at.AutotuneCache(
                str(common.RESULTS / "autotune_bench.json"))
            best, results = at.autotune(
                key, lambda c: bench(c)[0], at.default_candidates(kvp),
                cache=cache)
            costs = {r["config"].get("decode_kv_chunk"): r["cost_s"]
                     for r in results}
            best_s = min(costs.values())
            default_s = costs.get(snap["decode_kv_chunk"],
                                  max(costs.values()))
            rows.append((
                f"serve/tuned/kv-{kvp}", best_s / tokens * 1e6,
                f"{tokens/best_s:.1f} tok/s tuned {best.to_dict()} vs "
                f"{tokens/default_s:.1f} tok/s default "
                f"kv_chunk={snap['decode_kv_chunk']} "
                f"({default_s/best_s:.2f}x)"))
            summary["fused"][f"kv_{kvp}"] = {
                "tok_s_fused": tokens / dt_f,
                "tok_s_unfused": tokens / dt_un,
                "fused_speedup": dt_un / dt_f,
                "greedy_agree": agree,
                "tok_s_tuned": tokens / best_s,
                "tok_s_default": tokens / default_s,
                "tuned_config": best.to_dict(),
                "tuned_vs_default": default_s / best_s,
            }
            at.restore(snap)
    finally:
        at.restore(snap)
        dops.configure_decode_attn(backend=prev_backend)

    # spec k=2: fused draft-propose vs the two-pass throwaway-cache path
    plan = plan_for_variant(model, params, FAMILY_VARIANT)
    qparams = model.compile_plan(params, plan).params
    requests = synthetic_stream(
        NUM_REQUESTS, vocab_size=cfg.vocab_size, prompt_len=PROMPT_LEN,
        max_new_tokens=max_new, arrival_rate=ARRIVAL_RATE, seed=0)
    spec_seq = max(len(r.prompt) + r.max_new_tokens for r in requests) + 2

    def timed_spec(fused: bool):
        engine = ServeEngine(model, qparams, max_seq=spec_seq,
                             spec=SpecConfig(k=2, fused_propose=fused),
                             autotune=False)
        engine.plan = plan
        engine.serve(requests[:2], num_slots=NUM_SLOTS, chunk=2)  # warm
        t0 = time.perf_counter()
        outputs, stats = engine.serve(requests, num_slots=NUM_SLOTS,
                                      chunk=2)
        return outputs, stats, time.perf_counter() - t0

    out_un, st_un, dt_un = timed_spec(fused=False)
    out_f, st_f, dt_f = timed_spec(fused=True)
    tps_un = st_un.generated_tokens / dt_un
    tps_f = st_f.generated_tokens / dt_f
    agree = float(all((a.tokens == b.tokens).all()
                      for a, b in zip(out_un, out_f)))
    rows.append((
        "serve/fused/spec-k2",
        dt_f / max(st_f.generated_tokens, 1) * 1e6,
        f"{tps_f:.1f} tok/s fused propose vs {tps_un:.1f} tok/s "
        f"two-pass ({tps_f/tps_un:.2f}x) "
        f"acceptance {st_f.acceptance_rate:.2f} greedy agree {agree:.2f}"))
    summary["fused"]["spec_k2"] = {
        "tok_s_fused": tps_f, "tok_s_two_pass": tps_un,
        "fused_speedup": tps_f / tps_un,
        "acceptance_rate": st_f.acceptance_rate,
        "greedy_agree": agree,
    }
    return rows


def _paged_rows(max_new: int, reps: int, steps: int | None,
                summary: dict) -> list[tuple]:
    """Paged KV pool vs contiguous reservations (docs/DESIGN.md §13):

    * ``serve/paged/stream`` — continuous batching on a shared-prefix
      stream: paged vs dense tok/s, greedy token agreement, peak pool KV
      bytes vs the dense per-slot reservation.
    * ``serve/paged/prefix-ttft`` — prefill wall through the
      disaggregated API on a long prompt: a prefix-cache hit (page
      gather + suffix scan) vs the cold full-prompt prefill.
    * ``serve/paged/longctx-2048`` — equal-memory concurrency: short
      requests at ``max_seq=2048`` served from a pool holding exactly
      ``NUM_SLOTS`` dense reservations sustain >= 4x the concurrent
      slots (pages are allocated for the tokens a request can actually
      reach, not the max_seq worst case).
    """
    from repro.serving.pool import PagedConfig
    cfg, model, params = common.get_trained(ARCH, steps=steps)
    requests = synthetic_stream(
        NUM_REQUESTS, vocab_size=cfg.vocab_size, prompt_len=PROMPT_LEN,
        max_new_tokens=max_new, arrival_rate=ARRIVAL_RATE, seed=0)
    # common system prefix on every request (3/4 of the prompt) so the
    # prefix cache has something to share; page_size 8 keeps several pages
    # per slot at smoke scale
    shared = requests[0].prompt[:PROMPT_LEN - 4].copy()
    for r in requests:
        r.prompt[:len(shared)] = shared
    max_seq = max(len(r.prompt) + r.max_new_tokens for r in requests)
    rows = []

    def timed_serve(engine, reqs, slots, chunk):
        engine.serve(reqs[:2], num_slots=slots, chunk=chunk)  # warm
        best = None
        for _ in range(max(reps, 1)):
            t0 = time.perf_counter()
            outputs, stats = engine.serve(reqs, num_slots=slots, chunk=chunk)
            dt = time.perf_counter() - t0
            if best is None or dt < best[2]:
                best = (outputs, stats, dt)
        return best

    dense = ServeEngine(model, params, max_seq=max_seq)
    d_out, d_stats, d_dt = timed_serve(dense, requests, NUM_SLOTS, 4)
    d_tps = d_stats.generated_tokens / d_dt
    dense_resv = NUM_SLOTS * dense.kv_bytes_per_slot()

    paged = ServeEngine(model, params, max_seq=max_seq,
                        paged=PagedConfig(page_size=8))
    p_out, p_stats, p_dt = timed_serve(paged, requests, NUM_SLOTS, 4)
    p_tps = p_stats.generated_tokens / p_dt
    agree = float(all((a.tokens == b.tokens).all()
                      for a, b in zip(d_out, p_out)))
    rows.append((
        "serve/paged/stream", p_dt / max(p_stats.generated_tokens, 1) * 1e6,
        f"{p_tps:.1f} tok/s paged vs {d_tps:.1f} tok/s dense "
        f"({p_tps/d_tps:.2f}x) kv peak "
        f"{p_stats.kv_bytes_peak/2**20:.3f} MiB vs "
        f"{dense_resv/2**20:.3f} MiB dense reservation "
        f"greedy agree {agree:.2f}"))

    # prefix-hit TTFT: time prefill_request() itself through the
    # disaggregated API on a long prompt — a warm prefix cache replaces
    # the full-prompt prefill with a page gather plus a short suffix scan.
    # (The scheduler-level ttft p50 at smoke scale is chunk-granularity
    # noise; the prefill wall is the signal.)
    import numpy as np
    PFX_LEN, P_PG = 1024, 64
    pp = ServeEngine(model, params, max_seq=PFX_LEN + max_new,
                     paged=PagedConfig(page_size=P_PG))
    rs = np.random.RandomState(3)
    p1 = rs.randint(0, cfg.vocab_size, size=(PFX_LEN,)).astype(np.int32)
    p2 = p1.copy()   # shares all but the last 4 prompt tokens
    p2[-4:] = (p2[-4:] + 1) % cfg.vocab_size

    def prefill_pair():
        state = pp.init_decode_state(2)
        t0 = time.perf_counter()
        pf1 = pp.prefill_request(p1, state=state)
        jax.block_until_ready(pf1.last_logits)
        d_cold = time.perf_counter() - t0
        pp.insert(state, 0, pf1, max_new)   # registers p1's prefix pages
        t0 = time.perf_counter()
        pf2 = pp.prefill_request(p2, state=state)
        jax.block_until_ready(pf2.last_logits)
        return d_cold, time.perf_counter() - t0, pf2

    prefill_pair()   # compile the cold-prefill and seeded-suffix paths
    d_cold = d_hit = float("inf")
    hit_toks = 0
    for _ in range(max(reps, 1)):
        c, h, pf2 = prefill_pair()
        d_cold, d_hit = min(d_cold, c), min(d_hit, h)
        hit_toks = pf2.match.hit if pf2.match is not None else 0
    rows.append((
        "serve/paged/prefix-ttft", d_hit * 1e6,
        f"prefill {d_hit*1e3:.1f}ms on a {hit_toks}/{PFX_LEN}-token prefix "
        f"hit vs {d_cold*1e3:.1f}ms cold "
        f"({d_cold/max(d_hit, 1e-9):.2f}x faster to first token); stream: "
        f"{p_stats.prefix_hits} hits, {p_stats.prefix_hit_tokens} prompt "
        f"tokens skipped ({p_stats.prefix_hit_rate:.0%}), "
        f"{p_stats.cow_copies} cow"))

    # equal-memory concurrency at long context: the pool holds exactly
    # NUM_SLOTS dense reservations, yet short requests only consume the
    # pages they can reach — run 4x the slots concurrently through it
    LC_SEQ, LC_NEW = 2048, 4
    page = 64
    n_log = -(-LC_SEQ // page)
    lc_slots = 4 * NUM_SLOTS
    lc = ServeEngine(model, params, max_seq=LC_SEQ,
                     paged=PagedConfig(page_size=page,
                                       pool_pages=NUM_SLOTS * n_log,
                                       prefix_sharing=False))
    lc_reqs = synthetic_stream(
        lc_slots, vocab_size=cfg.vocab_size, prompt_len=PROMPT_LEN,
        max_new_tokens=LC_NEW, arrival_rate=0.0, seed=1)
    t0 = time.perf_counter()
    lc_out, lc_stats = lc.serve(lc_reqs, num_slots=lc_slots, chunk=4)
    lc_dt = time.perf_counter() - t0
    assert len(lc_out) == len(lc_reqs)
    per_req = lc.pool.pages_for(
        min(LC_SEQ, PROMPT_LEN + int(LC_NEW * 1.25) + 1))
    theo = (NUM_SLOTS * n_log) // per_req
    lc_resv = NUM_SLOTS * lc.kv_bytes_per_slot()
    rows.append((
        "serve/paged/longctx-2048",
        lc_dt / max(lc_stats.generated_tokens, 1) * 1e6,
        f"{lc_slots} concurrent slots ({lc_slots/NUM_SLOTS:.0f}x the "
        f"{NUM_SLOTS} dense slots the {lc_resv/2**20:.1f} MiB budget "
        f"reserves; theoretical max {theo} slots = "
        f"{theo/NUM_SLOTS:.0f}x) occupancy {lc_stats.occupancy:.2f} "
        f"peak {lc_stats.pool_pages_peak}/{lc_stats.pool_pages_total} "
        f"pages"))
    summary["paged"] = {
        "tok_s_paged": p_tps, "tok_s_dense": d_tps,
        "paged_vs_dense": p_tps / d_tps, "greedy_agree": agree,
        "kv_bytes_peak": p_stats.kv_bytes_peak,
        "dense_reservation_bytes": dense_resv,
        "prefix_hits": p_stats.prefix_hits,
        "prefix_hit_tokens": p_stats.prefix_hit_tokens,
        "prefix_hit_rate": p_stats.prefix_hit_rate,
        "cow_copies": p_stats.cow_copies,
        "prefill_s_prefix_hit": d_hit,
        "prefill_s_cold": d_cold,
        "prefix_hit_prefill_speedup": d_cold / max(d_hit, 1e-9),
        "prefix_hit_tokens_of_prompt": [hit_toks, PFX_LEN],
        "longctx": {
            "max_seq": LC_SEQ, "page_size": page,
            "pool_pages": NUM_SLOTS * n_log,
            "concurrent_slots": lc_slots,
            "dense_slots_at_equal_memory": NUM_SLOTS,
            "concurrency_uplift": lc_slots / NUM_SLOTS,
            "theoretical_max_slots": theo,
            "occupancy": lc_stats.occupancy,
            "pool_pages_peak": lc_stats.pool_pages_peak,
        },
    }
    return rows


def _slo_rows(max_new: int, reps: int, steps: int | None,
              summary: dict) -> list[tuple]:
    """SLO-aware serving under load (docs/DESIGN.md §14):

    * ``serve/slo/prefill-stall`` vs ``serve/slo/prefill-chunked`` — the
      tentpole measurement: per-chunk TPOT (decode-chunk wall / chunk
      steps) of RUNNING slots while a 1024-token prompt prefills
      mid-stream. Monolithic prefill dispatches the whole prompt between
      two decode chunks and every running slot stalls behind it (a
      multi-x spike in the max/p95 chunk TPOT vs the no-load baseline);
      chunked prefill (Sarathi-style ``prefill_chunk`` slices interleaved
      between decode chunks) keeps the p95 flat.
    * ``serve/slo/poisson-qps`` — open-loop Poisson arrivals at a target
      rate: queueing delay (submit -> admit) reported separately from
      TTFT.
    * ``serve/slo/priority-cancel`` — priority classes + timeout +
      cancellation + preemption on a PAGED engine under backpressure:
      priority-0 requests are admitted ahead of later-priority traffic,
      cancelled/timed-out requests release their slots and pages
      (``PoolSession.check_invariants`` asserted), preemptions requeue
      leak-free.
    """
    import numpy as np

    from repro.serving.pool import PagedConfig
    from repro.serving.scheduler import Request, SLOConfig
    cfg, model, params = common.get_trained(ARCH, steps=steps)
    rows = []

    # -- chunked-prefill interleaving vs monolithic stall --------------------
    LONG, PFCHUNK, SHORT_NEW, SCHUNK = 1024, 64, 96, 8
    max_seq = LONG + 8
    engine = ServeEngine(model, params, max_seq=max_seq)

    def shorts():
        return synthetic_stream(8, vocab_size=cfg.vocab_size,
                                prompt_len=PROMPT_LEN,
                                max_new_tokens=SHORT_NEW, seed=11)

    def with_long(reqs):
        rng = np.random.RandomState(13)
        prompt = rng.randint(0, cfg.vocab_size,
                             size=(LONG,)).astype(np.int32)
        # priority 0: admitted into the first freed slot, so its prefill
        # overlaps the remaining short requests' decode
        reqs.append(Request(rid=len(reqs), prompt=prompt, max_new_tokens=4,
                            arrival_step=4, priority=0))
        return reqs

    def chunk_tpots(requests, prefill_chunk):
        sess_kw = dict(num_slots=NUM_SLOTS, chunk=SCHUNK,
                       prefill_chunk=prefill_chunk)
        engine.serve(requests[:2], **sess_kw)     # warm the serve path
        best = None
        for _ in range(max(reps, 1)):
            t0 = time.perf_counter()
            _, stats = engine.serve(requests, **sess_kw)
            dt = time.perf_counter() - t0
            if best is None or dt < best[1]:
                best = (stats, dt)
        stats, dt = best
        return stats, dt, (stats.decode_gap_p95_s / SCHUNK,
                           stats.decode_gap_max_s / SCHUNK)

    base_stats, base_dt, (base_p95, base_max) = chunk_tpots(shorts(), None)
    m_stats, m_dt, (m_p95, m_max) = chunk_tpots(with_long(shorts()), None)
    c_stats, c_dt, (c_p95, c_max) = chunk_tpots(with_long(shorts()),
                                                PFCHUNK)
    rows.append((
        "serve/slo/no-load", base_p95 * 1e6,
        f"chunk tpot p95 {base_p95*1e3:.2f}ms (no long prompt; the "
        f"stall-row baseline)"))
    rows.append((
        "serve/slo/prefill-stall", m_p95 * 1e6,
        f"monolithic {LONG}-token prefill mid-stream: chunk tpot p95 "
        f"{m_p95*1e3:.2f}ms ({m_p95/base_p95:.2f}x no-load) max "
        f"{m_max*1e3:.2f}ms ({m_max/base_max:.1f}x) — every running slot "
        f"stalls behind the prefill"))
    rows.append((
        "serve/slo/prefill-chunked", c_p95 * 1e6,
        f"prefill_chunk={PFCHUNK}: chunk tpot p95 {c_p95*1e3:.2f}ms "
        f"({c_p95/base_p95:.2f}x no-load) max {c_max*1e3:.2f}ms "
        f"({c_max/base_max:.1f}x) over {c_stats.prefill_chunks} "
        f"interleaved prefill chunks"))
    summary["slo"]["prefill_stall"] = {
        "long_prompt": LONG, "prefill_chunk": PFCHUNK,
        "chunk_tpot_p95_s": {"no_load": base_p95, "monolithic": m_p95,
                             "chunked": c_p95},
        "chunk_tpot_max_s": {"no_load": base_max, "monolithic": m_max,
                             "chunked": c_max},
        "monolithic_p95_vs_no_load": m_p95 / base_p95,
        "chunked_p95_vs_no_load": c_p95 / base_p95,
        "monolithic_max_vs_no_load": m_max / base_max,
        "chunked_max_vs_no_load": c_max / base_max,
    }

    # -- open-loop Poisson sweep ---------------------------------------------
    sweep_seq = PROMPT_LEN + int(max_new * 1.25) + 1
    qengine = ServeEngine(model, params, max_seq=sweep_seq)
    qengine.serve(synthetic_stream(
        2, vocab_size=cfg.vocab_size, prompt_len=PROMPT_LEN,
        max_new_tokens=max_new), num_slots=NUM_SLOTS, chunk=4)    # warm
    summary["slo"]["poisson"] = {}
    for rate in (0.25, 1.0):
        reqs = synthetic_stream(
            2 * NUM_REQUESTS, vocab_size=cfg.vocab_size,
            prompt_len=PROMPT_LEN, max_new_tokens=max_new,
            arrival_rate=rate, poisson=True, seed=5)
        t0 = time.perf_counter()
        _, stats = qengine.serve(reqs, num_slots=NUM_SLOTS, chunk=4)
        dt = time.perf_counter() - t0
        tps = stats.generated_tokens / dt
        rows.append((
            f"serve/slo/poisson-qps-{rate}",
            stats.queue_delay_p95_s * 1e6,
            f"{tps:.1f} tok/s at {rate} req/step open-loop: queue delay "
            f"p50 {stats.queue_delay_p50_s*1e3:.0f}ms / "
            f"p95 {stats.queue_delay_p95_s*1e3:.0f}ms "
            f"(ttft p50 {stats.ttft_p50_s*1e3:.0f}ms, reported "
            f"separately), occupancy {stats.occupancy:.2f}"))
        summary["slo"]["poisson"][str(rate)] = {
            "tok_s_stream": tps, "occupancy": stats.occupancy,
            "queue_delay_p50_s": stats.queue_delay_p50_s,
            "queue_delay_p95_s": stats.queue_delay_p95_s,
            "ttft_p50_s": stats.ttft_p50_s, "ttft_p95_s": stats.ttft_p95_s,
        }

    # -- priorities, cancellation, preemption on a paged pool ----------------
    reqs = synthetic_stream(
        2 * NUM_REQUESTS, vocab_size=cfg.vocab_size, prompt_len=PROMPT_LEN,
        max_new_tokens=4 * max_new, arrival_rate=2.0, poisson=True, seed=5,
        priorities=(1, 1, 1, 0))
    pengine = ServeEngine(
        model, params,
        max_seq=max(len(r.prompt) + r.max_new_tokens for r in reqs),
        paged=PagedConfig(page_size=8))
    for r in reqs[::5]:
        r.cancel_at_step = r.arrival_step + 6
    for r in reqs[3::5]:
        r.queue_timeout_steps = 4
    t0 = time.perf_counter()
    outs, stats = pengine.serve(reqs, num_slots=NUM_SLOTS, chunk=4,
                                slo=SLOConfig(preempt=True))
    dt = time.perf_counter() - t0
    pengine.pool.check_invariants()    # cancellation frees pages leak-free
    admitted = [o for o in outs if o.admitted_step >= 0]
    arrival = {r.rid: r.arrival_step for r in reqs}
    # priority ordering: a priority-0 request is never admitted after a
    # lower-priority request that arrived no earlier than it did
    ordered = all(
        a.admitted_step <= b.admitted_step
        for a in admitted if a.priority == 0
        for b in admitted
        if b.priority > 0 and arrival[b.rid] >= arrival[a.rid])
    n_drop = sum(o.finish_reason in ("cancelled", "timeout") for o in outs)
    rows.append((
        "serve/slo/priority-cancel", dt / max(stats.generated_tokens, 1)
        * 1e6,
        f"{stats.generated_tokens/dt:.1f} tok/s with mixed priorities "
        f"(25% priority-0): {stats.preemptions} preemptions, "
        f"{stats.timeouts} timeouts, {stats.cancelled} cancelled "
        f"({n_drop} dropped reqs), pool invariants OK after "
        f"cancel/preempt, priority ordering "
        f"{'OK' if ordered else 'VIOLATED'}"))
    summary["slo"]["priority_cancel"] = {
        "preemptions": stats.preemptions, "timeouts": stats.timeouts,
        "cancelled": stats.cancelled,
        "pool_invariants_ok": True, "priority_ordering_ok": bool(ordered),
        "queue_delay_p95_s": stats.queue_delay_p95_s,
    }
    return rows


def _dp_rows(max_new: int, reps: int, steps: int | None,
             summary: dict) -> list[tuple]:
    """DP x TP replica serving (docs/DESIGN.md §14): the same request
    stream on one TP-only (1, N) engine vs a (2, N/2) ``data,model`` mesh
    split into two TP replicas behind the load-aware router — greedy
    token agreement must be 1.0, per-replica occupancy reported."""
    n_dev = len(jax.devices())
    if n_dev < 4 or n_dev % 2:
        return [("serve/dp/skipped", 0.0,
                 f"{n_dev} device(s) visible (set XLA_FLAGS="
                 f"--xla_force_host_platform_device_count=8 for DP rows)")]
    from repro.launch.mesh import make_mesh, split_data_replicas
    from repro.serving.replica import ReplicaServe
    cfg, model, params = common.get_trained(ARCH, steps=steps)
    plan = plan_for_variant(model, params, FAMILY_VARIANT)
    qparams = model.compile_plan(params, plan).params
    requests = synthetic_stream(
        NUM_REQUESTS, vocab_size=cfg.vocab_size, prompt_len=PROMPT_LEN,
        max_new_tokens=max_new, arrival_rate=ARRIVAL_RATE, seed=0)
    max_seq = max(len(r.prompt) + r.max_new_tokens for r in requests)
    rows = []

    def timed(fn):
        fn()                                     # warm
        best = None
        for _ in range(max(reps, 1)):
            t0 = time.perf_counter()
            out = fn()
            dt = time.perf_counter() - t0
            if best is None or dt < best[1]:
                best = (out, dt)
        return best

    tp = ServeEngine(model, qparams, max_seq=max_seq,
                     mesh=make_mesh((1, n_dev), ("data", "model")))
    (tp_out, tp_stats), tp_dt = timed(
        lambda: tp.serve(requests, num_slots=NUM_SLOTS, chunk=CHUNK))
    tp_tps = tp_stats.generated_tokens / tp_dt
    rows.append((
        f"serve/dp/1x{n_dev}/stream",
        tp_dt / max(tp_stats.generated_tokens, 1) * 1e6,
        f"{tp_tps:.1f} tok/s TP-only baseline, occupancy "
        f"{tp_stats.occupancy:.2f}"))

    shape = (2, n_dev // 2)
    mesh = make_mesh(shape, ("data", "model"))
    rep = ReplicaServe([ServeEngine(model, qparams, max_seq=max_seq,
                                    mesh=m)
                        for m in split_data_replicas(mesh)])
    (dp_out, rstats), dp_dt = timed(
        lambda: rep.serve(requests, num_slots=max(1, NUM_SLOTS // 2),
                          chunk=CHUNK))
    dp_stats = rstats.aggregate
    dp_tps = dp_stats.generated_tokens / dp_dt
    agree = float(len(dp_out) == len(tp_out) and all(
        (a.tokens == b.tokens).all() for a, b in zip(tp_out, dp_out)))
    occ = " ".join(f"r{i}:{o:.2f}"
                   for i, o in enumerate(rstats.occupancy_per_replica))
    rows.append((
        f"serve/dp/{shape[0]}x{shape[1]}/stream",
        dp_dt / max(dp_stats.generated_tokens, 1) * 1e6,
        f"{dp_tps:.1f} tok/s on {rstats.replicas} replicas "
        f"({dp_tps/tp_tps:.2f}x vs TP-only) assignments "
        f"{rstats.assignments} per-replica occupancy [{occ}] "
        f"greedy agree {agree:.2f}"))
    assert agree == 1.0, "DP x TP serve diverged from TP-only engine"
    summary["dp"] = {
        "devices": n_dev, "shape": list(shape),
        "tok_s_tp_only": tp_tps, "tok_s_dp": dp_tps,
        "dp_vs_tp": dp_tps / tp_tps,
        "assignments": rstats.assignments,
        "occupancy_per_replica": rstats.occupancy_per_replica,
        "greedy_agree": agree,
    }
    return rows


def _fault_rows(max_new: int, reps: int, steps: int | None,
                summary: dict) -> list[tuple]:
    """Fault tolerance (docs/DESIGN.md §15): the serving stack under
    injected faults. Two rows — (a) 1-of-2 replica loss mid-stream with
    failover + request re-drive (throughput retained vs the fault-free
    two-replica run, recovery p95, greedy agreement must stay 1.0) and
    (b) ewq graceful degradation under injected pool exhaustion (degraded
    vs nominal tok/s, tier histogram, zero lost requests). Replicas are
    unmeshed single-device engines — the fault paths under test are
    host-side, so the rows run at any device count."""
    from repro.serving import chaos
    from repro.serving.chaos import FaultConfig
    from repro.serving.pool import PagedConfig
    from repro.serving.replica import FailoverConfig, ReplicaServe
    from repro.serving.session import DegradeConfig
    cfg, model, params = common.get_trained(ARCH, steps=steps)
    plan = plan_for_variant(model, params, FAMILY_VARIANT)
    qparams = model.compile_plan(params, plan).params
    requests = synthetic_stream(
        NUM_REQUESTS, vocab_size=cfg.vocab_size, prompt_len=PROMPT_LEN,
        max_new_tokens=max_new, arrival_rate=ARRIVAL_RATE, seed=0)
    max_seq = max(len(r.prompt) + r.max_new_tokens for r in requests)
    # pool sized so only the INJECTED exhaustion (not real pressure)
    # drives the degradation ladder
    paged = PagedConfig(page_size=8,
                        pool_pages=NUM_SLOTS * -(-max_seq // 8))
    rows = []

    def timed(fn):
        fn()                                     # warm
        best = None
        for _ in range(max(reps, 1)):
            t0 = time.perf_counter()
            out = fn()
            dt = time.perf_counter() - t0
            if best is None or dt < best[1]:
                best = (out, dt)
        return best

    def agree_vs(ref, out):
        return float(len(out) == len(ref) and all(
            a.rid == b.rid and (a.tokens == b.tokens).all()
            for a, b in zip(ref, out)))

    # -- (a) replica loss: kill 1 of 2 replicas mid-stream ------------------
    def replicas():
        return ReplicaServe([
            ServeEngine(model, qparams, max_seq=max_seq, paged=paged)
            for _ in range(2)])

    rep = replicas()
    (ref_out, ref_stats), ref_dt = timed(
        lambda: rep.serve(requests, num_slots=max(1, NUM_SLOTS // 2),
                          chunk=CHUNK))
    ref_tps = ref_stats.aggregate.generated_tokens / ref_dt

    def lossy():
        with chaos.chaos(FaultConfig.parse("replica_fault", seed=0)):
            return rep.serve(requests, num_slots=max(1, NUM_SLOTS // 2),
                             chunk=CHUNK, failover=FailoverConfig())

    (loss_out, loss_rstats), loss_dt = timed(lossy)
    loss = loss_rstats.aggregate
    loss_tps = loss.generated_tokens / loss_dt
    agree = agree_vs(ref_out, loss_out)
    for eng in rep.engines:
        eng.pool.check_invariants()
    rows.append((
        "serve/fault/replica-loss/stream",
        loss_dt / max(loss.generated_tokens, 1) * 1e6,
        f"{loss_tps:.1f} tok/s with 1-of-2 replicas killed mid-stream "
        f"({loss_tps/ref_tps:.2f}x of fault-free), "
        f"{loss.redriven_requests} re-driven, recovery p95 "
        f"{loss.recovery_p95_s*1e3:.1f} ms, greedy agree {agree:.2f}"))
    assert agree == 1.0, \
        "failover re-drive diverged from the fault-free replica run"

    # -- (b) graceful degradation under injected pool exhaustion ------------
    nom = ServeEngine(model, qparams, max_seq=max_seq, paged=paged)
    (nom_out, nom_stats), nom_dt = timed(
        lambda: nom.serve(requests, num_slots=NUM_SLOTS, chunk=CHUNK))
    nom_tps = nom_stats.generated_tokens / nom_dt

    deg_eng = ServeEngine(model, qparams, max_seq=max_seq, paged=paged)

    def degraded():
        with chaos.chaos(FaultConfig.parse("oom", seed=0)):
            return deg_eng.serve(requests, num_slots=NUM_SLOTS, chunk=CHUNK,
                                 degrade=DegradeConfig())

    (deg_out, deg_stats), deg_dt = timed(degraded)
    deg_tps = deg_stats.generated_tokens / deg_dt
    deg_agree = agree_vs(nom_out, deg_out)
    deg_eng.pool.check_invariants()
    assert len(deg_out) == len(requests), \
        "graceful degradation lost requests under injected exhaustion"
    tiers = "/".join(str(t) for t in deg_stats.kv_tier_steps)
    rows.append((
        "serve/fault/degraded/stream",
        deg_dt / max(deg_stats.generated_tokens, 1) * 1e6,
        f"{deg_tps:.1f} tok/s under injected pool exhaustion "
        f"({deg_tps/nom_tps:.2f}x of nominal {nom_tps:.1f}), "
        f"{deg_stats.degrade_transitions} tier transitions, "
        f"tier steps [{tiers}], greedy agree {deg_agree:.2f}"))
    summary["fault"] = {
        "tok_s_two_replicas": ref_tps, "tok_s_replica_loss": loss_tps,
        "throughput_retained": loss_tps / ref_tps,
        "recovery_p95_s": loss.recovery_p95_s,
        "replica_restarts": loss.replica_restarts,
        "redriven_requests": loss.redriven_requests,
        "replica_loss_greedy_agree": agree,
        "tok_s_nominal": nom_tps, "tok_s_degraded": deg_tps,
        "degraded_vs_nominal": deg_tps / nom_tps,
        "degrade_transitions": deg_stats.degrade_transitions,
        "kv_tier_steps": list(deg_stats.kv_tier_steps),
        "degraded_greedy_agree": deg_agree,
    }
    return rows


def _obs_rows(max_new: int, reps: int, steps: int | None,
              summary: dict) -> list[tuple]:
    """Observability overhead (docs/DESIGN.md §16): the same
    continuous-batching stream untraced (no sinks installed — the
    production default) vs fully traced (span tracer + metrics registry
    through ``obs.install``). The rounds interleave off/on so machine
    drift biases neither; traced overhead is asserted < 2%. A separate
    microbenchmark times the disabled hook itself — one module-global
    ``None`` check — to pin the off-path cost near zero."""
    from repro import obs
    cfg, model, params = common.get_trained(ARCH, steps=steps)
    requests = synthetic_stream(
        NUM_REQUESTS, vocab_size=cfg.vocab_size, prompt_len=PROMPT_LEN,
        max_new_tokens=max_new, arrival_rate=ARRIVAL_RATE, seed=0)
    max_seq = max(len(r.prompt) + r.max_new_tokens for r in requests)
    engine = ServeEngine(model, params, max_seq=max_seq)
    engine.serve(requests[:2], num_slots=NUM_SLOTS, chunk=CHUNK)   # warm

    best_off = best_on = float("inf")
    tracer = registry = stats = None
    for _ in range(max(reps, 3)):
        t0 = time.perf_counter()
        _, st_off = engine.serve(requests, num_slots=NUM_SLOTS, chunk=CHUNK)
        best_off = min(best_off, time.perf_counter() - t0)

        tr, reg = obs.Tracer(), obs.MetricsRegistry()
        prev = obs.install(tr, reg, None)
        try:
            t0 = time.perf_counter()
            _, st_on = engine.serve(requests, num_slots=NUM_SLOTS,
                                    chunk=CHUNK)
            dt = time.perf_counter() - t0
        finally:
            obs.install(*prev)
        assert tr.open_spans() == [], \
            f"traced serve leaked open spans: {tr.open_spans()}"
        if dt < best_on:
            best_on, tracer, registry, stats = dt, tr, reg, st_on

    gen = max(stats.generated_tokens, 1)
    tps_off, tps_on = gen / best_off, gen / best_on
    overhead = best_on / best_off - 1.0
    events = sum(tracer.counts().values())
    families = len(registry.names())

    # disabled-hook microcost: with no sinks installed every obs call is
    # a module-global read plus a None check — the per-site price the
    # serving hot loop pays when telemetry is off
    N = 100_000
    t0 = time.perf_counter()
    for _ in range(N):
        obs.instant("bench/noop", 0)
        obs.count("bench_noop_total", 1)
    hook_ns = (time.perf_counter() - t0) / (2 * N) * 1e9

    rows = [
        ("serve/obs/off/stream", best_off / gen * 1e6,
         f"{tps_off:.1f} tok/s no telemetry sinks installed "
         f"(the production default)"),
        ("serve/obs/on/stream", best_on / gen * 1e6,
         f"{tps_on:.1f} tok/s traced+metered ({overhead:+.2%} vs off; "
         f"{events} trace events, {families} metric families, "
         f"0 open spans)"),
        ("serve/obs/hook-disabled", hook_ns / 1e3,
         f"{hook_ns:.0f} ns per disabled obs call (one None check; "
         f"{2 * N} calls timed)"),
    ]
    assert overhead < 0.02, \
        f"traced serve overhead {overhead:.2%} exceeds the 2% budget"
    summary["obs"] = {
        "tok_s_off": tps_off, "tok_s_on": tps_on,
        "traced_overhead": overhead,
        "trace_events": events, "metric_families": families,
        "disabled_hook_ns": hook_ns,
    }
    return rows


def run(smoke: bool = False) -> list[tuple]:
    max_new = 8 if smoke else MAX_NEW
    # best-of-3 even in smoke: the fused/tuned delta rows race paths that
    # are tens of percent apart, and a single rep flips sign under CI load
    reps = 3
    steps = SMOKE_TRAIN_STEPS if smoke else None
    summary: dict = {"variants": {}, "families": {}, "mesh": {},
                     "kv_cache": {}, "fused": {}, "spec": {}, "paged": {},
                     "slo": {}, "dp": {}, "fault": {}, "obs": {}}
    # smoke (CI): one quantized variant through stepwise/fused/stream so the
    # continuous-batching path is exercised, then the full family sweep
    variants = ("4bit/8bit",) if smoke else VARIANTS
    rows = _variant_rows(max_new, reps, summary, steps, variants)
    rows += _family_rows(max_new, reps, steps, summary)
    rows += _mesh_rows(max_new, reps, steps, summary)
    rows += _kv_rows(max_new, reps, steps, summary)
    rows += _fused_rows(max_new, reps, steps, summary)
    rows += _spec_rows(max_new, reps, steps, summary)
    rows += _paged_rows(max_new, reps, steps, summary)
    rows += _slo_rows(max_new, reps, steps, summary)
    rows += _dp_rows(max_new, reps, steps, summary)
    rows += _fault_rows(max_new, reps, steps, summary)
    rows += _obs_rows(max_new, reps, steps, summary)
    common.save_json("serve_throughput.json", summary)
    return rows


if __name__ == "__main__":
    import sys
    print("name,us_per_call,derived")
    common.emit(run(smoke="--smoke" in sys.argv))
