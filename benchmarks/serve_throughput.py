"""Serving throughput: fused chunked decode loop vs per-token dispatch.

For each deployment variant (raw bf16 | EWQ 8bit-mixed | EWQ 4bit/8bit) of
the same trained model, measures decode tokens/sec for:

  * ``stepwise`` — the legacy per-token Python loop (one jitted decode
    dispatch + host sync per token; what ServeEngine.generate did before
    the continuous-batching refactor);
  * ``fused``    — the jitted ``lax.scan`` chunked loop (one dispatch per
    CHUNK tokens);
  * ``stream``   — continuous batching over a simulated request stream
    (Poisson-ish arrivals, slots freed mid-run are re-filled), reporting
    batch occupancy and mid-run admissions alongside throughput.

Smoke-scale (CPU) defaults; run directly or via ``benchmarks/run.py serve``:

  PYTHONPATH=src python -m benchmarks.serve_throughput
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.serving.engine import ServeEngine
from repro.serving.quantized import plan_for_variant
from repro.serving.scheduler import synthetic_stream

ARCH = "llama3.2-3b"
VARIANTS = ("raw", "8bit-mixed", "4bit/8bit")
BATCH = 4
PROMPT_LEN = 16
MAX_NEW = 32
CHUNK = 16
# stream simulation
NUM_REQUESTS = 12
NUM_SLOTS = 4
ARRIVAL_RATE = 0.25   # requests per decode step


def _time(fn, reps: int = 3) -> float:
    """Best-of-``reps`` wall time after a warmup/compile call."""
    fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> list[tuple]:
    cfg, model, params = common.get_trained(ARCH)
    prompts = jax.random.randint(jax.random.PRNGKey(7), (BATCH, PROMPT_LEN),
                                 0, cfg.vocab_size, dtype=jnp.int32)
    rows = []
    summary = {}
    for variant in VARIANTS:
        plan = plan_for_variant(model, params, variant)
        engine = ServeEngine(model, params, plan=plan,
                             max_seq=PROMPT_LEN + int(MAX_NEW * 1.25) + 1)
        tokens = BATCH * MAX_NEW

        dt_step = _time(lambda: engine.generate_stepwise(prompts, MAX_NEW)
                        .tokens)
        dt_fused = _time(lambda: engine.generate(prompts, MAX_NEW,
                                                 chunk=CHUNK).tokens)
        tps_step = tokens / dt_step
        tps_fused = tokens / dt_fused

        requests = synthetic_stream(
            NUM_REQUESTS, vocab_size=cfg.vocab_size, prompt_len=PROMPT_LEN,
            max_new_tokens=MAX_NEW, arrival_rate=ARRIVAL_RATE, seed=0)
        # warm the serve path (chunk fn, batch=1 prefill, insert/release
        # compiles) so the timed run is steady-state like the rows above
        engine.serve(requests[:2], num_slots=NUM_SLOTS, chunk=CHUNK)
        t0 = time.perf_counter()
        _, stats = engine.serve(requests, num_slots=NUM_SLOTS, chunk=CHUNK)
        dt_stream = time.perf_counter() - t0
        tps_stream = stats.generated_tokens / dt_stream

        tag = variant.replace("/", "-")
        rows.append((f"serve/{tag}/stepwise", dt_step / tokens * 1e6,
                     f"{tps_step:.1f} tok/s"))
        rows.append((f"serve/{tag}/fused", dt_fused / tokens * 1e6,
                     f"{tps_fused:.1f} tok/s speedup {tps_fused/tps_step:.2f}x"))
        rows.append((f"serve/{tag}/stream", dt_stream / max(
            stats.generated_tokens, 1) * 1e6,
            f"{tps_stream:.1f} tok/s occupancy {stats.occupancy:.2f} "
            f"admissions {stats.admissions}"))
        summary[variant] = {
            "weight_mib": engine.weight_bytes() / 2**20,
            "tok_s_stepwise": tps_step, "tok_s_fused": tps_fused,
            "fused_speedup": tps_fused / tps_step,
            "tok_s_stream": tps_stream, "occupancy": stats.occupancy,
            "mid_run_admissions": stats.admissions,
            "decode_steps": stats.decode_steps,
        }
    common.save_json("serve_throughput.json", summary)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    common.emit(run())
