"""Paper Table 7: FastEWQ variants (fast = full-dataset classifier,
fast-train = 70% split) vs the EWQ plans, same metrics as Table 6."""

from __future__ import annotations

from repro.core.fastewq import train_fastewq
from repro.core.planner import plan_model
from repro.models.model import build

from benchmarks import common


def _block_sizes(model, params):
    import jax
    import numpy as np
    return [int(sum(np.prod(x.shape) for x in jax.tree.leaves(b)))
            for b in model.block_params(params)]


def run():
    rows_ds = common.fastewq_rows()
    fast = train_fastewq(rows_ds, full_dataset=True)      # paper "fast"
    fast_train = train_fastewq(rows_ds, full_dataset=False)  # "fast train"
    out_rows, table = [], []
    for arch in common.BENCH_ARCHS:
        cfg, model, params = common.get_trained(arch)
        sizes = _block_sizes(model, params)
        plans = {
            "8bit mixed": plan_model(model, params, variant="8bit-mixed"),
            "4bit/8bit mixed": plan_model(model, params, variant="4bit/8bit"),
            "fast 8bit mixed": fast.plan(sizes, variant="8bit-mixed"),
            "fast 4bit/8bit mixed": fast.plan(sizes, variant="4bit/8bit"),
            "fast train 8bit mixed": fast_train.plan(sizes,
                                                     variant="8bit-mixed"),
            "fast train 4bit/8bit mixed": fast_train.plan(
                sizes, variant="4bit/8bit"),
        }
        for name, plan in plans.items():
            m = common.quantized_metrics(model, params, plan)
            size = common.plan_sizes_mib(model, params, plan)
            c = plan.counts()
            table.append({
                "model": cfg.name, "variant": name,
                "accuracy": round(m["accuracy"], 4),
                "perplexity": round(m["perplexity"], 4),
                "blocks_mib": round(size, 3),
                "raw/8bit/4bit": f"{c['raw']}/{c['int8']}/{c['int4']}",
            })
            out_rows.append(
                (f"table7/{cfg.name}/{name.replace(' ', '_')}",
                 m["us_per_call"],
                 f"acc={m['accuracy']:.4f};ppl={m['perplexity']:.3f};"
                 f"mib={size:.2f}"))
    common.save_json("table7_fastewq.json", table)
    return out_rows


def main():
    common.emit(run())


if __name__ == "__main__":
    main()
