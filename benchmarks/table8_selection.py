"""Paper Table 8: blocks selected for quantization, by exec_index,
EWQ vs fast vs fast-train."""

from __future__ import annotations

import time

from repro.core.fastewq import train_fastewq
from repro.core.planner import plan_model

from benchmarks import common
from benchmarks.table7_fastewq import _block_sizes


def _selection(plan):
    sel = [d for d in plan.by_priority() if d.quantized]
    return {
        "by_exec_index": [d.exec_index for d in sel],
        "4bit": [d.exec_index for d in sel if d.precision == "int4"],
        "total": len(sel),
    }


def run():
    ds = common.fastewq_rows()
    fast = train_fastewq(ds, full_dataset=True)
    fast_train = train_fastewq(ds, full_dataset=False)
    rows, table = [], []
    for arch in common.BENCH_ARCHS:
        cfg, model, params = common.get_trained(arch)
        sizes = _block_sizes(model, params)
        t0 = time.perf_counter()
        plans = {
            "ewq": plan_model(model, params, variant="4bit/8bit"),
            "fast": fast.plan(sizes, variant="4bit/8bit"),
            "fast_train": fast_train.plan(sizes, variant="4bit/8bit"),
        }
        us = (time.perf_counter() - t0) * 1e6 / 3
        ewq_set = {d.exec_index for d in plans["ewq"].decisions if d.quantized}
        for name, plan in plans.items():
            s = _selection(plan)
            sel_set = set(s["by_exec_index"])
            overlap = (len(sel_set & ewq_set) / max(len(ewq_set), 1))
            table.append({"model": cfg.name, "variant": name, **s,
                          "overlap_with_ewq": round(overlap, 3)})
            rows.append((f"table8/{cfg.name}/{name}", us,
                         f"selected={s['total']};overlap={overlap:.2f}"))
    common.save_json("table8_selection.json", table)
    return rows


def main():
    common.emit(run())


if __name__ == "__main__":
    main()
