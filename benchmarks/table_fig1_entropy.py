"""Paper Figure 1: entropy distribution across transformer blocks."""

from __future__ import annotations

import time

from repro.core.planner import analyze

from benchmarks import common


def run():
    rows, table = [], []
    for arch in common.BENCH_ARCHS:
        cfg, model, params = common.get_trained(arch)
        t0 = time.perf_counter()
        ents = analyze(model.block_params(params))
        us = (time.perf_counter() - t0) / max(len(ents), 1) * 1e6
        hs = [round(b.entropy, 4) for b in ents]
        table.append({"model": cfg.name, "entropies": hs,
                      "min": min(hs), "max": max(hs)})
        spread = max(hs) - min(hs)
        rows.append((f"fig1/{cfg.name}", us,
                     f"blocks={len(hs)};spread={spread:.4f}"))
    common.save_json("fig1_entropy.json", table)
    return rows


def main():
    common.emit(run())


if __name__ == "__main__":
    main()
