"""Paper Table 14: relative accuracy / perplexity / size deltas vs raw."""

from __future__ import annotations

import json
import time

from benchmarks import common


def run():
    for f, mod in [("table6_ewq.json", "benchmarks.table6_ewq"),
                   ("table7_fastewq.json", "benchmarks.table7_fastewq")]:
        if not (common.RESULTS / f).exists():
            import importlib
            importlib.import_module(mod).run()
    t6 = json.load(open(common.RESULTS / "table6_ewq.json"))
    t7 = json.load(open(common.RESULTS / "table7_fastewq.json"))
    raw = {e["model"]: e for e in t6 if e["variant"] == "raw"}
    rows, table = [], []
    t0 = time.perf_counter()
    for e in t6 + t7:
        if e["variant"] == "raw":
            continue
        r = raw[e["model"]]
        entry = {
            "model": e["model"], "variant": e["variant"],
            "acc_delta_pct": round(100 * (e["accuracy"] - r["accuracy"])
                                   / max(r["accuracy"], 1e-9), 2),
            "ppl_delta_pct": round(100 * (e["perplexity"] - r["perplexity"])
                                   / r["perplexity"], 2),
            "size_delta_pct": round(100 * (e["blocks_mib"] - r["blocks_mib"])
                                    / r["blocks_mib"], 2),
            "complexity": "O(1)" if "fast" in e["variant"] or
                          e["variant"] in ("4bit", "8bit") else "O(n)",
        }
        table.append(entry)
    us = (time.perf_counter() - t0) * 1e6 / max(len(table), 1)
    common.save_json("table14_summary.json", table)
    for e in table:
        rows.append((f"table14/{e['model']}/{e['variant'].replace(' ', '_')}",
                     us, f"acc{e['acc_delta_pct']:+.2f}%;"
                     f"ppl{e['ppl_delta_pct']:+.2f}%;"
                     f"size{e['size_delta_pct']:+.2f}%;{e['complexity']}"))
    return rows


def main():
    common.emit(run())


if __name__ == "__main__":
    main()
