"""Paper Table 6: EWQ variants — accuracy / perplexity / size per variant.

Reduced-scale analogue: accuracy = next-token top-1 on a held-out synthetic
stream (the MMLU proxy available without external data), perplexity =
exp(mean token loss), size = effective transformer-block + embedding bytes.
"""

from __future__ import annotations

from repro.core.planner import plan_model

from benchmarks import common

VARIANTS = ["raw", "4bit", "8bit", "8bit-mixed", "4bit/8bit"]


def run():
    rows = []
    table = []
    for arch in common.BENCH_ARCHS:
        cfg, model, params = common.get_trained(arch)
        for variant in VARIANTS:
            plan = plan_model(model, params, variant=variant)
            if variant == "raw":
                m = common.eval_metrics(model, params)
            else:
                m = common.quantized_metrics(model, params, plan)
            size = common.plan_sizes_mib(model, params, plan)
            c = plan.counts()
            table.append({
                "model": cfg.name, "variant": variant,
                "accuracy": round(m["accuracy"], 4),
                "perplexity": round(m["perplexity"], 4),
                "blocks_mib": round(size, 3),
                "raw/8bit/4bit": f"{c['raw']}/{c['int8']}/{c['int4']}",
            })
            rows.append((f"table6/{cfg.name}/{variant}", m["us_per_call"],
                         f"acc={m['accuracy']:.4f};ppl={m['perplexity']:.3f};"
                         f"mib={size:.2f}"))
    common.save_json("table6_ewq.json", table)
    return rows


def main():
    common.emit(run())


if __name__ == "__main__":
    main()
