"""Observability layer: tracer, metrics registry, serve-metric schema,
renderer (docs/DESIGN.md §16).

Five layers:

* the metrics registry (obs/metrics.py): counter/gauge/histogram
  semantics, label handling, exact quantiles, merge roll-up, and golden
  Prometheus / JSON expositions;
* the span tracer (obs/trace.py): B/E balance bookkeeping, the
  per-request phase state machine, abandon, and the Chrome trace_event
  JSON schema Perfetto loads;
* the facade (obs/__init__.py): off-by-default no-ops, install/restore,
  scoped capture;
* the serve-metric schema (obs/serve_metrics.py): two-way coverage
  between ``SCHEMA``/``STATS_FIELD_METRICS`` and the ``ServeStats``
  fields, and the publish -> stats_fields round trip;
* end-to-end leak freedom: ``open_spans() == []`` after plain streams,
  cancellation/preemption, OutOfPages backpressure and chaos-driven
  failover re-drive — and ``ServeStats`` back-compat across all four
  model families (traced or not, the snapshot is identical).
"""

import dataclasses
import json

import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                               MetricsRegistry)
from repro.obs.serve_metrics import SCHEMA, STATS_FIELD_METRICS
from repro.obs.trace import DECODE_TRACK, ENGINE_TRACK, REQ_TRACK_BASE, Tracer


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_accumulates_per_label_set():
    reg = MetricsRegistry()
    c = reg.counter("serve_x_total", "help")
    c.inc(2, replica="0")
    c.inc(3, replica="0")
    c.inc(1, replica="1")
    assert c.value(replica="0") == 5
    assert c.value(replica="1") == 1
    assert c.total() == 6
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)


def test_gauge_set_is_level_not_flow():
    reg = MetricsRegistry()
    g = reg.gauge("serve_level")
    g.set(4.0, kind="peak")
    g.set(2.0, kind="peak")
    assert g.value(kind="peak") == 2.0
    g.inc(1.5, kind="peak")
    assert g.value(kind="peak") == 3.5


def test_registry_rejects_kind_conflicts_and_backfills_help():
    reg = MetricsRegistry()
    reg.counter("serve_x_total")            # created help-less (live emitter)
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("serve_x_total")
    m = reg.counter("serve_x_total", "later help")
    assert m.help == "later help"           # schema-carrying call backfills


def test_histogram_quantiles_are_exact():
    reg = MetricsRegistry()
    h = reg.histogram("serve_lat_seconds")
    vals = [0.001 * i for i in range(1, 101)]
    for v in vals:
        h.observe(v, replica="0")
    assert h.count() == 100
    assert h.sum() == pytest.approx(sum(vals))
    assert h.quantile(50) == pytest.approx(np.percentile(vals, 50))
    assert h.quantile(95) == pytest.approx(np.percentile(vals, 95))
    assert h.max() == pytest.approx(max(vals))
    assert reg.quantile("serve_lat_seconds", 50) == h.quantile(50)
    assert reg.quantile("serve_missing", 50) == 0.0


def test_histogram_label_superset_matching():
    h = Histogram("serve_lat_seconds")
    h.observe(0.1, replica="0", priority="0")
    h.observe(0.3, replica="0", priority="1")
    h.observe(0.5, replica="1", priority="1")
    assert sorted(h.samples()) == [0.1, 0.3, 0.5]          # aggregate
    assert h.samples(priority="1") == [0.3, 0.5]           # narrow one key
    assert h.samples(replica="0", priority="0") == [0.1]
    assert h.label_values("priority") == ["0", "1"]


def test_merge_counters_add_gauges_take_level_histograms_add():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("serve_x_total").inc(1, replica="0")
    b.counter("serve_x_total").inc(2, replica="0")
    a.gauge("serve_g").set(1.0)
    b.gauge("serve_g").set(9.0)
    a.histogram("serve_h_seconds").observe(0.1)
    b.histogram("serve_h_seconds").observe(0.2)
    a.merge(b)
    assert a.get("serve_x_total").value(replica="0") == 3
    assert a.get("serve_g").value() == 9.0
    assert sorted(a.get("serve_h_seconds").samples()) == [0.1, 0.2]
    assert a.get("serve_h_seconds").count() == 2
    bad = MetricsRegistry()
    bad.histogram("serve_h_seconds", buckets=(1.0, 2.0)).observe(0.5)
    with pytest.raises(ValueError, match="bucket mismatch"):
        a.merge(bad)


def test_prometheus_exposition_golden():
    reg = MetricsRegistry()
    reg.counter("serve_requests_total", "finished requests").inc(
        3, replica="0", reason="eos")
    reg.gauge("serve_occupancy_ratio", "mean active fraction").set(0.5)
    h = reg.histogram("serve_ttft_seconds", "time to first token",
                      buckets=(0.1, 1.0))
    h.observe(0.05, replica="0")
    h.observe(0.5, replica="0")
    assert reg.to_prometheus() == (
        "# HELP serve_occupancy_ratio mean active fraction\n"
        "# TYPE serve_occupancy_ratio gauge\n"
        "serve_occupancy_ratio 0.5\n"
        "# HELP serve_requests_total finished requests\n"
        "# TYPE serve_requests_total counter\n"
        'serve_requests_total{reason="eos",replica="0"} 3\n'
        "# HELP serve_ttft_seconds time to first token\n"
        "# TYPE serve_ttft_seconds histogram\n"
        'serve_ttft_seconds_bucket{replica="0",le="0.1"} 1\n'
        'serve_ttft_seconds_bucket{replica="0",le="1"} 2\n'
        'serve_ttft_seconds_bucket{replica="0",le="+Inf"} 2\n'
        'serve_ttft_seconds_sum{replica="0"} 0.55\n'
        'serve_ttft_seconds_count{replica="0"} 2\n')


def test_json_snapshot_is_stable_and_round_trips(tmp_path):
    reg = MetricsRegistry()
    reg.counter("serve_b_total", "b").inc(1, replica="1")
    reg.counter("serve_a_total", "a").inc(2)
    reg.histogram("serve_h_seconds", "h").observe(0.01)
    snap = json.loads(reg.to_json())
    assert list(snap) == sorted(snap)               # sorted family names
    assert snap["serve_a_total"]["type"] == "counter"
    assert snap["serve_a_total"]["samples"][0] == {"labels": {}, "value": 2}
    assert snap["serve_h_seconds"]["buckets"] == list(DEFAULT_BUCKETS)
    assert snap["serve_h_seconds"]["samples"][0]["count"] == 1
    reg.write_prometheus(str(tmp_path / "m.prom"))
    reg.write_json(str(tmp_path / "m.json"))
    assert json.loads((tmp_path / "m.json").read_text()) == snap
    assert "# TYPE serve_a_total counter" in (
        tmp_path / "m.prom").read_text()


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_spans_balance_and_misnesting_asserts():
    tr = Tracer()
    tr.begin("tick/dispatch", 0)
    tr.begin("inner", 0)
    assert tr.open_spans() == [(0, ENGINE_TRACK, "tick/dispatch"),
                               (0, ENGINE_TRACK, "inner")]
    tr.end("inner", 0)
    tr.end("tick/dispatch", 0)
    assert tr.open_spans() == []
    tr.begin("a", 0)
    with pytest.raises(AssertionError, match="misnesting"):
        tr.end("b", 0)


def test_request_phase_state_machine_closes_previous():
    tr = Tracer()
    tr.request_phase(0, 3, "queued")
    tr.request_phase(0, 3, "prefill")
    tr.request_phase(0, 3, "decode")
    tr.request_done(0, 3, "finish", args={"reason": "eos"})
    assert tr.open_spans() == []
    counts = tr.counts()
    for phase in ("queued", "prefill", "decode"):
        assert counts[(f"request/{phase}", "B")] == 1
        assert counts[(f"request/{phase}", "E")] == 1
    assert counts[("request/finish", "i")] == 1
    # all on the request's own track
    assert all(ev["tid"] == REQ_TRACK_BASE + 3
               for ev in tr.events if ev["name"].startswith("request/"))


def test_abandon_closes_one_track():
    tr = Tracer()
    tr.begin("a", 1, DECODE_TRACK)
    tr.begin("b", 1, DECODE_TRACK)
    tr.begin("c", 0)
    tr.abandon(1, DECODE_TRACK, reason="quarantine")
    assert tr.open_spans() == [(0, ENGINE_TRACK, "c")]
    ends = [ev for ev in tr.events if ev["ph"] == "E"]
    assert [e["name"] for e in ends] == ["b", "a"]       # LIFO unwind
    assert all(e["args"]["reason"] == "quarantine" for e in ends)


def test_trace_json_schema_golden():
    tr = Tracer()
    tr.set_process_name(0, "replica0")
    tr.set_process_name(0, "replica0")                   # idempotent
    tr.begin("tick/dispatch", 0)
    tr.end("tick/dispatch", 0)
    t0 = tr.now_us()
    tr.complete("decode/chunk", t0, 0, DECODE_TRACK, args={"steps": 4})
    tr.instant("chaos/fire", 0, args={"site": "pool.oom"})
    doc = tr.to_json()
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert json.loads(json.dumps(doc)) == doc            # serializable
    # one process_name + two thread_name M records, emitted once
    assert sum(e["ph"] == "M" for e in evs) == 3
    by_ph = {}
    for e in evs:
        by_ph.setdefault(e["ph"], []).append(e)
    for e in by_ph.get("B", []) + by_ph.get("E", []) + by_ph.get("i", []):
        assert {"name", "ph", "pid", "tid", "ts"} <= set(e)
    (x,) = by_ph["X"]
    assert x["dur"] >= 0 and x["ts"] == pytest.approx(t0)
    assert x["args"] == {"steps": 4}
    (i,) = by_ph["i"]
    assert i["s"] == "t"
    assert len(by_ph["B"]) == len(by_ph["E"]) == 1


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------

def test_off_by_default_and_install_restores():
    assert obs.tracer() is None and obs.metrics() is None
    assert not obs.enabled()
    # every free helper is a no-op with nothing installed
    obs.request_phase(0, 0, "queued")
    obs.request_done(0, 0, "finish")
    obs.instant("x", 0)
    obs.count("serve_x_total", 1)
    obs.observe("serve_x_seconds", 0.1)
    tr, mx = Tracer(), MetricsRegistry()
    prev = obs.install(tr, mx)
    try:
        assert obs.enabled()
        obs.instant("x", 0)
        obs.count("serve_x_total", 2, "help text", replica="0")
        obs.observe("serve_x_seconds", 0.5)
    finally:
        obs.install(*prev)
    assert obs.tracer() is None and obs.metrics() is None
    assert tr.counts()[("x", "i")] == 1
    assert mx.get("serve_x_total").value(replica="0") == 2
    assert mx.get("serve_x_total").help == "help text"
    assert mx.get("serve_x_seconds").count() == 1


def test_capture_is_scoped():
    with obs.capture() as (tr, mx):
        assert obs.tracer() is tr and obs.metrics() is mx
        obs.instant("y", 0)
    assert obs.tracer() is None
    assert tr.counts()[("y", "i")] == 1


# ---------------------------------------------------------------------------
# profiler capture window
# ---------------------------------------------------------------------------

def _fake_profiler(prof, calls):
    """Replace the jax.profiler start/stop with recorders."""
    def fake_start():
        calls.append("start")
        prof._capturing = True
    def fake_stop():
        if not prof._capturing:
            return
        prof._capturing = False
        prof.steps = None
        prof.windows += 1
        calls.append("stop")
    prof._start = fake_start
    prof.stop = fake_stop


def test_profile_window_triggers_on_crossing():
    """The decode clock advances by ``chunk`` per tick, so a window
    narrower than one stride must trigger on *crossing* A, not on a
    tick landing inside [A, B) — `1:3` with chunk=4 sees clocks
    0, 4, 8 and still records exactly one window."""
    prof = obs.ProfileHooks.parse("1:3")
    calls = []
    _fake_profiler(prof, calls)
    for clock in (0, 4, 8):
        prof.tick(clock)
    assert calls == ["start", "stop"]
    assert prof.windows == 1 and not prof._capturing
    # disarmed after one window: later ticks past A never re-open it
    prof.tick(12)
    assert calls == ["start", "stop"]


def test_profile_window_aligned_and_teardown_flush():
    prof = obs.ProfileHooks.parse("2:6")
    calls = []
    _fake_profiler(prof, calls)
    for clock in (0, 2, 4):
        prof.tick(clock)
    assert calls == ["start"] and prof._capturing
    prof.stop()              # session teardown flushes an open window
    assert calls == ["start", "stop"] and prof.windows == 1
    prof.stop()              # idempotent
    assert prof.windows == 1


def test_profile_parse_rejects_bad_specs():
    with pytest.raises(ValueError):
        obs.ProfileHooks.parse("3:1")
    with pytest.raises(ValueError):
        obs.ProfileHooks.parse("nope")


# ---------------------------------------------------------------------------
# serve-metric schema coverage
# ---------------------------------------------------------------------------

def test_schema_naming_conventions():
    for name, (kind, help_) in SCHEMA.items():
        assert name.startswith("serve_"), name
        assert kind in ("counter", "gauge", "histogram"), name
        assert help_, f"{name} has no help text"
        if kind == "counter":
            assert name.endswith("_total"), name
        if kind == "histogram":
            assert name.endswith("_seconds"), name


def test_every_stats_field_has_a_metric_and_vice_versa():
    from repro.serving.engine import ServeStats
    fields = {f.name for f in dataclasses.fields(ServeStats)} - {"registry"}
    assert fields == set(STATS_FIELD_METRICS), (
        fields ^ set(STATS_FIELD_METRICS))
    for field, metric in STATS_FIELD_METRICS.items():
        assert metric in SCHEMA, (field, metric)


def test_publish_stats_fields_round_trip():
    """publish -> stats_fields reconstructs exactly what went in, with
    quantiles matching np.percentile over the original lists."""
    from repro.obs.serve_metrics import publish_session, stats_fields

    @dataclasses.dataclass
    class Out:
        priority: int = 1
        finish_reason: str = "eos"
        preempted: int = 0
        ttft_s: float = 0.1
        tpot_s: float = 0.01
        queue_delay_s: float = 0.05

    outs = [Out(), Out(priority=0, finish_reason="timeout", ttft_s=0.3),
            Out(finish_reason="cancelled", preempted=2)]
    reg = MetricsRegistry()
    publish_session(
        reg, replica=1, outputs=outs, occupancy=0.75, num_chunks=5,
        chunk=4, admissions=2, generated=40, prefill_chunks=3,
        gaps=[0.02, 0.04], spec_m=dict(rounds=10, proposed=20, accepted=15,
                                       committed=25),
        spec_labels={"k": "2", "source": "self"}, watchdog_trips=1,
        degraded_steps=8, transitions=2, tier_steps=(12, 8),
        tier_labels=["bf16", "int8"], tuned="dense/int8",
        pool=dict(pages_total=6, pages_peak=5, page_size=8, prefix_hits=2,
                  prefix_hit_tokens=12, prompt_tokens=24, cow_copies=1,
                  kv_bytes_peak=4096.0),
        device_times=[0.01], host_gaps=[0.005],
        recovery=[0.2], restarts=1, redriven=4)
    f = stats_fields(reg)
    assert f["decode_steps"] == 20 and f["num_chunks"] == 5
    assert f["generated_tokens"] == 40 and f["admissions"] == 2
    assert f["occupancy"] == 0.75 and f["prefill_chunks"] == 3
    assert f["ttft_p95_s"] == pytest.approx(
        np.percentile([0.1, 0.3, 0.1], 95))
    assert f["preemptions"] == 2 and f["timeouts"] == 1
    assert f["cancelled"] == 1
    assert f["decode_gap_max_s"] == 0.04
    assert f["spec_rounds"] == 10
    assert f["acceptance_rate"] == pytest.approx(15 / 20)
    assert f["tokens_per_round"] == pytest.approx(25 / 10)
    assert f["pool_pages_total"] == 6 and f["pool_pages_peak"] == 5
    assert f["pool_page_size"] == 8 and f["cow_copies"] == 1
    assert f["prefix_hit_rate"] == pytest.approx(12 / 24)
    assert f["kv_bytes_peak"] == 4096.0
    assert f["tuned"] == "dense/int8"
    assert f["kv_tier_steps"] == (12, 8)
    assert f["degraded_steps"] == 8 and f["degrade_transitions"] == 2
    assert f["replica_restarts"] == 1 and f["redriven_requests"] == 4
    assert f["recovery_p95_s"] == pytest.approx(0.2)
    # the per-priority breakdown the flat fields aggregate away
    m = reg.get("serve_ttft_seconds")
    assert m.samples(priority="0") == [0.3]
    # every published family carries its schema help line
    prom = reg.to_prometheus()
    for name in reg.names():
        assert f"# HELP {name} {SCHEMA[name][1]}" in prom


def test_priority_report_needs_two_classes():
    from repro.obs.render import priority_report
    assert priority_report(None) == []
    reg = MetricsRegistry()
    assert priority_report(reg) == []
    reg.counter("serve_requests_total").inc(3, priority="1", reason="eos")
    assert priority_report(reg) == []                   # one class: silent
    reg.counter("serve_requests_total").inc(1, priority="0", reason="eos")
    reg.histogram("serve_ttft_seconds").observe(0.2, priority="0")
    reg.histogram("serve_ttft_seconds").observe(0.4, priority="1")
    lines = priority_report(reg)
    assert len(lines) == 2
    assert lines[0].lstrip().startswith("priority 0: 1 reqs")
    assert "ttft p50 200ms" in lines[0]


# ---------------------------------------------------------------------------
# end-to-end: span balance / leak freedom on the serving stack
# ---------------------------------------------------------------------------

def _requests(cfg, n=6, prompt_len=8, max_new=8, arrival_every=2, **kw):
    import jax
    import jax.numpy as jnp

    from repro.serving.scheduler import Request
    out = []
    for i in range(n):
        pr = np.array(jax.random.randint(jax.random.PRNGKey(10 + i),
                                         (prompt_len,), 0, cfg.vocab_size,
                                         dtype=jnp.int32))
        out.append(Request(rid=i, prompt=pr, max_new_tokens=max_new,
                           arrival_step=i * arrival_every, **kw))
    return out


def _balanced(tr):
    assert tr.open_spans() == []
    counts = tr.counts()
    b = sum(n for (_, ph), n in counts.items() if ph == "B")
    e = sum(n for (_, ph), n in counts.items() if ph == "E")
    assert b == e and b > 0
    return counts


def test_traced_stream_is_leak_free_and_stats_match(trained):
    from repro.serving.engine import ServeEngine
    cfg, model, params = trained["dense"]
    eng = ServeEngine(model, params, max_seq=18)
    reqs = _requests(cfg)
    ref_out, ref_stats = eng.serve(reqs, num_slots=2, chunk=4)
    with obs.capture() as (tr, mx):
        out, stats = eng.serve(reqs, num_slots=2, chunk=4)
    counts = _balanced(tr)
    # every request walked queued -> prefill -> decode -> finish
    assert counts[("request/prefill", "B")] == len(reqs)
    assert counts[("request/decode", "B")] == len(reqs)
    assert counts[("request/finish", "i")] == len(reqs)
    assert counts[("decode/chunk", "X")] == stats.num_chunks
    assert counts[("tick/dispatch", "B")] == counts[("tick/harvest", "B")]
    # tracing changes no tokens and no counted stats
    for a, b in zip(ref_out, out):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    for f in ("decode_steps", "generated_tokens", "num_chunks",
              "admissions", "preemptions", "timeouts", "cancelled"):
        assert getattr(stats, f) == getattr(ref_stats, f)
    # the run merged into the installed registry
    assert mx.total("serve_generated_tokens_total") == stats.generated_tokens
    assert mx.get("serve_requests_total").value(
        replica="0", reason="length", priority="1") == len(reqs)


def test_traced_cancellation_preemption_leak_free(trained):
    from repro.serving.engine import ServeEngine
    from repro.serving.pool import PagedConfig
    from repro.serving.scheduler import SLOConfig
    cfg, model, params = trained["dense"]
    eng = ServeEngine(model, params, max_seq=34,
                      paged=PagedConfig(page_size=8))
    reqs = _requests(cfg, n=8, max_new=24, arrival_every=1,
                     priority=1)
    for r in reqs[::3]:
        r.cancel_at_step = r.arrival_step + 4
    for r in reqs[1::3]:
        r.queue_timeout_steps = 2
    reqs[-1].priority = 0          # late high-priority arrival -> preempt
    with obs.capture() as (tr, mx):
        out, stats = eng.serve(reqs, num_slots=2, chunk=4,
                               slo=SLOConfig(preempt=True))
    counts = _balanced(tr)
    assert stats.cancelled + stats.timeouts > 0
    assert counts.get(("request/finish", "i"), 0) == len(out)
    if stats.preemptions:
        assert counts[("request/preempt", "i")] == stats.preemptions
    eng.pool.check_invariants()
    # per-priority histograms recorded both classes
    m = mx.get("serve_requests_total")
    assert set(m.labeled("priority")) >= {"0", "1"}


def test_traced_out_of_pages_unwinds_leak_free(trained):
    from repro.serving.engine import ServeEngine
    from repro.serving.pool import OutOfPages, PagedConfig
    from repro.serving.scheduler import Request
    from repro.serving.session import DegradeConfig
    cfg, model, params = trained["dense"]
    eng = ServeEngine(model, params, max_seq=64,
                      paged=PagedConfig(page_size=8, pool_pages=1))
    req = Request(rid=0, prompt=np.zeros(32, np.int32), max_new_tokens=32)
    with obs.capture() as (tr, _):
        with pytest.raises(OutOfPages):
            eng.serve([req], num_slots=1, chunk=4, degrade=DegradeConfig())
    _balanced(tr)
    assert tr.counts().get(("request/redrive", "i"), 0) == 1


def test_traced_chaos_failover_redrive_leak_free(trained):
    from repro.serving import chaos
    from repro.serving.chaos import FaultConfig
    from repro.serving.engine import ServeEngine
    from repro.serving.pool import PagedConfig
    from repro.serving.replica import FailoverConfig, ReplicaServe
    cfg, model, params = trained["dense"]
    pc = PagedConfig(page_size=8, pool_pages=6)

    def two():
        return ReplicaServe([
            ServeEngine(model, params, max_seq=18, paged=pc),
            ServeEngine(model, params, max_seq=18, paged=pc)])

    reqs = _requests(cfg)
    ref_out, _ = two().serve(reqs, num_slots=2, chunk=4)
    with obs.capture() as (tr, mx):
        with chaos.chaos(FaultConfig.parse("replica_fault")):
            out, stats = two().serve(reqs, num_slots=2, chunk=4,
                                     failover=FailoverConfig())
    counts = _balanced(tr)
    agg = stats.aggregate
    assert counts[("replica/failover", "X")] == agg.replica_restarts == 1
    assert counts[("request/redrive", "i")] == agg.redriven_requests > 0
    assert counts[("chaos/fire", "i")] >= 1
    for a, b in zip(ref_out, out):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    # router-level counters landed in the installed registry AND in the
    # aggregate's merged view
    assert mx.total("serve_replica_restarts_total") == 1
    assert mx.total("serve_chaos_faults_total") >= 1
    assert agg.registry.total("serve_replica_restarts_total") == 1
    assert agg.registry.quantile("serve_recovery_seconds", 95) > 0


# ---------------------------------------------------------------------------
# ServeStats back-compat across families
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid", "encdec"])
def test_stats_view_round_trips_per_family(trained, family):
    """Every family's serve stats are a registry view: rebuilding the
    snapshot from the attached registry reproduces the dataclass
    field-for-field (registry is excluded from ==)."""
    from repro.serving.engine import ServeEngine, ServeStats
    cfg, model, params = trained[family]
    eng = ServeEngine(model, params, max_seq=18)
    out, stats = eng.serve(_requests(cfg, n=3), num_slots=2, chunk=4)
    assert len(out) == 3
    assert stats.generated_tokens > 0 and stats.num_chunks > 0
    assert 0.0 < stats.occupancy <= 1.0
    assert stats.ttft_p50_s >= 0.0
    assert stats.registry is not None
    assert ServeStats.from_registry(stats.registry) == stats
