"""Entropy-guided self-speculative decoding (docs/DESIGN.md §11).

Anchor invariant: greedy speculative serve() emits TOKEN-IDENTICAL output
vs the non-spec engine — accepted prefixes are the baseline's own argmax
choices and the correction/bonus token is the baseline's next choice, so
any divergence is a rollback/acceptance bug, not noise.

Covers: greedy spec-vs-baseline parity on all four families, forced-
mismatch drafts (acceptance ~ 0 must still be exact, incl. int8/int4 KV
cache rollback), multi-query decode_attn backend-vs-ref parity (pallas
interpret included), draft-plan payload sharing (already-int4 blocks are
the SAME buffers), and the artifact round-trip of the stamped draft plan.
"""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels.decode_attn.ops import _pallas, decode_attention
from repro.kernels.decode_attn.ref import decode_attn_ref
from repro.models.model import build
from repro.quant.compiler import (compile_draft_plan, compile_plan,
                                  load_artifact, save_artifact)
from repro.quant.kvcache import dequantize_kv, make_page
from repro.serving.engine import ServeEngine
from repro.serving.quantized import explicit_plan
from repro.serving.scheduler import Request
from repro.serving.spec import SpecConfig

FAMILY_ARCHS = (("dense", "llama3.2-3b"), ("ssm", "mamba2-780m"),
                ("hybrid", "zamba2-2.7b"), ("encdec", "whisper-medium"))


def _tiny(arch):
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(
        cfg, num_layers=4 if cfg.family == "hybrid" else 2)
    model = build(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _prompts(cfg, b, p, seed=3):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, p), 0,
                              cfg.vocab_size, dtype=jnp.int32)


def _frames(cfg, b):
    if cfg.family != "encdec":
        return None
    return jax.random.normal(jax.random.PRNGKey(5),
                             (b, cfg.encoder_seq, cfg.d_model))


# ---------------------------------------------------------------------------
# multi-query decode attention: backends vs the dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", ["bf16", "int8", "int4"])
@pytest.mark.parametrize("s", [2, 5])
@pytest.mark.parametrize("causal", [True, False])
def test_multi_query_backends_match_ref(precision, s, causal):
    b, t, hkv, rep, hd = 3, 40, 2, 3, 32
    ks = jax.random.split(jax.random.PRNGKey(s), 3)
    q = jax.random.normal(ks[0], (b, s, hkv * rep, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, hkv, hd)) * 0.5
    v = jax.random.normal(ks[2], (b, t, hkv, hd)) * 0.5
    kp, vp = make_page(k, precision, 32), make_page(v, precision, 32)
    valid = jnp.array([9, 40, 13], jnp.int32)
    ref = decode_attn_ref(q, dequantize_kv(kp), dequantize_kv(vp), valid,
                          causal=causal)
    for backend in ("simple", "grouped"):
        got = decode_attention(q, kp, vp, valid_len=valid, backend=backend,
                               kv_chunk=7, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)
    got = _pallas(q, kp, vp, valid, 16, causal, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_multi_query_causal_offsets_hide_future():
    """Query i must see exactly the rows a sequential single-query decode
    at position valid - s + i would see."""
    b, t, hkv, rep, hd, s = 1, 16, 1, 2, 16, 3
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hkv * rep, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, hkv, hd))
    v = jax.random.normal(ks[2], (b, t, hkv, hd))
    valid = jnp.int32(10)   # queries sit at absolute positions 7, 8, 9
    multi = decode_attention(q, k, v, valid_len=valid, backend="grouped")
    for i in range(s):
        one = decode_attention(q[:, i:i + 1], k, v,
                               valid_len=jnp.int32(8 + i),
                               backend="grouped")
        np.testing.assert_allclose(np.asarray(multi[:, i]),
                                   np.asarray(one[:, 0]), atol=2e-5,
                                   rtol=2e-5)


# ---------------------------------------------------------------------------
# greedy spec serve == baseline serve, all four families
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,arch", FAMILY_ARCHS)
def test_greedy_spec_parity_all_families(family, arch):
    cfg, model, params = _tiny(arch)
    prompts = _prompts(cfg, 2, 8)
    frames = _frames(cfg, 2)
    base = ServeEngine(model, params, max_seq=32)
    spec = ServeEngine(model, params, max_seq=32, spec=SpecConfig(k=3))
    ref = base.generate(prompts, 8, chunk=4, frames=frames)
    out = spec.generate(prompts, 8, chunk=2, frames=frames)
    np.testing.assert_array_equal(np.asarray(ref.tokens),
                                  np.asarray(out.tokens))
    np.testing.assert_allclose(np.asarray(ref.logprobs),
                               np.asarray(out.logprobs), atol=1e-4)


def test_greedy_spec_serve_stream_parity():
    cfg, model, params = _tiny("llama3.2-3b")
    base = ServeEngine(model, params, max_seq=32, eos_id=7)
    spec = ServeEngine(model, params, max_seq=32, eos_id=7,
                       spec=SpecConfig(k=3))
    reqs = [Request(rid=i, prompt=np.asarray(_prompts(cfg, 1, 6, seed=i)[0]),
                    max_new_tokens=6, arrival_step=i) for i in range(5)]
    outs_b, _ = base.serve(reqs, num_slots=2, chunk=4)
    outs_s, stats = spec.serve(reqs, num_slots=2, chunk=2)
    for ob, os_ in zip(outs_b, outs_s):
        np.testing.assert_array_equal(ob.tokens, os_.tokens)
        assert ob.finish_reason == os_.finish_reason
    assert stats.draft_proposed > 0
    assert 0.0 <= stats.acceptance_rate <= 1.0
    assert stats.tokens_per_round >= 1.0   # every live round commits >= 1


# ---------------------------------------------------------------------------
# acceptance / rollback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_precision", ["int8", "int4"])
def test_forced_mismatch_draft_rolls_back_exactly(kv_precision):
    """A draft with DIFFERENT random weights proposes mostly-wrong tokens
    (acceptance ~ 0 at this vocab); every round must fall back to the
    baseline's token via rollback + correction — output stays identical,
    including over a quantized KV cache."""
    cfg, model, params = _tiny("llama3.2-3b")
    prompts = _prompts(cfg, 2, 8)
    base = ServeEngine(model, params, max_seq=32, kv_precision=kv_precision)
    spec = ServeEngine(model, params, max_seq=32, kv_precision=kv_precision,
                       spec=SpecConfig(k=3))
    # sabotage the draft: unrelated weights -> near-zero acceptance
    spec._draft = spec._ensure_draft()
    spec._draft.params = model.init(jax.random.PRNGKey(99))
    ref = base.generate(prompts, 8, chunk=4)
    out = spec.generate(prompts, 8, chunk=1)
    np.testing.assert_array_equal(np.asarray(ref.tokens),
                                  np.asarray(out.tokens))


def test_rollback_restores_cache_pos_invariant():
    """After any spec chunk, cache_pos == lengths - 1 for live slots (the
    pending-token invariant; admission starts at pos == lengths) — the
    verify's k+1 speculative rows were rolled back by position
    arithmetic."""
    cfg, model, params = _tiny("llama3.2-3b")
    engine = ServeEngine(model, params, max_seq=32, spec=SpecConfig(k=3))
    prompts = _prompts(cfg, 2, 8)
    state = engine._batch_state(prompts, None, 8, 0.0, 0, 1.0,
                                jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(state.cache.pos), [8, 8])
    fn = engine._spec_fn(1)
    state, m = fn(engine.params, engine.draft_params, state)
    live = np.asarray(state.active & ~state.done)
    pos = np.asarray(state.cache.pos)
    lengths = np.asarray(state.lengths)
    np.testing.assert_array_equal(pos[live], lengths[live] - 1)
    assert int(m.committed) == int(lengths.sum() - 2 * 8)


def test_spec_respects_budget_and_headroom():
    cfg, model, params = _tiny("llama3.2-3b")
    engine = ServeEngine(model, params, max_seq=20, spec=SpecConfig(k=4))
    prompts = _prompts(cfg, 1, 8)
    out = engine.generate(prompts, 8, chunk=2)   # 8+8+4 = 20 fits exactly
    assert int((np.asarray(out.tokens)[0] != 0).sum()) >= 16
    with pytest.raises(AssertionError, match="max_seq"):
        engine.generate(prompts, 9, chunk=2)


def test_spec_single_token_prompt():
    """Freshness handling makes even one-token prompts exact (the first
    round takes candidate-0 from the prefill logits)."""
    cfg, model, params = _tiny("llama3.2-3b")
    base = ServeEngine(model, params, max_seq=16)
    spec = ServeEngine(model, params, max_seq=16, spec=SpecConfig(k=2))
    prompts = _prompts(cfg, 2, 1)
    ref = base.generate(prompts, 6, chunk=3)
    out = spec.generate(prompts, 6, chunk=2)
    np.testing.assert_array_equal(np.asarray(ref.tokens),
                                  np.asarray(out.tokens))


# ---------------------------------------------------------------------------
# draft plan: payload sharing + artifact stamp
# ---------------------------------------------------------------------------

def test_draft_plan_shares_aggressive_payloads():
    cfg, model, params = _tiny("llama3.2-3b")
    cfg4 = dataclasses.replace(cfg, num_layers=4)
    model = build(cfg4)
    params = model.init(jax.random.PRNGKey(0))
    plan = explicit_plan(cfg4, ["int4", "ternary", "int8", "raw"])
    compiled = compile_plan(model, params, plan)
    draft = compile_draft_plan(model, compiled.params, plan)
    tgt_segs = compiled.params["layers"].segments
    d_segs = draft.params["layers"].segments
    assert [s.precision for s in d_segs] == ["int4", "ternary", "int4",
                                             "int4"]
    # already-aggressive blocks: the SAME Segment objects — zero new bytes
    assert d_segs[0] is tgt_segs[0] and d_segs[1] is tgt_segs[1]
    # overhead counts ONLY the re-quantized blocks, and int4 re-encoding
    # never exceeds the bytes of the blocks it replaces
    from repro.quant.apply import tree_nbytes
    requant_src = sum(tree_nbytes(s.params) for s in tgt_segs[2:])
    assert 0 < draft.overhead_bytes <= requant_src
    assert draft.shared_blocks == 2 and draft.requantized_blocks == 3
    # the derived plan is the entropy decisions clamped to int4
    assert draft.precisions[1:5] == ("int4", "ternary", "int4", "int4")


def test_draft_plan_artifact_roundtrip():
    cfg, model, params = _tiny("llama3.2-3b")
    plan = explicit_plan(cfg, ["int4", "int8"])
    compiled = compile_plan(model, params, plan)
    draft = compile_draft_plan(model, compiled.params, plan)
    compiled.draft = draft.to_manifest()
    with tempfile.TemporaryDirectory() as d:
        save_artifact(d, compiled)
        loaded = load_artifact(d, model)
        assert loaded.draft == compiled.draft
        eng = ServeEngine.from_artifact(model, d, max_seq=32,
                                        spec=SpecConfig(k=2))
        # the lazily re-derived draft matches the stamp bit-for-bit
        rederived = eng._ensure_draft()
        assert list(rederived.precisions) == loaded.draft["precisions"]
        assert rederived.overhead_bytes == loaded.draft["overhead_bytes"]
        base = ServeEngine(model, compiled.params, max_seq=32)
        prompts = _prompts(cfg, 2, 8)
        np.testing.assert_array_equal(
            np.asarray(base.generate(prompts, 6, chunk=3).tokens),
            np.asarray(eng.generate(prompts, 6, chunk=2).tokens))


# ---------------------------------------------------------------------------
# sampling satellites
# ---------------------------------------------------------------------------

def test_sampling_params_do_not_recompile():
    cfg, model, params = _tiny("llama3.2-3b")
    engine = ServeEngine(model, params, max_seq=32)
    prompts = _prompts(cfg, 2, 8)
    engine.generate(prompts, 4, temperature=0.0)
    engine.generate(prompts, 4, temperature=0.7)
    engine.generate(prompts, 4, temperature=0.3, top_k=5, top_p=0.9)
    assert len(engine._chunk_fns) == 1   # one compile per (chunk, slots)


def test_top_k_one_equals_greedy():
    cfg, model, params = _tiny("llama3.2-3b")
    engine = ServeEngine(model, params, max_seq=32)
    prompts = _prompts(cfg, 2, 8)
    greedy = engine.generate(prompts, 6, temperature=0.0)
    topk1 = engine.generate(prompts, 6, temperature=0.9, top_k=1,
                            key=jax.random.PRNGKey(4))
    np.testing.assert_array_equal(np.asarray(greedy.tokens),
                                  np.asarray(topk1.tokens))


def test_spec_sampling_path_is_finite_and_in_budget():
    cfg, model, params = _tiny("llama3.2-3b")
    engine = ServeEngine(model, params, max_seq=32, spec=SpecConfig(k=3))
    prompts = _prompts(cfg, 2, 8)
    out = engine.generate(prompts, 8, temperature=0.8, top_p=0.95, chunk=2,
                          key=jax.random.PRNGKey(2))
    toks = np.asarray(out.tokens)
    assert toks.shape == (2, 16)
    assert (toks[:, 8:] < cfg.vocab_size).all()
    assert np.isfinite(np.asarray(out.logprobs)).all()


def test_serve_reports_latency_percentiles():
    cfg, model, params = _tiny("llama3.2-3b")
    engine = ServeEngine(model, params, max_seq=32)
    reqs = [Request(rid=i, prompt=np.asarray(_prompts(cfg, 1, 6, seed=i)[0]),
                    max_new_tokens=6) for i in range(3)]
    outs, stats = engine.serve(reqs, num_slots=2, chunk=3)
    assert all(o.ttft_s is not None and o.ttft_s >= 0 for o in outs)
    assert all(o.tpot_s is not None and o.tpot_s >= 0 for o in outs)
    assert stats.ttft_p95_s >= stats.ttft_p50_s >= 0.0
    assert stats.tpot_p95_s >= stats.tpot_p50_s >= 0.0


# ---------------------------------------------------------------------------
# fused draft-propose (docs/DESIGN.md §12): one cache sweep per round
# ---------------------------------------------------------------------------

def test_fused_propose_token_identical_to_two_pass():
    """The fused no-write propose must emit the SAME tokens as the
    two-pass throwaway-cache propose — both are greedy-exact, so any
    divergence is a fresh-KV masking bug."""
    cfg, model, params = _tiny("llama3.2-3b")
    prompts = _prompts(cfg, 2, 8)
    two_pass = ServeEngine(model, params, max_seq=32,
                           spec=SpecConfig(k=3, fused_propose=False))
    fused = ServeEngine(model, params, max_seq=32,
                        spec=SpecConfig(k=3, fused_propose=True))
    a = two_pass.generate(prompts, 8, chunk=2)
    b = fused.generate(prompts, 8, chunk=2)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    np.testing.assert_allclose(np.asarray(a.logprobs),
                               np.asarray(b.logprobs), atol=1e-4)


@pytest.mark.parametrize("kv_precision", ["int8", "int4"])
def test_fused_propose_parity_quantized_kv(kv_precision):
    cfg, model, params = _tiny("llama3.2-3b")
    prompts = _prompts(cfg, 2, 8)
    outs = []
    for fused in (False, True):
        eng = ServeEngine(model, params, max_seq=32,
                          kv_precision=kv_precision,
                          spec=SpecConfig(k=2, fused_propose=fused))
        outs.append(eng.generate(prompts, 8, chunk=2))
    np.testing.assert_array_equal(np.asarray(outs[0].tokens),
                                  np.asarray(outs[1].tokens))


def test_truncated_draft_stays_greedy_exact():
    """draft_layers early-exit drafting may tank acceptance but can never
    change greedy output (verification is the full target stack)."""
    cfg, model, params = _tiny("llama3.2-3b")
    prompts = _prompts(cfg, 2, 8)
    base = ServeEngine(model, params, max_seq=32).generate(prompts, 8,
                                                           chunk=4)
    spec = ServeEngine(model, params, max_seq=32,
                       spec=SpecConfig(k=3, draft_layers=1))
    out = spec.generate(prompts, 8, chunk=2)
    np.testing.assert_array_equal(np.asarray(base.tokens),
                                  np.asarray(out.tokens))


# ---------------------------------------------------------------------------
# prompt-lookup (ngram) draft source
# ---------------------------------------------------------------------------

def test_ngram_draft_greedy_identical_to_baseline():
    """The ngram draft proposes copied context tokens; verification keeps
    greedy output token-identical regardless of what was proposed."""
    cfg, model, params = _tiny("llama3.2-3b")
    prompts = _prompts(cfg, 2, 8)
    base = ServeEngine(model, params, max_seq=40).generate(prompts, 12,
                                                           chunk=4)
    spec = ServeEngine(model, params, max_seq=40,
                       spec=SpecConfig(k=2, draft_source="ngram"))
    out = spec.generate(prompts, 12, chunk=2)
    np.testing.assert_array_equal(np.asarray(base.tokens),
                                  np.asarray(out.tokens))
    np.testing.assert_allclose(np.asarray(base.logprobs),
                               np.asarray(out.logprobs), atol=1e-4)


@pytest.mark.parametrize("kv_precision", ["int8", "int4"])
def test_ngram_draft_parity_quantized_kv(kv_precision):
    cfg, model, params = _tiny("llama3.2-3b")
    prompts = _prompts(cfg, 2, 8)
    base = ServeEngine(model, params, max_seq=40,
                       kv_precision=kv_precision).generate(prompts, 10,
                                                           chunk=4)
    spec = ServeEngine(model, params, max_seq=40, kv_precision=kv_precision,
                       spec=SpecConfig(k=3, draft_source="ngram"))
    out = spec.generate(prompts, 10, chunk=2)
    np.testing.assert_array_equal(np.asarray(base.tokens),
                                  np.asarray(out.tokens))


def test_ngram_draft_accepts_on_repetitive_context():
    """A periodic prompt makes the trailing bigram match earlier context,
    so the lookup proposes real continuations — acceptance must be
    nonzero when the model itself continues the repetition it sees."""
    cfg, model, params = _tiny("llama3.2-3b")
    # period-2 prompt: every bigram (a, b) recurs; lookups always hit
    pat = np.array([3, 11] * 8, dtype=np.int32)
    reqs = [Request(rid=0, prompt=pat, max_new_tokens=8, arrival_step=0)]
    spec = ServeEngine(model, params, max_seq=40,
                       spec=SpecConfig(k=2, draft_source="ngram"))
    _, stats = spec.serve(reqs, num_slots=1, chunk=2)
    assert stats.draft_proposed > 0
    assert stats.tokens_per_round >= 1.0
    # parity with the baseline regardless of what was accepted
    base = ServeEngine(model, params, max_seq=40)
    outs_b, _ = base.serve(reqs, num_slots=1, chunk=4)
    outs_s, _ = spec.serve(reqs, num_slots=1, chunk=2)
    np.testing.assert_array_equal(outs_b[0].tokens, outs_s[0].tokens)


def test_ngram_draft_sampling_path_is_finite_and_in_budget():
    """Stochastic slots accept a copied token w.p. p(x) (q is one-hot) and
    resample from clip(p - onehot, 0): output must stay finite and within
    the token budget."""
    cfg, model, params = _tiny("llama3.2-3b")
    prompts = _prompts(cfg, 2, 8)
    spec = ServeEngine(model, params, max_seq=40,
                       spec=SpecConfig(k=2, draft_source="ngram"))
    out = spec.generate(prompts, 8, chunk=2, temperature=0.9,
                        key=jax.random.PRNGKey(11), top_k=8)
    toks = np.asarray(out.tokens)
    assert toks.shape[1] == 16
    assert (toks >= 0).all() and (toks < cfg.vocab_size).all()
    assert np.isfinite(np.asarray(out.logprobs)).all()


def test_ngram_draft_builds_no_model_draft():
    cfg, model, params = _tiny("llama3.2-3b")
    spec = ServeEngine(model, params, max_seq=32,
                       spec=SpecConfig(k=2, draft_source="ngram"))
    assert spec.draft_overhead_bytes() == 0.0
    assert spec.draft_weight_bytes() == 0.0
    spec.generate(_prompts(cfg, 1, 6), 4, chunk=2)
    assert spec._draft is None   # never derived the int4 draft


def test_ngram_draft_config_validation():
    with pytest.raises(ValueError, match="draft_source"):
        SpecConfig(k=2, draft_source="oracle")
    with pytest.raises(ValueError, match="draft_layers"):
        SpecConfig(k=2, draft_source="ngram", draft_layers=1)
