import os

# Tests run single-device (the dry-run sets its own XLA_FLAGS in subprocesses).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest

FAMILY_ARCHS = (("dense", "llama3.2-3b"), ("ssm", "mamba2-780m"),
                ("hybrid", "zamba2-2.7b"), ("encdec", "whisper-medium"))


@pytest.fixture(scope="session")
def trained():
    """Briefly-trained f32 smoke models, one per family (greedy decode has
    stable top-1 gaps, so int8 cache noise — ~1e-2 logprobs — cannot flip
    tokens). Session-scoped: shared by the decode-attn and paged-serving
    suites."""
    import dataclasses

    from repro.configs.base import RunConfig
    from repro.configs.registry import get_config
    from repro.train.loop import train

    out = {}
    for family, arch in FAMILY_ARCHS:
        cfg = get_config(arch, smoke=True)
        cfg = dataclasses.replace(cfg, dtype="float32")
        # hybrid converges slowest on the smoke corpus: at 40 steps its
        # greedy top-1 gaps sit at ~3e-4 — BELOW int8 cache noise — and
        # quantized serving flips tokens. Train it to a stable margin.
        steps, lr = (120, 1e-2) if family == "hybrid" else (40, 3e-3)
        run = RunConfig(steps=steps, learning_rate=lr, warmup_steps=3,
                        remat=False)
        res = train(cfg, run, batch=8, seq=16)
        out[family] = (cfg, res["model"], res["params"])
    return out

try:
    from hypothesis import HealthCheck, settings
except ImportError:
    # Property tests importorskip hypothesis per-module; everything else
    # must still collect and run without it.
    pass
else:
    settings.register_profile(
        "repro", max_examples=20, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("repro")
