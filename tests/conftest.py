import os

# Tests run single-device (the dry-run sets its own XLA_FLAGS in subprocesses).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    from hypothesis import HealthCheck, settings
except ImportError:
    # Property tests importorskip hypothesis per-module; everything else
    # must still collect and run without it.
    pass
else:
    settings.register_profile(
        "repro", max_examples=20, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("repro")
