"""Sharding rules + multi-device lowering (subprocess with virtual devices)."""

import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P


def _run_subprocess(code: str):
    """Run code under 8 virtual CPU devices (XLA_FLAGS must be set before
    jax import, so a subprocess is required)."""
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=560,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_param_spec_rules():
    """Rule checks on a trivial 1x1 mesh (axis sizes 1 divide everything)."""
    from repro.launch.mesh import make_mesh
    from repro.sharding.specs import param_specs
    mesh = make_mesh((1, 1), ("data", "model"))
    params = {
        "layers": {
            "attn": {"wq": np.zeros((4, 8, 16)), "wo": np.zeros((4, 16, 8))},
            "mlp": {"w_up": np.zeros((4, 32, 16)),
                    "w_down": np.zeros((4, 16, 32))},
            "ln1": np.zeros((4, 16)),
            "moe": {"w_gate": np.zeros((4, 2, 32, 16))},
        },
        "embed": {"tok": np.zeros((128, 16))},
    }
    specs = param_specs(params, mesh)
    assert specs["layers"]["attn"]["wq"] == P(None, "model", "data")
    assert specs["layers"]["attn"]["wo"] == P(None, "data", "model")
    assert specs["layers"]["mlp"]["w_up"] == P(None, "model", "data")
    assert specs["layers"]["mlp"]["w_down"] == P(None, "data", "model")
    # stacked per-layer vector (L, D): default rule shards the stack dim
    assert specs["layers"]["ln1"] == P("data", None)
    assert specs["embed"]["tok"] == P("model", "data")
    # MoE experts: E=2 divides axis size 1 -> EP on expert dim
    assert specs["layers"]["moe"]["w_gate"][1] == "model"


def _abstract_mesh(**axes):
    """Rule tests only need mesh.shape/axis_names — AbstractMesh lets us
    exercise 8-way layouts without 8 devices."""
    from jax.sharding import AbstractMesh
    return AbstractMesh(tuple(axes.items()))


def test_param_specs_serving_tp_only():
    """serving=True keeps weights TP-sharded, replicated over data axes
    (decode re-reads weights every step — FSDP would force per-step
    gathers). Previously dead code; now the ServeEngine mesh path."""
    from repro.sharding.specs import param_specs
    mesh = _abstract_mesh(data=4, model=8)
    params = {
        "layers": {"attn": {"wq": np.zeros((4, 64, 32)),
                            "wo": np.zeros((4, 32, 64))},
                   "ln1": np.zeros((4, 32))},
        "embed": {"tok": np.zeros((512, 32))},
    }
    specs = param_specs(params, mesh, serving=True)
    assert specs["layers"]["attn"]["wq"] == P(None, "model", None)
    assert specs["layers"]["attn"]["wo"] == P(None, None, "model")
    assert specs["embed"]["tok"] == P("model", None)
    assert specs["layers"]["ln1"] == P(None, None)
    # no leaf references the data axes
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all("data" not in s and "pod" not in s for s in flat)
    # contrast: training specs do use the data axis
    train = param_specs(params, mesh)
    assert train["layers"]["attn"]["wq"] == P(None, "model", "data")


def test_param_specs_serving_qtensor_leaves():
    """QTensor payload inherits the weight rule; per-group scales inherit
    dims that still divide (the group axis usually does not)."""
    from repro.quant.quantize import quantize
    from repro.sharding.specs import param_specs
    mesh = _abstract_mesh(data=2, model=8)
    wq = quantize(np.float32(np.random.RandomState(0).randn(256, 256)),
                  "int8", group=128)
    specs = param_specs({"layers": {"attn": {"wq": wq}}}, mesh, serving=True)
    qspec = specs["layers"]["attn"]["wq"]
    assert qspec.data == P("model", None)
    # scale (256, 2): out dim inherits "model", 2 groups don't divide 8
    assert qspec.scale == P("model", None)


def test_specs_tolerate_mesh_without_model_axis():
    """Pure-DP serving mesh: no KeyError, everything model-wise replicated
    (regression: mesh.shape["model"] used to raise)."""
    from repro.sharding.specs import cache_specs, param_specs
    mesh = _abstract_mesh(data=8)
    params = {"layers": {"attn": {"wq": np.zeros((4, 64, 32)),
                                  "wo": np.zeros((4, 32, 64))},
                         "moe": {"w_gate": np.zeros((4, 8, 64, 32))}},
              "embed": {"tok": np.zeros((512, 32))}}
    specs = param_specs(params, mesh, serving=True)
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(ax is None for s in flat for ax in s)
    cache = {"k": np.zeros((2, 8, 32, 4, 16)), "v": np.zeros((2, 8, 32, 4, 16))}
    cspecs = cache_specs(cache, mesh)
    assert cspecs["k"] == P(None, "data", None, None, None)


def test_activation_ctx_tolerates_mesh_without_model_axis():
    """activation_sharding / model_shards on a data-only mesh (regression:
    KeyError: 'model'). Runs on the real 1-device mesh."""
    from repro.launch.mesh import make_mesh
    from repro.sharding.ctx import (activation_sharding, constrain,
                                    data_shards, model_shards)
    mesh = make_mesh((1,), ("data",))
    with mesh, activation_sharding(mesh):
        assert model_shards() == 1
        assert data_shards() == 1
        x = jax.numpy.zeros((2, 4, 8))
        y = constrain(x, ("batch", None, "model"))
        assert y.shape == x.shape


def test_cache_specs_gqa_fallback():
    """KV-head sharding when heads divide the model axis; sequence-dim
    fallback when they don't (replicating a deep cache 8x is what blew
    decode memory in the baseline sweep); full replication when neither
    divides."""
    from repro.sharding.specs import cache_specs
    mesh = _abstract_mesh(data=1, model=8)

    def kv(h, s):
        z = np.zeros((2, 4, s, h, 16))
        return {"k": z, "v": z}

    head = cache_specs(kv(h=8, s=30), mesh)
    assert head["k"] == P(None, "data", None, "model", None)
    fallback = cache_specs(kv(h=2, s=32), mesh)
    assert fallback["k"] == P(None, "data", "model", None, None)
    assert fallback["v"] == fallback["k"]
    neither = cache_specs(kv(h=2, s=30), mesh)
    assert neither["k"] == P(None, "data", None, None, None)
    # SSM fields: conv (L,B,W-1,C) channels over model, state heads over model
    ssm = cache_specs({"conv": np.zeros((4, 2, 3, 64)),
                       "state": np.zeros((4, 2, 8, 16, 16))}, mesh)
    assert ssm["conv"] == P(None, "data", None, "model")
    assert ssm["state"] == P(None, "data", "model", None, None)


def test_small_mesh_train_lowering():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs.base import ShapeConfig, RunConfig
        from repro.configs.registry import get_config
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import step_for_shape
        from repro.launch.dryrun import input_shardings_for
        from repro.sharding.specs import to_shardings
        from repro.sharding.ctx import activation_sharding
        from repro.models.model import build
        from repro.launch.roofline import collective_bytes_from_hlo

        cfg = get_config("olmo-1b", smoke=True)
        model = build(cfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        shape = ShapeConfig("t", 128, 8, "train")
        fn, inputs = step_for_shape(model, shape, RunConfig(remat=False))
        sh = to_shardings(input_shardings_for(model, shape, inputs, mesh),
                          mesh)
        with mesh, activation_sharding(mesh):
            compiled = jax.jit(fn, in_shardings=sh).lower(*inputs).compile()
        coll = collective_bytes_from_hlo(compiled.as_text())
        assert coll["total"] > 0, "expected collectives on a 2x4 mesh"
        print("OK", int(coll["total"]))
    """)
    assert "OK" in out


def test_small_mesh_execution_matches_single_device():
    """Sharded loss == single-device loss (8 virtual devices, real exec)."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ShapeConfig, RunConfig
        from repro.configs.registry import get_config
        from repro.launch.mesh import make_mesh
        from repro.data.synthetic import synthetic_batch
        from repro.models.model import build
        from repro.sharding.specs import param_specs, batch_specs, to_shardings
        from repro.sharding.ctx import activation_sharding
        from repro.train.step import make_loss_fn
        import dataclasses

        cfg = dataclasses.replace(get_config("llama3.2-3b", smoke=True),
                                  dtype="float32")
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = synthetic_batch(cfg, batch=8, seq=32, step=0)
        loss_fn = make_loss_fn(model, remat=False)
        ref = float(loss_fn(params, batch)[0])

        mesh = make_mesh((2, 4), ("data", "model"))
        sh_p = to_shardings(param_specs(params, mesh), mesh)
        sh_b = to_shardings(batch_specs(batch, mesh), mesh)
        with mesh, activation_sharding(mesh):
            sharded = jax.jit(lambda p, b: loss_fn(p, b)[0],
                              in_shardings=(sh_p, sh_b))(params, batch)
        got = float(sharded)
        assert abs(got - ref) < 1e-3, (got, ref)
        print("OK", got, ref)
    """)
    assert "OK" in out


def test_compressed_psum_matches_mean():
    """int8 EF gradient all-reduce approximates the true mean; error
    feedback keeps the bias bounded across steps."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_mesh
        from repro.optim.compress import compressed_psum_mean, init_error
        from jax.experimental.shard_map import shard_map

        mesh = make_mesh((8,), ("data",))
        g_global = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 0.1
        true_mean = jnp.mean(g_global, axis=0)

        @partial(shard_map, mesh=mesh,
                 in_specs=(P("data", None), P("data", None)),
                 out_specs=(P("data", None), P("data", None)))
        def sync(g, e):
            m, e2 = compressed_psum_mean({"g": g}, {"g": e}, ("data",))
            return m["g"], e2["g"]

        err = jnp.zeros((8, 64))
        mean, err2 = sync(g_global, err)
        # every replica holds ~the mean
        got = np.asarray(mean)
        want = np.asarray(true_mean)
        rel = np.abs(got - want[None]).max() / (np.abs(want).max() + 1e-9)
        assert rel < 0.05, rel
        print("OK", rel)
    """)
    assert "OK" in out
