"""Fused dequant megakernels (docs/DESIGN.md §12): qmlp / qkv / fresh-KV.

Anchor invariants:

* ``qmlp_pallas`` / ``qkv_pallas`` (interpret mode on CPU) match the
  unfused qdot sequence for int8 / int4 / ternary segments — the fused
  launch never materializes a bf16 weight or the (M, FF) hidden
  activation, but its math is the segment-by-segment oracle's.
* ``fused_mlp`` / ``fused_qkv`` on a non-TPU backend ARE the unfused
  sequence (bit-identical fallback) — greedy serving output cannot
  depend on which path ran.
* ``decode_attention(fresh_kv=...)`` reads un-written draft rows exactly
  as if they had been quantize-on-insert written to the cache.
* int4 KV pages store their packed payload FLAT over F = Hkv * hd
  (the (…, S, Hkv, hd/2) layout de-vectorizes XLA CPU loops), and the
  flat layout round-trips through quantize/update/dequantize.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attn.ops import decode_attention
from repro.kernels.qmatmul.kernel import qkv_pallas, qmlp_pallas
from repro.kernels.qmatmul.ops import fused_mlp, fused_qkv, qdot
from repro.quant.kvcache import dequantize_kv, make_page, update_page
from repro.quant.quantize import (quantize_int4, quantize_int8,
                                  quantize_ternary)

QUANTIZERS = {"int8": quantize_int8, "int4": quantize_int4,
              "ternary": quantize_ternary}
PRECISIONS = tuple(QUANTIZERS)


def _mlp_weights(precision, k=256, ff=512, d=256, group=128, gated=True):
    ks = jax.random.split(jax.random.PRNGKey(k + ff), 3)
    quant = QUANTIZERS[precision]
    wg = quant(jax.random.normal(ks[0], (ff, k)) * 0.2, group) if gated \
        else None
    wu = quant(jax.random.normal(ks[1], (ff, k)) * 0.2, group)
    wd = quant(jax.random.normal(ks[2], (d, ff)) * 0.2, group)
    return wg, wu, wd


def _mlp_oracle(x, wg, wu, wd, act):
    if act == "swiglu":
        g = qdot(x, wg, backend="grouped")
        u = qdot(x, wu, backend="grouped")
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = qdot(x, wu, backend="grouped")
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return qdot(h, wd, backend="grouped")


# ---------------------------------------------------------------------------
# Pallas megakernels vs the unfused sequence (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", PRECISIONS)
@pytest.mark.parametrize("act", ["swiglu", "gelu"])
def test_qmlp_pallas_matches_unfused_sequence(precision, act):
    wg, wu, wd = _mlp_weights(precision, gated=act == "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 256),
                          jnp.float32) * 0.5
    got = qmlp_pallas(
        x,
        None if wg is None else wg.data, None if wg is None else wg.scale,
        wu.data, wu.scale, wd.data, wd.scale,
        group=wu.group, precision=precision, act=act,
        bm=128, bf=256, interpret=True)
    want = _mlp_oracle(x, wg, wu, wd, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("precision", PRECISIONS)
def test_qkv_pallas_matches_three_qdots(precision):
    k, nq, nkv, group = 256, 128, 64, 64
    quant = QUANTIZERS[precision]
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    wq = quant(jax.random.normal(ks[0], (nq, k)) * 0.2, group)
    wk = quant(jax.random.normal(ks[1], (nkv, k)) * 0.2, group)
    wv = quant(jax.random.normal(ks[2], (nkv, k)) * 0.2, group)
    x = jax.random.normal(jax.random.PRNGKey(2), (128, k),
                          jnp.float32) * 0.5
    got = qkv_pallas(x, wq.data, wq.scale, wk.data, wk.scale, wv.data,
                     wv.scale, group=group, precision=precision,
                     bm=128, bk=128, interpret=True)
    want = tuple(qdot(x, w, backend="grouped") for w in (wq, wk, wv))
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# fused_* entry points: the non-TPU fallback is bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.default_backend() == "tpu",
                    reason="fallback identity is the non-TPU contract")
@pytest.mark.parametrize("precision", PRECISIONS)
@pytest.mark.parametrize("act", ["swiglu", "gelu"])
def test_fused_mlp_fallback_is_bit_identical(precision, act):
    wg, wu, wd = _mlp_weights(precision, k=64, ff=96, d=64, group=32,
                              gated=act == "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 64),
                          jnp.bfloat16)
    got = fused_mlp(x, wg, wu, wd, act=act, backend="grouped")
    want = _mlp_oracle(x, wg, wu, wd, act)
    assert got.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.skipif(jax.default_backend() == "tpu",
                    reason="fallback identity is the non-TPU contract")
@pytest.mark.parametrize("precision", PRECISIONS)
def test_fused_qkv_fallback_is_bit_identical(precision):
    quant = QUANTIZERS[precision]
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    wq = quant(jax.random.normal(ks[0], (64, 64)) * 0.2, 32)
    wk = quant(jax.random.normal(ks[1], (32, 64)) * 0.2, 32)
    wv = quant(jax.random.normal(ks[2], (32, 64)) * 0.2, 32)
    x = jax.random.normal(jax.random.PRNGKey(5), (3, 7, 64), jnp.bfloat16)
    got = fused_qkv(x, wq, wk, wv, backend="grouped")
    want = tuple(qdot(x, w, backend="grouped") for w in (wq, wk, wv))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_fused_mlp_mixed_precision_segments_fall_back():
    """A block whose projections landed in different precisions is not
    mega-eligible; the entry point must still serve it (unfused)."""
    _, wu, wd = _mlp_weights("int8", k=64, ff=96, d=64, group=32,
                             gated=False)
    wu4 = quantize_int4(jax.random.normal(jax.random.PRNGKey(6),
                                          (96, 64)) * 0.2, 32)
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 64), jnp.bfloat16)
    got = fused_mlp(x, None, wu4, wd, act="gelu", backend="grouped")
    want = _mlp_oracle(x, None, wu4, wd, "gelu")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# fresh-KV: un-written draft rows == quantize-on-insert written rows
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", ["bf16", "int8", "int4"])
def test_fresh_kv_matches_written_cache(precision):
    b, t, hkv, rep, hd, sf = 2, 32, 2, 2, 32, 3
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    k = jax.random.normal(ks[0], (b, t, hkv, hd)) * 0.5
    v = jax.random.normal(ks[1], (b, t, hkv, hd)) * 0.5
    kp, vp = make_page(k, precision, 32), make_page(v, precision, 32)
    fk = jax.random.normal(ks[2], (b, sf, hkv, hd)) * 0.5
    fv = jax.random.normal(ks[3], (b, sf, hkv, hd)) * 0.5
    q = jax.random.normal(ks[4], (b, sf, hkv * rep, hd), jnp.float32)
    base = jnp.array([10, 17], jnp.int32)
    valid = base + sf
    # oracle: actually write the rows, then attend (simple backend)
    want = decode_attention(q, update_page(kp, fk, base),
                            update_page(vp, fv, base),
                            valid_len=valid, backend="simple")
    for backend in ("simple", "grouped"):
        got = decode_attention(q, kp, vp, valid_len=valid, backend=backend,
                               kv_chunk=7, fresh_kv=(fk, fv, base))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("precision", ["int8", "int4"])
def test_fresh_kv_masks_stale_cache_rows(precision):
    """Rows at positions >= base are STALE (a rolled-back draft's debris)
    and must not leak into the fused sweep."""
    b, t, hkv, hd, sf = 1, 16, 1, 32, 2
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    k = jax.random.normal(ks[0], (b, t, hkv, hd)) * 0.5
    v = jax.random.normal(ks[1], (b, t, hkv, hd)) * 0.5
    base = jnp.array([8], jnp.int32)
    # poison the cache beyond base with huge values
    k = k.at[:, 8:].set(37.0)
    v = v.at[:, 8:].set(-37.0)
    kp, vp = make_page(k, precision, 32), make_page(v, precision, 32)
    fk = jax.random.normal(ks[2], (b, sf, hkv, hd)) * 0.5
    fv = jax.random.normal(ks[3], (b, sf, hkv, hd)) * 0.5
    q = jax.random.normal(ks[4], (b, sf, hkv, hd), jnp.float32)
    clean_k = make_page(k.at[:, 8:].set(0.0), precision, 32)
    clean_v = make_page(v.at[:, 8:].set(0.0), precision, 32)
    want = decode_attention(q, update_page(clean_k, fk, base),
                            update_page(clean_v, fv, base),
                            valid_len=base + sf, backend="simple")
    got = decode_attention(q, kp, vp, valid_len=base + sf,
                           backend="grouped", kv_chunk=5,
                           fresh_kv=(fk, fv, base))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# flat int4 KV page layout
# ---------------------------------------------------------------------------

def test_int4_kv_page_is_flat_and_roundtrips():
    b, t, hkv, hd = 2, 8, 4, 64
    raw = jax.random.normal(jax.random.PRNGKey(10), (b, t, hkv, hd))
    page = make_page(raw, "int4", 128)
    f = hkv * hd
    assert page.data.shape == (b, t, f // 2)    # flat packed payload
    assert page.num_kv_heads == hkv
    assert page.seq_len == t
    deq = dequantize_kv(page)
    assert deq.shape == raw.shape
    # 4-bit grouped quantization: coarse but bounded by scale resolution
    assert float(jnp.max(jnp.abs(deq - raw))) < 0.5


def test_int4_flat_layout_matches_per_head_reference():
    """Flat packing is a pure relayout: dequantizing the flat page equals
    quantize/dequantize over the flattened (…, F) axis head-by-head."""
    b, t, hkv, hd, group = 1, 4, 2, 32, 32
    raw = jax.random.normal(jax.random.PRNGKey(11), (b, t, hkv, hd))
    page = make_page(raw, "int4", group)
    flat = raw.reshape(b, t, hkv * hd)
    ref_page = make_page(flat[..., None, :], "int4", group)  # 1 "head" of F
    ref = dequantize_kv(ref_page)[..., 0, :].reshape(b, t, hkv, hd)
    np.testing.assert_allclose(np.asarray(dequantize_kv(page)),
                               np.asarray(ref), atol=1e-6)


def test_int4_update_page_writes_flat_rows():
    b, t, hkv, hd = 2, 8, 2, 64
    raw = jnp.zeros((b, t, hkv, hd))
    page = make_page(raw, "int4", 64)
    new = jax.random.normal(jax.random.PRNGKey(12), (b, 1, hkv, hd))
    pos = jnp.array([3, 5], jnp.int32)
    upd = update_page(page, new, pos)
    assert upd.data.shape == page.data.shape
    deq = dequantize_kv(upd)
    # written rows hold exactly the quantize-on-insert values
    want = dequantize_kv(make_page(new, "int4", 64))
    for i, p in enumerate((3, 5)):
        np.testing.assert_allclose(np.asarray(deq[i, p]),
                                   np.asarray(want[i, 0]), atol=1e-6)
    # untouched rows stay zero
    assert float(jnp.max(jnp.abs(deq[0, :3]))) == 0.0
