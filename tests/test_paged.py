"""Paged quantized KV pool + disaggregated serving API (docs/DESIGN.md §13).

Three layers:

* host allocator (serving/pool.py): free-list/refcount invariants, the
  COW prefix-sharing admission protocol, LRU eviction, backpressure;
* device ops (quant/paged.py + the decode-attention trio): pool
  insert/gather/update round-trips and paged-vs-dense backend parity for
  bf16 / int8 / int4 pools, multi-query verify windows included;
* engine (serving/engine.py): the paged prefill/insert/generate engine
  must emit greedy tokens IDENTICAL to the dense engine on all four
  families and all KV precisions, with prefix sharing, spec decode and
  pool backpressure live.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant import kvcache as KV
from repro.quant import paged as PG
from repro.serving.engine import ServeEngine
from repro.serving.pool import (OutOfPages, PagedConfig, PoolSession,
                                PrefixMatch)
from repro.serving.scheduler import Request

PC4 = PagedConfig(page_size=4)


# ---------------------------------------------------------------------------
# host allocator
# ---------------------------------------------------------------------------

def test_alloc_release_refcounts_and_free_list():
    pool = PoolSession(num_pages=6, page_size=4, n_log=6)
    row, wrow = pool.admit(0, list(range(10)), 3)
    assert pool.pages_in_use == 3 and pool.pages_free == 3
    assert list(row[:3]) == list(wrow[:3]) and all(row[3:] == 0)
    assert 0 not in row[:3]                    # dump page never handed out
    pool.check_invariants()
    pool.release(0)
    assert pool.pages_in_use == 0 and pool.pages_free == 6
    pool.check_invariants()


def test_pages_for_and_can_admit():
    pool = PoolSession(num_pages=4, page_size=4, n_log=6,
                       prefix_sharing=False)
    assert pool.pages_for(1) == 1
    assert pool.pages_for(4) == 1
    assert pool.pages_for(5) == 2
    assert pool.pages_for(1000) == 6           # clamped to n_log
    assert pool.can_admit(4) and not pool.can_admit(5)
    pool.admit(0, [1, 2, 3], 3)
    assert pool.can_admit(1) and not pool.can_admit(2)


def test_out_of_pages_leaks_nothing():
    pool = PoolSession(num_pages=3, page_size=4, n_log=6,
                       prefix_sharing=False)
    pool.admit(0, [1], 2)
    with pytest.raises(OutOfPages):
        pool.admit(1, [2], 2)
    # the failed admission returned its partial allocation
    assert pool.pages_in_use == 2
    pool.check_invariants()
    pool.release(0)
    pool.admit(1, [2], 3)                      # now it fits
    pool.check_invariants()


def test_prefix_match_register_and_cow_demotion():
    pool = PoolSession(num_pages=12, page_size=4, n_log=6)
    toks = list(range(100, 116))               # 16 tokens = 4 full pages
    m0 = pool.match(toks)
    assert m0 == PrefixMatch()                 # cold cache
    pool.admit(0, toks, 5, m0)
    pool.register(0, toks, len(toks))
    pool.check_invariants()
    # identical prompt: all 4 pages known, but the hit is capped at p-1 so
    # the model still produces last-token logits — the 4th page demotes to
    # a COW donor contributing 3 tokens
    m1 = pool.match(toks)
    assert m1.hit == 15 and len(m1.full_ids) == 3
    assert m1.donor is not None and m1.donor_tokens == 3
    before = pool.pages_in_use
    row, wrow = pool.admit(1, toks, 5, m1)
    assert pool.cow_copies == 1
    assert list(row[:3]) == list(m1.full_ids)
    assert all(wrow[:3] == 0)                  # shared pages write to dump
    assert all(row[3:5] != 0) and all(wrow[3:5] == row[3:5])
    # shared pages mapped, not copied: only 2 private pages were allocated
    assert pool.pages_in_use == before + 2
    pool.register(1, toks, len(toks))
    pool.check_invariants()
    # divergent tail: only the 3 common full pages match, no donor overlap
    toks2 = toks[:12] + [900, 901, 902, 903]
    m2 = pool.match(toks2)
    assert m2.hit == 12 and len(m2.full_ids) == 3 and m2.donor is None
    pool.unpin(m2)
    pool.check_invariants()


def test_shared_pages_survive_donor_release():
    pool = PoolSession(num_pages=8, page_size=4, n_log=6)
    toks = list(range(16))
    pool.admit(0, toks, 4, pool.match(toks))
    pool.register(0, toks, 16)
    m = pool.match(toks)
    pool.admit(1, toks, 4, m)
    pool.release(0)                            # donor gone; pages must live
    pool.check_invariants()
    m2 = pool.match(toks)
    assert m2.hit == 15                        # still fully matchable
    pool.unpin(m2)
    pool.release(1)
    pool.check_invariants()
    assert pool.pages_in_use > 0               # prefix cache keeps its refs


def test_lru_eviction_frees_cache_only_pages():
    pool = PoolSession(num_pages=4, page_size=4, n_log=6)
    toks = list(range(8))                      # 2 full pages
    pool.admit(0, toks, 2, pool.match(toks))
    pool.register(0, toks, 8)
    pool.release(0)                            # only the prefix cache holds 2
    assert pool.pages_in_use == 2 and pool.can_admit(4)
    pool.admit(1, list(range(50, 58)), 4)      # forces eviction of both
    assert pool.pages_in_use == 4
    pool.check_invariants()
    m = pool.match(toks)
    assert m.hit == 0                          # evicted entries are gone


# ---------------------------------------------------------------------------
# device ops + backend parity
# ---------------------------------------------------------------------------

def _mk_pool(precision, b=3, s_max=32, hkv=2, hd=8, p=8, group=8):
    return PG.init_pool_field(
        jnp.zeros((1, b, s_max, hkv, hd), jnp.float32), [(precision, 0, 1)],
        num_pages=b * (s_max // p), page_size=p, num_slots=b, group=group)


def _fill(pool, raw, valid, p=8):
    nxt = 1
    rows = []
    for b in range(raw.shape[1]):
        row = [0] * pool.table.shape[-1]
        for j in range(-(-int(valid[b]) // p)):
            row[j] = nxt
            nxt += 1
        rows.append(row)
    rows = np.array(rows, np.int32)
    for b in range(raw.shape[1]):
        pool = PG.insert_slot_paged(pool, jnp.asarray(raw[:, b:b + 1]), b,
                                    rows[b], rows[b])
    return pool, rows


@pytest.mark.parametrize("precision", ["bf16", "int8", "int4"])
def test_paged_backends_match_dense_oracle(precision):
    from repro.kernels.decode_attn import ops
    rng = np.random.default_rng(0)
    b, s_max, hkv, hd, h, p = 3, 32, 2, 8, 4, 8
    valid = np.array([13, 30, 21], np.int32)
    kraw = rng.normal(size=(1, b, s_max, hkv, hd)).astype(np.float32)
    vraw = rng.normal(size=(1, b, s_max, hkv, hd)).astype(np.float32)
    kpool, _ = _fill(_mk_pool(precision), kraw, valid)
    vpool, _ = _fill(_mk_pool(precision), vraw, valid)
    kp = jax.tree.map(lambda x: x[0], kpool)   # strip the layer axis
    vp = jax.tree.map(lambda x: x[0], vpool)
    if precision == "bf16":
        kd = KV.KVPage(data=jnp.asarray(kraw[0]), scale=None,
                       precision="bf16", head_dim=hd, group=8)
        vd = KV.KVPage(data=jnp.asarray(vraw[0]), scale=None,
                       precision="bf16", head_dim=hd, group=8)
    else:
        kd = KV.make_page(jnp.asarray(kraw[0]), precision, 8)
        vd = KV.make_page(jnp.asarray(vraw[0]), precision, 8)
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)).astype(np.float32))
    vl = jnp.asarray(valid)
    ref = ops._grouped(q, kd, vd, vl, 16, True)
    outs = {"grouped": ops._grouped(q, kp, vp, vl, 16, True),
            "simple": ops._simple(q, kp, vp, vl, True),
            "pallas": ops._pallas(q, kp, vp, vl, 16, True, interpret=True)}
    for name, out in outs.items():
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5, rtol=3e-5, err_msg=name)
    # multi-query verify window + fresh side-buffer rows (spec decode)
    qs = 3
    qm = jnp.asarray(rng.normal(size=(b, qs, h, hd)).astype(np.float32))
    fk = jnp.asarray(rng.normal(size=(b, 2, hkv, hd)).astype(np.float32))
    fv = jnp.asarray(rng.normal(size=(b, 2, hkv, hd)).astype(np.float32))
    base = jnp.asarray(valid - 2)
    ref2 = ops._grouped(qm, kd, vd, vl, 16, True, fresh=(fk, fv, base))
    outs2 = {
        "grouped": ops._grouped(qm, kp, vp, vl, 16, True,
                                fresh=(fk, fv, base)),
        "simple": ops._simple(qm, kp, vp, vl, True, fresh=(fk, fv, base)),
        "pallas": ops._pallas(qm, kp, vp, vl, 16, True,
                              fresh=(fk, fv, base), interpret=True)}
    for name, out in outs2.items():
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref2),
                                   atol=3e-5, rtol=3e-5, err_msg=name)


def test_update_pages_writes_through_table_and_release_dumps():
    rng = np.random.default_rng(1)
    b, s_max, hkv, hd, p = 3, 32, 2, 8, 8
    valid = np.array([13, 30, 21], np.int32)
    raw = rng.normal(size=(1, b, s_max, hkv, hd)).astype(np.float32)
    pool, rows = _fill(_mk_pool("bf16"), raw, valid)
    pg = jax.tree.map(lambda x: x[0], pool)
    new = jnp.asarray(rng.normal(size=(b, 1, hkv, hd)).astype(np.float32))
    pg2 = KV.update_page(pg, new, jnp.asarray(valid))   # PagedKV dispatch
    dense = PG.gather(pg2)
    for i in range(b):
        np.testing.assert_array_equal(
            np.asarray(dense.data)[i, int(valid[i])],
            np.asarray(new)[i, 0])             # bf16 pools store raw values
    # releasing slot 1 points its table at the dump page
    rel = PG.release_slot_pages(pool, 1)
    assert np.all(np.asarray(rel.table)[:, 1] == PG.DUMP_PAGE)
    assert np.array_equal(np.asarray(rel.table)[:, 0],
                          np.asarray(pool.table)[:, 0])


def test_pool_table_spec_is_replicated():
    """cache_specs must not crash on the rank-3 int32 page table (it has
    no head axis) — the "#2" leaf is replicated; the pool payload keeps
    the positional dense KV rules."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding.specs import cache_specs
    from typing import NamedTuple

    class C(NamedTuple):
        k: object
        v: object
        pos: object

    pool = _mk_pool("int8")
    cache = C(k=pool, v=pool, pos=jnp.zeros((3,), jnp.int32))
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    specs = cache_specs(cache, mesh)
    assert specs.k.table == P()
    assert isinstance(specs.k.data, P) and isinstance(specs.k.scale, P)


# ---------------------------------------------------------------------------
# engine parity (paged vs dense, greedy token-identical)
# ---------------------------------------------------------------------------

def _requests(cfg, n=3, prompt_len=6, max_new=6, prefix=None):
    out = []
    for i in range(n):
        pr = np.array(jax.random.randint(jax.random.PRNGKey(10 + i),
                                         (prompt_len,), 0, cfg.vocab_size,
                                         dtype=jnp.int32))
        if prefix is not None:
            pr[:len(prefix)] = prefix
        out.append(Request(rid=i, prompt=pr, max_new_tokens=max_new))
    return out


def _assert_same(outs_a, outs_b, atol=1e-2):
    for a, b in zip(outs_a, outs_b):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_allclose(a.logprobs, b.logprobs, atol=atol)


@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid", "encdec"])
def test_paged_serve_matches_dense(trained, family):
    cfg, model, params = trained[family]
    reqs = _requests(cfg)
    ref = ServeEngine(model, params, max_seq=24)
    pg = ServeEngine(model, params, max_seq=24, paged=PC4)
    outs_ref, _ = ref.serve(reqs, num_slots=2, chunk=4)
    outs_pg, stats = pg.serve(reqs, num_slots=2, chunk=4)
    _assert_same(outs_pg, outs_ref, atol=1e-4)
    if family == "ssm":                        # attention-free: pool inert
        assert pg.pool is None and stats.pool_pages_total == 0
    else:
        assert stats.pool_pages_total == 2 * (24 // 4)
        assert stats.pool_pages_peak > 0
        pg.pool.check_invariants()
        # slots drained: anything still held belongs to the prefix cache
        # only (retained for future sharing, evictable on demand)
        assert (pg.pool.pages_in_use
                == pg.pool.prefix.evictable(pg.pool._ref))


@pytest.mark.parametrize("kv_precision", ["int8", "int4"])
def test_paged_serve_quantized_kv_matches_dense_quantized(trained,
                                                          kv_precision):
    """A paged int8/int4 pool must agree with the DENSE engine at the same
    KV precision — same quantize-on-insert math, different storage."""
    cfg, model, params = trained["dense"]
    reqs = _requests(cfg)
    ref = ServeEngine(model, params, max_seq=24, kv_precision=kv_precision)
    pg = ServeEngine(model, params, max_seq=24, kv_precision=kv_precision,
                     paged=PC4)
    outs_ref, _ = ref.serve(reqs, num_slots=2, chunk=4)
    outs_pg, _ = pg.serve(reqs, num_slots=2, chunk=4)
    _assert_same(outs_pg, outs_ref, atol=1e-4)


def test_paged_generate_matches_dense(trained):
    cfg, model, params = trained["dense"]
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    ref = ServeEngine(model, params, max_seq=24)
    pg = ServeEngine(model, params, max_seq=24, paged=PC4)
    o_ref = ref.generate(prompts, 6, chunk=3)
    o_pg = pg.generate(prompts, 6, chunk=3)
    np.testing.assert_array_equal(np.asarray(o_ref.tokens),
                                  np.asarray(o_pg.tokens))
    np.testing.assert_allclose(np.asarray(o_ref.logprobs),
                               np.asarray(o_pg.logprobs), atol=1e-4)


def test_paged_bf16_over_segmented_stack_matches_dense(trained):
    """bf16 KV pools over a MIXED-PRECISION weight stack: decode scans per
    weight segment, so the pool must split at the segment cuts (a single
    full-stack pool would mismatch the scan's leading axis)."""
    from repro.serving.quantized import plan_for_variant
    cfg, model, params = trained["dense"]
    plan = plan_for_variant(model, params, "8bit-mixed")
    qparams = model.compile_plan(params, plan).params
    prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    ref = ServeEngine(model, qparams, max_seq=24)
    pg = ServeEngine(model, qparams, max_seq=24, paged=PC4)
    from repro.quant.apply import segment_slices
    n_seg = len(segment_slices(qparams["layers"]))
    k = pg._paged_cache(2, 8).k
    assert len(k if isinstance(k, tuple) else (k,)) == n_seg
    o_ref = ref.generate(prompts, 6, chunk=3)
    o_pg = pg.generate(prompts, 6, chunk=3)
    np.testing.assert_array_equal(np.asarray(o_ref.tokens),
                                  np.asarray(o_pg.tokens))


def test_prefix_sharing_skips_prefill_and_stays_exact(trained):
    """Requests sharing a 12-token system prefix: the paged engine maps the
    shared pages, skips their prefill (dense seeded path), and still emits
    the dense engine's exact greedy tokens."""
    cfg, model, params = trained["dense"]
    prefix = np.array(jax.random.randint(jax.random.PRNGKey(99), (12,), 0,
                                         cfg.vocab_size, dtype=jnp.int32))
    reqs = _requests(cfg, n=4, prompt_len=16, max_new=6, prefix=prefix)
    ref = ServeEngine(model, params, max_seq=24)
    pg = ServeEngine(model, params, max_seq=24, paged=PC4)
    outs_ref, _ = ref.serve(reqs, num_slots=2, chunk=4)
    outs_pg, st = pg.serve(reqs, num_slots=2, chunk=4)
    _assert_same(outs_pg, outs_ref, atol=1e-4)
    assert st.prefix_hits == 3                 # every follower hit
    assert st.prefix_hit_tokens == 3 * 12
    assert 0.0 < st.prefix_hit_rate < 1.0
    pg.pool.check_invariants()


@pytest.mark.parametrize("kv_precision", ["bf16", "int8"])
def test_cow_boundary_page_materializes(trained, kv_precision):
    """Identical page-aligned prompts force the demoted-donor COW path: the
    follower maps 3 shared pages and copies the boundary page privately."""
    cfg, model, params = trained["dense"]
    pr = np.array(jax.random.randint(jax.random.PRNGKey(7), (16,), 0,
                                     cfg.vocab_size, dtype=jnp.int32))
    reqs = [Request(rid=i, prompt=pr.copy(), max_new_tokens=6)
            for i in range(3)]
    ref = ServeEngine(model, params, max_seq=24, kv_precision=kv_precision)
    pg = ServeEngine(model, params, max_seq=24, kv_precision=kv_precision,
                     paged=PC4)
    outs_ref, _ = ref.serve(reqs, num_slots=2, chunk=4)
    outs_pg, st = pg.serve(reqs, num_slots=2, chunk=4)
    _assert_same(outs_pg, outs_ref, atol=1e-2)
    assert st.cow_copies == 2 and st.prefix_hits == 2
    assert st.prefix_hit_tokens == 2 * 15      # capped at prompt_len - 1
    pg.pool.check_invariants()


def test_pool_backpressure_requeues_and_completes(trained):
    """A pool too small for 4 concurrent slots: admission stalls, requests
    requeue, everything still finishes with the dense engine's tokens."""
    cfg, model, params = trained["dense"]
    reqs = _requests(cfg, n=4)
    ref = ServeEngine(model, params, max_seq=24)
    pg = ServeEngine(model, params, max_seq=24,
                     paged=PagedConfig(page_size=4, pool_pages=7,
                                       prefix_sharing=False))
    outs_ref, _ = ref.serve(reqs, num_slots=4, chunk=4)
    outs_pg, st = pg.serve(reqs, num_slots=4, chunk=4)
    _assert_same(outs_pg, outs_ref, atol=1e-4)
    assert st.pool_pages_peak <= 7
    assert pg.pool.pages_in_use == 0
    pg.pool.check_invariants()


def test_impossible_request_raises_out_of_pages(trained):
    cfg, model, params = trained["dense"]
    reqs = _requests(cfg, n=1, prompt_len=6, max_new=6)   # needs 3 pages
    pg = ServeEngine(model, params, max_seq=24,
                     paged=PagedConfig(page_size=4, pool_pages=2,
                                       prefix_sharing=False))
    with pytest.raises(OutOfPages):
        pg.serve(reqs, num_slots=2, chunk=4)


def test_spec_decode_paged_parity(trained):
    """Spec verify writes K+1 rows through the page table and rolls back by
    position arithmetic; paged spec serving matches dense spec serving."""
    from repro.serving.spec import SpecConfig
    cfg, model, params = trained["dense"]
    reqs = _requests(cfg)
    ref = ServeEngine(model, params, max_seq=24, spec=SpecConfig(k=2),
                      kv_precision="int8")
    pg = ServeEngine(model, params, max_seq=24, spec=SpecConfig(k=2),
                     kv_precision="int8", paged=PC4)
    outs_ref, _ = ref.serve(reqs, num_slots=2, chunk=2)
    outs_pg, _ = pg.serve(reqs, num_slots=2, chunk=2)
    _assert_same(outs_pg, outs_ref, atol=1e-4)
    pg.pool.check_invariants()


def test_kv_bytes_allocated_is_honest(trained):
    """Dense reserves num_slots * full depth up front; the paged engine
    charges only referenced pages (0 when drained, shared pages once)."""
    cfg, model, params = trained["dense"]
    ref = ServeEngine(model, params, max_seq=24)
    pg = ServeEngine(model, params, max_seq=24,
                     paged=PagedConfig(page_size=4, prefix_sharing=False))
    assert ref.kv_bytes_allocated(4) == 4 * ref.kv_bytes_per_slot()
    reqs = _requests(cfg, n=2)
    pg.serve(reqs, num_slots=2, chunk=4)
    assert pg.kv_bytes_allocated(2) == 0.0     # pool fully drained
    # mid-flight accounting: admit one short request by hand
    state = pg.init_decode_state(2)
    pf = pg.prefill_request(reqs[0].prompt, state=state)
    pg.insert(state, 0, pf, reqs[0].max_new_tokens)
    used = pg.kv_bytes_allocated(2)
    assert 0.0 < used < ref.kv_bytes_allocated(2)
    assert used == pg.pool.pages_in_use * pg._page_bytes


def test_disaggregated_api_matches_serve(trained):
    """Driving prefill_request / insert / decode_chunk / release by hand
    produces the same greedy tokens as serve() for the same request."""
    cfg, model, params = trained["dense"]
    req = _requests(cfg, n=1)[0]
    nosh = PagedConfig(page_size=4, prefix_sharing=False)
    eng = ServeEngine(model, params, max_seq=24, paged=nosh)
    outs, _ = eng.serve([req], num_slots=2, chunk=4)
    eng2 = ServeEngine(model, params, max_seq=24, paged=nosh)
    state = eng2.init_decode_state(2)
    pf = eng2.prefill_request(req.prompt, state=state)
    state = eng2.insert(state, 0, pf, req.max_new_tokens)
    for _ in range(req.max_new_tokens):
        state = eng2.decode_chunk(state, 2)
        if bool(np.asarray(state.done)[0]):
            break
    n = int(np.asarray(state.lengths)[0])
    got = np.asarray(state.tokens)[0, :n]
    state = eng2.release(state, 0)
    assert eng2.pool.pages_in_use == 0
    np.testing.assert_array_equal(got, outs[0].tokens)


# ---------------------------------------------------------------------------
# chunked prefill + SLO drops over the paged pool (docs/DESIGN.md §14)
# ---------------------------------------------------------------------------

def test_chunked_prefill_paged_parity(trained):
    """Chunked prefill composes with the paged pool and prefix sharing:
    the interleaved engine (non-dividing chunk) emits the dense engine's
    exact greedy tokens, still detects the shared prefix, and the pool
    invariants hold after the stream drains."""
    cfg, model, params = trained["dense"]
    prefix = np.array(jax.random.randint(jax.random.PRNGKey(99), (12,), 0,
                                         cfg.vocab_size, dtype=jnp.int32))
    reqs = _requests(cfg, n=4, prompt_len=16, max_new=6, prefix=prefix)
    ref = ServeEngine(model, params, max_seq=24)
    pg = ServeEngine(model, params, max_seq=24, paged=PC4)
    outs_ref, _ = ref.serve(reqs, num_slots=2, chunk=4)
    outs_pg, st = pg.serve(reqs, num_slots=2, chunk=4, prefill_chunk=5)
    _assert_same(outs_pg, outs_ref, atol=1e-4)
    assert st.prefill_chunks > 0
    # rids 0 and 1 start prefilling concurrently (2 slots), before rid 0's
    # pages register — so rid 1 can miss; later admissions must hit
    assert st.prefix_hits >= 2
    assert st.prefix_hit_tokens == st.prefix_hits * 12
    pg.pool.check_invariants()
    assert (pg.pool.pages_in_use
            == pg.pool.prefix.evictable(pg.pool._ref))


def test_cancellation_under_load_frees_pages(trained):
    """Poisson load with cancellations, queue timeouts and preemption on a
    paged engine: every drop path — queued, prefilling, or decoding —
    returns its pages (check_invariants), and the drained engine holds
    only evictable prefix-cache pages."""
    from repro.serving.scheduler import SLOConfig, synthetic_stream
    cfg, model, params = trained["dense"]
    reqs = synthetic_stream(12, vocab_size=cfg.vocab_size, prompt_len=6,
                            max_new_tokens=8, arrival_rate=2.0,
                            poisson=True, seed=3, priorities=(1, 1, 1, 0))
    for r in reqs[::4]:
        r.cancel_at_step = r.arrival_step + 4
    for r in reqs[2::4]:
        r.queue_timeout_steps = 3
    max_seq = max(len(r.prompt) + r.max_new_tokens for r in reqs)
    pg = ServeEngine(model, params, max_seq=max_seq, paged=PC4)
    outs, st = pg.serve(reqs, num_slots=2, chunk=4, prefill_chunk=4,
                        slo=SLOConfig(preempt=True))
    assert len(outs) == len(reqs)
    reasons = {o.finish_reason for o in outs}
    assert st.cancelled > 0 and "cancelled" in reasons
    assert st.timeouts > 0 and "timeout" in reasons
    pg.pool.check_invariants()
    assert (pg.pool.pages_in_use
            == pg.pool.prefix.evictable(pg.pool._ref))
