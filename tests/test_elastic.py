"""Elastic scaling: checkpoint on one mesh, restore+reshard onto another."""

import subprocess
import sys
import textwrap

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, strategies as st

from repro.core.entropy import BlockEntropy
from repro.core.policy import decide


def test_elastic_remesh_restore(tmp_path):
    """Save sharded state on a (4,2) mesh; restore onto (2,4) — the logical
    arrays must be identical (ckpt stores logically, reshards on restore)."""
    code = f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import ckpt
        from repro.launch.mesh import make_mesh
        from repro.sharding.specs import param_specs, to_shardings
        from repro.configs.registry import get_config
        from repro.models.model import build

        cfg = get_config("olmo-1b", smoke=True)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))

        mesh_a = make_mesh((4, 2), ("data", "model"))
        specs_a = param_specs(params, mesh_a)
        sharded = jax.device_put(params, to_shardings(specs_a, mesh_a))
        ckpt.save(r"{tmp_path}", 1, sharded, extra={{"mesh": "4x2"}})

        mesh_b = make_mesh((2, 4), ("data", "model"))
        specs_b = param_specs(params, mesh_b)
        restored, extra = ckpt.restore(r"{tmp_path}", params, mesh=mesh_b,
                                       specs=specs_b)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored leaves actually live on mesh_b
        leaf = jax.tree.leaves(restored)[0]
        assert leaf.sharding.mesh.shape["model"] == 4
        print("OK elastic remesh")
    """
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=560,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-3000:]
    assert "OK elastic remesh" in res.stdout


@given(st.lists(st.floats(0.1, 9.9), min_size=4, max_size=20, unique=True),
       st.randoms(use_true_random=False))
def test_plan_equivariant_under_block_permutation(ents, rng):
    """Permuting block order permutes decisions identically: the decision
    depends only on each block's entropy vs the global (mu, sigma)."""
    blocks = [BlockEntropy(block_index=i, exec_index=i + 1, entropy=h,
                           num_parameters=100, per_matrix={})
              for i, h in enumerate(ents)]
    base = {b.entropy: d.precision
            for b, d in zip(blocks, decide(blocks).decisions)}
    idx = list(range(len(ents)))
    rng.shuffle(idx)
    perm = [BlockEntropy(block_index=i, exec_index=i + 1,
                         entropy=ents[j], num_parameters=100, per_matrix={})
            for i, j in enumerate(idx)]
    for b, d in zip(perm, decide(perm).decisions):
        assert d.precision == base[b.entropy]


def test_plan_threshold_scaling_monotone():
    """Raising X (more aggressive threshold) never increases the number of
    int4 blocks."""
    ents = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]
    blocks = [BlockEntropy(block_index=i, exec_index=i + 1, entropy=h,
                           num_parameters=10, per_matrix={})
              for i, h in enumerate(ents)]
    prev = None
    for x in [0.0, 0.5, 1.0, 1.5, 2.0]:
        n4 = decide(blocks, x_factor=x).counts()["int4"]
        if prev is not None:
            assert n4 <= prev
        prev = n4
