"""Fault-tolerant serving: chaos harness, failover, degradation
(docs/DESIGN.md §15).

Four layers:

* the injector itself (serving/chaos.py): deterministic per-(site, tag)
  occurrence schedules, shorthand parsing, scoped installation;
* artifact integrity (checkpoint/ckpt.py): per-leaf crc32 stamped at
  save, verified at load, bounded retry on transient reads, corruption
  and truncation surfaced as ``ArtifactCorruptionError`` naming the leaf;
* leak-free teardown: any failure inside the serve loop releases slots
  and pool pages (``ServeSession.abort`` + ``check_invariants``);
* recovery end-to-end: replica kill mid-stream re-drives onto survivors
  with token-identical greedy output, ewq degradation spills KV tiers
  deterministically under injected pool pressure, and a saturated
  Poisson stream under compound faults loses zero requests and zero
  pages.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import chaos
from repro.serving.chaos import (ChaosInjector, FaultConfig, FaultRule,
                                 InjectedFault, TransientFault)
from repro.serving.engine import ServeEngine
from repro.serving.pool import OutOfPages, PagedConfig
from repro.serving.replica import FailoverConfig, ReplicaServe, _sum_tiers
from repro.serving.scheduler import Request
from repro.serving.session import DegradeConfig, ServeSession

PC8 = PagedConfig(page_size=8, pool_pages=6)


def _requests(cfg, n=6, prompt_len=8, max_new=8, arrival_every=2):
    out = []
    for i in range(n):
        pr = np.array(jax.random.randint(jax.random.PRNGKey(10 + i),
                                         (prompt_len,), 0, cfg.vocab_size,
                                         dtype=jnp.int32))
        out.append(Request(rid=i, prompt=pr, max_new_tokens=max_new,
                           arrival_step=i * arrival_every))
    return out


def _assert_tokens_equal(outs_a, outs_b):
    assert len(outs_a) == len(outs_b)
    for a, b in zip(outs_a, outs_b):
        assert a.rid == b.rid
        np.testing.assert_array_equal(a.tokens, b.tokens)


def _assert_pool_clean(engine):
    """Engine teardown: zero leaked pages (anything still held belongs to
    the prefix cache, evictable on demand)."""
    pool = engine.pool
    if pool is None:
        return
    pool.check_invariants()
    held = pool.pages_in_use
    assert held == (pool.prefix.evictable(pool._ref)
                    if pool.prefix is not None else 0), held


# ---------------------------------------------------------------------------
# the injector
# ---------------------------------------------------------------------------

def test_occurrence_schedule_is_deterministic():
    cfg = FaultConfig(rules=(FaultRule(site="pool.oom", at=(2, 5),
                                       count=0),), seed=3)

    def run():
        inj = ChaosInjector(cfg)
        return [inj.deny("pool.oom", tag=0) for _ in range(6)], inj.log

    hits_a, log_a = run()
    hits_b, log_b = run()
    assert hits_a == hits_b == [False, True, False, False, True, False]
    assert log_a == log_b == [("pool.oom", 0, 2), ("pool.oom", 0, 5)]


def test_counters_are_per_site_and_tag():
    inj = ChaosInjector(FaultConfig(rules=(
        FaultRule(site="replica.dispatch", tag=1, at=(2,)),)))
    # replica 0's occurrences never match a tag-1 rule
    inj.fire("replica.dispatch", tag=0)
    inj.fire("replica.dispatch", tag=0)
    inj.fire("replica.dispatch", tag=1)        # occurrence 1 for tag 1
    with pytest.raises(InjectedFault) as e:
        inj.fire("replica.dispatch", tag=1)    # occurrence 2 -> fires
    assert e.value.occurrence == 2 and e.value.tag == 1
    assert not e.value.transient


def test_count_budget_and_transient_flag():
    inj = ChaosInjector(FaultConfig(rules=(
        FaultRule(site="artifact.read", at=(1, 2, 3), count=2,
                  transient=True),)))
    for _ in range(2):
        with pytest.raises(TransientFault):
            inj.fire("artifact.read")
    inj.fire("artifact.read")                  # budget spent: occ 3 passes


def test_probabilistic_rules_draw_one_sample_per_call():
    cfg = FaultConfig(rules=(FaultRule(site="pool.oom", prob=0.5,
                                       count=0),), seed=7)

    def seq():
        inj = ChaosInjector(cfg)
        return [inj.deny("pool.oom") for _ in range(32)]

    assert seq() == seq()
    assert any(seq()) and not all(seq())


def test_parse_shorthands_and_unknown():
    cfg = FaultConfig.parse("replica_fault,oom", seed=4)
    assert cfg.seed == 4 and len(cfg.rules) == 2
    assert {r.site for r in cfg.rules} == {"replica.dispatch", "pool.oom"}
    with pytest.raises(ValueError, match="unknown chaos shorthand"):
        FaultConfig.parse("nope")
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultRule(site="replica.explode")
    with pytest.raises(ValueError, match="unknown fault mode"):
        FaultRule(site="pool.oom", mode="smolder")


def test_module_level_sites_are_noops_when_inactive():
    assert chaos.active() is None
    chaos.fire("replica.dispatch", tag=0)      # must not raise
    assert chaos.deny("pool.oom") is False
    with chaos.chaos(FaultConfig(rules=(
            FaultRule(site="pool.oom", at=(1,)),))) as inj:
        assert chaos.active() is inj
        assert chaos.deny("pool.oom") is True
    assert chaos.active() is None


# ---------------------------------------------------------------------------
# artifact integrity
# ---------------------------------------------------------------------------

def _ckpt_tree():
    from repro.quant.quantize import quantize_int8
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
            "q": quantize_int8(jnp.ones((4, 128)) * 0.3)}


def test_crc_detects_corrupted_leaf(tmp_path):
    from repro.checkpoint import ckpt
    tree = _ckpt_tree()
    ckpt.save(tmp_path, 1, tree)
    # the chaos corrupt site flips one byte of the first loaded payload
    with chaos.chaos(FaultConfig(rules=(
            FaultRule(site="artifact.corrupt", at=(1,)),))):
        with pytest.raises(ckpt.ArtifactCorruptionError) as e:
            ckpt.restore(tmp_path, tree)
    assert e.value.leaf    # the error names the bad leaf
    # without chaos the same checkpoint verifies clean
    restored, _ = ckpt.restore(tmp_path, tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_transient_read_fault_is_retried(tmp_path):
    from repro.checkpoint import ckpt
    tree = _ckpt_tree()
    ckpt.save(tmp_path, 1, tree)
    with chaos.chaos(FaultConfig.parse("artifact")) as inj:
        restored, _ = ckpt.restore(tmp_path, tree)
    assert inj.log and inj.log[0][0] == "artifact.read"
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_truncated_checkpoint_names_missing_leaf(tmp_path):
    from repro.checkpoint import ckpt
    tree = _ckpt_tree()
    ckpt.save(tmp_path, 1, tree)
    step_dir = next(p for p in tmp_path.iterdir() if p.is_dir())
    for shard in sorted(step_dir.glob("shard_*.npz"))[:1]:
        shard.unlink()
    with pytest.raises(ckpt.ArtifactCorruptionError, match="truncated"):
        ckpt.restore(tmp_path, tree)


# ---------------------------------------------------------------------------
# leak-free teardown
# ---------------------------------------------------------------------------

def test_session_abort_releases_slots_and_pages(trained):
    cfg, model, params = trained["dense"]
    eng = ServeEngine(model, params, max_seq=18, paged=PC8)
    reqs = _requests(cfg, n=4, arrival_every=0)
    sess = ServeSession(eng, reqs, num_slots=2, chunk=4)
    sess.dispatch()
    sess.harvest()
    assert sess.sched.num_active > 0
    survivors = sess.abort()
    assert sess.sched.num_active == 0
    # every submitted-but-unfinished request came back for re-drive
    assert {r.rid for r in survivors} == {r.rid for r in reqs}
    _assert_pool_clean(eng)


def test_mid_decode_fault_leaves_pool_clean(trained):
    """A permanent fault thrown from inside the serve loop must unwind
    through ``abort``: no slot or page survives the wreck."""
    cfg, model, params = trained["dense"]
    eng = ServeEngine(model, params, max_seq=18, paged=PC8)
    reqs = _requests(cfg, n=4, arrival_every=0)
    with chaos.chaos(FaultConfig(rules=(
            FaultRule(site="replica.dispatch", at=(3,)),))):
        with pytest.raises(InjectedFault):
            eng.serve(reqs, num_slots=2, chunk=4)
    _assert_pool_clean(eng)


def test_impossible_request_still_raises_out_of_pages(trained):
    """Degradation must not mask a genuine sizing error: when the ladder
    is exhausted the admission deadlock still raises."""
    cfg, model, params = trained["dense"]
    eng = ServeEngine(model, params, max_seq=64,
                      paged=PagedConfig(page_size=8, pool_pages=1))
    req = Request(rid=0, prompt=np.zeros(32, np.int32), max_new_tokens=32)
    with pytest.raises(OutOfPages):
        eng.serve([req], num_slots=1, chunk=4, degrade=DegradeConfig())
    _assert_pool_clean(eng)


# ---------------------------------------------------------------------------
# replica failover + re-drive
# ---------------------------------------------------------------------------

def _two_replicas(model, params, max_seq=18):
    return ReplicaServe([
        ServeEngine(model, params, max_seq=max_seq, paged=PC8),
        ServeEngine(model, params, max_seq=max_seq, paged=PC8)])


def test_replica_kill_redrives_token_identical(trained):
    cfg, model, params = trained["dense"]
    reqs = _requests(cfg)
    ref_out, _ = _two_replicas(model, params).serve(reqs, num_slots=2,
                                                    chunk=4)
    rs = _two_replicas(model, params)
    with chaos.chaos(FaultConfig.parse("replica_fault")):
        out, stats = rs.serve(reqs, num_slots=2, chunk=4,
                              failover=FailoverConfig())
    _assert_tokens_equal(out, ref_out)
    agg = stats.aggregate
    assert agg.replica_restarts == 1
    assert agg.redriven_requests > 0
    assert agg.recovery_p95_s > 0.0
    for eng in rs.engines:
        _assert_pool_clean(eng)


def test_transient_fault_retries_in_place(trained):
    cfg, model, params = trained["dense"]
    reqs = _requests(cfg)
    ref_out, _ = _two_replicas(model, params).serve(reqs, num_slots=2,
                                                    chunk=4)
    rs = _two_replicas(model, params)
    with chaos.chaos(FaultConfig.parse("replica_transient")) as inj:
        out, stats = rs.serve(reqs, num_slots=2, chunk=4,
                              failover=FailoverConfig())
    assert len(inj.log) == 2                   # both hiccups fired...
    assert stats.aggregate.replica_restarts == 0   # ...neither quarantined
    _assert_tokens_equal(out, ref_out)


def test_failover_budget_exhaustion_raises(trained):
    """The last replica standing must not quarantine silently."""
    cfg, model, params = trained["dense"]
    reqs = _requests(cfg, n=4)
    rs = _two_replicas(model, params)
    rules = (FaultRule(site="replica.dispatch", tag=0, at=(1,)),
             FaultRule(site="replica.dispatch", tag=1, at=(1,)))
    with chaos.chaos(FaultConfig(rules=rules)):
        with pytest.raises(RuntimeError, match="failover exhausted"):
            rs.serve(reqs, num_slots=2, chunk=4, failover=FailoverConfig())
    for eng in rs.engines:
        _assert_pool_clean(eng)


def test_without_failover_fault_propagates(trained):
    cfg, model, params = trained["dense"]
    reqs = _requests(cfg, n=4)
    rs = _two_replicas(model, params)
    with chaos.chaos(FaultConfig.parse("replica_fault")):
        with pytest.raises(InjectedFault):
            rs.serve(reqs, num_slots=2, chunk=4)


# ---------------------------------------------------------------------------
# graceful degradation (ewq tier ladder)
# ---------------------------------------------------------------------------

def test_degrade_ladder_is_segment_aligned(trained):
    cfg, model, params = trained["dense"]
    eng = ServeEngine(model, params, max_seq=18, paged=PC8)
    ladder = eng.degrade_ladder()
    assert len(ladder) >= 2 and ladder[0] is eng.kv_plan
    cuts = set(eng._kv_cuts())
    for plan in ladder[1:]:
        # precision constant within each parameter scan segment
        for i in range(1, len(plan.precisions)):
            if plan.precisions[i] != plan.precisions[i - 1]:
                assert i in cuts, (i, plan.precisions)
    assert all(p == "int4" for p in ladder[-1].precisions)


def test_degradation_spills_deterministically_and_agrees(trained):
    cfg, model, params = trained["dense"]
    reqs = _requests(cfg)

    def run():
        eng = ServeEngine(model, params, max_seq=18, paged=PC8)
        with chaos.chaos(FaultConfig.parse("oom", seed=0)) as inj:
            out, stats = eng.serve(reqs, num_slots=2, chunk=4,
                                   degrade=DegradeConfig())
        _assert_pool_clean(eng)
        # sequential serves on this engine restart at tier 0
        assert eng.kv_plan is eng.degrade_ladder()[0]
        return out, stats, inj.log

    ref_out, _ = ServeEngine(model, params, max_seq=18,
                             paged=PC8).serve(reqs, num_slots=2, chunk=4)
    out_a, stats_a, log_a = run()
    out_b, stats_b, log_b = run()
    assert log_a == log_b
    assert stats_a.kv_tier_steps == stats_b.kv_tier_steps
    assert stats_a.degrade_transitions == stats_b.degrade_transitions >= 1
    assert stats_a.kv_tier_steps[1] > 0        # decode ran on the int8 tier
    assert stats_a.degraded_steps > 0
    _assert_tokens_equal(out_a, out_b)
    # int8 cache noise on a trained smoke model cannot flip greedy tokens
    _assert_tokens_equal(out_a, ref_out)


def test_degradation_promotes_back_when_pressure_clears(trained):
    cfg, model, params = trained["dense"]
    reqs = _requests(cfg, n=6, arrival_every=4)
    eng = ServeEngine(model, params, max_seq=18, paged=PC8)
    degrade = DegradeConfig(cooldown=2, headroom=0.3)
    with chaos.chaos(FaultConfig.parse("oom", seed=0)):
        out, stats = eng.serve(reqs, num_slots=2, chunk=4, degrade=degrade)
    assert len(out) == len(reqs)
    assert stats.degrade_transitions >= 2      # the spill AND a promotion
    assert stats.kv_tier_steps[0] > 0          # decode ran back at tier 0
    _assert_pool_clean(eng)


def test_unpaged_engine_ignores_degrade(trained):
    cfg, model, params = trained["dense"]
    eng = ServeEngine(model, params, max_seq=18)
    assert eng.degrade_ladder() == []
    out, stats = eng.serve(_requests(cfg, n=3), num_slots=2, chunk=4,
                           degrade=DegradeConfig())
    assert len(out) == 3 and stats.degrade_transitions == 0


# ---------------------------------------------------------------------------
# compound chaos under saturation
# ---------------------------------------------------------------------------

def test_saturated_poisson_with_faults_loses_nothing(trained):
    """Kill a replica, deny admissions, and stall a tick under a Poisson
    stream that saturates both replicas: every request completes exactly
    once and every page is accounted for."""
    from repro.serving.scheduler import synthetic_stream
    cfg, model, params = trained["dense"]
    reqs = synthetic_stream(12, vocab_size=cfg.vocab_size, prompt_len=8,
                            max_new_tokens=8, arrival_rate=2.0,
                            poisson=True)
    max_seq = max(len(r.prompt) + r.max_new_tokens for r in reqs)
    rs = ReplicaServe([
        ServeEngine(model, params, max_seq=max_seq, paged=PC8),
        ServeEngine(model, params, max_seq=max_seq, paged=PC8)])
    with chaos.chaos(FaultConfig.parse("replica_fault,oom,stall", seed=0)):
        out, stats = rs.serve(reqs, num_slots=2, chunk=4,
                              failover=FailoverConfig(),
                              degrade=DegradeConfig())
    assert [o.rid for o in out] == sorted(r.rid for r in reqs)
    agg = stats.aggregate
    assert agg.replica_restarts == 1 and agg.redriven_requests > 0
    assert sum(agg.kv_tier_steps[1:]) > 0      # pressure forced a spill
    for eng in rs.engines:
        _assert_pool_clean(eng)


def test_sum_tiers_handles_ragged_histograms():
    assert _sum_tiers([(4, 2), (1,), ()]) == (5, 2)
    assert _sum_tiers([]) == ()
