"""Selection policy (paper §3.3)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, strategies as st

from repro.core.entropy import BlockEntropy
from repro.core import policy as P
from repro.core.planner import plan


def _blocks(entropies, size=1000):
    return [BlockEntropy(block_index=i, exec_index=i + 1, entropy=h,
                         num_parameters=size, per_matrix={})
            for i, h in enumerate(entropies)]


def test_threshold_decision_tiers():
    # mu = 5, sigma = sqrt(8) for [1,3,5,7,9]: T = 5 - 2.828 = 2.17
    ents = [1.0, 3.0, 5.0, 7.0, 9.0]
    p = P.decide(_blocks(ents), x_factor=1.0)
    assert abs(p.mu - 5.0) < 1e-9
    assert p.threshold < p.mu
    assert p.decisions[0].precision == "int4"     # 1.0 <= T
    assert p.decisions[1].precision == "int8"     # T < 3 <= mu
    assert p.decisions[2].precision == "int8"     # 5 == mu -> int8
    assert p.decisions[3].precision == "raw"
    assert p.decisions[4].precision == "raw"


def test_x_factor_zero_means_threshold_at_mean():
    ents = [1.0, 2.0, 3.0]
    p = P.decide(_blocks(ents), x_factor=0.0)
    assert p.threshold == p.mu


@given(st.lists(st.floats(0.1, 10.0), min_size=3, max_size=30),
       st.floats(0.0, 2.0))
def test_monotone_in_entropy(ents, x):
    """Lower-entropy blocks never get less aggressive precision."""
    p = P.decide(_blocks(ents), x_factor=x)
    order = {"int4": 0, "int8": 1, "raw": 2}
    by_h = sorted(p.decisions, key=lambda d: d.entropy)
    ranks = [order[d.precision] for d in by_h]
    assert ranks == sorted(ranks)


def test_priority_view_ascending():
    p = P.decide(_blocks([5.0, 1.0, 3.0]))
    pri = p.by_priority()
    assert [d.entropy for d in pri] == [1.0, 3.0, 5.0]


def test_bytes_accounting():
    p = P.decide_uniform(_blocks([1.0, 2.0], size=1280), "int8")
    # int8: (8 + 16/128)/8 bytes/param
    expected = 2 * 1280 * (8 + 0.125) / 8
    assert abs(p.total_bytes() - expected) < 1e-6
    assert abs(p.reduction() - (1 - expected / (2 * 1280 * 2))) < 1e-9


def test_json_roundtrip():
    p = P.decide(_blocks([1.0, 5.0, 9.0]))
    q = P.QuantPlan.from_json(p.to_json())
    assert q.precisions() == p.precisions()
    assert q.mu == p.mu and q.threshold == p.threshold


def test_planner_variants():
    import jax.numpy as jnp
    import jax
    blocks = []
    for i in range(6):
        w = jax.random.normal(jax.random.PRNGKey(i), (64, 64)) * (0.1 + i)
        blocks.append({"w": w})
    for variant in ("raw", "4bit", "8bit", "8bit-mixed", "4bit/8bit",
                    "ternary/4bit"):
        p = plan(blocks, variant=variant)
        assert len(p.decisions) == 6
    assert plan(blocks, variant="raw").counts()["raw"] == 6
    assert plan(blocks, variant="4bit").counts()["int4"] == 6
    m = plan(blocks, variant="8bit-mixed").counts()
    assert m["int8"] >= 1 and m["raw"] >= 1 and m["int4"] == 0
    t = plan(blocks, variant="ternary/4bit").counts()
    assert t["int8"] == 0


def test_promote_demote_chain():
    assert P.promote("int4") == "int8" and P.promote("int8") == "raw"
    assert P.promote("raw") == "raw"
    assert P.demote("raw") == "int8" and P.demote("int8") == "int4"
    assert P.demote("int4") == "ternary" and P.demote("ternary") == "ternary"
