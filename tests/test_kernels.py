"""Pallas kernels vs pure-jnp oracles (interpret mode) with shape sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.kernels.entropy.kernel import CHUNK, entropy_pallas
from repro.kernels.entropy.ref import entropy_ref
from repro.kernels.qmatmul.kernel import qmatmul_pallas
from repro.kernels.qmatmul.ref import qmatmul_ref
from repro.kernels.quantize.kernel import quantize_int8_pallas
from repro.kernels.quantize.ref import quantize_int8_ref
from repro.quant.quantize import quantize_int4, quantize_int8, quantize_ternary


# --------------------------------------------------------------------------
# entropy kernel
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(7,), (1024,), (CHUNK,), (CHUNK + 3,),
                                   (3 * CHUNK,), (123, 45), (256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_entropy_kernel_matches_ref(shape, dtype):
    w = (jax.random.normal(jax.random.PRNGKey(hash(shape) % 2**31), shape)
         * 0.7).astype(dtype)
    got = float(entropy_pallas(w, interpret=True))
    want = float(entropy_ref(w))
    assert abs(got - want) < 1e-3 * max(1.0, abs(want))


@given(st.integers(1, 5000), st.floats(0.01, 5.0))
@settings(max_examples=10)
def test_entropy_kernel_property(n, scale):
    w = jax.random.normal(jax.random.PRNGKey(n), (n,)) * scale
    got = float(entropy_pallas(w, interpret=True))
    want = float(entropy_ref(w))
    assert abs(got - want) < 2e-3 * max(1.0, abs(want))


# --------------------------------------------------------------------------
# qmatmul kernel
# --------------------------------------------------------------------------

QUANTIZERS = {"int8": quantize_int8, "int4": quantize_int4,
              "ternary": quantize_ternary}


@pytest.mark.parametrize("precision", ["int8", "int4", "ternary"])
@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (128, 128, 256, 128, 128, 128),
    (256, 128, 512, 128, 128, 256),
    (128, 256, 1024, 128, 128, 512),
])
@pytest.mark.parametrize("x_dtype", [jnp.float32, jnp.bfloat16])
def test_qmatmul_kernel_matches_ref(precision, m, n, k, bm, bn, bk, x_dtype):
    kx = jax.random.PRNGKey(m * 7 + n * 3 + k)
    x = (jax.random.normal(kx, (m, k)) * 0.5).astype(x_dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (n, k)) * 0.2
    q = QUANTIZERS[precision](w)
    got = qmatmul_pallas(x.astype(jnp.float32), q.data, q.scale,
                         precision=precision, bm=bm, bn=bn, bk=bk,
                         interpret=True)
    want = qmatmul_ref(x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


# --------------------------------------------------------------------------
# quantize kernel
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,bn,bk", [(128, 256, 128, 128),
                                       (256, 512, 128, 256),
                                       (512, 1024, 256, 512)])
def test_quantize_kernel_matches_ref(n, k, bn, bk):
    w = jax.random.normal(jax.random.PRNGKey(n + k), (n, k)) * 0.3
    qk, sk = quantize_int8_pallas(w, bn=bn, bk=bk, interpret=True)
    qr, sr = quantize_int8_ref(w)
    assert bool(jnp.all(qk == qr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)


def test_qmatmul_int4_halves_payload():
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 512))
    q8, q4 = quantize_int8(w), quantize_int4(w)
    assert q4.data.nbytes == q8.data.nbytes // 2
