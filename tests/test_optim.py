"""Optimizer, schedules, clipping, int8 moments."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamW, clip_by_global_norm, global_norm
from repro.optim.schedule import make_schedule, wsd


def _params():
    return {"w": jnp.ones((4, 128)) * 0.5, "b": jnp.zeros((7,))}


def test_adamw_matches_reference_update():
    opt = AdamW(learning_rate=0.1, b1=0.9, b2=0.95, eps=1e-8,
                weight_decay=0.0)
    params = {"w": jnp.array([[1.0, -2.0]])}
    grads = {"w": jnp.array([[0.5, 0.25]])}
    state = opt.init(params)
    new_p, state = opt.update(grads, state, params)
    # step 1: m = 0.1*g, v = 0.05*g^2; mhat = g, vhat = g^2
    # update = g / (|g| + eps) = sign(g)
    expected = np.array([[1.0 - 0.1, -2.0 - 0.1]])
    np.testing.assert_allclose(np.asarray(new_p["w"]), expected, rtol=1e-5)


def test_adamw_weight_decay_matrices_only():
    opt = AdamW(learning_rate=0.1, weight_decay=0.5)
    params = _params()
    grads = jax.tree.map(jnp.zeros_like, params)
    state = opt.init(params)
    new_p, _ = opt.update(grads, state, params)
    assert float(jnp.abs(new_p["w"] - params["w"]).max()) > 0  # decayed
    np.testing.assert_allclose(np.asarray(new_p["b"]),
                               np.asarray(params["b"]))  # vectors skip decay


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_moment_dtypes_converge_quadratic(dtype):
    """min ||w||^2 converges under all moment encodings."""
    opt = AdamW(learning_rate=0.05, weight_decay=0.0, moment_dtype=dtype)
    params = {"w": jnp.ones((2, 128))}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss(params)) < 1e-2, dtype


def test_int8_moments_memory():
    from repro.quant.qtypes import QTensor
    opt = AdamW(learning_rate=0.1, moment_dtype="int8")
    state = opt.init({"w": jnp.ones((4, 256))})
    assert isinstance(state.m["w"], QTensor)
    assert state.m["w"].data.dtype == jnp.int8


def test_clip_by_global_norm():
    grads = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert abs(float(norm) - 3.0 * np.sqrt(10)) < 1e-4
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4
    # below the limit -> untouched
    clipped2, _ = clip_by_global_norm(grads, 100.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]),
                               np.asarray(grads["a"]))


def test_wsd_schedule_shape():
    lr = lambda s: float(wsd(s, base_lr=1.0, warmup_steps=10,
                             total_steps=100, decay_frac=0.1))
    assert lr(0) == 0.0
    assert abs(lr(5) - 0.5) < 1e-6        # warmup
    assert abs(lr(50) - 1.0) < 1e-6       # stable plateau
    assert abs(lr(89) - 1.0) < 1e-6       # still stable
    assert lr(95) < 0.5                   # decaying
    assert lr(100) <= 0.011               # final_frac


def test_cosine_schedule_monotone_decay():
    sched = make_schedule("cosine", base_lr=1.0, warmup_steps=5,
                          total_steps=50)
    vals = [float(sched(s)) for s in range(5, 50, 5)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


def test_grad_compression_roundtrip():
    """int8 EF quantize/dequantize error bounded by group absmax/127."""
    from repro.optim.compress import _dequant_leaf, _quant_leaf
    g = jax.random.normal(jax.random.PRNGKey(0), (37, 13)) * 0.1
    q, scale, n = _quant_leaf(g, group=64)
    back = _dequant_leaf(q, scale, n, g.shape)
    err = float(jnp.max(jnp.abs(back - g)))
    assert err <= float(scale.max()) * 0.5 + 1e-7
