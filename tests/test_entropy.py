"""Entropy analysis (paper §3.1-3.2): correctness + properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests skip cleanly without it
from hypothesis import given, strategies as st

from repro.core import entropy as E


def test_paper_formula_uniform_weights():
    # constant weights -> softmax uniform -> H = -sum 1/n log(1/n + eps)
    n = 1000
    w = jnp.zeros((n,))
    h = float(E.matrix_entropy(w, mode="paper", eps=0.01))
    expected = -n * (1 / n) * np.log(1 / n + 0.01)
    assert abs(h - expected) < 1e-4


def test_stream_matches_paper_at_small_eps():
    w = jax.random.normal(jax.random.PRNGKey(0), (257, 129)) * 0.5
    h_paper = float(E.matrix_entropy(w, mode="paper", eps=1e-8))
    h_stream = float(E.matrix_entropy(w, mode="stream"))
    assert abs(h_paper - h_stream) < 1e-3


def test_stream_shift_invariance():
    # softmax entropy is invariant to adding a constant
    w = jax.random.normal(jax.random.PRNGKey(1), (513,))
    h0 = float(E.matrix_entropy_stream(w))
    h1 = float(E.matrix_entropy_stream(w + 100.0))
    assert abs(h0 - h1) < 1e-3


def test_peaked_distribution_low_entropy():
    w = jnp.zeros((1024,)).at[0].set(50.0)
    h = float(E.matrix_entropy(w, mode="stream"))
    assert h < 0.01  # one dominant weight -> near-zero entropy
    h_uniform = float(E.matrix_entropy(jnp.zeros((1024,)), mode="stream"))
    assert h_uniform > 6.9  # log(1024) = 6.93


@given(st.integers(2, 2000), st.floats(0.01, 3.0))
def test_entropy_bounds(n, scale):
    w = jax.random.normal(jax.random.PRNGKey(n), (n,)) * scale
    h = float(E.matrix_entropy_stream(w))
    assert -1e-4 <= h <= np.log(n) + 1e-3


def test_block_entropy_weighted_mean():
    a = jnp.zeros((64, 64))            # uniform -> high entropy
    b = jnp.zeros((32, 32)).at[0, 0].set(100.0)  # peaked -> low
    h, n, per = E.block_entropy_from_matrices({"a": a, "b": b}, mode="stream")
    ha, na = per["a"]
    hb, nb = per["b"]
    assert n == 64 * 64 + 32 * 32
    assert abs(h - (ha * na + hb * nb) / n) < 1e-6
    # vectors excluded
    h2, n2, per2 = E.block_entropy_from_matrices(
        {"a": a, "bias": jnp.zeros((128,))}, mode="stream")
    assert "bias" not in per2 and n2 == 64 * 64


def test_analyze_blocks_exec_index():
    blocks = [{"w": jnp.ones((16, 16)) * i} for i in range(3)]
    out = E.analyze_blocks(blocks, first_exec_index=2)
    assert [b.exec_index for b in out] == [2, 3, 4]
    assert [b.block_index for b in out] == [0, 1, 2]


def test_entropy_stats_population_std():
    mu, sigma = E.entropy_stats([1.0, 2.0, 3.0])
    assert abs(mu - 2.0) < 1e-9
    assert abs(sigma - np.sqrt(2.0 / 3.0)) < 1e-9
