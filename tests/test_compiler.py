"""Family-universal plan compiler: segmented mixed-precision execution.

Covers the compiler contract (docs/DESIGN.md §8):
  * mixed "4bit/8bit"-style plans on hybrid and enc-dec yield QUANTIZED
    (QTensor-bearing) segmented stacks — regression for the old silent raw
    fallback — with logits matching the per-block ``apply_plan_blocks``
    reference within quantization tolerance;
  * compile -> save -> restore -> serve produces identical outputs to the
    in-memory plan, including int4-packed and ternary segments;
  * explicit qdot backends (grouped/simple) agree with the ref oracle.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels.qmatmul.ops import get_qdot_backend, qdot, set_qdot_backend
from repro.kernels.qmatmul.ref import qmatmul_ref
from repro.models.model import build
from repro.quant.apply import (SegmentedParams, apply_plan_blocks,
                               plan_segments, tree_nbytes)
from repro.quant.compiler import (compile_plan, family_layout, load_artifact,
                                  plan_length, save_artifact)
from repro.quant.qtypes import QTensor
from repro.quant.quantize import dequantize, quantize
from repro.serving.engine import ServeEngine
from repro.serving.quantized import (apply_plan_to_params, explicit_plan,
                                     fastewq_metadata_plan)

KEY = jax.random.PRNGKey(0)


def _model(arch, **over):
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32",
                              **over)
    model = build(cfg)
    return cfg, model, model.init(KEY)


def _batch(cfg, b=2, s=16):
    from repro.data.synthetic import synthetic_batch
    return synthetic_batch(cfg, batch=b, seq=s, step=0)


def _dequant_tree(tree):
    """Replace every QTensor with its dequantized weight, carried through
    bf16 exactly like qdot's simple backend so the reference and the
    compiled path see numerically identical weights."""
    return jax.tree.map(
        lambda x: (dequantize(x, jnp.bfloat16).astype(jnp.float32)
                   if isinstance(x, QTensor) else x),
        tree, is_leaf=lambda x: isinstance(x, QTensor))


def _restack(blocks):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


def _blockwise_reference(model, params, plan):
    """The per-block reference: quantize each block independently
    (apply_plan_blocks), dequantize, and restack into the raw layout."""
    cfg = model.cfg
    deq = [_dequant_tree(b)
           for b in apply_plan_blocks(model.block_params(params), plan)]
    new = dict(params)
    new["embed"] = deq[0]
    if cfg.family == "encdec":
        ne = cfg.num_encoder_layers
        new["enc_layers"] = _restack(deq[1:1 + ne])
        new["dec_layers"] = _restack(deq[1 + ne:1 + ne + cfg.num_layers])
    else:
        new["layers"] = _restack(deq[1:1 + cfg.num_layers])
        if cfg.family == "hybrid":
            new["shared"] = deq[-1]
    return new


def _stack_qtensors(params, keys):
    return [leaf for k in keys for leaf in
            jax.tree.leaves(params[k], is_leaf=lambda x: isinstance(x, QTensor))
            if isinstance(leaf, QTensor)]


# ---------------------------------------------------------------------------
# segmentation with forced cuts
# ---------------------------------------------------------------------------

def test_plan_segments_with_cuts():
    from repro.core.policy import BlockDecision, QuantPlan
    ds = [BlockDecision(block_index=i, exec_index=i + 1, entropy=0.0,
                        num_parameters=0, precision=p)
          for i, p in enumerate(["int8", "int8", "int8", "int4", "raw",
                                 "raw"])]
    plan = QuantPlan(decisions=ds, mu=0, sigma=0, threshold=0, x_factor=1)
    assert plan_segments(plan, cuts=(2, 4)) == [
        ("int8", 0, 2), ("int8", 2, 3), ("int4", 3, 4), ("raw", 4, 6)]
    # no cuts: unchanged behaviour
    assert plan_segments(plan) == [("int8", 0, 3), ("int4", 3, 4),
                                   ("raw", 4, 6)]


def test_family_layout_covers_all_families():
    for arch in ("llama3.2-3b", "grok-1-314b", "mamba2-780m", "zamba2-2.7b",
                 "whisper-medium"):
        cfg = get_config(arch, smoke=True)
        stacks, extras = family_layout(cfg)
        n = plan_length(cfg)
        covered = set()
        for s in stacks:
            covered |= set(range(s.lo, s.hi))
        covered |= {e.index for e in extras}
        assert covered == set(range(n)), arch
        assert len(fastewq_metadata_plan(cfg).decisions) == n, arch


# ---------------------------------------------------------------------------
# mixed-plan parity vs the blockwise reference (regression: no raw fallback)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,stack_keys", [
    ("zamba2-2.7b", ("layers",)),
    ("whisper-medium", ("enc_layers", "dec_layers")),
])
def test_mixed_plan_parity_and_no_raw_fallback(arch, stack_keys):
    cfg, model, params = _model(arch)
    n = cfg.num_layers + (cfg.num_encoder_layers or 0)
    precs = (["int8", "int4", "raw", "int8"] * n)[:n]
    plan = explicit_plan(cfg, precs, shared_precision="int8")

    pq = apply_plan_to_params(model, params, plan)
    for key in stack_keys:
        assert isinstance(pq[key], SegmentedParams), key
        assert len(pq[key].segments) > 1  # genuinely mixed
    qts = _stack_qtensors(pq, stack_keys)
    assert qts, "mixed plan must quantize layer stacks (old fallback bug)"
    assert {q.precision for q in qts} >= {"int8", "int4"}

    batch = _batch(cfg)
    logits_q, _ = model.apply(pq, batch, remat=False)
    ref = _blockwise_reference(model, params, plan)
    logits_ref, _ = model.apply(ref, batch, remat=False)
    err = float(jnp.max(jnp.abs(logits_q - logits_ref)))
    scale = float(jnp.max(jnp.abs(logits_ref))) + 1e-6
    assert err / scale < 2e-3, f"{arch}: rel err {err/scale}"


@pytest.mark.parametrize("arch", ["zamba2-2.7b", "whisper-medium"])
def test_mixed_plan_weight_bytes_shrink(arch):
    """weight_bytes() must strictly shrink vs raw for the two families the
    old code silently served raw under mixed plans."""
    cfg, model, params = _model(arch)
    n = cfg.num_layers + (cfg.num_encoder_layers or 0)
    plan = explicit_plan(cfg, (["int4", "int8"] * n)[:n],
                         shared_precision="int8")
    raw_engine = ServeEngine(model, params, max_seq=24)
    q_engine = ServeEngine(model, params, max_seq=24, plan=plan)
    assert q_engine.weight_bytes() < 0.7 * raw_engine.weight_bytes()


@pytest.mark.parametrize("arch", ["zamba2-2.7b", "whisper-medium"])
def test_mixed_plan_decode_matches_forward(arch):
    """Segmented cached decode == segmented teacher-forced forward on the
    SAME compiled params (validates the per-unit / per-segment cache
    slicing in the decode paths)."""
    cfg, model, params = _model(arch)
    n = cfg.num_layers + (cfg.num_encoder_layers or 0)
    plan = explicit_plan(cfg, (["raw", "int8", "int4", "int8"] * n)[:n],
                         shared_precision="int8")
    pq = apply_plan_to_params(model, params, plan)
    b, s = 2, 12
    batch = _batch(cfg, b, s)
    logits_tf, _ = model.apply(pq, batch, remat=False)
    cache = model.init_cache(b, s)
    if cfg.family == "encdec":
        from repro.models import encdec
        enc_out = encdec.encode(pq, batch["frames"], cfg, remat=False)
        ck, cv = encdec.precompute_cross_kv(pq, enc_out, cfg)
        cache = cache._replace(cross_k=ck, cross_v=cv)
    outs = []
    for t in range(s):
        lg, cache = model.decode_step(pq, cache, batch["tokens"][:, t:t + 1])
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(logits_tf - logits_dec)))
    scale = float(jnp.max(jnp.abs(logits_tf))) + 1e-6
    assert err / scale < 5e-5, f"{arch}: rel err {err/scale}"


def test_hybrid_segments_respect_unit_boundaries():
    cfg, model, params = _model("zamba2-2.7b")  # 4 layers, period 2
    plan = explicit_plan(cfg, ["int8", "int8", "int8", "int4"],
                         shared_precision="int8")
    compiled = compile_plan(model, params, plan)
    segs = [(s.precision, s.start, s.stop)
            for s in compiled.params["layers"].segments]
    assert segs == [("int8", 0, 2), ("int8", 2, 3), ("int4", 3, 4)]
    p = cfg.shared_attn_period
    for _, start, stop in segs:
        assert start // p == (stop - 1) // p  # within one unit


# ---------------------------------------------------------------------------
# artifact round-trip: compile -> save -> restore -> serve
# ---------------------------------------------------------------------------

def test_artifact_roundtrip_dense_all_precisions(tmp_path):
    cfg, model, params = _model("llama3.2-3b", num_layers=4)
    plan = explicit_plan(cfg, ["ternary", "int4", "int8", "raw"])
    compiled = compile_plan(model, params, plan)
    save_artifact(str(tmp_path), compiled)
    loaded = load_artifact(str(tmp_path), model)
    assert (tmp_path / "plan_manifest.json").exists()
    assert loaded.plan.precisions() == plan.precisions()
    assert loaded.nbytes_effective() == compiled.nbytes_effective()
    precisions = {s.precision for s in loaded.params["layers"].segments}
    assert precisions == {"ternary", "int4", "int8", "raw"}
    batch = _batch(cfg)
    l1, _ = model.apply(compiled.params, batch, remat=False)
    l2, _ = model.apply(loaded.params, batch, remat=False)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


@pytest.mark.parametrize("arch", ["zamba2-2.7b", "whisper-medium"])
def test_artifact_serve_matches_in_memory(arch, tmp_path):
    """Engine booted from the artifact generates token-identical output to
    the engine holding the in-memory compiled plan."""
    cfg, model, params = _model(arch)
    n = cfg.num_layers + (cfg.num_encoder_layers or 0)
    plan = explicit_plan(cfg, (["int4", "ternary", "int8", "raw"] * n)[:n],
                         shared_precision="int8")
    compiled = compile_plan(model, params, plan)
    save_artifact(str(tmp_path), compiled)

    mem = ServeEngine(model, compiled.params, max_seq=20)
    art = ServeEngine.from_artifact(model, str(tmp_path), max_seq=20)
    assert art.plan is not None and art.plan.precisions() == plan.precisions()
    assert art.weight_bytes() == mem.weight_bytes()

    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    out_mem = mem.generate(prompts, 6)
    out_art = art.generate(prompts, 6)
    np.testing.assert_array_equal(np.asarray(out_mem.tokens),
                                  np.asarray(out_art.tokens))
    np.testing.assert_allclose(np.asarray(out_mem.logprobs),
                               np.asarray(out_art.logprobs), atol=1e-5)


def test_artifact_rejects_wrong_model(tmp_path):
    cfg, model, params = _model("llama3.2-3b", num_layers=4)
    plan = explicit_plan(cfg, ["int8"] * 4)
    save_artifact(str(tmp_path), compile_plan(model, params, plan))
    _, other, _ = _model("mamba2-780m")
    with pytest.raises(ValueError, match="compiled for"):
        load_artifact(str(tmp_path), other)


def test_artifact_rejects_layer_count_mismatch(tmp_path):
    """Same config name, different depth: the manifest validator must name
    the plan-length mismatch up front instead of failing deep inside the
    restore shape checks."""
    cfg, model, params = _model("llama3.2-3b", num_layers=4)
    save_artifact(str(tmp_path),
                  compile_plan(model, params, explicit_plan(cfg, ["int8"] * 4)))
    from repro.models.model import build
    deeper = build(dataclasses.replace(cfg, num_layers=6))
    with pytest.raises(ValueError, match="block decisions"):
        load_artifact(str(tmp_path), deeper)


def test_artifact_rejects_tampered_group(tmp_path):
    """A manifest group that quantizes different leaves than the save-time
    compile is rejected with a named leaf-kind ValueError, not a
    stack-trace deep inside restore."""
    import json as _json
    cfg, model, params = _model("llama3.2-3b", num_layers=2)
    save_artifact(str(tmp_path),
                  compile_plan(model, params, explicit_plan(cfg, ["int8"] * 2)))
    mpath = tmp_path / "plan_manifest.json"
    manifest = _json.loads(mpath.read_text())
    manifest["group"] = 100   # divides nothing: skeleton stays raw
    mpath.write_text(_json.dumps(manifest))
    with pytest.raises(ValueError, match="group/plan mismatch"):
        load_artifact(str(tmp_path), model)
    manifest["group"] = 0
    mpath.write_text(_json.dumps(manifest))
    with pytest.raises(ValueError, match="positive integer"):
        load_artifact(str(tmp_path), model)


def test_artifact_roundtrip_with_non_dividing_group(tmp_path):
    """A group that skips some leaves (quantization passes them through
    raw) must still round-trip — validation rejects only genuine
    mismatches, not unusual-but-self-consistent artifacts."""
    cfg, model, params = _model("llama3.2-3b", num_layers=2)
    compiled = compile_plan(model, params, explicit_plan(cfg, ["int8"] * 2),
                            group=33)
    save_artifact(str(tmp_path), compiled)
    loaded = load_artifact(str(tmp_path), model)
    assert loaded.nbytes_effective() == compiled.nbytes_effective()


def test_artifact_records_save_mesh():
    """save_artifact(mesh=...) stamps the save-time layout; artifacts stay
    mesh-portable (restorable without any mesh)."""
    import json as _json
    import tempfile
    from repro.checkpoint.ckpt import load_artifact_manifest
    from repro.launch.mesh import make_mesh
    cfg, model, params = _model("llama3.2-3b", num_layers=2)
    compiled = compile_plan(model, params, explicit_plan(cfg, ["int8", "raw"]))
    d = tempfile.mkdtemp()
    save_artifact(d, compiled, mesh=make_mesh((1, 1), ("data", "model")))
    manifest = load_artifact_manifest(d)
    assert manifest["saved_mesh"] == {"axis_names": ["data", "model"],
                                      "shape": [1, 1]}
    loaded = load_artifact(d, model)   # no mesh: plain single-device boot
    assert loaded.plan.precisions() == ["raw", "int8", "raw"]


# ---------------------------------------------------------------------------
# qdot backend selector (satellite: _dequant_fused wired in, validated)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", ["int8", "int4", "ternary"])
def test_qdot_backends_match_ref(precision):
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 256))
    w = quantize(jax.random.normal(jax.random.PRNGKey(2), (32, 256)),
                 precision)
    ref = np.asarray(qmatmul_ref(x, w))
    for backend in ("grouped", "simple"):
        y = np.asarray(qdot(x, w, out_dtype=jnp.float32, backend=backend))
        np.testing.assert_allclose(y, ref, rtol=2e-2, atol=2e-2 * np.abs(
            ref).max())


def test_qdot_backend_selection_and_errors():
    assert get_qdot_backend() == "auto"
    with pytest.raises(ValueError):
        set_qdot_backend("nope")
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256))
    w = quantize(jax.random.normal(jax.random.PRNGKey(2), (16, 256)), "int8")
    with pytest.raises(ValueError):
        qdot(x, w, backend="not-a-backend")
    if jax.default_backend() != "tpu":
        with pytest.raises(ValueError):  # forced pallas off-TPU: loud, not silent
            qdot(x, w, backend="pallas")
    set_qdot_backend("grouped")
    try:
        y = np.asarray(qdot(x, w, out_dtype=jnp.float32))
        np.testing.assert_allclose(y, np.asarray(qmatmul_ref(x, w)),
                                   rtol=2e-2, atol=1e-2)
    finally:
        set_qdot_backend("auto")
