"""Deterministic sharded data pipeline."""

import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.synthetic import DataLoader, synthetic_batch, synthetic_tokens


def test_determinism():
    a = synthetic_tokens(batch=8, seq=32, vocab=100, step=3, seed=1)
    b = synthetic_tokens(batch=8, seq=32, vocab=100, step=3, seed=1)
    np.testing.assert_array_equal(a, b)
    c = synthetic_tokens(batch=8, seq=32, vocab=100, step=4, seed=1)
    assert not np.array_equal(a, c)


def test_shards_partition_global_stream():
    full = synthetic_tokens(batch=8, seq=16, vocab=50, step=2, seed=0)
    parts = [synthetic_tokens(batch=8, seq=16, vocab=50, step=2, seed=0,
                              shard=i, num_shards=4) for i in range(4)]
    np.testing.assert_array_equal(full, np.concatenate(parts, axis=0))


def test_tokens_in_vocab_and_learnable():
    toks = synthetic_tokens(batch=4, seq=256, vocab=97, step=0, seed=0)
    assert toks.min() >= 0 and toks.max() < 97
    # learnable: successor entropy per token is limited (4 branches)
    succ = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    avg_branch = np.mean([len(v) for v in succ.values()])
    assert avg_branch <= 8


def test_loader_state_resume():
    cfg = get_config("olmo-1b", smoke=True)
    l1 = DataLoader(cfg, global_batch=4, seq=16, seed=0)
    batches = [next(l1) for _ in range(5)]
    state = l1.state()
    l2 = DataLoader(cfg, global_batch=4, seq=16, seed=0)
    l2.restore(state)
    np.testing.assert_array_equal(np.asarray(next(l1)["tokens"]),
                                  np.asarray(next(l2)["tokens"]))


def test_encdec_batch_has_frames():
    cfg = get_config("whisper-medium", smoke=True)
    b = synthetic_batch(cfg, batch=2, seq=16, step=0)
    assert b["frames"].shape == (2, cfg.encoder_seq, cfg.d_model)
