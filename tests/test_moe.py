"""MoE layer: gather dispatch vs dense reference, capacity semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import capacity_of, init_moe_params, moe_block


def _dense_reference(p, x, num_experts, top_k):
    """All-experts reference: every token through every expert, gate-sum."""
    b, s, d = x.shape
    xt = x.reshape(-1, d).astype(jnp.float32)
    logits = xt @ np.asarray(p["router"], np.float32).T
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    outs = []
    for e in range(num_experts):
        g = xt @ p["w_gate"][e].astype(jnp.float32).T
        u = xt @ p["w_up"][e].astype(jnp.float32).T
        h = jax.nn.silu(g) * u
        outs.append(h @ p["w_down"][e].astype(jnp.float32).T)
    outs = jnp.stack(outs, axis=1)  # (T, E, D)
    y = jnp.zeros_like(xt)
    for k in range(top_k):
        y += gate[:, k:k + 1] * jnp.take_along_axis(
            outs, idx[:, k][:, None, None], axis=1)[:, 0]
    return y.reshape(b, s, d)


def test_moe_matches_dense_reference_when_no_drops():
    e, k, d, f = 4, 2, 32, 64
    key = jax.random.PRNGKey(0)
    p = init_moe_params(key, d, f, e, 1, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d), jnp.float32)
    # capacity_factor big enough that nothing ever drops
    y, aux = moe_block(p, x, num_experts=e, top_k=k, capacity_factor=8.0)
    y_ref = _dense_reference(p, x, e, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux["moe_aux_loss"]) >= 1.0  # >= 1 by Cauchy-Schwarz


def test_capacity_drops_bounded():
    """With tiny capacity, output is a gated subset — no NaN, norm bounded."""
    e, k, d, f = 4, 2, 16, 32
    p = init_moe_params(jax.random.PRNGKey(0), d, f, e, 1, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, d), jnp.float32)
    y_full, _ = moe_block(p, x, num_experts=e, top_k=k, capacity_factor=8.0)
    y_tiny, _ = moe_block(p, x, num_experts=e, top_k=k, capacity_factor=0.25)
    assert not bool(jnp.isnan(y_tiny).any())
    assert float(jnp.linalg.norm(y_tiny)) <= float(jnp.linalg.norm(y_full)) * 1.5


def test_capacity_of_rounds_up():
    assert capacity_of(64, 4, 2, 1.0) == 32
    assert capacity_of(64, 4, 2, 1.25) == 40
    assert capacity_of(3, 4, 1, 1.0) == 8  # floor of 8


def test_grad_flows_through_dispatch():
    e, k, d, f = 4, 2, 16, 32
    p = init_moe_params(jax.random.PRNGKey(0), d, f, e, 1, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, d), jnp.float32)

    def loss(p):
        y, aux = moe_block(p, x, num_experts=e, top_k=k)
        return jnp.sum(y ** 2) + 0.01 * aux["moe_aux_loss"]

    g = jax.grad(loss)(p)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    # every expert weight received gradient (all experts active at cf=1.25)
    assert float(jnp.abs(g["w_gate"]).sum(axis=(1, 2)).min()) > 0
