"""Entropy-weighted quantized KV cache + fused decode attention
(docs/DESIGN.md §10).

Kernel-level: the ``grouped`` (chunked online-softmax) and ``simple``
fallbacks and the Pallas kernel (interpret mode) must match the dense
ref.py oracle for bf16 / int8 / int4 caches, scalar and per-slot (B,)
positions, GQA ``rep > 1``, and chunk widths that don't divide the cache.

Engine-level: ``serve()`` under ``kv_precision="int8"`` must emit the SAME
greedy tokens as the bf16 cache (logprobs within 1e-2) on all four
families, with KV bytes/slot reduced >= 1.9x; the kv_plan round-trips
through compiled artifacts.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.planner import plan_kv
from repro.kernels.decode_attn.kernel import decode_attn_pallas
from repro.kernels.decode_attn.ops import decode_attention
from repro.kernels.decode_attn.ref import decode_attn_ref
from repro.models.model import build
from repro.quant.kvcache import (KVPlan, dequantize_kv, is_kv_page,
                                 make_page, quantize_cache_field)
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import Request

FAMILY_ARCHS = (("dense", "llama3.2-3b"), ("ssm", "mamba2-780m"),
                ("hybrid", "zamba2-2.7b"), ("encdec", "whisper-medium"))


def _qkv(seed, b, s, hkv, rep, hd):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, hkv * rep, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, hd)) * 0.5
    v = jax.random.normal(ks[2], (b, s, hkv, hd)) * 0.5
    return q, k, v


# ---------------------------------------------------------------------------
# backend parity vs the dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", ["bf16", "int8", "int4"])
@pytest.mark.parametrize("hkv,rep,hd", [(2, 3, 32), (4, 1, 32), (1, 4, 64)])
@pytest.mark.parametrize("vec_pos", [False, True])
def test_fallbacks_match_ref(precision, hkv, rep, hd, vec_pos):
    b, s = 3, 40
    q, k, v = _qkv(hkv * 11 + rep, b, s, hkv, rep, hd)
    kp, vp = make_page(k, precision, 32), make_page(v, precision, 32)
    valid = (jnp.array([5, 40, 13], jnp.int32) if vec_pos
             else jnp.int32(17))
    # oracle runs on the dequantized pages: backends must match its MATH
    # exactly; quantization error is not part of this comparison
    ref = decode_attn_ref(q, dequantize_kv(kp), dequantize_kv(vp), valid)
    for backend in ("simple", "grouped"):
        got = decode_attention(q, kp, vp, valid_len=valid, backend=backend,
                               kv_chunk=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)
    # chunk width not dividing S: the final chunk is read with a clamped
    # start and re-visited rows masked — still O(chunk) temps, same math
    got = decode_attention(q, kp, vp, valid_len=valid, backend="grouped",
                           kv_chunk=7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("precision", ["bf16", "int8", "int4"])
@pytest.mark.parametrize("s,kv_chunk", [(32, 8), (40, 16)])
def test_pallas_kernel_matches_ref_interpret(precision, s, kv_chunk):
    b, hkv, rep, hd = 2, 2, 3, 32
    q, k, v = _qkv(5, b, s, hkv, rep, hd)
    kp, vp = make_page(k, precision, 32), make_page(v, precision, 32)
    valid = jnp.array([9, s], jnp.int32)
    ref = decode_attn_ref(q, dequantize_kv(kp), dequantize_kv(vp), valid)

    def flat(p):
        data = p.data.reshape(b, s, -1)
        scale = (jnp.ones((b, s, 1), jnp.bfloat16) if p.scale is None
                 else p.scale)
        return data, scale

    kd, ks = flat(kp)
    vd, vs = flat(vp)
    got = decode_attn_pallas(
        q.reshape(b, hkv, rep, 1, hd), kd, ks, vd, vs, valid[:, None],
        precision=precision, group=kp.group, head_dim=hd, kv_chunk=kv_chunk,
        interpret=True)
    np.testing.assert_allclose(np.asarray(got).reshape(ref.shape),
                               np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_pallas_backend_raises_off_tpu():
    if jax.default_backend() == "tpu":
        pytest.skip("pallas backend is legal on TPU")
    q, k, v = _qkv(0, 1, 8, 2, 2, 32)
    with pytest.raises(ValueError, match="pallas"):
        decode_attention(q, k, v, backend="pallas")


def test_raw_cache_and_padded_head_shapes():
    """Raw bf16 arrays route through the same fused math, including the
    flat-q-head layout (rep=1 with padded head counts) that
    ``_flatten_gqa_for_sharding`` produces under TP."""
    b, s, hd = 2, 24, 32
    # rep=1, 6 heads (a padded-to-8 variant changes only the head count)
    for h in (6, 8):
        q, k, v = _qkv(h, b, s, h, 1, hd)
        valid = jnp.array([4, 21], jnp.int32)
        ref = decode_attn_ref(q, k.astype(jnp.float32),
                              v.astype(jnp.float32), valid)
        got = decode_attention(q, k, v, valid_len=valid, backend="grouped",
                               kv_chunk=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# page plumbing
# ---------------------------------------------------------------------------

def test_kv_quantize_roundtrip_error_bounds():
    k = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 2, 32))
    absmax = float(jnp.abs(k).max())
    # int8: absmax/254 rounding + bf16 scale rounding; int4: absmax/14
    for precision, tol in (("int8", absmax / 200), ("int4", absmax / 11)):
        page = make_page(k, precision, 64)
        err = float(jnp.abs(dequantize_kv(page) - k).max())
        assert err < tol, (precision, err)
    # int4 payload is genuinely half of int8
    assert make_page(k, "int4", 64).data.nbytes \
        == make_page(k, "int8", 64).data.nbytes // 2


def test_mixed_plan_pages_cut_at_segment_boundaries():
    raw = jnp.zeros((6, 2, 8, 2, 32), jnp.bfloat16)
    plan = KVPlan(precisions=("int8",) * 4 + ("bf16",) * 2, group=64)
    pages = quantize_cache_field(raw, plan, cuts=(2,))
    assert isinstance(pages, tuple) and len(pages) == 3
    assert [p.precision for p in pages] == ["int8", "int8", "bf16"]
    assert [p.data.shape[0] for p in pages] == [2, 2, 2]
    assert pages[2].scale is None
    assert is_kv_page(pages)
    # uniform plan, no cuts -> a single bare page
    uni = quantize_cache_field(raw, KVPlan(precisions=("int8",) * 6))
    assert is_kv_page(uni) and not isinstance(uni, tuple)


def test_plan_kv_entropy_mapping():
    from repro.serving.quantized import explicit_plan
    cfg = dataclasses.replace(get_config("llama3.2-3b", smoke=True),
                              num_layers=4)
    wplan = explicit_plan(cfg, ["int4", "int8", "raw", "int8"])
    kv = plan_kv(cfg, wplan, kv_precision="auto")
    assert kv.precisions == ("int4", "int8", "bf16", "int8")
    assert plan_kv(cfg, None, kv_precision="int8").precisions == ("int8",) * 4
    assert plan_kv(cfg, None, kv_precision="bf16") is None
    with pytest.raises(ValueError):
        plan_kv(cfg, None, kv_precision="auto")


# ---------------------------------------------------------------------------
# engine-level parity: int8 KV cache vs bf16, all four families
# ---------------------------------------------------------------------------

def _requests(cfg, n=3, prompt_len=6, max_new=6):
    return [Request(rid=i, prompt=np.asarray(jax.random.randint(
        jax.random.PRNGKey(10 + i), (prompt_len,), 0, cfg.vocab_size,
        dtype=jnp.int32)), max_new_tokens=max_new) for i in range(n)]


@pytest.mark.parametrize("family", [f for f, _ in FAMILY_ARCHS])
def test_serve_int8_kv_matches_bf16_cache(trained, family):
    cfg, model, params = trained[family]
    reqs = _requests(cfg)
    ref = ServeEngine(model, params, max_seq=24)
    q8 = ServeEngine(model, params, max_seq=24, kv_precision="int8")
    outs_ref, _ = ref.serve(reqs, num_slots=2, chunk=4)
    outs_q8, _ = q8.serve(reqs, num_slots=2, chunk=4)
    for a, b in zip(outs_q8, outs_ref):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_allclose(a.logprobs, b.logprobs, atol=1e-2)
    if family == "ssm":           # attention-free: the knob is a no-op
        assert q8.kv_bytes_per_slot() == 0.0
    else:
        # f32 smoke cache -> >= 3.8x; (bf16 serving dtype -> >= 1.9x)
        ratio = ref.kv_bytes_per_slot() / q8.kv_bytes_per_slot()
        assert ratio >= 3.8, (family, ratio)


def test_generate_int8_kv_matches_bf16_cache(trained):
    """The generate() path (prefill cache quantized wholesale, vector pos)
    agrees too, and kv bytes at bf16 serving dtype shrink >= 1.9x."""
    cfg, model, params = trained["dense"]
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    ref = ServeEngine(model, params, max_seq=24)
    q8 = ServeEngine(model, params, max_seq=24, kv_precision="int8")
    o_ref = ref.generate(prompts, 8, chunk=3)
    o_q8 = q8.generate(prompts, 8, chunk=3)
    np.testing.assert_array_equal(np.asarray(o_ref.tokens),
                                  np.asarray(o_q8.tokens))
    np.testing.assert_allclose(np.asarray(o_ref.logprobs),
                               np.asarray(o_q8.logprobs), atol=1e-2)
    bf16_cfg = dataclasses.replace(cfg, dtype="bfloat16")
    bmodel = build(bf16_cfg)
    bref = ServeEngine(bmodel, params, max_seq=24)
    bq8 = ServeEngine(bmodel, params, max_seq=24, kv_precision="int8")
    assert bref.kv_bytes_per_slot() / bq8.kv_bytes_per_slot() >= 1.9


def test_int4_kv_cache_serves_and_shrinks(trained):
    cfg, model, params = trained["dense"]
    reqs = _requests(cfg, n=2)
    ref = ServeEngine(model, params, max_seq=24)
    q4 = ServeEngine(model, params, max_seq=24, kv_precision="int4")
    outs_ref, _ = ref.serve(reqs, num_slots=2, chunk=4)
    outs_q4, _ = q4.serve(reqs, num_slots=2, chunk=4)
    agree = np.mean([float(np.mean(np.asarray(a.tokens) ==
                                   np.asarray(b.tokens)))
                     for a, b in zip(outs_q4, outs_ref)])
    assert agree >= 0.75, agree   # int4 is lossier; most tokens still agree
    assert ref.kv_bytes_per_slot() / q4.kv_bytes_per_slot() >= 7.0


def test_auto_kv_with_mixed_weight_plan(trained):
    """Entropy-derived per-layer KV precisions ride a segmented weight
    plan: pages align with the weight segments and serving stays coherent
    with the same quantized weights on a bf16 cache."""
    from repro.serving.quantized import explicit_plan
    cfg, model, params = trained["dense"]
    wplan = explicit_plan(cfg, ["int4", "int8"])
    reqs = _requests(cfg, n=2)
    ref = ServeEngine(model, params, max_seq=24, plan=wplan)
    auto = ServeEngine(model, params, max_seq=24, plan=wplan,
                       kv_precision="auto")
    assert auto.kv_plan.precisions == ("int4", "int8")
    outs_ref, _ = ref.serve(reqs, num_slots=2, chunk=4)
    outs_auto, _ = auto.serve(reqs, num_slots=2, chunk=4)
    for a, b in zip(outs_auto, outs_ref):
        same = np.asarray(a.tokens) == np.asarray(b.tokens)
        assert same.mean() >= 0.75
        np.testing.assert_allclose(a.logprobs[same[len(reqs[0].prompt):]],
                                   b.logprobs[same[len(reqs[0].prompt):]],
                                   atol=0.3)


def test_kv_plan_roundtrips_through_artifact(trained, tmp_path):
    """compile_plan stamps the kv_plan into the manifest; from_artifact
    boots an engine serving with the same quantized cache policy."""
    from repro.quant.compiler import load_artifact, save_artifact
    from repro.serving.quantized import explicit_plan
    cfg, model, params = trained["dense"]
    wplan = explicit_plan(cfg, ["int4", "int8"])
    compiled = model.compile_plan(params, wplan, kv_precision="auto")
    assert compiled.kv_plan is not None
    d = str(tmp_path / "art")
    save_artifact(d, compiled)
    restored = load_artifact(d, model)
    assert restored.kv_plan == compiled.kv_plan
    eng = ServeEngine.from_artifact(model, d, max_seq=24)
    assert eng.kv_plan == compiled.kv_plan
    prompts = jax.random.randint(jax.random.PRNGKey(5), (1, 6), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    mem = ServeEngine(model, compiled.params, max_seq=24,
                      kv_precision=compiled.kv_plan)
    o_mem, o_art = mem.generate(prompts, 5), eng.generate(prompts, 5)
    np.testing.assert_array_equal(np.asarray(o_mem.tokens),
                                  np.asarray(o_art.tokens))


def test_mesh_serve_with_int8_kv_cache_matches_single_device():
    """A 1x8 TP mesh serving a quantized KV cache places KVPage payload +
    scale leaves (cache_specs \"#0\"/\"#1\" branch) and emits the same
    tokens as the single-device engine. Subprocess: XLA_FLAGS must be set
    before jax import (same pattern as tests/test_serving.py)."""
    import subprocess
    import sys
    import textwrap
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import dataclasses, jax, jax.numpy as jnp, numpy as np
            from repro.configs.registry import get_config
            from repro.models.model import build
            from repro.launch.mesh import make_mesh
            from repro.serving.engine import ServeEngine
            from repro.serving.scheduler import Request

            mesh = make_mesh((1, 8), ("data", "model"))
            cfg = dataclasses.replace(get_config("llama3.2-3b", smoke=True),
                                      dtype="float32", num_layers=2)
            model = build(cfg)
            params = model.init(jax.random.PRNGKey(0))
            reqs = [Request(rid=i, prompt=np.asarray(jax.random.randint(
                        jax.random.PRNGKey(i), (6,), 0, cfg.vocab_size,
                        dtype=jnp.int32)), max_new_tokens=5)
                    for i in range(3)]
            ref = ServeEngine(model, params, max_seq=24,
                              kv_precision="int8")
            outs_ref, _ = ref.serve(reqs, num_slots=2, chunk=4)
            eng = ServeEngine(model, params, max_seq=24,
                              kv_precision="int8", mesh=mesh)
            outs, _ = eng.serve(reqs, num_slots=2, chunk=4)
            for a, b in zip(outs, outs_ref):
                np.testing.assert_array_equal(a.tokens, b.tokens)
                np.testing.assert_allclose(a.logprobs, b.logprobs,
                                           atol=1e-4)
            print("OK")
        """)],
        capture_output=True, text=True, timeout=560,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-3000:]
    assert "OK" in res.stdout


def test_pallas_aligned_accounts_for_int4_packing():
    from repro.kernels.qmatmul.ops import _pallas_aligned
    assert _pallas_aligned(128, 128, 512, "int8")
    assert not _pallas_aligned(128, 128, 512, "int4")  # packed lane = 256
    assert _pallas_aligned(128, 128, 1024, "int4")


def test_chunking_knobs_configurable():
    from repro.models import attention as A
    old = (A.CHUNK_THRESHOLD, A.Q_CHUNK, A.KV_CHUNK)
    try:
        A.configure_chunking(chunk_threshold=16, q_chunk=8, kv_chunk=8)
        assert (A.CHUNK_THRESHOLD, A.Q_CHUNK, A.KV_CHUNK) == (16, 8, 8)
        with pytest.raises(ValueError):
            A.configure_chunking(q_chunk=0)
    finally:
        A.configure_chunking(*old)
